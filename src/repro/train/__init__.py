from repro.train.step import init_state, make_train_step  # noqa: F401
from repro.train.loop import train  # noqa: F401
