"""jaxlint: AST rules for the SPMD hot path.

Each rule has a code, a one-line title, and an ``--explain`` doc
(``python -m repro.analysis --explain JL101``). Rules are plain functions
``rule(ctx) -> list[Finding]`` over a parsed :class:`FileContext`; the
runner (``repro.analysis.lint``) handles discovery, scoping, inline
``# jaxlint: disable=CODE`` comments and the suppression file.

Scoping (who gets which rules) is decided per file by the runner:

* JL101 (axis literals), JL103 (Tracer isinstance), JL105/JL106 (Pallas
  debris / unmasked dynamic loads) run on every discovered file;
* JL102 (host syncs) runs on the traced hot-path modules ``core/``,
  ``kernels/``, ``comm/``, ``train/step.py`` plus ``obs/metrics.py``
  (where the deliberate fencing sites carry ``@host_sync_allowed``);
* JL104 (nondeterminism) runs on ``core/``, ``kernels/``, ``comm/``,
  ``train/step.py`` only — host-side drivers legitimately use clocks.
"""

from __future__ import annotations

import ast
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

from repro.analysis.findings import Finding

# The canonical mesh-axis names. Imported — not spelled — so the only
# file in the tree holding the raw strings stays launch/mesh.py (JL101's
# own invariant).
from repro.launch.mesh import DATA_AXIS, MODEL_AXIS, POD_AXIS, SEQ_AXIS

AXIS_NAMES = frozenset({DATA_AXIS, SEQ_AXIS, MODEL_AXIS, POD_AXIS})

# Non-axis meanings the axis words also carry in this tree (JL101 deny
# contexts): the data-dependent decay *kind* of linear-attention configs
# (compared/passed as ``decay=``/``kind=``), and phase-timer labels.
_KIND_NAMES = {"decay", "kind"}
_KIND_CALLS = {"phase", "LinearAttnConfig"}

_HOST_SYNC_DECORATOR = "host_sync_allowed"


# ---------------------------------------------------------------------------
# File context.
# ---------------------------------------------------------------------------

@dataclass
class FileContext:
    """One parsed file plus the per-node bookkeeping rules need."""

    path: str                      # display path (repo-relative)
    text: str
    sync_scope: bool = False       # JL102 applies
    det_scope: bool = False        # JL104 applies
    axis_exempt: bool = False      # JL101 skipped (launch/mesh.py)
    tracer_exempt: bool = False    # JL103 skipped (core/compat.py)
    tree: Optional[ast.AST] = None
    parents: Dict[ast.AST, ast.AST] = field(default_factory=dict)
    lines: List[str] = field(default_factory=list)

    def __post_init__(self):
        self.tree = ast.parse(self.text, filename=self.path)
        self.lines = self.text.splitlines()
        for node in ast.walk(self.tree):
            for child in ast.iter_child_nodes(node):
                self.parents[child] = node

    def src(self, node) -> str:
        ln = getattr(node, "lineno", 0)
        return self.lines[ln - 1].strip() if 0 < ln <= len(self.lines) else ""

    def finding(self, code, node, message) -> Finding:
        return Finding(code=code, path=self.path,
                       line=getattr(node, "lineno", 0),
                       col=getattr(node, "col_offset", 0),
                       message=message, source=self.src(node))

    def ancestors(self, node):
        while node in self.parents:
            node = self.parents[node]
            yield node

    def in_host_sync_allowed(self, node) -> bool:
        for anc in self.ancestors(node):
            if isinstance(anc, (ast.FunctionDef, ast.AsyncFunctionDef)):
                for dec in anc.decorator_list:
                    if _terminal_name(dec) == _HOST_SYNC_DECORATOR:
                        return True
        return False


def _terminal_name(node) -> Optional[str]:
    """Rightmost identifier of a Name/Attribute/Call chain."""
    if isinstance(node, ast.Call):
        node = node.func
    if isinstance(node, ast.Attribute):
        return node.attr
    if isinstance(node, ast.Name):
        return node.id
    return None


def _base_name(node) -> Optional[str]:
    """Leftmost identifier: ``np.random.normal`` -> ``np``."""
    while isinstance(node, ast.Attribute):
        node = node.value
    return node.id if isinstance(node, ast.Name) else None


# ---------------------------------------------------------------------------
# JL101 — raw axis-name string literals.
# ---------------------------------------------------------------------------

def _axis_literal_denied(ctx: FileContext, node: ast.Constant) -> bool:
    """True when an axis-word literal is *not* a mesh-axis usage."""
    prev = node
    for anc in ctx.ancestors(node):
        if isinstance(anc, ast.Compare):
            others = [anc.left] + list(anc.comparators)
            for other in others:
                if other is prev:
                    continue
                if _terminal_name(other) in _KIND_NAMES:
                    return True
        if isinstance(anc, ast.keyword) and anc.arg in _KIND_NAMES:
            return True
        if isinstance(anc, ast.Call):
            if _terminal_name(anc.func) in _KIND_CALLS:
                return True
            return False        # any other call: axis context, flag it
        if isinstance(anc, (ast.FunctionDef, ast.AsyncFunctionDef,
                            ast.ClassDef, ast.Module)):
            return False
        prev = anc
    return False


def check_axis_literals(ctx: FileContext) -> List[Finding]:
    if ctx.axis_exempt:
        return []
    out = []
    for node in ast.walk(ctx.tree):
        if (isinstance(node, ast.Constant) and isinstance(node.value, str)
                and node.value in AXIS_NAMES
                and not _axis_literal_denied(ctx, node)):
            out.append(ctx.finding(
                "JL101", node,
                f'raw axis-name literal "{node.value}" — use the constant '
                f"exported by repro.launch.mesh (DATA_AXIS / SEQ_AXIS / "
                f"MODEL_AXIS / POD_AXIS)"))
    return out


# ---------------------------------------------------------------------------
# JL102 — host syncs in traced hot-path modules.
# ---------------------------------------------------------------------------

_SYNC_NAMES = {"block_until_ready", "device_get"}
_NUMPY_ALIASES = {"np", "numpy", "onp"}


def check_host_syncs(ctx: FileContext) -> List[Finding]:
    if not ctx.sync_scope:
        return []
    out = []
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.Call):
            continue
        f = node.func
        name = _terminal_name(f)
        what = None
        if isinstance(f, ast.Name) and name == "print":
            what = "print()"
        elif name in _SYNC_NAMES:
            what = f"{name}()"
        elif (isinstance(f, ast.Attribute) and name == "item"
                and not node.args and not node.keywords):
            what = ".item()"
        elif (isinstance(f, ast.Attribute) and name == "asarray"
                and _base_name(f.value) in _NUMPY_ALIASES):
            what = "np.asarray()"
        if what is None:
            continue
        if ctx.in_host_sync_allowed(node):
            continue
        out.append(ctx.finding(
            "JL102", node,
            f"host-sync call {what} in a traced hot-path module — it "
            f"stalls the dispatch pipeline (or fails under tracing); "
            f"fence through repro.obs instead, or mark a deliberate "
            f"fencing helper with @host_sync_allowed"))
    return out


# ---------------------------------------------------------------------------
# JL103 — isinstance(x, jax.core.Tracer) bypassing compat.is_tracer.
# ---------------------------------------------------------------------------

def _mentions_tracer(node) -> bool:
    for sub in ast.walk(node):
        if isinstance(sub, ast.Attribute) and sub.attr == "Tracer":
            return True
        if isinstance(sub, ast.Name) and sub.id == "Tracer":
            return True
    return False


def check_tracer_isinstance(ctx: FileContext) -> List[Finding]:
    if ctx.tracer_exempt:
        return []
    out = []
    for node in ast.walk(ctx.tree):
        if (isinstance(node, ast.Call)
                and _terminal_name(node.func) == "isinstance"
                and len(node.args) == 2 and _mentions_tracer(node.args[1])):
            out.append(ctx.finding(
                "JL103", node,
                "isinstance(x, ...Tracer) — use repro.core.compat."
                "is_tracer, which tracks the Tracer class across the "
                "pinned jax versions"))
    return out


# ---------------------------------------------------------------------------
# JL104 — nondeterminism sources in traced code.
# ---------------------------------------------------------------------------

_NONDET_MODULES = {"time", "random"}


def check_nondeterminism(ctx: FileContext) -> List[Finding]:
    if not ctx.det_scope:
        return []
    out = []
    for node in ast.walk(ctx.tree):
        if isinstance(node, ast.Import):
            for alias in node.names:
                if alias.name.split(".")[0] in _NONDET_MODULES:
                    out.append(ctx.finding(
                        "JL104", node,
                        f"import of '{alias.name}' in traced code — "
                        f"clocks/host RNG poison custom_vjp replay and "
                        f"compile-cache determinism; thread jax.random "
                        f"keys or host-side timestamps in as inputs"))
        elif isinstance(node, ast.ImportFrom):
            if node.module and node.module.split(".")[0] in _NONDET_MODULES:
                out.append(ctx.finding(
                    "JL104", node,
                    f"import from '{node.module}' in traced code (see "
                    f"JL104 --explain)"))
        elif (isinstance(node, ast.Attribute) and node.attr == "random"
                and _base_name(node) in _NUMPY_ALIASES):
            out.append(ctx.finding(
                "JL104", node,
                "np.random in traced code — host RNG is invisible to "
                "jax's tracing and breaks bitwise replay; use "
                "jax.random with a threaded key"))
    return out


# ---------------------------------------------------------------------------
# JL105 — Pallas debug debris.
# ---------------------------------------------------------------------------

_PALLAS_ALIASES = {"pl", "pallas", "pltpu"}


def check_pallas_debris(ctx: FileContext) -> List[Finding]:
    out = []
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.Call):
            continue
        name = _terminal_name(node.func)
        if (name == "debug_print"
                and (not isinstance(node.func, ast.Attribute)
                     or _base_name(node.func.value) in _PALLAS_ALIASES)):
            out.append(ctx.finding(
                "JL105", node,
                "pl.debug_print left in a kernel — debug scaffolding; "
                "it forces a host round-trip per grid step"))
        if name == "pallas_call":
            for kw in node.keywords:
                if (kw.arg == "interpret"
                        and isinstance(kw.value, ast.Constant)
                        and kw.value.value is True):
                    out.append(ctx.finding(
                        "JL105", node,
                        "pallas_call(interpret=True) hard-coded — "
                        "interpret mode must flow from the "
                        "kernel_backend knob, never be baked in"))
    return out


# ---------------------------------------------------------------------------
# JL106 — unmasked dynamic pl.load / pl.store.
# ---------------------------------------------------------------------------

_DSLICE_NAMES = {"ds", "dslice", "dynamic_slice"}


def check_unmasked_dynamic_load(ctx: FileContext) -> List[Finding]:
    out = []
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.Call):
            continue
        name = _terminal_name(node.func)
        if name not in ("load", "store"):
            continue
        if not (isinstance(node.func, ast.Attribute)
                and _base_name(node.func.value) in _PALLAS_ALIASES):
            continue
        dynamic = any(
            isinstance(sub, ast.Call)
            and _terminal_name(sub.func) in _DSLICE_NAMES
            for arg in node.args for sub in ast.walk(arg))
        masked = any(kw.arg in ("mask", "other") for kw in node.keywords)
        if dynamic and not masked:
            out.append(ctx.finding(
                "JL106", node,
                f"dynamic pl.{name} without mask= — a padded tail block "
                f"reads/writes out of bounds; pass mask= (and other= for "
                f"loads) covering the valid prefix"))
    return out


# ---------------------------------------------------------------------------
# Registry + explain docs.
# ---------------------------------------------------------------------------

Rule = Callable[[FileContext], List[Finding]]

RULES: Dict[str, Tuple[str, Rule]] = {
    "JL101": ("raw axis-name string literal", check_axis_literals),
    "JL102": ("host sync in traced hot path", check_host_syncs),
    "JL103": ("Tracer isinstance bypassing compat", check_tracer_isinstance),
    "JL104": ("nondeterminism in traced code", check_nondeterminism),
    "JL105": ("Pallas debug debris", check_pallas_debris),
    "JL106": ("unmasked dynamic pl.load/store", check_unmasked_dynamic_load),
}

EXPLAIN: Dict[str, str] = {
    "JL101": """\
JL101 — raw axis-name string literal

The mesh axis names ("data", "sequence", "model", "pod") are exported as
constants by repro/launch/mesh.py (DATA_AXIS, SEQ_AXIS, MODEL_AXIS,
POD_AXIS), and mesh.py is the ONLY module allowed to spell the strings.
Everything else — PartitionSpec entries, shard_map axis_names, psum/
all_gather axis arguments, sharding-rule tables, budget keys — must use
the constants, so renaming an axis is a one-line change the type of
which the compiler can check, instead of a repo-wide grep with silent
misses. MODEL_AXIS is a LIVE training axis since the 3D DP×SP×TP
ulysses mesh landed — "model" literals in training code are real
budget-classification hazards, not dead-axis pedantry.

Denied contexts (not flagged): the axis words also appear as linear-
attention decay *kinds* (cfg.linear_attn.decay == "data") and phase-
timer labels (timer.phase("data")); comparisons against names/attributes
called `decay`/`kind`, `decay=`/`kind=` keywords, and arguments to
`phase(...)`/`LinearAttnConfig(...)` are recognized as non-axis usages.

Fix: from repro.launch.mesh import DATA_AXIS, SEQ_AXIS, ...
""",
    "JL102": """\
JL102 — host-sync call inside a traced hot-path module

block_until_ready, .item(), np.asarray, jax.device_get and print() all
force a device->host round-trip. Inside the traced hot path (core/,
kernels/, comm/, train/step.py) they either fail outright under tracing
or — worse — silently serialize the async dispatch pipeline, which is
exactly the per-step stall LASP-2's single-AllGather structure exists to
avoid. Host-side drivers (train/loop.py, serve/, launch/) are out of
scope: they own the synchronization points.

The observability fencing helpers in obs/metrics.py are the one
legitimate holder: they synchronize deliberately so per-phase walls
attribute async work to the right phase. Those sites carry
@repro.analysis.decorators.host_sync_allowed, which exempts the
enclosing function.

Fix: return values out of the traced region and sync in the driver, or
route timing through repro.obs (scoped_timer / Fence).
""",
    "JL103": """\
JL103 — isinstance(x, jax.core.Tracer)

jax.core.Tracer moved across the jax versions this repo pins
(jax.core -> jax._src.core re-exports). repro/core/compat.py owns the
version dance and exports is_tracer(); direct isinstance checks bypass
it and break on the next pin bump.

Fix: from repro.core.compat import is_tracer; is_tracer(x).
""",
    "JL104": """\
JL104 — time/random/np.random in traced code

Traced code (core/, kernels/, comm/, train/step.py) runs under jit:
host clocks and host RNG are read ONCE at trace time and baked into the
program — the value silently freezes, and any dependence on it breaks
both the custom_vjp forward/backward consistency and compile-cache
determinism (two lowerings of the same step must produce identical
programs; the sanitizer's SAN205 check asserts exactly that).

Fix: randomness flows through jax.random keys threaded as inputs;
timestamps are host-driver concerns (train/loop.py, repro.obs).
""",
    "JL105": """\
JL105 — Pallas debug debris

pl.debug_print and hard-coded pallas_call(interpret=True) are debugging
scaffolding. debug_print forces a host round-trip per grid step;
interpret=True silently runs the kernel on the interpreter — orders of
magnitude slower — while looking like a real Pallas deployment. The
interpret path is a supported *backend* (kernel_backend="interpret"),
so it must always arrive via the knob, never a literal.

Fix: delete the debug_print; pass interpret through from the caller's
kernel_backend plumbing (repro/kernels/ops.py).
""",
    "JL106": """\
JL106 — dynamic pl.load / pl.store without mask=

A pl.load/pl.store whose index contains pl.ds(...) (a dynamic slice)
can straddle the padded tail of a block — on TPU the out-of-bounds
lanes read garbage (or clamp), which is how padding bugs ship silently.
Any dynamic load/store must pass mask= (and other= for loads) covering
the valid prefix, like the flash kernels' where-masked tails.

Fix: mask = iota < valid_len; pl.load(ref, idx, mask=mask, other=0.0).
""",
    "PAL301": """\
PAL301 — BlockSpec index_map out of grid bounds

Every pallas_call BlockSpec index_map must map every grid point to a
block index inside the operand's block grid (0 <= idx < ceil(dim /
block)). An out-of-range index map reads a neighboring batch row's
blocks (or clamps silently on TPU) — the bug class PR 3 fixed by hand
in the backward band arithmetic. repro.analysis.pallas_check evaluates
every index map of every kernel at every grid point under
jax.eval_shape (no kernel execution) and flags violations.

Fix: clamp with jnp.clip against the block count (see
kernels/flash_attention.py kv_im) or fix the band arithmetic.
""",
    "SAN201": """\
SAN201 — host transfer in a compiled hot-path program

The compiled (post-SPMD) HLO of the train/decode steps must contain no
infeed/outfeed ops and no host custom-calls: any of these means a
device<->host round trip inside the step, serializing the async
dispatch pipeline every iteration.
""",
    "SAN202": """\
SAN202 — f64 ops in a compiled hot-path program

Nothing in the training or decode path is f64: an f64[...] (or
c128[...]) buffer in compiled HLO means an accidental Python-float
promotion doubled somebody's bytes (and on TPU, f64 is emulated).
Keep scalars jnp-typed; check weak-type promotion at the site the
sanitizer names.
""",
    "SAN203": """\
SAN203 — comm_dtype=bf16 collective not actually bf16 on the wire

With comm_dtype=bf16, the LASP-2 state exchange (the per-layer
all-gather of (M_t, A_t) over the sequence axis, and its reduce-scatter
transpose) must carry bf16 element type. The check reads the LOWERED
StableHLO (the compiled CPU HLO upcasts bf16 collectives to f32 —
storage-only bf16 on XLA:CPU — so the wire dtype is only visible before
optimization). The ZeRO-1 parameter all-gather over the data axis and
the packed gradient all-reduce stay fp32 by design and are exempt.
""",
    "SAN204": """\
SAN204 — donated buffers not actually aliased

train/loop.py donates the step state (donate_argnums=(0,)) and the
serve engine donates the decode cache; if the compiled program's
input_output_alias table is empty the donation silently degraded to a
copy — peak memory doubles for the params + optimizer state. Usually a
dtype/layout mismatch between the donated input and its output.
""",
    "SAN205": """\
SAN205 — nondeterministic lowering (collective fingerprint drift)

Two independent lowerings of the same step must produce the identical
sequence of collectives (op, element type, shape, replica groups). A
drift means something nondeterministic leaked into trace time — dict
ordering, host RNG (JL104's dynamic twin) — and invalidates the HLO
budget checks and compile caching.
""",
}


def explain(code: str) -> str:
    try:
        return EXPLAIN[code.upper()]
    except KeyError:
        known = ", ".join(sorted(EXPLAIN))
        raise KeyError(f"unknown rule code {code!r}; known: {known}")
