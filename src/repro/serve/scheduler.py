"""Continuous-batching scheduler: slot bookkeeping for the serving engine.

Pure-Python request/slot logic, no jax — the engine owns the device arrays.
The decode batch is a fixed grid of ``max_batch`` slots; every scheduler
"tick" (a) admits waiting requests into free slots, grouped into prefill
batches by bucketed prompt length, and (b) after the engine's decode step,
records sampled tokens, applies per-sequence stopping (EOS / token budget /
context limit), and evicts finished requests so their slots free up for
the next admission — requests join and leave the batch mid-flight, no
generation ever waits for the longest member of a static batch.

Prompt-length bucketing: requests are grouped by exact prompt length by
default (one prefill compilation per distinct length — fine when lengths
repeat). With ``bucket_lengths=True`` the engine additionally rounds
lengths up to the next power of two and LEFT-pads the prompts, bounding
compilations to O(log max_len) — only exact for pad-safe configs (see
``repro.models.model.pad_safe``), which is why the engine, not this
module, decides to enable it.
"""

from __future__ import annotations

import itertools
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

from repro.obs.metrics import Metrics


class QueueFullError(RuntimeError):
    """Admission queue is at ``max_queue`` — the caller should back off
    and retry (reject-on-full backpressure, docs/resilience.md)."""


def bucket_length(n: int, *, minimum: int = 16) -> int:
    """Next power of two >= n (floored at ``minimum``)."""
    b = minimum
    while b < n:
        b *= 2
    return b


@dataclass
class Request:
    """One generation request and its runtime state."""

    uid: int
    prompt: np.ndarray                  # (L,) int32
    max_new_tokens: int
    temperature: float = 0.0
    eos_id: Optional[int] = None
    seed: int = 0
    stream: int = 0                     # RNG stream id (seed, stream) -> key

    tokens: List[int] = field(default_factory=list)   # generated so far
    slot: int = -1
    done: bool = False
    finish_reason: Optional[str] = None               # eos | length | deadline
    deadline: Optional[float] = None                  # absolute clock() time
    finished_at: Optional[float] = None               # set on eviction

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.shape[0])

    def record(self, tok: int) -> bool:
        """Append a sampled token; returns True if the request finished."""
        self.tokens.append(tok)
        if self.eos_id is not None and tok == self.eos_id:
            self.done, self.finish_reason = True, "eos"
        elif len(self.tokens) >= self.max_new_tokens:
            self.done, self.finish_reason = True, "length"
        return self.done


@dataclass
class PrefillBatch:
    """One admission group: same padded prompt length, assigned slots."""

    requests: List[Request]
    prompts: np.ndarray                 # (n, Lb) int32, left-padded
    pad_lens: np.ndarray                # (n,) int32 (zeros when exact)
    slots: np.ndarray                   # (n,) int32

    @property
    def padded(self) -> bool:
        return bool(self.pad_lens.any())


class ContinuousScheduler:
    """Admit/evict requests over a fixed grid of decode slots.

    ``metrics`` (a :class:`repro.obs.Metrics` registry, usually the
    engine's) receives the scheduler-side telemetry: ``submitted`` /
    ``admitted`` / ``evicted`` / ``finished_<reason>`` / ``rejected``
    counters and the ``queue_depth`` gauge (+peak).

    Graceful degradation under overload (docs/resilience.md):

    * ``max_queue`` bounds the waiting list — ``submit`` raises
      :class:`QueueFullError` when full, so upstream load sheds at the
      door instead of growing an unbounded backlog;
    * per-request deadlines (``submit(..., deadline_s=...)``): each
      :meth:`expire` pass evicts waiting AND active requests past their
      deadline with ``finish_reason="deadline"``, freeing their slots;
    * ``finished_timeout`` bounds the ``finished`` dict — results not
      collected within the timeout are dropped by :meth:`expire`, so a
      long-lived engine cannot leak memory on abandoned requests."""

    def __init__(self, max_batch: int, max_len: int, *,
                 bucket_lengths: bool = False, pad_token: int = 0,
                 metrics: Optional[Metrics] = None,
                 max_queue: Optional[int] = None,
                 finished_timeout: Optional[float] = None,
                 clock=time.monotonic):
        self.max_batch = max_batch
        self.max_len = max_len
        self.bucket_lengths = bucket_lengths
        self.pad_token = pad_token
        self.max_queue = max_queue
        self.finished_timeout = finished_timeout
        self.clock = clock
        self.metrics = metrics if metrics is not None else Metrics()
        self.waiting: List[Request] = []
        self.slots: List[Optional[Request]] = [None] * max_batch
        self.finished: Dict[int, Request] = {}
        self._uid = itertools.count()

    # -- submission ---------------------------------------------------------

    def submit(self, prompt, max_new_tokens: int, *, temperature: float = 0.0,
               eos_id: Optional[int] = None, seed: int = 0,
               stream: int = 0, deadline_s: Optional[float] = None) -> int:
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if prompt.shape[0] < 1:
            raise ValueError("prompt must contain at least one token")
        if max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1 (the first token "
                             "is sampled from the prefill logits)")
        if prompt.shape[0] + max_new_tokens > self.max_len:
            raise ValueError(
                f"prompt ({prompt.shape[0]}) + max_new_tokens "
                f"({max_new_tokens}) exceeds max_len={self.max_len}")
        if self.max_queue is not None \
                and len(self.waiting) >= self.max_queue:
            self.metrics.inc("rejected")
            raise QueueFullError(
                f"admission queue full ({len(self.waiting)}/"
                f"{self.max_queue} waiting, {len(self.active)} active) — "
                "back off and retry")
        req = Request(uid=next(self._uid), prompt=prompt,
                      max_new_tokens=max_new_tokens, temperature=temperature,
                      eos_id=eos_id, seed=seed, stream=stream,
                      deadline=(self.clock() + deadline_s
                                if deadline_s is not None else None))
        self.waiting.append(req)
        self.metrics.inc("submitted")
        self.metrics.gauge("queue_depth", len(self.waiting))
        return req.uid

    # -- state queries ------------------------------------------------------

    @property
    def active(self) -> List[Request]:
        return [r for r in self.slots if r is not None]

    def free_slots(self) -> List[int]:
        return [i for i, r in enumerate(self.slots) if r is None]

    def has_work(self) -> bool:
        return bool(self.waiting) or any(r is not None for r in self.slots)

    # -- admission ----------------------------------------------------------

    def admit(self) -> List[PrefillBatch]:
        """Move waiting requests into free slots, FIFO within each group;
        one :class:`PrefillBatch` per (bucketed) prompt length."""
        free = self.free_slots()
        if not free or not self.waiting:
            return []
        take = self.waiting[:len(free)]
        self.waiting = self.waiting[len(take):]
        self.metrics.inc("admitted", len(take))
        self.metrics.gauge("queue_depth", len(self.waiting))

        groups: Dict[int, List[Request]] = {}
        for r in take:
            lb = min(bucket_length(r.prompt_len), self.max_len) \
                if self.bucket_lengths else r.prompt_len
            groups.setdefault(lb, []).append(r)

        batches = []
        for lb, reqs in groups.items():
            n = len(reqs)
            prompts = np.full((n, lb), self.pad_token, np.int32)
            pads = np.zeros((n,), np.int32)
            slots = np.empty((n,), np.int32)
            for j, r in enumerate(reqs):
                pads[j] = lb - r.prompt_len
                prompts[j, pads[j]:] = r.prompt
                r.slot = slots[j] = free.pop(0)
                self.slots[r.slot] = r
            batches.append(PrefillBatch(reqs, prompts, pads, slots))
        return batches

    # -- per-step bookkeeping ----------------------------------------------

    def record_step(self, sampled: np.ndarray) -> List[Request]:
        """Record one decode step's sampled token per active slot; evict
        and return the requests that finished."""
        out = []
        for i, r in enumerate(self.slots):
            if r is None or r.done:
                continue
            if r.record(int(sampled[i])):
                out.append(self._evict(r))
        return out

    def record_prefill(self, batch: PrefillBatch,
                       sampled: np.ndarray) -> List[Request]:
        """Record the first token (sampled from prefill logits) for each
        request of an admission group; evicts immediate EOS hits."""
        out = []
        for j, r in enumerate(batch.requests):
            if r.record(int(sampled[j])):
                out.append(self._evict(r))
        return out

    def _evict(self, req: Request) -> Request:
        if req.slot >= 0:
            self.slots[req.slot] = None
        req.finished_at = self.clock()
        self.finished[req.uid] = req
        self.metrics.inc("evicted")
        self.metrics.inc(f"finished_{req.finish_reason}")
        return req

    # -- degradation: deadlines + finished-result eviction ------------------

    def expire(self, now: Optional[float] = None) -> List[Request]:
        """One degradation pass (call once per engine tick): evict
        waiting and active requests past their deadline
        (``finish_reason="deadline"``, partial tokens kept) and drop
        finished results older than ``finished_timeout``. Returns the
        newly deadline-evicted requests so the engine can emit their
        records."""
        now = self.clock() if now is None else now
        out: List[Request] = []
        expired_waiting = [r for r in self.waiting
                           if r.deadline is not None and now >= r.deadline]
        if expired_waiting:
            self.waiting = [r for r in self.waiting
                            if r not in expired_waiting]
            self.metrics.gauge("queue_depth", len(self.waiting))
        for r in expired_waiting + [
                r for r in self.slots
                if r is not None and r.deadline is not None
                and now >= r.deadline]:
            r.done, r.finish_reason = True, "deadline"
            out.append(self._evict(r))
        if self.finished_timeout is not None:
            stale = [uid for uid, r in self.finished.items()
                     if r.finished_at is not None
                     and now - r.finished_at > self.finished_timeout]
            for uid in stale:
                del self.finished[uid]
            if stale:
                self.metrics.inc("finished_expired", len(stale))
        return out
