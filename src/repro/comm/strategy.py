"""Pluggable inter-chunk state-exchange strategies for LASP-2 layers.

A strategy answers one question: given each rank's local chunk state
``M_t`` (+ total chunk log-decay ``A_t``), how does rank t obtain the
decayed prefix state ``M_{1:t-1}``?

=============  ===========================  =======  =====================
strategy       forward collectives          steps    backward (autodiff)
=============  ===========================  =======  =====================
"allgather"    1 all-gather (packed M‖A)    1        1 reduce-scatter
"ring"         W-1 collective-permutes      W-1      W-1 permutes
"pipelined"    k(W-1) permutes (1/k size)   W-1*     W-1* (k chains)
=============  ===========================  =======  =====================

(*) pipelined chains are dataflow-independent, so the W-1 hops of one
slice hide behind the accumulates of another — same volume as "ring",
pipelined latency (ZeCO-style; see EXPERIMENTS.md).

"allgather" is the paper's LASP-2 and the only strategy compatible with
the paper-faithful Algorithm 3/4 ``custom_vjp`` (its backward AllGathers
the state grads and needs the gathered cumulative decays as residuals);
"ring" reproduces LASP-1's sequential-dependency pattern *inside* the
LASP-2 layer for apples-to-apples strategy benchmarking.
"""

from __future__ import annotations

from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.comm import primitives
from repro.comm.overlap import DoubleBufferedScheduler
from repro.core.linear_attention import prefix_state_combine


class PrefixExchange(NamedTuple):
    """Result of one inter-chunk prefix exchange.

    ``cum``/``states`` (the gathered (W, ...) cumulative log-decays and
    chunk states) are only available under the "allgather" strategy —
    ring-family exchanges never materialize them (that is the point).
    """

    m_prev: jax.Array              # (..., dk, dv) decayed prefix state
    intra: object                  # whatever the overlapped compute returned
    cum: Optional[jax.Array]       # (W, ...) or None
    states: Optional[jax.Array]    # (W, ..., dk, dv) or None


def pack_state(m_loc, a_loc):
    """Pack (M_t, A_t) into ONE tensor so the exchange is a single
    collective: (..., dk, dv) ‖ (...,) -> (..., dk*dv + 1) fp32."""
    lead = m_loc.shape[:-2]
    return jnp.concatenate(
        [m_loc.reshape(*lead, -1), a_loc[..., None]], axis=-1)


def unpack_state(packed, dk: int, dv: int):
    """Inverse of :func:`pack_state` (gathered: leading W axis rides
    along). Returns (ms (..., dk, dv), las (...,))."""
    ms = packed[..., :-1].reshape(*packed.shape[:-1], dk, dv)
    return ms, packed[..., -1]


class CommStrategy:
    name: str = "?"
    supports_faithful = False

    def __init__(self, comm_dtype: Optional[str] = None):
        # Wire dtype of the exchange payload (docs/communication.md):
        # fp32 states are cast to this dtype for the collective and the
        # prefix combine happens in fp32 locally — "bf16" halves the
        # per-layer exchange bytes.
        self.comm_dtype = comm_dtype
        self.wire = primitives.wire_dtype(comm_dtype)

    def prefix(self, m_loc, a_loc, axis: str, axis_size: int, t,
               scheduler: DoubleBufferedScheduler,
               compute: Callable[[], object]) -> PrefixExchange:
        raise NotImplementedError


class AllGatherStrategy(CommStrategy):
    """LASP-2 proper: one AllGather of sequence-length-independent state."""

    name = "allgather"
    supports_faithful = True

    def prefix(self, m_loc, a_loc, axis, axis_size, t, scheduler, compute):
        dk, dv = m_loc.shape[-2:]
        packed = pack_state(m_loc, a_loc).astype(self.wire)
        gathered, intra = scheduler.run(
            packed,
            lambda p: primitives.allgather_states(
                p, axis, axis_size=axis_size, tag="lasp2.states"),
            compute)
        ms, las = unpack_state(
            primitives.upcast_gathered(gathered, jnp.float32), dk, dv)
        cum = jnp.cumsum(las, axis=0)
        return PrefixExchange(prefix_state_combine(ms, cum, t), intra,
                              cum, ms)


class RingStrategy(CommStrategy):
    """LASP-1's pattern: W-1 sequential P2P hops of the full state."""

    name = "ring"

    def prefix(self, m_loc, a_loc, axis, axis_size, t, scheduler, compute):
        m_prev, intra = scheduler.run(
            m_loc,
            lambda m: primitives.pipelined_prefix_exchange(
                m, a_loc, axis, axis_size=axis_size, t=t, n_slices=1,
                comm_dtype=self.comm_dtype, tag="lasp2.ring"),
            compute)
        return PrefixExchange(m_prev, intra, None, None)


class PipelinedStrategy(CommStrategy):
    """ZeCO-style pipelined prefix-scan: the ring, sliced along dv into
    independent chains so hops of one slice hide behind accumulates of
    another."""

    name = "pipelined"

    def __init__(self, n_slices: Optional[int] = None,
                 comm_dtype: Optional[str] = None):
        super().__init__(comm_dtype)
        self.n_slices = n_slices

    def prefix(self, m_loc, a_loc, axis, axis_size, t, scheduler, compute):
        m_prev, intra = scheduler.run(
            m_loc,
            lambda m: primitives.pipelined_prefix_exchange(
                m, a_loc, axis, axis_size=axis_size, t=t,
                n_slices=self.n_slices, comm_dtype=self.comm_dtype,
                tag="lasp2.pipelined"),
            compute)
        return PrefixExchange(m_prev, intra, None, None)


_STRATEGIES = {
    "allgather": AllGatherStrategy,
    "ring": RingStrategy,
    "pipelined": PipelinedStrategy,
}


def get_strategy(name: str,
                 comm_dtype: Optional[str] = None) -> CommStrategy:
    try:
        cls = _STRATEGIES[name]
    except KeyError:
        raise ValueError(
            f"unknown comm strategy {name!r}; expected one of "
            f"{tuple(_STRATEGIES)}") from None
    return cls(comm_dtype=comm_dtype)
