"""AdamW + cosine schedule, pure JAX (no optax in this container).

Paper hyperparameters (§4.1): Adam β1=0.9, β2=0.95, weight decay 0.1,
grad clip 1.0, cosine schedule with linear warmup to min_lr=1e-6.

Two optimizer-state layouts:

* :class:`AdamState` — per-leaf m/v pytrees mirroring the params. Under
  FSDP parameter sharding the moments inherit the parameter shardings,
  which already is optimizer-state sharding.
* :class:`Zero1AdamState` — ZeRO-1 for the 2D DP×SP training plan
  (replicated params): m/v live as ONE flat fp32 vector, padded to a
  multiple of the data-parallel degree and sharded over the "data" axis.
  Each rank updates only its 1/dp slice of the parameters
  (:func:`zero1_update_shard`) and the updated slices are re-assembled
  with a single all-gather over "data" — the all-gather-on-update path
  (docs/parallelism.md). The shard math mirrors :func:`update`
  elementwise, so ZeRO-sharded and replicated AdamW agree to fp32
  exactness (pinned in tests/distributed_checks.py).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.flatten_util import ravel_pytree


class AdamState(NamedTuple):
    m: object
    v: object
    count: jax.Array


def init(params) -> AdamState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamState(m=jax.tree.map(zeros, params),
                     v=jax.tree.map(zeros, params),
                     count=jnp.zeros((), jnp.int32))


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def clip_by_global_norm(grads, max_norm):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), norm


def _decayable(path) -> bool:
    """Weight decay applies to matrices, not norms/biases/scalars."""
    name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
    return name not in ("scale", "bias", "bq", "bk", "bv", "gate",
                        "dt_bias", "a_log", "d_skip")


def update(grads, state: AdamState, params, *, lr, b1=0.9, b2=0.95,
           eps=1e-8, weight_decay=0.1):
    count = state.count + 1
    cf = count.astype(jnp.float32)
    bc1 = 1.0 - b1 ** cf
    bc2 = 1.0 - b2 ** cf

    def upd(path, p, g, m, v):
        gf = g.astype(jnp.float32)
        m_ = b1 * m + (1 - b1) * gf
        v_ = b2 * v + (1 - b2) * gf * gf
        step_ = (m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps)
        if weight_decay and _decayable(path):
            step_ = step_ + weight_decay * p.astype(jnp.float32)
        p_ = p.astype(jnp.float32) - lr * step_
        return p_.astype(p.dtype), m_, v_

    flat = jax.tree_util.tree_map_with_path(upd, params, grads,
                                            state.m, state.v)
    new_params = jax.tree.map(lambda t: t[0], flat,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], flat,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t: t[2], flat,
                         is_leaf=lambda x: isinstance(x, tuple))
    return new_params, AdamState(new_m, new_v, count)


# ---------------------------------------------------------------------------
# ZeRO-1: flat, data-axis-sharded optimizer state.
# ---------------------------------------------------------------------------

class Zero1AdamState(NamedTuple):
    """Flat fp32 Adam moments, padded to ``n_shards`` and sharded over the
    data axis at the jit level (each rank holds a ``(L/n_shards,)`` slice
    inside the manual train step)."""

    m: jax.Array          # (L,) fp32
    v: jax.Array          # (L,) fp32
    count: jax.Array


def zero1_padded_size(params, n_shards: int) -> int:
    """Total parameter count rounded up to a multiple of ``n_shards``."""
    n = sum(int(leaf.size) for leaf in jax.tree.leaves(params))
    return ((n + n_shards - 1) // n_shards) * n_shards


def zero1_init(params, n_shards: int) -> Zero1AdamState:
    size = zero1_padded_size(params, n_shards)
    return Zero1AdamState(m=jnp.zeros((size,), jnp.float32),
                          v=jnp.zeros((size,), jnp.float32),
                          count=jnp.zeros((), jnp.int32))


def decay_mask(params) -> jax.Array:
    """Flat fp32 mask, 1.0 where weight decay applies (:func:`_decayable`
    by leaf path — same rule as :func:`update`). Unpadded length."""
    ones = jax.tree_util.tree_map_with_path(
        lambda path, p: jnp.full(p.shape,
                                 1.0 if _decayable(path) else 0.0,
                                 jnp.float32), params)
    return ravel_pytree(ones)[0]


def zero1_update_shard(grad_shard, m_shard, v_shard, param_shard,
                       decay_shard, count, *, lr, b1=0.9, b2=0.95,
                       eps=1e-8, weight_decay=0.1):
    """One AdamW step on one rank's flat fp32 slice.

    ``count`` is the post-increment step count (caller increments once per
    global step). Returns ``(new_param_shard, new_m, new_v)`` — the same
    elementwise math as :func:`update`, so the gathered result is
    identical to the replicated optimizer."""
    cf = count.astype(jnp.float32)
    bc1 = 1.0 - b1 ** cf
    bc2 = 1.0 - b2 ** cf
    gf = grad_shard.astype(jnp.float32)
    m_ = b1 * m_shard + (1 - b1) * gf
    v_ = b2 * v_shard + (1 - b2) * gf * gf
    step_ = (m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps)
    if weight_decay:
        step_ = step_ + weight_decay * decay_shard \
            * param_shard.astype(jnp.float32)
    return param_shard.astype(jnp.float32) - lr * step_, m_, v_


def cosine_schedule(step, *, base_lr, warmup_steps, total_steps,
                    min_lr=1e-6):
    sf = step.astype(jnp.float32) if hasattr(step, "astype") \
        else jnp.float32(step)
    warm = base_lr * sf / jnp.maximum(warmup_steps, 1)
    prog = jnp.clip((sf - warmup_steps)
                    / jnp.maximum(total_steps - warmup_steps, 1), 0.0, 1.0)
    cos = min_lr + 0.5 * (base_lr - min_lr) * (1 + jnp.cos(jnp.pi * prog))
    return jnp.where(sf < warmup_steps, warm, cos)
