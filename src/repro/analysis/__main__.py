"""CLI: ``python -m repro.analysis``.

Default run = both layers: jaxlint (AST rules + the PAL301 kernel
bounds battery) repo-wide, then the compiled-program sanitizer on the
(1,8) and (2,4) train steps and the serve decode step. Exit 0 iff no
findings survive suppressions.

  python -m repro.analysis                    # everything
  python -m repro.analysis --lint-only src/repro/train/step.py
  python -m repro.analysis --sanitize-only
  python -m repro.analysis --explain JL101
  python -m repro.analysis --json findings.json   # CI artifact; render
                                                  # with scripts/report.py
"""

from __future__ import annotations

import argparse
import os
import sys

# The sanitizer needs the 8-virtual-device CPU topology; the flag must
# land before jax initializes its backends (so: before any repro import
# that pulls jax in).
_DEVICE_FLAG = "--xla_force_host_platform_device_count=8"
if _DEVICE_FLAG.split("=")[0] not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = \
        (os.environ.get("XLA_FLAGS", "") + " " + _DEVICE_FLAG).strip()
os.environ.setdefault("JAX_PLATFORMS", "cpu")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="jaxlint (AST) + compiled-program sanitizer")
    ap.add_argument("paths", nargs="*",
                    help="files/dirs to lint (default: repo roots)")
    ap.add_argument("--lint-only", action="store_true",
                    help="AST rules + kernel bounds battery only")
    ap.add_argument("--sanitize-only", action="store_true",
                    help="compiled-program sanitizer only")
    ap.add_argument("--no-kernel-check", action="store_true",
                    help="skip the PAL301 Pallas bounds battery")
    ap.add_argument("--json", dest="json_out", metavar="PATH",
                    help="write the machine-readable findings document")
    ap.add_argument("--explain", metavar="CODE",
                    help="print the rule doc for CODE and exit")
    args = ap.parse_args(argv)

    if args.explain:
        from repro.analysis.rules import explain
        try:
            print(explain(args.explain))
        except KeyError as e:
            print(e.args[0], file=sys.stderr)
            return 2
        return 0

    from repro.analysis.findings import AnalysisResult
    result = AnalysisResult()

    if not args.sanitize_only:
        from pathlib import Path

        from repro.analysis.lint import discover_files, run_lint
        paths = None
        if args.paths:
            paths = []
            for p in args.paths:
                paths += discover_files(Path(p))
        result.extend(run_lint(paths))
        if not args.no_kernel_check:
            from repro.analysis.pallas_check import check_repo_kernels
            kf, n_kernels = check_repo_kernels()
            result.findings += kf
            result.checked["kernels"] = n_kernels

    if not args.lint_only and not args.paths:
        from repro.analysis.sanitizer import run_sanitizer
        result.extend(run_sanitizer())

    if args.json_out:
        with open(args.json_out, "w") as f:
            f.write(result.to_json())

    for f in result.findings:
        print(f)
    n_sup = len(result.suppressed)
    checked = ", ".join(f"{v} {k}" for k, v in sorted(
        result.checked.items()))
    if result.ok:
        print(f"OK: 0 findings ({checked}"
              + (f"; {n_sup} suppressed" if n_sup else "") + ")")
        return 0
    counts = ", ".join(f"{k}×{v}" for k, v in sorted(
        result.counts().items()))
    print(f"FAIL: {len(result.findings)} finding(s) [{counts}] "
          f"({checked})", file=sys.stderr)
    return 1


if __name__ == "__main__":
    sys.exit(main())
