"""Documentation suite invariants: cross-references must resolve."""

import subprocess
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent


def test_doc_cross_references_resolve():
    proc = subprocess.run(
        [sys.executable, str(ROOT / "scripts" / "check_links.py")],
        capture_output=True, text=True)
    assert proc.returncode == 0, proc.stdout + proc.stderr


def test_required_docs_exist():
    # EXPERIMENTS.md is referenced by src docstrings (core/lasp2.py etc.)
    for name in ("README.md", "EXPERIMENTS.md", "docs/algorithms.md",
                 "pyproject.toml", ".github/workflows/ci.yml"):
        assert (ROOT / name).exists(), f"missing {name}"
