"""Post-compile HLO analysis: collective traffic + roofline terms.

``cost_analysis()`` supplies per-device HLO FLOPs/bytes, but counts each
``while`` body (scan) ONCE — verified empirically. The roofline therefore
extrapolates from reduced-depth *unrolled* lowers (see
``repro.launch.roofline``); this module handles the per-compile parsing.

Collective bytes are not in ``cost_analysis`` at all: we parse the
compiled (post-SPMD) HLO text and apply the standard ring-cost model per
op (paper §3.4's communication model, generalized):

  all-gather        (g-1)/g × result_bytes
  reduce-scatter    (g-1)   × result_bytes          (input = g × result)
  all-reduce        2(g-1)/g × bytes
  all-to-all        (g-1)/g × bytes
  collective-permute  result_bytes

Hardware constants (TPU v5e, per chip): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional

PEAK_FLOPS = 197e12        # bf16 per chip
HBM_BW = 819e9             # bytes/s per chip
ICI_BW = 50e9              # bytes/s per link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLL_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
             "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _type_bytes(type_str: str) -> int:
    """Sum bytes over every `dtype[shape]` group in an HLO type string."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str, total_devices: int) -> int:
    m = re.search(r"replica_groups=\{\{([0-9,]+)\}", line)
    if m:
        return len(m.group(1).split(","))
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)
    if m:
        return int(m.group(2))
    return total_devices


def parse_replica_groups(line: str):
    """Device-id groups of one collective instruction, or ``None`` when
    the instruction carries no ``replica_groups`` attribute (= one group
    of all devices).

    Handles both HLO spellings: the explicit list
    ``replica_groups={{0,1},{2,3}}`` and the iota form
    ``replica_groups=[2,2]<=[4]`` / ``[2,2]<=[2,2]T(1,0)`` (ids =
    ``arange(prod(dims)).reshape(dims).transpose(perm).reshape(n, g)``).
    """
    m = re.search(r"replica_groups=\{((?:\{[0-9, ]*\},?)*)\}", line)
    if m:
        groups = [[int(x) for x in g.split(",") if x.strip()]
                  for g in re.findall(r"\{([0-9, ]*)\}", m.group(1))]
        # ``replica_groups={}`` is XLA's spelling for ONE group of all
        # devices — same meaning as the attribute being absent.
        return groups or None
    # collective-permute carries source_target_pairs instead; each (src,
    # tgt) pair is a 2-device "group" for axis-span purposes.
    m = re.search(r"source_target_pairs=\{((?:\{[0-9, ]*\},?)*)\}", line)
    if m:
        pairs = [[int(x) for x in g.split(",") if x.strip()]
                 for g in re.findall(r"\{([0-9, ]*)\}", m.group(1))]
        return pairs or None
    m = re.search(
        r"replica_groups=\[(\d+),(\d+)\]<=\[([0-9,]+)\](?:T\(([0-9,]+)\))?",
        line)
    if m:
        import numpy as np
        n, g = int(m.group(1)), int(m.group(2))
        dims = [int(x) for x in m.group(3).split(",")]
        ids = np.arange(int(np.prod(dims))).reshape(dims)
        if m.group(4):
            ids = ids.transpose([int(x) for x in m.group(4).split(",")])
        return ids.reshape(n, g).tolist()
    return None


def group_axes(groups, mesh) -> tuple:
    """Which mesh axes a collective's device groups span.

    Returns the (mesh-ordered) tuple of axis names whose coordinate
    varies within at least one group — e.g. on a ``(data, sequence)``
    mesh, groups ``{{0,1,2,3},{4,5,6,7}}`` span ``("sequence",)`` and
    ``{{0,4},...}`` span ``("data",)``. ``groups=None`` (no
    ``replica_groups`` attribute) spans every non-trivial axis.
    """
    import numpy as np
    names = tuple(mesh.axis_names)
    devs = np.asarray(mesh.devices)
    if groups is None:
        return tuple(n for n, s in zip(names, devs.shape) if s > 1)
    coord = {}
    for idx in np.ndindex(devs.shape):
        coord[int(devs[idx].id)] = idx
    varying = set()
    for g in groups:
        unknown = [d for d in g if d not in coord]
        if unknown:
            # Fail loudly: silently dropping ids would misclassify the
            # axes a collective spans and corrupt every budget built on
            # this (e.g. a mesh over a device subset, or ids that are not
            # the flat 0..N-1 ordering of this mesh).
            raise ValueError(
                f"replica group {g} names device ids {unknown} not in "
                f"the mesh (known: {sorted(coord)})")
        cs = [coord[d] for d in g]
        for ax in range(len(names)):
            if len({c[ax] for c in cs}) > 1:
                varying.add(names[ax])
    return tuple(n for n in names if n in varying)


def collective_axis_counts(hlo_text: str, mesh):
    """Instruction counts per (collective op, spanned mesh axes).

    The per-axis view of :func:`collective_counts`: keys are
    ``(op, axes)`` with ``axes`` the mesh-ordered tuple from
    :func:`group_axes`. This is what proves the 2D DP×SP budget — e.g.
    "every LASP-2 all-gather spans ONLY the sequence axis, exactly one
    reduction spans data" (``repro.comm.budget.check_axis_budget``).
    """
    import numpy as np
    total = int(np.asarray(mesh.devices).size)
    counts = {}
    for c in parse_collectives(hlo_text, total):
        key = (c.op, group_axes(c.groups, mesh))
        counts[key] = counts.get(key, 0) + c.count
    return counts


@dataclass
class Collective:
    op: str
    result_bytes: int
    group_size: int
    count: int = 1
    groups: Optional[List[List[int]]] = None   # device-id replica groups

    @property
    def traffic_bytes(self) -> float:
        g = max(self.group_size, 2)
        b = self.result_bytes
        if self.op == "all-gather":
            t = (g - 1) / g * b
        elif self.op == "all-reduce":
            t = 2 * (g - 1) / g * b
        elif self.op == "reduce-scatter":
            t = (g - 1) * b
        elif self.op == "all-to-all":
            t = (g - 1) / g * b
        else:  # collective-permute
            t = b
        return t * self.count


def parse_collectives(hlo_text: str, total_devices: int) -> List[Collective]:
    """All collective ops in the compiled module ('-start' variants counted,
    '-done' skipped). NOTE: ops inside while bodies appear once — callers
    using scans must extrapolate (repro.launch.roofline)."""
    out = []
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = re.match(r"(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.+?)\s+([\w\-]+)\(",
                     stripped)
        if not m:
            continue
        type_str, op = m.groups()
        base = op.replace("-start", "")
        if base not in _COLL_OPS or op.endswith("-done"):
            continue
        rb = _type_bytes(type_str)
        if base == "all-gather" and op.endswith("-start"):
            rb //= 2   # start ops carry (operand, result) tuple types
        out.append(Collective(base, rb,
                              _group_size(stripped, total_devices),
                              groups=parse_replica_groups(stripped)))
    return out


def collective_counts(hlo_text: str, total_devices: int) -> Dict[str, int]:
    """Instruction counts per collective op in the compiled module (same
    while-body caveat as :func:`parse_collectives`). The comm-budget
    checks (``repro.comm.budget``) are built on this."""
    counts: Dict[str, int] = {}
    for c in parse_collectives(hlo_text, total_devices):
        counts[c.op] = counts.get(c.op, 0) + c.count
    return counts


def collective_summary(colls: List[Collective]) -> Dict[str, float]:
    summary: Dict[str, float] = {}
    for c in colls:
        summary[c.op] = summary.get(c.op, 0.0) + c.traffic_bytes
        summary[f"{c.op}_count"] = summary.get(f"{c.op}_count", 0) + c.count
    summary["total_bytes"] = sum(c.traffic_bytes for c in colls)
    return summary


@dataclass
class CostVector:
    """Per-device cost of one compiled program (additive, scalable)."""

    flops: float = 0.0
    hbm_bytes: float = 0.0
    coll_bytes: float = 0.0
    coll_by_op: Dict[str, float] = field(default_factory=dict)

    def __add__(self, o):
        d = dict(self.coll_by_op)
        for k, v in o.coll_by_op.items():
            d[k] = d.get(k, 0.0) + v
        return CostVector(self.flops + o.flops,
                          self.hbm_bytes + o.hbm_bytes,
                          self.coll_bytes + o.coll_bytes, d)

    def __sub__(self, o):
        d = {k: v - o.coll_by_op.get(k, 0.0)
             for k, v in self.coll_by_op.items()}
        return CostVector(self.flops - o.flops,
                          self.hbm_bytes - o.hbm_bytes,
                          self.coll_bytes - o.coll_bytes, d)

    def scale(self, f):
        return CostVector(self.flops * f, self.hbm_bytes * f,
                          self.coll_bytes * f,
                          {k: v * f for k, v in self.coll_by_op.items()})


def measure(compiled, total_devices: int) -> CostVector:
    from repro.core.compat import cost_analysis
    ca = cost_analysis(compiled)
    colls = parse_collectives(compiled.as_text(), total_devices)
    summ = collective_summary(colls)
    by_op = {c: summ.get(c, 0.0) for c in _COLL_OPS if c in summ}
    return CostVector(
        flops=float(ca.get("flops", 0.0)),
        hbm_bytes=float(ca.get("bytes accessed", 0.0)),
        coll_bytes=float(summ.get("total_bytes", 0.0)),
        coll_by_op=by_op)


def model_flops(cfg, shape) -> float:
    """MODEL_FLOPS: 6·N_active·D (train), 2·N_active·D (prefill),
    2·N_active·B (decode, D = one token per row).

    Lives here (not in ``repro.launch.roofline``, which re-exports it)
    so runtime telemetry (``repro.obs``) can compute achieved-MFU
    without importing the roofline module, whose import sets the
    512-virtual-device ``XLA_FLAGS`` for its own subprocesses.
    """
    n = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * n * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n * shape.global_batch * shape.seq_len
    return 2.0 * n * shape.global_batch


def roofline_terms(cost: CostVector) -> Dict[str, float]:
    """The three per-step time lower bounds, in seconds (per chip; FLOPs
    and bytes here are already per-device post-SPMD)."""
    t_compute = cost.flops / PEAK_FLOPS
    t_memory = cost.hbm_bytes / HBM_BW
    t_coll = cost.coll_bytes / ICI_BW
    dominant = max((t_compute, "compute"), (t_memory, "memory"),
                   (t_coll, "collective"))[1]
    return {"compute_s": t_compute, "memory_s": t_memory,
            "collective_s": t_coll, "dominant": dominant}


def memory_report(compiled) -> Dict[str, float]:
    ma = compiled.memory_analysis()
    if ma is None:
        return {}
    return {
        "argument_bytes": ma.argument_size_in_bytes,
        "output_bytes": ma.output_size_in_bytes,
        "temp_bytes": ma.temp_size_in_bytes,
        "alias_bytes": ma.alias_size_in_bytes,
        "generated_code_bytes": ma.generated_code_size_in_bytes,
        "peak_bytes": (ma.argument_size_in_bytes
                       + ma.output_size_in_bytes
                       + ma.temp_size_in_bytes
                       - ma.alias_size_in_bytes),
    }


# ---------------------------------------------------------------------------
# Lowered (pre-optimization) StableHLO parsing — the wire-dtype view.
#
# XLA:CPU's float normalization UPCASTS bf16 collectives to f32 in the
# *compiled* HLO (bf16 is storage-only there), so a comm_dtype=bf16
# assertion must read the LOWERED StableHLO, where the element types the
# program put on the wire are still visible. Used by the compiled-program
# sanitizer (repro.analysis.sanitizer, SAN203/SAN205).
# ---------------------------------------------------------------------------

_STABLEHLO_OPS = ("all_gather", "all_reduce", "reduce_scatter",
                  "all_to_all", "collective_permute", "collective_broadcast")
_STABLEHLO_OP_RE = re.compile(
    r'"stablehlo\.(' + "|".join(_STABLEHLO_OPS) + r')"')
_STABLEHLO_GROUPS_RE = re.compile(
    r"replica_groups\s*=\s*dense<(\[\[.*?\]\]|\[?[0-9 ,]*\]?)>", re.S)
_STABLEHLO_FNTYPE_RE = re.compile(
    r":\s*\((tensor<[^)]*?)\)\s*->", re.S)
_TENSOR_RE = re.compile(r"tensor<([0-9x]*)([a-z][a-z0-9]*)>")


@dataclass(frozen=True)
class StableHloCollective:
    """One collective in lowered StableHLO text, with its wire-visible
    element type (the thing compiled CPU HLO loses for bf16)."""

    op: str                     # hlo-style name, e.g. "all-gather"
    dtype: str                  # element type of the first operand
    shape: tuple                # dims of the first operand
    groups: Optional[tuple]     # replica groups (device ids), or None


def parse_stablehlo_collectives(text: str) -> List[StableHloCollective]:
    """Every collective op in a ``lowered.as_text()`` module, in program
    order. Region-holding ops (all_reduce/reduce_scatter) print their
    function type after the region body, so the scan is text-positional,
    not line-based."""
    import json
    out = []
    for m in _STABLEHLO_OP_RE.finditer(text):
        tail = text[m.end():]
        gm = _STABLEHLO_GROUPS_RE.search(tail[:2000])
        groups = None
        if gm:
            raw = gm.group(1)
            if not raw.startswith("[["):
                raw = f"[[{raw.strip('[]')}]]"
            groups = tuple(tuple(g) for g in json.loads(raw))
        fm = _STABLEHLO_FNTYPE_RE.search(tail)
        dtype, shape = "?", ()
        if fm:
            tm = _TENSOR_RE.search(fm.group(1))
            if tm:
                shape = tuple(int(d) for d in tm.group(1).split("x") if d)
                dtype = tm.group(2)
        out.append(StableHloCollective(
            op=m.group(1).replace("_", "-"), dtype=dtype, shape=shape,
            groups=groups))
    return out


def collective_fingerprint(text: str) -> List[tuple]:
    """Order-preserving (op, dtype, shape, groups) sequence of a lowered
    module — the determinism invariant: two independent lowerings of the
    same step must produce the identical fingerprint (SAN205)."""
    return [(c.op, c.dtype, c.shape, c.groups)
            for c in parse_stablehlo_collectives(text)]


def alias_entries(compiled_text: str) -> int:
    """Number of entries in the compiled module's input/output alias
    table (``input_output_alias={ {0}: (0, {}, may-alias), ... }``).
    0 = donation degraded to a copy (SAN204)."""
    m = re.search(r"input_output_alias=\{", compiled_text)
    if not m:
        return 0
    depth, i = 1, m.end()
    while i < len(compiled_text) and depth:
        depth += {"{": 1, "}": -1}.get(compiled_text[i], 0)
        i += 1
    return compiled_text[m.end():i].count("alias")
