"""Paper Table 3 (Appendix A.5.1): bidirectional language modeling.

RoBERTa-style masked-token objective at tiny scale: standard bidirectional
attention baseline vs basic linear attention trained with LASP-2 w/o
masking (paper Alg. 1). Expectation (paper): near-identical losses.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit

STEPS = 100
SEQ = 128
BATCH = 8
VOCAB = 1024
MASK_ID = 0


def _mlm_batch(step, seed=0):
    rng = np.random.default_rng([seed, step])
    u = rng.random((BATCH, SEQ))
    tokens = np.minimum((VOCAB * u ** 4).astype(np.int32), VOCAB - 1)
    mask = rng.random((BATCH, SEQ)) < 0.15
    inp = np.where(mask, MASK_ID, tokens)
    labels = np.where(mask, tokens, -1)
    return jnp.asarray(inp), jnp.asarray(labels)


def _run(linear: bool):
    from repro.configs.base import LayerSpec, LinearAttnConfig, ModelConfig
    from repro.models import model as M
    from repro.optim import adamw

    pattern = (LayerSpec(mixer="linear" if linear else "softmax"),)
    cfg = ModelConfig(name="roberta-tiny", family="dense", n_layers=4,
                      d_model=128, n_heads=4, n_kv_heads=4, d_ff=352,
                      vocab_size=VOCAB, pattern=pattern,
                      linear_attn=LinearAttnConfig("elu1", "none",
                                                   "faithful"))
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    opt = adamw.init(params)

    @jax.jit
    def step_fn(params, opt, inp, labels):
        def loss_fn(p):
            logits, _ = M.forward(p, inp, cfg, causal=False, remat="none")
            return M.lm_loss(logits, labels)
        loss, g = jax.value_and_grad(loss_fn)(params)
        g, _ = adamw.clip_by_global_norm(g, 1.0)
        params, opt = adamw.update(g, opt, params, lr=1e-3,
                                   weight_decay=0.1)
        return params, opt, loss

    t0 = time.perf_counter()
    losses = []
    for s in range(STEPS):
        inp, labels = _mlm_batch(s)
        params, opt, loss = step_fn(params, opt, inp, labels)
        losses.append(float(loss))
    dt = time.perf_counter() - t0
    return sum(losses[-10:]) / 10, dt


def main():
    rows = []
    for name, linear in (("standard-attn-baseline", False),
                         ("basic-linear-lasp2-nomask", True)):
        loss, dt = _run(linear)
        rows.append((f"table3/{name}", dt / STEPS * 1e6,
                     f"train_loss={loss:.3f}"))
    emit(rows)
    return rows


if __name__ == "__main__":
    main()
