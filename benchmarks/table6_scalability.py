"""Paper Table 6 / Fig. 4: scalability — memory per device and linear
sequence scaling with device count.

Two parts:
(a) compiled evidence: per-device memory from the dry-run artifacts
    (results/dryrun/*.json) for each arch × shape on the 256-chip pod;
(b) LASP-2 scaling law reproduced structurally: compile the paper's pure-
    SP workload (Linear-Llama3-1B, batch 1) at W ∈ {2,4,8} devices with
    S ∝ W and verify per-device memory stays ~constant (the paper's
    Fig. 4 "same memory, 16× devices → 16× sequence" result).
"""

from __future__ import annotations

import glob
import json
import os

from benchmarks.common import emit, run_subprocess_bench

_CODE = r"""
import json
import jax, jax.numpy as jnp
from repro.launch.mesh import auto_axis_types
from repro.core.lasp2 import lasp2, SPConfig
from jax.sharding import PartitionSpec as P, NamedSharding

res = {}
for w, s in ((2, 16384), (4, 32768), (8, 65536)):
    mesh = jax.make_mesh((w,), ("data",), **auto_axis_types(1))
    sp = SPConfig(mesh=mesh, sp_axis="data")
    B, H, d = 1, 16, 128
    sh = NamedSharding(mesh, P(None, None, "data", None))
    args = [jax.ShapeDtypeStruct((B, H, s, d), jnp.bfloat16)] * 3

    def f(q, k, v):
        return lasp2(q, k, v, sp=sp)

    compiled = jax.jit(f, in_shardings=(sh, sh, sh)).lower(*args).compile()
    ma = compiled.memory_analysis()
    per_dev = (ma.argument_size_in_bytes + ma.output_size_in_bytes
               + ma.temp_size_in_bytes - ma.alias_size_in_bytes)
    res[f"W{w}_S{s}"] = per_dev / 1e6
print(json.dumps(res))
"""


def main():
    rows = []
    # (a) dry-run memory table
    for path in sorted(glob.glob("results/dryrun/*16x16.json")):
        with open(path) as f:
            rec = json.load(f)
        if rec.get("status") != "ok" or "2x16x16" in os.path.basename(path):
            continue
        mem = rec.get("memory", {})
        peak = mem.get("peak_bytes", 0) / 2 ** 30
        rows.append((f"table6/mem/{rec['arch']}@{rec['shape']}", 0.0,
                     f"peak_GiB_per_dev={peak:.2f}"))
    # (b) constant-memory sequence scaling
    res = run_subprocess_bench(_CODE, devices=8, timeout=900)
    vals = sorted(res.items())
    base = vals[0][1]
    for k, mb in vals:
        rows.append((f"table6/scaling/{k}", 0.0,
                     f"per_dev_MB={mb:.1f};rel={mb / base:.3f}"))
    emit(rows)
    return rows


if __name__ == "__main__":
    main()
