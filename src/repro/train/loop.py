"""Fault-tolerant training loop.

* auto-resume from the latest checkpoint (determinism: batch(step) is a
  pure function, so resumed runs are bitwise-identical),
* periodic async checkpointing (atomic; crash-safe),
* step watchdog: wall-time per step is tracked, slow steps logged — the
  single-host analogue of straggler detection; on a real cluster the same
  hook triggers the coordinator's unhealthy-host path,
* non-finite gradient steps are skipped inside the jitted step,
* SIGTERM/KeyboardInterrupt → final checkpoint, clean exit (preemption),
* optional telemetry (``sink=``, docs/observability.md): per-step phase
  walls / tokens-per-s / MFU records plus a compile-time flight-recorder
  snapshot of the comm tape vs the compiled HLO. With ``sink=None`` the
  loop runs the exact uninstrumented path — no tape, no AOT lowering, no
  extra host work per step.
"""

from __future__ import annotations

import signal
import time
from contextlib import nullcontext
from typing import Callable, Optional

import jax
import numpy as np

from repro.checkpoint.manager import CheckpointError, CheckpointManager
from repro.configs.base import ModelConfig, RunConfig
from repro.data.pipeline import SyntheticLM
from repro.resilience.guard import GuardAbort
from repro.sharding.rules import Parallelism
from repro.train.step import init_state, make_train_step


class StepWatchdog:
    """Tracks step durations; flags stragglers (> factor × median).

    The first ``warmup`` recorded durations are compile/resume spikes
    (the step wall includes trace+compile time): they are never flagged
    and never enter the rolling window, so a one-off 100× outlier can't
    poison the median every subsequent step is judged against.
    """

    def __init__(self, factor: float = 3.0, window: int = 50,
                 warmup: int = 1):
        self.times, self.factor, self.window = [], factor, window
        self.warmup = warmup
        self.seen = 0
        self.slow_steps = 0

    def record(self, dt: float) -> bool:
        self.seen += 1
        if self.seen <= self.warmup:
            return False
        self.times.append(dt)
        self.times = self.times[-self.window:]
        med = float(np.median(self.times))
        slow = len(self.times) >= 10 and dt > self.factor * med
        self.slow_steps += int(slow)
        return slow


def train(cfg: ModelConfig, run: RunConfig, data: SyntheticLM, *,
          plan: Optional[Parallelism] = None, ckpt_dir: Optional[str] = None,
          ckpt_every: int = 50, log_every: int = 10,
          log_fn: Callable[[str], None] = print, max_steps=None,
          sink=None):
    """Returns (final_state, history list of metric dicts).

    ``sink``: optional :class:`repro.obs.MetricsSink`. When set, the loop
    (a) traces the step under the ``repro.comm`` tape and compiles it
    ahead-of-time ONCE (the AOT result is also the HLO the flight
    recorder cross-validates the tape against — no second compile),
    (b) emits one ``step`` record per step with phase walls
    (data/step/ckpt), tokens/s, MFU and expected-vs-compiled collective
    bytes, and (c) turns resume/straggler/signal prints into structured
    ``event`` records. The caller owns the sink's lifetime.
    """
    # single-device default still honours the kernel-backend knob
    plan = plan or Parallelism(backend=run.kernel_backend)
    key = jax.random.PRNGKey(run.seed)
    state = init_state(key, cfg, run, plan)
    start_step = 0

    recorder = None
    timer = None
    if sink is not None:
        from repro.configs.base import ShapeConfig
        from repro.launch.hlo_analysis import model_flops
        from repro.obs import FlightRecorder, PhaseTimer, render_step
        n_devices = plan.mesh.size if plan.mesh is not None else 1
        shape = ShapeConfig("train-run", data.seq_len, data.global_batch,
                            "train")
        recorder = FlightRecorder(sink,
                                  model_flops_per_step=model_flops(cfg,
                                                                   shape),
                                  n_devices=n_devices)
        timer = PhaseTimer()
    phase = timer.phase if timer is not None else (lambda _n: nullcontext())
    tokens_per_step = data.global_batch * data.seq_len

    mgr = CheckpointManager(ckpt_dir, verify=run.ckpt_verify) \
        if ckpt_dir else None
    if mgr is not None:
        latest = mgr.latest_step()
        if latest is not None:
            try:
                state = mgr.restore(latest, state)
                start_step = latest
            except (CheckpointError, ValueError) as e:
                # corrupt/unreadable latest: fall back to the newest
                # checkpoint that verifies (docs/resilience.md)
                log_fn(f"[resume] checkpoint step {latest} invalid "
                       f"({type(e).__name__}); falling back")
                start_step, state, rejected = \
                    mgr.restore_latest_valid(state)
                log_fn(f"[resume] fell back to step {start_step} "
                       f"(rejected {[s for s, _ in rejected]})")
                if recorder is not None:
                    recorder.event("ckpt_fallback", bad_step=latest,
                                   restored_step=start_step,
                                   rejected=[s for s, _ in rejected],
                                   error=type(e).__name__)
            log_fn(f"[resume] restored step {start_step} from {ckpt_dir}")
            if recorder is not None:
                recorder.event("resume", step=start_step,
                               ckpt_dir=ckpt_dir)

    jitted = jax.jit(make_train_step(cfg, run, plan), donate_argnums=(0,))
    if recorder is None:
        step_fn = jitted
    else:
        # One shared compile: trace under the comm tape (the "expected"
        # collective view), compile ahead-of-time, and run the compiled
        # program directly — AOT results don't populate the jit cache,
        # so calling ``jitted`` afterwards would compile a second time.
        from repro.comm import tape
        t_c0 = time.perf_counter()
        with tape() as records:
            lowered = jitted.lower(
                state, data.microbatched(start_step, run.num_microbatches))
        compiled = lowered.compile()
        compile_s = time.perf_counter() - t_c0
        recorder.on_compile(records=records, hlo_text=compiled.as_text(),
                            total_devices=recorder.n_devices,
                            note=f"{cfg.name} train step")
        recorder.event("compile", step=start_step, seconds=compile_s)
        step_fn = compiled

    watchdog = StepWatchdog()
    history = []
    skipped_total = 0
    total = max_steps if max_steps is not None else run.total_steps

    stop = {"now": False}

    def _sig(_sig, _frm):
        stop["now"] = True

    old_handler = signal.signal(signal.SIGTERM, _sig)
    try:
        for step in range(start_step, total):
            with phase("data"):
                batch = data.microbatched(step, run.num_microbatches)
            t0 = time.perf_counter()
            with phase("step") as f:
                state, metrics = step_fn(state, batch)
                if f is not None:
                    f.set(metrics)
                metrics = {k: float(v) for k, v in metrics.items()}
            dt = time.perf_counter() - t0
            slow = watchdog.record(dt)
            with phase("ckpt"):
                if mgr is not None and (step + 1) % ckpt_every == 0:
                    mgr.save_async(step + 1, state)
            rec = None
            if recorder is not None:
                rec = recorder.on_step(step, dt, tokens=tokens_per_step,
                                       phases=timer.flush(),
                                       metrics=metrics, straggler=slow)
            metrics["step"], metrics["dt"] = step, dt
            history.append(metrics)
            skipped_total += int(metrics.get("skipped", 0))
            if metrics.get("skipped"):
                consec = int(metrics.get("consecutive_skips", 0))
                log_fn(f"[guard] step {step} skipped (non-finite update; "
                       f"consecutive {max(consec, 1)})")
                if recorder is not None:
                    recorder.event("guard_skip", step=step,
                                   consecutive=consec,
                                   total=skipped_total)
                if run.guard and \
                        consec >= run.guard_max_consecutive_skips:
                    # params are clean — skips never applied an update —
                    # so the finally-block checkpoint is safe to resume
                    # from once the cause is fixed.
                    if recorder is not None:
                        recorder.event("guard_abort", step=step,
                                       consecutive=consec)
                    raise GuardAbort(
                        f"{consec} consecutive skipped steps at step "
                        f"{step} (threshold "
                        f"{run.guard_max_consecutive_skips}) — the run "
                        "cannot make progress; a final checkpoint was "
                        "saved")
            if slow:
                log_fn(f"[watchdog] step {step} straggled: {dt:.2f}s")
            if step % log_every == 0:
                if rec is not None:
                    log_fn(render_step(rec))
                else:
                    log_fn(f"step {step:5d} loss {metrics['loss']:.4f} "
                           f"gnorm {metrics['grad_norm']:.2f} "
                           f"lr {metrics['lr']:.2e} {dt*1e3:.0f}ms")
            if stop["now"]:
                log_fn(f"[signal] interrupted at step {step}; saving")
                if recorder is not None:
                    recorder.event("signal", step=step, signal="SIGTERM")
                break
    except KeyboardInterrupt:
        log_fn("[interrupt] saving final checkpoint")
        if recorder is not None:
            recorder.event("interrupt")
    finally:
        signal.signal(signal.SIGTERM, old_handler)
        if mgr is not None:
            mgr.wait()
            mgr.save(int(state["step"]), state)
        if recorder is not None:
            recorder.summary(final_step=int(state["step"]),
                             slow_steps=watchdog.slow_steps,
                             skipped_steps=skipped_total,
                             **{f"phase_{k}_{s}": v
                                for k, h in timer.summaries().items()
                                for s, v in h.items()})
    return state, history
