"""HLO-verified collective budgets.

The paper's claims are *counts*: LASP-2 does exactly one forward
AllGather of sequence-length-independent state; LASP-1's ring does
2(W-1) sequential permutes per fwd+bwd. A :class:`CollectiveBudget` is
that claim written down; :func:`assert_budget` proves it against the
compiled (post-SPMD) HLO via ``repro.launch.hlo_analysis`` — not against
what the Python source *intended* to emit. Tests in
``tests/comm_checks.py`` pin every strategy to its budget.

Caveat inherited from ``parse_collectives``: ops inside ``while`` bodies
(scans/fori_loops) appear once in HLO. The ring strategies are therefore
UNROLLED (static mesh degree) so their W-1 hops are literally countable.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Mapping, Optional

from repro.launch.hlo_analysis import (_COLL_OPS, collective_axis_counts,
                                       collective_counts,
                                       parse_collectives)
from repro.launch.mesh import DATA_AXIS, MODEL_AXIS, SEQ_AXIS


@dataclass(frozen=True)
class CollectiveBudget:
    """Exact expected instruction counts; unlisted collective ops must be
    absent (strict=True) or are ignored (strict=False)."""

    counts: Mapping[str, int]
    strict: bool = True
    # optional per-op ceiling on summed per-device traffic bytes
    max_traffic: Mapping[str, float] = field(default_factory=dict)
    note: str = ""


def comm_itemsize(comm_dtype: Optional[str] = None) -> int:
    """Bytes per element on the wire for a ``comm_dtype`` knob value
    (derived from the single registry in ``repro.comm.primitives``)."""
    import numpy as np

    from repro.comm.primitives import wire_dtype
    return np.dtype(wire_dtype(comm_dtype)).itemsize


def packed_state_bytes(b: int, h: int, dk: int, dv: int,
                       comm_dtype: Optional[str] = None) -> int:
    """Per-device payload of the packed ``(M_t ‖ A_t)`` state exchange —
    ``B·H·(dk·dv + 1)`` scalars in the wire dtype. What the comm_dtype
    knob halves (bf16) while the collective *count* stays fixed."""
    return b * h * (dk * dv + 1) * comm_itemsize(comm_dtype)


def allgather_state_budget(world: int, *, with_grad: bool = False,
                           backward: str = "faithful", n_slices: int = 1,
                           state_bytes: Optional[int] = None
                           ) -> CollectiveBudget:
    """Registry ``budget_fn`` for the "allgather" (and "ulysses", whose
    linear-layer exchange IS allgather) inter-chunk state exchange:
    exactly 1 forward all-gather of the packed ``(M_t ‖ A_t)`` states;
    ``with_grad`` adds the backward's dM gather (faithful, Alg. 4) or
    its AD transpose reduce-scatter (autodiff)."""
    del n_slices  # allgather has no slicing knob

    def traffic(n_gathers, n_rs=0):
        if state_bytes is None:
            return {}
        out = {}
        if n_gathers:
            out["all-gather"] = n_gathers * (world - 1) * state_bytes
        if n_rs:
            # RS input is the gathered size: (g-1) × result bytes
            out["reduce-scatter"] = n_rs * (world - 1) * state_bytes
        return out

    if not with_grad:
        return CollectiveBudget({"all-gather": 1},
                                max_traffic=traffic(1))
    if backward == "faithful":
        return CollectiveBudget({"all-gather": 2},
                                max_traffic=traffic(2),
                                note="paper Alg. 2+4: fwd + dM gathers")
    return CollectiveBudget({"all-gather": 1, "reduce-scatter": 1},
                            max_traffic=traffic(1, 1),
                            note="autodiff: RS is the gather transpose")


def ring_state_budget(world: int, *, with_grad: bool = False,
                      backward: str = "autodiff", n_slices: int = 1,
                      state_bytes: Optional[int] = None
                      ) -> CollectiveBudget:
    """Registry ``budget_fn`` for the "ring"/"pipelined" exchanges:
    n_slices·(W-1) collective-permutes per pass, transposing 1:1 under
    autodiff. ``state_bytes`` ceilings describe the packed (M‖A) gather
    payload; the ring paths ship the unpacked M_t per hop, so only the
    count is pinned here."""
    del backward, state_bytes
    per_pass = n_slices * (world - 1)
    n = 2 * per_pass if with_grad else per_pass
    return CollectiveBudget({"collective-permute": n})


def lasp2_budget(strategy: str, world: int, *, with_grad: bool = False,
                 backward: str = "faithful", n_slices: int = 1,
                 state_bytes: Optional[int] = None) -> CollectiveBudget:
    """What one LASP-2 layer is allowed to put on the wire.

    forward only:
      allgather/ulysses → exactly 1 all-gather (the packed M‖A states)
      ring              → W-1 collective-permutes
      pipelined         → n_slices·(W-1) permutes (1/n_slices size)
    with_grad adds the strategy's backward:
      allgather faithful → +1 all-gather (Alg. 4's dM gather)
      allgather autodiff → +1 reduce-scatter (AD transpose of the gather)
      ring/pipelined     → the permutes transpose 1:1 (total doubles)

    ``state_bytes`` (see :func:`packed_state_bytes`): per-device payload
    of one exchange in the *wire* dtype — when given, the budget also
    pins per-op traffic ceilings under the ring cost model, so a
    comm_dtype=bf16 run is asserted to actually halve the bytes (an
    fp32-sized gather then exceeds the ceiling and fails).

    Dispatch is through the strategy registry (the per-strategy
    ``budget_fn`` passed to ``register_strategy``), so a strategy added
    through the public API gets budget coverage without touching this
    module.
    """
    from repro.comm.strategy import get_budget_fn
    return get_budget_fn(strategy)(world, with_grad=with_grad,
                                   backward=backward, n_slices=n_slices,
                                   state_bytes=state_bytes)


def hybrid_context_budget(strategy: str, degree: int, *, sp: int = 1,
                          b: int, hq: int, hkv: int, c: int, dh: int,
                          with_grad: bool = False,
                          comm_dtype: Optional[str] = None,
                          compute_itemsize: int = 4) -> CollectiveBudget:
    """What ONE LASP-2H softmax context-attention call may put on the
    wire, per strategy (registry ``context_budget_fn``).

    ``degree`` is the strategy's context-exchange axis size: the full
    sequence-sharding width for the K/V AllGather path, the ulysses
    (head-parallel) axis size for the All-to-All path. ``sp`` is the
    residual sequence axis ulysses still gathers K/V over on a 3D mesh
    (1 on 1D/2D meshes). ``c`` is the per-device chunk length, ``b``
    batch, ``hq``/``hkv`` query/KV head counts, ``dh`` head dim.
    """
    from repro.comm.strategy import get_context_budget_fn
    return get_context_budget_fn(strategy)(
        degree, sp=sp, b=b, hq=hq, hkv=hkv, c=c, dh=dh,
        with_grad=with_grad, comm_dtype=comm_dtype,
        compute_itemsize=compute_itemsize)


def allgather_context_budget(degree: int, *, sp: int = 1, b: int, hq: int,
                             hkv: int, c: int, dh: int,
                             with_grad: bool = False,
                             comm_dtype: Optional[str] = None,
                             compute_itemsize: int = 4
                             ) -> CollectiveBudget:
    """Registry ``context_budget_fn`` for the K/V AllGather context path
    (LASP-2H default; ring/pipelined layers use the same context path):
    exactly 2 all-gathers (K and V) over the full ``degree``-wide
    sequence sharding; autodiff transposes each into a reduce-scatter.
    Per-link volume is constant in ``degree``: (degree-1)·|K/V local|."""
    del sp, hq, compute_itemsize
    kv = b * hkv * c * dh * comm_itemsize(comm_dtype)
    counts: Dict[str, int] = {"all-gather": 2}
    ceil: Dict[str, float] = {"all-gather": 2 * (degree - 1) * kv}
    if with_grad:
        counts["reduce-scatter"] = 2
        ceil["reduce-scatter"] = 2 * (degree - 1) * kv
    return CollectiveBudget(counts, max_traffic=ceil,
                            note=f"K/V allgather, degree={degree}")


def ulysses_context_budget(degree: int, *, sp: int = 1, b: int, hq: int,
                           hkv: int, c: int, dh: int,
                           with_grad: bool = False,
                           comm_dtype: Optional[str] = None,
                           compute_itemsize: int = 4) -> CollectiveBudget:
    """Registry ``context_budget_fn`` for the ulysses head-parallel
    path: exactly 2 All-to-Alls per forward (packed q‖k‖v seq→head in,
    attention output head→seq out), mirrored 1:1 by the custom_vjp
    backward. Per-link volume shrinks ∝ (degree-1)/degree² relative to
    the payload — the Ulysses selling point vs the gather's constant
    per-link volume. On a 3D mesh (``sp > 1``) K/V additionally gather
    over the residual sequence axis: head count divides by ``degree``
    but token count multiplies by it, so that gather ships the same
    bytes as a 2D K/V gather of width ``sp``."""
    g = degree
    wi = comm_itemsize(comm_dtype)
    a2a_in = b * (hq + 2 * hkv) * c * dh * wi    # packed q‖k‖v blocks
    a2a_out = b * hq * c * dh * compute_itemsize  # attention output
    per_fwd = (g - 1) * a2a_in // g + (g - 1) * a2a_out // g
    counts: Dict[str, int] = {"all-to-all": 4 if with_grad else 2}
    ceil: Dict[str, float] = {
        "all-to-all": per_fwd * (2 if with_grad else 1)}
    if sp > 1:
        # after the a2a: hkv/g heads × c·g tokens per device = hkv·c
        kv = b * hkv * c * dh * wi
        counts["all-gather"] = 2
        ceil["all-gather"] = 2 * (sp - 1) * kv
        if with_grad:
            counts["reduce-scatter"] = 2
            ceil["reduce-scatter"] = 2 * (sp - 1) * kv
    return CollectiveBudget(counts, max_traffic=ceil,
                            note=f"ulysses a2a, degree={g} sp={sp}")


def ring_baseline_budget(world: int, *,
                         with_grad: bool = False) -> CollectiveBudget:
    """LASP-1 baseline (paper Alg. 5/6): W-1 permutes per pass — the
    2(W-1) sequential steps per iteration LASP-2 removes."""
    n = (world - 1) * (2 if with_grad else 1)
    return CollectiveBudget({"collective-permute": n})


def check_budget(hlo_text: str, budget: CollectiveBudget,
                 total_devices: int, records=None) -> List[str]:
    """Return human-readable violations (empty list = within budget).

    Counts always come from the compiled HLO. Traffic ceilings
    (``budget.max_traffic``) come from the HLO too unless ``records`` (a
    list of trace-time :class:`repro.comm.CommRecord`) is given — the
    wire-dtype-true view. Pass the tape when asserting ``comm_dtype``
    byte budgets on CPU: XLA-CPU's float-normalization pass upcasts bf16
    collectives to f32 in compiled HLO (bf16 is storage-only there), so
    only the tape shows the halving this backend cannot express; on TPU
    bf16 collectives are native and the two views agree.

    The tape only records collectives issued through the named
    primitives — AD-emitted ones (e.g. the reduce-scatter transpose of
    the forward gather) never reach it. A ceiling op the HLO count
    expects but the tape lacks is therefore reported as a violation
    rather than passing vacuously against 0 tape bytes.
    """
    counts = collective_counts(hlo_text, total_devices)
    violations = []
    for op, expected in budget.counts.items():
        got = counts.get(op, 0)
        if got != expected:
            violations.append(f"{op}: expected exactly {expected}, "
                              f"compiled HLO has {got}")
    if budget.strict:
        for op in _COLL_OPS:
            if op not in budget.counts and counts.get(op, 0):
                violations.append(f"{op}: expected none, compiled HLO has "
                                  f"{counts[op]}")
    if budget.max_traffic:
        by_op: Dict[str, float] = {}
        if records is not None:
            for r in records:
                by_op[r.op] = by_op.get(r.op, 0.0) + r.traffic_bytes
        else:
            for c in parse_collectives(hlo_text, total_devices):
                by_op[c.op] = by_op.get(c.op, 0.0) + c.traffic_bytes
        src = "tape" if records is not None else "compiled HLO"
        for op, ceiling in budget.max_traffic.items():
            if records is not None and op not in by_op \
                    and budget.counts.get(op, 0):
                violations.append(
                    f"{op}: expected on the wire but absent from the "
                    f"CommRecord tape (AD-emitted?) — byte ceiling "
                    f"unverifiable from records")
            elif by_op.get(op, 0.0) > ceiling:
                violations.append(
                    f"{op}: {src} traffic {by_op.get(op, 0.0):.0f}B "
                    f"exceeds budget {ceiling:.0f}B")
    return violations


def assert_budget(hlo_text: str, budget: CollectiveBudget,
                  total_devices: int, records=None) -> None:
    violations = check_budget(hlo_text, budget, total_devices, records)
    if violations:
        note = f" ({budget.note})" if budget.note else ""
        raise AssertionError(
            "collective budget violated" + note + ":\n  "
            + "\n  ".join(violations))


# ---------------------------------------------------------------------------
# Per-axis budgets (2D DP×SP training, docs/parallelism.md).
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class AxisBudget:
    """Exact expected counts per (collective op, spanned mesh axes).

    Keys are ``(op, axes)`` with ``axes`` the mesh-ordered tuple of axis
    names the collective's replica groups span
    (``hlo_analysis.group_axes``). ``strict=True``: any collective with a
    key not listed is a violation."""

    counts: Mapping[tuple, int]
    strict: bool = True
    note: str = ""


def train_step_axis_budget(mesh, *, n_sp_layers: int,
                           n_hybrid_layers: int = 0,
                           comm_strategy: str = "allgather",
                           microbatches: int = 1,
                           backward: str = "autodiff",
                           zero1: bool = True) -> AxisBudget:
    """What one compiled (scan-unrolled) DP×SP(×TP) train step may put
    on the wire — the LASP-2(H) composition claim written down:

    * per LASP-2 layer × microbatch, over the sequence sharding ONLY
      (``(sequence,)`` on 2D, ``(sequence, model)`` on 3D — tokens shard
      over both): 1 forward all-gather of the packed ``(M_t, A_t)``
      states, plus the backward's 1 reduce-scatter (autodiff transpose)
      or 1 all-gather of ``dM_t`` (the paper-faithful Alg. 4).
    * per hybrid (softmax) layer × microbatch: the context exchange.
      ulysses → exactly 2 All-to-Alls over ``(model,)`` per forward (or
      over ``(sequence,)`` when there is no model axis), +2 mirrored in
      the backward, plus — 3D only, sp>1 — 2 K/V all-gathers over
      ``(sequence,)`` and their 2 backward reduce-scatters. allgather →
      2 K/V all-gathers over the full sequence sharding + 2 backward
      reduce-scatters.
    * exactly 1 gradient reduction spanning every nontrivial axis per
      step: the packed flat-gradient all-reduce (params are replicated;
      token/batch shards all contribute partial gradients).
    * ZeRO-1 only: 1 all-gather over the optimizer-shard axes — ``data``
      on 2D, ``(data, model)`` on 3D (the parameter re-assembly after
      the sharded update).
    """
    nontrivial = tuple(n for n in mesh.axis_names if mesh.shape[n] > 1)
    dp = mesh.shape.get(DATA_AXIS, 1)
    sp = mesh.shape.get(SEQ_AXIS, 1)
    tp = mesh.shape.get(MODEL_AXIS, 1)
    # tokens shard over both sequence-like axes; mesh order (SEQ, MODEL)
    seq_axes = tuple(a for a in (SEQ_AXIS, MODEL_AXIS)
                     if mesh.shape.get(a, 1) > 1)
    counts: Dict[tuple, int] = {}

    def add(op, axes, n):
        if n and axes:
            counts[(op, axes)] = counts.get((op, axes), 0) + n

    if seq_axes and n_sp_layers:
        per_pass = n_sp_layers * microbatches
        if backward == "faithful":
            add("all-gather", seq_axes, 2 * per_pass)
        else:
            add("all-gather", seq_axes, per_pass)
            add("reduce-scatter", seq_axes, per_pass)
    if seq_axes and n_hybrid_layers:
        per_pass = n_hybrid_layers * microbatches
        if comm_strategy == "ulysses":
            a2a_axes = (MODEL_AXIS,) if tp > 1 else (SEQ_AXIS,)
            add("all-to-all", a2a_axes, 4 * per_pass)  # 2 fwd + 2 bwd
            if tp > 1 and sp > 1:
                add("all-gather", (SEQ_AXIS,), 2 * per_pass)
                add("reduce-scatter", (SEQ_AXIS,), 2 * per_pass)
        else:
            add("all-gather", seq_axes, 2 * per_pass)
            add("reduce-scatter", seq_axes, 2 * per_pass)
    counts[("all-reduce", nontrivial)] = 1
    zero_axes = tuple(a for a in (DATA_AXIS, MODEL_AXIS)
                      if mesh.shape.get(a, 1) > 1)
    if zero1 and zero_axes:
        add("all-gather", zero_axes, 1)
    return AxisBudget(counts, note=f"dp={dp} sp={sp} tp={tp} "
                                   f"layers={n_sp_layers}"
                                   f"+{n_hybrid_layers}h A={microbatches}")


def check_axis_budget(hlo_text: str, mesh,
                      budget: AxisBudget) -> List[str]:
    """Human-readable violations of an :class:`AxisBudget` (empty list =
    within budget)."""
    got = collective_axis_counts(hlo_text, mesh)
    violations = []
    for key, expected in budget.counts.items():
        if got.get(key, 0) != expected:
            violations.append(
                f"{key[0]} over {key[1] or ('<none>',)}: expected exactly "
                f"{expected}, compiled HLO has {got.get(key, 0)}")
    if budget.strict:
        for key, n in got.items():
            if key not in budget.counts and n:
                violations.append(
                    f"{key[0]} over {key[1] or ('<none>',)}: expected "
                    f"none, compiled HLO has {n}")
    return violations


def assert_axis_budget(hlo_text: str, mesh, budget: AxisBudget) -> None:
    violations = check_axis_budget(hlo_text, mesh, budget)
    if violations:
        note = f" ({budget.note})" if budget.note else ""
        raise AssertionError(
            "per-axis collective budget violated" + note + ":\n  "
            + "\n  ".join(violations))


def compiled_hlo(fn, *args, static_argnums=()) -> str:
    """Compiled (post-SPMD) HLO text of ``jit(fn)(*args)``."""
    import jax
    return jax.jit(fn, static_argnums=static_argnums).lower(
        *args).compile().as_text()


def gather_result_bytes(hlo_text: str, total_devices: int,
                        op: str = "all-gather") -> Optional[int]:
    """Result size of the largest ``op`` in the module — used to pin the
    state gather to its expected W·(dk·dv+1)-scalar volume."""
    sizes = [c.result_bytes for c in parse_collectives(hlo_text,
                                                       total_devices)
             if c.op == op]
    return max(sizes) if sizes else None
