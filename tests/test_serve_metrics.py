"""Serving telemetry (docs/observability.md): engine/scheduler metrics
through the sink API, and byte-accurate cache_stats totals."""

import jax
import numpy as np

from repro.configs import get_smoke
from repro.models import model as M
from repro.obs import InMemorySink
from repro.serve.engine import ServeEngine


def _engine(cfg, rng, *, max_len=96, max_batch=4, sink=None):
    params = M.init_params(rng, cfg)
    return ServeEngine(cfg, params, max_len=max_len, max_batch=max_batch,
                       sink=sink)


def _hybrid_smoke():
    """2 linear + 1 windowed-softmax + 1 dense-mlp linear layer."""
    import dataclasses
    from repro.configs.base import LayerSpec
    base = get_smoke("linear-llama3-1b")
    dense = dataclasses.replace(base, pattern=(LayerSpec(),), n_layers=4,
                                name="smoke-dense")
    return dense.linearize(hybrid_every=4)


def test_engine_latency_and_queue_metrics(rng):
    cfg = get_smoke("linear-llama3-1b")
    sink = InMemorySink()
    engine = _engine(cfg, rng, max_batch=2, sink=sink)
    # 5 requests through 2 slots: the queue must back up, then drain
    uids = [engine.submit(np.arange(4 + i) % cfg.vocab_size, 4, stream=i)
            for i in range(5)]
    results = engine.run()
    assert set(results) == set(uids)

    s = engine.stats()
    assert s["submitted"] == 5
    assert s["admitted"] == 5
    assert s["evicted"] == 5
    assert s["finished_length"] == 5
    assert s["queue_depth"] == 0, "drained queue must read 0"
    assert s["queue_depth_peak"] >= 3, "5 requests into 2 slots must queue"
    assert s["cache_occupancy_peak"] == 1.0
    assert s["active_slots"] == 0

    # latency histograms exposed as p50/p99 via the sink-API snapshot
    assert s["ttft_s_count"] == 5
    assert 0 < s["ttft_s_p50"] <= s["ttft_s_p99"]
    assert s["decode_step_s_count"] >= 4
    assert 0 < s["decode_step_s_p50"] <= s["decode_step_s_p99"]
    assert 0 < s["prefill_s_p50"]
    assert s["decode_tokens_per_s"] > 0
    # decode counter arithmetic: tokens = sum of active slots per step
    assert s["decode_tokens"] <= 2 * s["decode_steps"]

    # per-request records flowed through the sink as requests finished
    reqs = sink.by_kind("request")
    assert len(reqs) == 5
    assert {r["uid"] for r in reqs} == set(uids)
    for r in reqs:
        assert r["finish_reason"] == "length"
        assert r["new_tokens"] == 4
        assert 0 < r["ttft_s"] <= r["wall_s"]

    summ = engine.emit_summary(requests=len(results))
    assert summ["kind"] == "summary" and summ["component"] == "serve"
    assert summ["requests"] == 5 and summ["ttft_s_count"] == 5
    assert sink.by_kind("summary")[-1] == summ


def test_reset_metrics_drops_history_keeps_cache_gauges(rng):
    cfg = get_smoke("linear-llama3-1b")
    engine = _engine(cfg, rng, max_batch=2)
    engine.generate(jax.random.randint(rng, (2, 8), 0, cfg.vocab_size), 4)
    assert engine.stats()["submitted"] == 2
    engine.reset_metrics()
    s = engine.stats()
    assert "submitted" not in s and "ttft_s_count" not in s
    # static cache gauges are re-seeded on the fresh registry
    assert s["cache_bytes_total"] == engine.cache_stats()["total"]
    # the fresh registry is re-shared with the scheduler
    engine.generate(jax.random.randint(rng, (1, 8), 0, cfg.vocab_size), 2)
    assert engine.stats()["submitted"] == 1


def test_cache_stats_byte_accurate_pure_linear(rng):
    cfg = get_smoke("linear-llama3-1b")
    B = 4
    engine = _engine(cfg, rng, max_len=96, max_batch=B)
    stats = engine.cache_stats()
    n_linear = sum(1 for s in cfg.pattern if s.mixer == "linear") \
        * cfg.n_groups
    dk = dv = cfg.head_dim
    # per layer: fp32 m (B,H,dk,dv) + fp32 log_decay (B,H)
    expect = n_linear * (B * cfg.n_heads * dk * dv * 4 +
                         B * cfg.n_heads * 4)
    assert stats["linear_state"] == expect == \
        n_linear * B * cfg.n_heads * (dk * dv + 1) * 4
    assert stats["kv_ring"] == 0
    # m + log_decay per pattern entry (n_groups stacks a leading dim on
    # the same arrays rather than adding arrays)
    assert stats["linear_state_arrays"] == 2 * len(
        [s for s in cfg.pattern if s.mixer == "linear"])
    assert stats["total"] == sum(
        stats[k] for k in ("linear_state", "kv_ring", "conv", "other"))


def test_cache_stats_byte_accurate_hybrid(rng):
    cfg = _hybrid_smoke()
    B, max_len = 3, 80
    engine = _engine(cfg, rng, max_len=max_len, max_batch=B)
    stats = engine.cache_stats()

    linear_specs = [s for s in cfg.pattern if s.mixer == "linear"]
    softmax_specs = [s for s in cfg.pattern if s.mixer == "softmax"]
    assert len(linear_specs) == 3 and len(softmax_specs) == 1

    dk = dv = cfg.head_dim
    expect_linear = len(linear_specs) * cfg.n_groups \
        * B * cfg.n_heads * (dk * dv + 1) * 4
    assert stats["linear_state"] == expect_linear

    expect_kv = 0
    for spec in softmax_specs:
        ring = min(max_len, spec.sliding_window) if spec.sliding_window \
            else max_len
        # bf16 K + V, int32 kpos per softmax layer
        expect_kv += cfg.n_groups * (
            2 * B * cfg.n_kv_heads * ring * cfg.head_dim * 2 + B * ring * 4)
    assert stats["kv_ring"] == expect_kv
    assert stats["kv_ring_arrays"] == 3 * len(softmax_specs)  # k, v, kpos

    # the paper's claim in bytes: the linear portion is constant in
    # max_len while the ring only tracks the window
    far = _engine(cfg, rng, max_len=4 * max_len, max_batch=B).cache_stats()
    assert far["linear_state"] == stats["linear_state"]
    window = softmax_specs[0].sliding_window
    assert window, "hybrid softmax layers must be windowed"
    if 4 * max_len <= window:
        ratio = 4 * max_len / max_len
        assert far["kv_ring"] == stats["kv_ring"] * ratio


def test_cache_gauges_seeded_at_construction(rng):
    cfg = get_smoke("linear-llama3-1b")
    engine = _engine(cfg, rng)
    s = engine.stats()
    stats = engine.cache_stats()
    assert s["cache_bytes_linear_state"] == stats["linear_state"]
    assert s["cache_bytes_total"] == stats["total"]
    assert "cache_bytes_linear_state_arrays" not in s


def test_null_sink_engine_behaves_identically(rng):
    """sink=None must not change generated tokens (telemetry is
    host-side only)."""
    cfg = get_smoke("linear-llama3-1b")
    prompts = jax.random.randint(rng, (3, 8), 0, cfg.vocab_size)
    params = M.init_params(rng, cfg)
    a = ServeEngine(cfg, params, max_len=64).generate(prompts, 6)
    b = ServeEngine(cfg, params, max_len=64,
                    sink=InMemorySink()).generate(prompts, 6)
    np.testing.assert_array_equal(a, b)
