"""Train loop: learning, checkpoint-resume determinism, crash recovery,
non-finite-step skipping, watchdog."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke
from repro.configs.base import RunConfig
from repro.data.pipeline import SyntheticLM
from repro.train.loop import StepWatchdog, train
from repro.train.step import init_state, make_train_step
from repro.sharding.rules import local_plan


def _run(tmp, steps, cfg, run, data, **kw):
    return train(cfg, run, data, ckpt_dir=tmp, ckpt_every=5,
                 log_every=10 ** 9, log_fn=lambda *_: None,
                 max_steps=steps, **kw)


def test_loss_decreases(tmp_path):
    cfg = get_smoke("linear-llama3-1b")
    run = RunConfig(num_microbatches=1, total_steps=60, warmup_steps=5,
                    learning_rate=1e-3, remat="none")
    data = SyntheticLM(cfg.vocab_size, 128, 8, seed=0)
    _, hist = train(cfg, run, data, log_every=10 ** 9,
                    log_fn=lambda *_: None)
    assert hist[-1]["loss"] < hist[0]["loss"] - 0.2


def test_crash_resume_bitwise(tmp_path):
    """Train 20 straight vs train 10 + restart + 10: identical params."""
    cfg = get_smoke("mamba2-2.7b")
    run = RunConfig(num_microbatches=1, total_steps=20, warmup_steps=2,
                    learning_rate=1e-3, remat="none")
    data = SyntheticLM(cfg.vocab_size, 64, 4, seed=1)

    s_full, _ = _run(str(tmp_path / "a"), 20, cfg, run, data)
    _run(str(tmp_path / "b"), 10, cfg, run, data)          # "crash"
    s_resumed, hist2 = _run(str(tmp_path / "b"), 20, cfg, run, data)
    assert hist2[0]["step"] == 10, "must resume from the checkpoint"
    for a, b in zip(jax.tree.leaves(s_full["params"]),
                    jax.tree.leaves(s_resumed["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_nonfinite_grad_skipped(rng):
    cfg = get_smoke("linear-llama3-1b")
    run = RunConfig(num_microbatches=1, total_steps=5, remat="none")
    state = init_state(rng, cfg, run)
    step = jax.jit(make_train_step(cfg, run, local_plan()))
    data = SyntheticLM(cfg.vocab_size, 32, 4, seed=0)
    batch = data.microbatched(0, 1)
    # poison the params: forward produces NaNs → grads non-finite
    bad = jax.tree.map(lambda x: x, state)
    bad["params"]["embed"]["table"] = \
        state["params"]["embed"]["table"].at[0, 0].set(jnp.nan)
    before = jax.tree.leaves(bad["params"])[0]
    new_state, metrics = step(bad, batch)
    assert float(metrics["skipped"]) == 1.0
    after = jax.tree.leaves(new_state["params"])[0]
    # params unchanged where finite comparison applies
    np.testing.assert_array_equal(
        np.asarray(before[1:]), np.asarray(after[1:]))


def test_watchdog_flags_stragglers():
    wd = StepWatchdog(factor=3.0)
    for _ in range(20):
        assert not wd.record(0.1)
    assert wd.record(1.0)
    assert wd.slow_steps == 1


def test_watchdog_compile_spike_cannot_poison_window():
    """The first recorded step carries trace+compile (or resume) time —
    often 100x a warm step. It must be swallowed by the warmup, never
    flagged, and never enter the rolling window the median is taken
    over, so later genuinely-slow steps still trip the detector."""
    wd = StepWatchdog(factor=3.0, warmup=1)
    assert wd.record(30.0) is False, "compile spike must not be flagged"
    for _ in range(12):
        assert not wd.record(0.1)
    assert 30.0 not in wd.times, \
        "warmup duration must be excluded from the rolling window"
    assert wd.record(0.5) is True, "5x the warm median must still flag"
    assert wd.slow_steps == 1


def test_checkpoints_pruned(tmp_path):
    cfg = get_smoke("linear-llama3-1b")
    run = RunConfig(num_microbatches=1, total_steps=20, warmup_steps=2,
                    remat="none")
    data = SyntheticLM(cfg.vocab_size, 32, 4, seed=0)
    _run(str(tmp_path / "c"), 20, cfg, run, data)
    from repro.checkpoint.manager import CheckpointManager
    mgr = CheckpointManager(str(tmp_path / "c"))
    assert len(mgr.all_steps()) <= 3
    assert mgr.latest_step() == 20
