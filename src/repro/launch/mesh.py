"""Mesh construction + canonical mesh-axis names.

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run sets ``XLA_FLAGS`` for 512 host devices before any jax
initialization; tests and benches see the default single device).

Axis naming is unified HERE and consumed everywhere else (sharding rules,
SP configs, the test batteries, benchmarks) — no other module may invent
axis names:

* ``DATA_AXIS`` ("data")      — data parallelism: batch sharding, gradient
  reduction, ZeRO-1 optimizer-state sharding; doubles as the FSDP axis on
  the production inference meshes.
* ``SEQ_AXIS`` ("sequence")   — LASP-2 sequence parallelism: every
  inter-chunk state exchange (the paper's single AllGather) runs over this
  axis and ONLY this axis.
* ``MODEL_AXIS`` ("model")    — tensor parallelism on the production
  inference meshes; on 3D training meshes it is the ulysses head-parallel
  axis (All-to-All repartition of attention heads) and additionally
  carries a share of the sequence for the linear layers.
* ``POD_AXIS`` ("pod")        — cross-pod data parallelism.
"""

from __future__ import annotations

import jax

DATA_AXIS = "data"
SEQ_AXIS = "sequence"
MODEL_AXIS = "model"
POD_AXIS = "pod"


def auto_axis_types(n: int):
    """``axis_types`` kwargs compatible with both old and new jax.

    ``jax.sharding.AxisType`` only exists from jax 0.5; older versions
    treat every axis as Auto already, so the kwarg is simply omitted.
    """
    if hasattr(jax.sharding, "AxisType"):
        return {"axis_types": (jax.sharding.AxisType.Auto,) * n}
    return {}


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 16×16 = 256 chips, (DATA_AXIS, MODEL_AXIS).
    Multi-pod: 2×16×16 = 512 chips, (POD_AXIS, DATA_AXIS, MODEL_AXIS)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = (POD_AXIS, DATA_AXIS, MODEL_AXIS) if multi_pod \
        else (DATA_AXIS, MODEL_AXIS)
    return jax.make_mesh(shape, axes, **auto_axis_types(len(axes)))


def make_training_mesh(dp_degree: int, sp_degree: int, tp_degree: int = 1,
                       *, devices=None):
    """The training deployment mesh.

    ``tp_degree == 1`` (default): the paper's 2D mesh (PAPER.md §4,
    Table 6) — batch over ``DATA_AXIS`` × sequence over ``SEQ_AXIS``;
    ``(1, W)`` is pure sequence parallelism, ``(W, 1)`` pure data
    parallelism. ``tp_degree > 1``: the 3D DP×SP×TP mesh
    ``(DATA_AXIS, SEQ_AXIS, MODEL_AXIS)`` — tokens shard over the
    combined (sequence, model) axes and the model axis additionally
    carries the ulysses head-parallel All-to-All
    (docs/parallelism.md §3D)."""
    devices = devices if devices is not None else jax.devices()
    if dp_degree * sp_degree * tp_degree != len(devices):
        raise ValueError(
            f"dp_degree×sp_degree×tp_degree = {dp_degree}×{sp_degree}×"
            f"{tp_degree} must equal the device count {len(devices)}")
    import numpy as np
    if tp_degree == 1:
        dev = np.asarray(devices).reshape(dp_degree, sp_degree)
        return jax.sharding.Mesh(dev, (DATA_AXIS, SEQ_AXIS))
    dev = np.asarray(devices).reshape(dp_degree, sp_degree, tp_degree)
    return jax.sharding.Mesh(dev, (DATA_AXIS, SEQ_AXIS, MODEL_AXIS))


def make_sp_mesh(sp_degree: int, *, devices=None):
    """1-D pure-SP mesh over ``SEQ_AXIS`` (the SP test batteries and
    benchmarks)."""
    devices = devices if devices is not None else jax.devices()
    if sp_degree > len(devices):
        raise ValueError(
            f"sp_degree {sp_degree} exceeds {len(devices)} devices")
    import numpy as np
    return jax.sharding.Mesh(np.asarray(devices[:sp_degree]), (SEQ_AXIS,))


def make_test_mesh(shape=(2, 4), axes=(DATA_AXIS, SEQ_AXIS)):
    """Small mesh for in-repo distributed tests (8 host devices).

    Defaults to the 2D DP×SP training mesh; the TP batteries pass
    ``axes=(DATA_AXIS, MODEL_AXIS)`` explicitly."""
    return jax.make_mesh(shape, axes, **auto_axis_types(len(axes)))
