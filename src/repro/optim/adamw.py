"""AdamW + cosine schedule, pure JAX (no optax in this container).

Paper hyperparameters (§4.1): Adam β1=0.9, β2=0.95, weight decay 0.1,
grad clip 1.0, cosine schedule with linear warmup to min_lr=1e-6.

ZeRO-1 note: with FSDP parameter sharding over the "data" axis, the m/v
moments inherit the parameter shardings, which *is* optimizer-state
sharding — no separate machinery needed.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdamState(NamedTuple):
    m: object
    v: object
    count: jax.Array


def init(params) -> AdamState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamState(m=jax.tree.map(zeros, params),
                     v=jax.tree.map(zeros, params),
                     count=jnp.zeros((), jnp.int32))


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def clip_by_global_norm(grads, max_norm):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), norm


def _decayable(path) -> bool:
    """Weight decay applies to matrices, not norms/biases/scalars."""
    name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
    return name not in ("scale", "bias", "bq", "bk", "bv", "gate",
                        "dt_bias", "a_log", "d_skip")


def update(grads, state: AdamState, params, *, lr, b1=0.9, b2=0.95,
           eps=1e-8, weight_decay=0.1):
    count = state.count + 1
    cf = count.astype(jnp.float32)
    bc1 = 1.0 - b1 ** cf
    bc2 = 1.0 - b2 ** cf

    def upd(path, p, g, m, v):
        gf = g.astype(jnp.float32)
        m_ = b1 * m + (1 - b1) * gf
        v_ = b2 * v + (1 - b2) * gf * gf
        step_ = (m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps)
        if weight_decay and _decayable(path):
            step_ = step_ + weight_decay * p.astype(jnp.float32)
        p_ = p.astype(jnp.float32) - lr * step_
        return p_.astype(p.dtype), m_, v_

    flat = jax.tree_util.tree_map_with_path(upd, params, grads,
                                            state.m, state.v)
    new_params = jax.tree.map(lambda t: t[0], flat,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], flat,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t: t[2], flat,
                         is_leaf=lambda x: isinstance(x, tuple))
    return new_params, AdamState(new_m, new_v, count)


def cosine_schedule(step, *, base_lr, warmup_steps, total_steps,
                    min_lr=1e-6):
    sf = step.astype(jnp.float32) if hasattr(step, "astype") \
        else jnp.float32(step)
    warm = base_lr * sf / jnp.maximum(warmup_steps, 1)
    prog = jnp.clip((sf - warmup_steps)
                    / jnp.maximum(total_steps - warmup_steps, 1), 0.0, 1.0)
    cos = min_lr + 0.5 * (base_lr - min_lr) * (1 + jnp.cos(jnp.pi * prog))
    return jnp.where(sf < warmup_steps, warm, cos)
