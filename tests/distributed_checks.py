"""Distributed-correctness battery, run on 8 virtual host devices.

Invoked by tests/test_distributed.py in a subprocess (so the main pytest
process keeps its single default device — the dry-run is the only place
with 512). Each check compares a sharded computation against its
single-device oracle. Exits non-zero on the first failure.

Mesh axis names come from ``repro.launch.mesh`` (the single source of
truth): the SP batteries shard over ``SEQ_AXIS``, the DP×SP(×TP)
battery runs on a ``(DATA_AXIS, SEQ_AXIS[, MODEL_AXIS])`` mesh.
``REPRO_TEST_MESH=AxB`` or ``AxBxC`` (dp×sp[×tp], default ``2x4``)
picks that battery's mesh split — the CI matrix sweeps
``8x1 | 4x2 | 2x4 | 2x2x2``.
"""

import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import sys  # noqa: E402

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax                     # noqa: E402
import jax.numpy as jnp        # noqa: E402
import numpy as np             # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro.core.compat import shard_map as _shard_map       # noqa: E402

from repro.core import linear_attention as la               # noqa: E402
from repro.core.baselines import (lasp1, megatron_sp_attention,  # noqa: E402
                                  ring_attention)
from repro.core.lasp2 import SPConfig, lasp2, lasp2_with_state  # noqa: E402
from repro.core.lasp2h import (allgather_context_attention,  # noqa: E402
                               sharded_decode_attention)
from repro.launch.mesh import (DATA_AXIS, MODEL_AXIS, POD_AXIS,  # noqa: E402
                               SEQ_AXIS, make_sp_mesh, make_test_mesh,
                               make_training_mesh)

PASSED = []
SKIPPED = []
# REPRO_2D_ONLY=1: run only the mesh-split-dependent 2D DP×SP section —
# the CI matrix legs other than the default split set this so the
# mesh-independent checks (identical on every leg) run exactly once.
_2D_ONLY = os.environ.get("REPRO_2D_ONLY") == "1"


def check(name, section="base"):
    def deco(fn):
        if _2D_ONLY and section != "2d":
            SKIPPED.append(name)
            return
        fn()
        PASSED.append(name)
        print(f"  ✓ {name}", flush=True)
    return deco


def _env_mesh():
    """(dp, sp, tp) split of the mesh battery, from
    ``REPRO_TEST_MESH=AxB`` (tp defaults to 1) or ``AxBxC``."""
    raw = os.environ.get("REPRO_TEST_MESH", "2x4")
    parts = [int(x) for x in raw.lower().split("x")]
    if len(parts) == 2:
        parts.append(1)
    if len(parts) != 3 or parts[0] * parts[1] * parts[2] != 8:
        raise SystemExit(
            f"REPRO_TEST_MESH={raw!r} must be AxB or AxBxC multiplying to 8")
    return tuple(parts)


mesh1d = make_sp_mesh(8)
sp = SPConfig(mesh=mesh1d, sp_axis=SEQ_AXIS)
key = jax.random.PRNGKey(1)
B, H, S, dk, dv = 2, 4, 512, 32, 64
ks = jax.random.split(key, 4)
q = jax.random.normal(ks[0], (B, H, S, dk)) * 0.3
k = jax.random.normal(ks[1], (B, H, S, dk)) * 0.3
v = jax.random.normal(ks[2], (B, H, S, dv)) * 0.5
log_a = -jnp.abs(jax.random.normal(ks[3], (B, H, S))) * 0.03


@check("lasp2 forward parity (decay + no-decay, both backwards)")
def _():
    for la_in in (jnp.zeros((B, H, S)), log_a):
        ref = la.sequential_oracle(q, k, v, la_in)
        for bwd in ("faithful", "autodiff"):
            o = jax.jit(lambda a, b, c, d, bwd=bwd: lasp2(
                a, b, c, d, sp=sp, backward=bwd))(q, k, v, la_in)
            np.testing.assert_allclose(o, ref.o, rtol=3e-4, atol=3e-4)


@check("lasp2 custom_vjp (Alg.3/4) grads == autodiff == oracle")
def _():
    def gradf(fn):
        return jax.jit(jax.grad(
            lambda q_, k_, v_: jnp.sum(jnp.sin(fn(q_, k_, v_))),
            argnums=(0, 1, 2)))
    g_or = gradf(lambda a, b, c: la.sequential_oracle(a, b, c, log_a).o)(
        q, k, v)
    g_f = gradf(lambda a, b, c: lasp2(a, b, c, log_a, sp=sp,
                                      backward="faithful"))(q, k, v)
    g_a = gradf(lambda a, b, c: lasp2(a, b, c, log_a, sp=sp,
                                      backward="autodiff"))(q, k, v)
    for go, gf, ga in zip(g_or, g_f, g_a):
        np.testing.assert_allclose(gf, go, rtol=1e-3, atol=1e-3)
        np.testing.assert_allclose(ga, go, rtol=1e-3, atol=1e-3)


@check("lasp2 data-dependent decay gradient (autodiff path)")
def _():
    g1 = jax.jit(jax.grad(lambda a: jnp.sum(jnp.sin(
        lasp2(q, k, v, a, sp=sp, backward="autodiff")))))(log_a)
    g2 = jax.jit(jax.grad(lambda a: jnp.sum(jnp.sin(
        la.sequential_oracle(q, k, v, a).o))))(log_a)
    np.testing.assert_allclose(g1, g2, rtol=2e-3, atol=2e-3)


@check("lasp2 bidirectional (Alg.1/3) fwd+bwd vs oracle")
def _():
    ref = la.sequential_oracle(q, k, v, None, causal=False)
    o = jax.jit(lambda a, b, c: lasp2(a, b, c, sp=sp, causal=False))(q, k, v)
    np.testing.assert_allclose(o, ref.o, rtol=3e-4, atol=3e-4)
    gn = jax.jit(jax.grad(lambda a, b, c: jnp.sum(jnp.sin(
        lasp2(a, b, c, sp=sp, causal=False))), argnums=(0, 1, 2)))(q, k, v)
    go = jax.jit(jax.grad(lambda a, b, c: jnp.sum(jnp.sin(
        la.sequential_oracle(a, b, c, None, causal=False).o)),
        argnums=(0, 1, 2)))(q, k, v)
    for a_, b_ in zip(gn, go):
        np.testing.assert_allclose(a_, b_, rtol=1e-3, atol=1e-3)


@check("lasp2_with_state: SP prefill state == oracle final state")
def _():
    ref = la.sequential_oracle(q, k, v, log_a)
    o, st = jax.jit(lambda a, b, c, d: lasp2_with_state(
        a, b, c, d, sp=sp))(q, k, v, log_a)
    np.testing.assert_allclose(o, ref.o, rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(st, ref.state, rtol=3e-4, atol=3e-4)


@check("LASP-1 ring (Alg.5/6) == LASP-2 == oracle")
def _():
    ref = la.sequential_oracle(q, k, v, log_a)
    o = jax.jit(lambda a, b, c, d: lasp1(a, b, c, d, sp=sp))(q, k, v, log_a)
    np.testing.assert_allclose(o, ref.o, rtol=3e-4, atol=3e-4)


@check("lasp2 exactly ONE fwd AllGather of the packed (M_t, A_t)")
def _():
    import re
    txt = jax.jit(lambda a, b, c, d: lasp2(a, b, c, d, sp=sp)).lower(
        q, k, v, log_a).compile().as_text()
    ags = [l for l in txt.splitlines() if re.search(r"all-gather\(", l)]
    sizes = sorted(
        int(np.prod([int(x) for x in re.search(
            r"\[([\d,]+)\]", l).group(1).split(",")])) for l in ags)
    assert len(ags) == 1, f"expected 1 all-gather, got {len(ags)}"
    # the (W, B, H, dk*dv + 1) packed state-and-decay gather
    assert sizes[-1] == 8 * B * H * (dk * dv + 1)
    assert not re.search(r"all-to-all\(|collective-permute\(", txt)


@check("lasp2 kernel_backend=interpret: Pallas intra-chunk under shard_map")
def _():
    """The interpret-mode kernel-grad battery: the Pallas chunk kernel's
    custom_vjp runs INSIDE the SP shard_map — forward parity, faithful
    grads (pulling dO and dM through the kernel), data-dependent decay
    grads via autodiff, and the untouched collective budget (exactly one
    packed forward all-gather per layer)."""
    import re
    spk = SPConfig(mesh=mesh1d, sp_axis=SEQ_AXIS,
                   kernel_backend="interpret")
    ref = la.sequential_oracle(q, k, v, log_a)
    o = jax.jit(lambda a, b, c, d: lasp2(a, b, c, d, sp=spk,
                                         backward="faithful"))(q, k, v, log_a)
    np.testing.assert_allclose(o, ref.o, rtol=3e-4, atol=3e-4)
    g_f = jax.jit(jax.grad(lambda a, b, c: jnp.sum(jnp.sin(
        lasp2(a, b, c, log_a, sp=spk, backward="faithful"))),
        argnums=(0, 1, 2)))(q, k, v)
    g_o = jax.jit(jax.grad(lambda a, b, c: jnp.sum(jnp.sin(
        la.sequential_oracle(a, b, c, log_a).o)),
        argnums=(0, 1, 2)))(q, k, v)
    for gf, go in zip(g_f, g_o):
        np.testing.assert_allclose(gf, go, rtol=1e-3, atol=1e-3)
    ga = jax.jit(jax.grad(lambda a: jnp.sum(jnp.sin(
        lasp2(q, k, v, a, sp=spk, backward="autodiff")))))(log_a)
    gr = jax.jit(jax.grad(lambda a: jnp.sum(jnp.sin(
        la.sequential_oracle(q, k, v, a).o))))(log_a)
    np.testing.assert_allclose(ga, gr, rtol=2e-3, atol=2e-3)
    txt = jax.jit(lambda a, b, c, d: lasp2(a, b, c, d, sp=spk)).lower(
        q, k, v, log_a).compile().as_text()
    n_ag = len(re.findall(r"all-gather\(", txt))
    assert n_ag == 1, f"expected 1 fwd all-gather, got {n_ag}"
    assert not re.search(r"all-to-all\(|collective-permute\(", txt)


@check("LASP-1 emits W-1 sequential permute steps (ring), LASP-2 none")
def _():
    import re
    txt = jax.jit(lambda a, b, c, d: lasp1(a, b, c, d, sp=sp)).lower(
        q, k, v, log_a).compile().as_text()
    n = len(re.findall(r"collective-permute\(", txt))
    assert n == 7, f"ring should unroll to W-1=7 ppermutes, got {n}"
    assert not re.search(r"all-gather\(", txt)


# --- softmax side (LASP-2H) -------------------------------------------------

Hq, Hkv, dh = 8, 2, 32
qs = jax.random.normal(ks[0], (B, Hq, S, dh)) * 0.5
ks_ = jax.random.normal(ks[1], (B, Hkv, S, dh)) * 0.5
vs = jax.random.normal(ks[2], (B, Hkv, S, dh)) * 0.5


@check("LASP-2H AllGather-CP (Alg.7) == full attention (+grads)")
def _():
    ref = allgather_context_attention(qs, ks_, vs, sp=None)
    o = jax.jit(lambda a, b, c: allgather_context_attention(
        a, b, c, sp=sp))(qs, ks_, vs)
    np.testing.assert_allclose(o, ref, rtol=2e-4, atol=2e-4)
    g1 = jax.jit(jax.grad(lambda a: jnp.sum(jnp.sin(
        allgather_context_attention(a, ks_, vs, sp=sp)))))(qs)
    g0 = jax.jit(jax.grad(lambda a: jnp.sum(jnp.sin(
        allgather_context_attention(a, ks_, vs, sp=None)))))(qs)
    np.testing.assert_allclose(g1, g0, rtol=1e-3, atol=1e-3)


@check("LASP-2H trains through the flash kernel (interpret) in shard_map")
def _():
    """The sharded hybrid path dispatches through ops.flash_attention_op:
    the Pallas flash custom_vjp runs INSIDE the SP shard_map with the
    rank offset t·C as a traced q_offset — forward parity, grads, and
    the unchanged 2-gather (K, V) collective budget."""
    import re
    spk = SPConfig(mesh=mesh1d, sp_axis=SEQ_AXIS,
                   kernel_backend="interpret")
    for window in (None, 64):
        ref = allgather_context_attention(qs, ks_, vs, sp=None,
                                          sliding_window=window)
        o = jax.jit(lambda a, b, c, w=window: allgather_context_attention(
            a, b, c, sp=spk, sliding_window=w))(qs, ks_, vs)
        np.testing.assert_allclose(o, ref, rtol=2e-4, atol=2e-4)
    g1 = jax.jit(jax.grad(lambda a, b, c: jnp.sum(jnp.sin(
        allgather_context_attention(a, b, c, sp=spk))),
        argnums=(0, 1, 2)))(qs, ks_, vs)
    g0 = jax.jit(jax.grad(lambda a, b, c: jnp.sum(jnp.sin(
        allgather_context_attention(a, b, c, sp=None))),
        argnums=(0, 1, 2)))(qs, ks_, vs)
    for a_, b_ in zip(g1, g0):
        np.testing.assert_allclose(a_, b_, rtol=1e-3, atol=1e-3)
    txt = jax.jit(lambda a, b, c: allgather_context_attention(
        a, b, c, sp=spk)).lower(qs, ks_, vs).compile().as_text()
    n_ag = len(re.findall(r"all-gather\(", txt))
    assert n_ag == 2, f"expected the K and V gathers only, got {n_ag}"


@check("comm_dtype=bf16: same collectives, half the bytes, output parity")
def _():
    """The bf16 wire knob: collective *counts* are unchanged (1 packed
    state gather for LASP-2; K+V gathers for LASP-2H) while the
    CommRecord bytes halve — asserted via the dtype-aware budget — and
    outputs stay within bf16 payload tolerance of the fp32 exchange."""
    from repro.comm import tape, tape_summary
    from repro.comm.budget import (assert_budget, lasp2_budget,
                                   packed_state_bytes)
    sp_bf = SPConfig(mesh=mesh1d, sp_axis=SEQ_AXIS, comm_dtype="bf16")
    ref = la.sequential_oracle(q, k, v, log_a)
    o = jax.jit(lambda a, b, c, d: lasp2(a, b, c, d, sp=sp_bf))(
        q, k, v, log_a)
    np.testing.assert_allclose(o, ref.o, rtol=3e-2, atol=3e-2)
    with tape() as recs:
        txt = jax.jit(lambda a, b, c, d: lasp2(
            a, b, c, d, sp=sp_bf)).lower(q, k, v, log_a).compile().as_text()
    sb = packed_state_bytes(B, H, dk, dv, "bf16")
    assert sb == packed_state_bytes(B, H, dk, dv, "fp32") // 2
    # count from compiled HLO; byte ceiling from the dtype-true tape
    # (XLA-CPU float-normalization upcasts bf16 collectives in HLO)
    assert_budget(txt, lasp2_budget("allgather", 8, state_bytes=sb), 8,
                  records=recs)
    assert tape_summary(recs)["total_bytes"] == 7 * sb
    # LASP-2H K/V gathers in bf16: half the KV bytes, parity holds
    sph = SPConfig(mesh=mesh1d, sp_axis=SEQ_AXIS, comm_dtype="bf16")
    refh = allgather_context_attention(qs, ks_, vs, sp=None)
    with tape() as recs:
        oh = jax.jit(lambda a, b, c: allgather_context_attention(
            a, b, c, sp=sph))(qs, ks_, vs)
    np.testing.assert_allclose(oh, refh, rtol=2e-2, atol=2e-2)
    s = tape_summary(recs)
    kv_payload = B * Hkv * (S // 8) * dh * 2
    assert s["all-gather_count"] == 2
    assert s["total_bytes"] == 2 * 7 * kv_payload
    # the knob only ever NARROWS: bf16 activations under the default
    # comm_dtype="fp32" keep their native bf16-sized K/V gather
    # (widening would double the bytes the knob exists to halve)
    sp32 = SPConfig(mesh=mesh1d, sp_axis=SEQ_AXIS)
    qb, kb, vb = (x.astype(jnp.bfloat16) for x in (qs, ks_, vs))
    with tape() as recs:
        jax.jit(lambda a, b, c: allgather_context_attention(
            a, b, c, sp=sp32)).lower(qb, kb, vb)
    assert tape_summary(recs)["total_bytes"] == 2 * 7 * kv_payload


@check("Ring Attention == Megatron-SP == full attention")
def _():
    ref = allgather_context_attention(qs, ks_, vs, sp=None)
    o1 = jax.jit(lambda a, b, c: ring_attention(a, b, c, sp=sp))(qs, ks_, vs)
    o2 = jax.jit(lambda a, b, c: megatron_sp_attention(
        a, b, c, sp=sp))(qs, ks_, vs)
    np.testing.assert_allclose(o1, ref, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(o2, ref, rtol=2e-4, atol=2e-4)


@check("sliding-window CP == sliding-window reference")
def _():
    for causal in (True, False):
        ref = allgather_context_attention(qs, ks_, vs, sp=None,
                                          causal=causal, sliding_window=64)
        o = jax.jit(lambda a, b, c, ca=causal: allgather_context_attention(
            a, b, c, sp=sp, causal=ca, sliding_window=64))(qs, ks_, vs)
        np.testing.assert_allclose(o, ref, rtol=2e-4, atol=2e-4)


@check("flash-decoding sharded decode == local decode (3 cache lens)")
def _():
    Sc = 512
    kc = jax.random.normal(ks[0], (B, Hkv, Sc, dh)) * 0.5
    vc = jax.random.normal(ks[1], (B, Hkv, Sc, dh)) * 0.5
    q1 = jax.random.normal(ks[2], (B, Hq, 1, dh)) * 0.5
    for clen in (Sc, 300, 37):
        ref = sharded_decode_attention(q1, kc, vc, clen, sp=None)
        o = jax.jit(lambda a, b, c, cl=clen: sharded_decode_attention(
            a, b, c, cl, sp=sp))(q1, kc, vc)
        np.testing.assert_allclose(o, ref, rtol=2e-4, atol=2e-4)


# --- model-level on a 2D mesh ----------------------------------------------

@check("sharded model forward == single-device forward (dense+SP)")
def _():
    from repro.configs import get_smoke
    from repro.models import model as M
    from repro.sharding.rules import make_plan

    mesh = make_test_mesh((4, 2), (DATA_AXIS, MODEL_AXIS))
    cfg = get_smoke("starcoder2-15b")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 64), 0,
                                cfg.vocab_size)
    ref, _ = jax.jit(lambda p, t: M.forward(p, t, cfg, remat="none"))(
        params, tokens)
    plan = make_plan(mesh, "prefill", global_batch=2,
                     n_kv_heads=cfg.n_kv_heads)
    out, _ = jax.jit(lambda p, t: M.forward(p, t, cfg, plan,
                                            remat="none"))(params, tokens)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=2e-2, atol=2e-2)


@check("sharded train step == single-device train step (loss match)")
def _():
    from repro.configs import get_smoke
    from repro.configs.base import RunConfig
    from repro.data.pipeline import SyntheticLM
    from repro.sharding.rules import make_plan
    from repro.train.step import init_state, make_train_step

    cfg = get_smoke("linear-llama3-1b")
    run = RunConfig(num_microbatches=2, remat="none", total_steps=10)
    data = SyntheticLM(cfg.vocab_size, 64, 8, seed=3)
    batch = data.microbatched(0, 2)

    s0 = init_state(jax.random.PRNGKey(0), cfg, run)
    from repro.sharding.rules import local_plan
    _, m_ref = jax.jit(make_train_step(cfg, run, local_plan()))(s0, batch)

    mesh = make_test_mesh((4, 2), (DATA_AXIS, MODEL_AXIS))
    plan = make_plan(mesh, "train", global_batch=8,
                     n_kv_heads=cfg.n_kv_heads)
    s1 = init_state(jax.random.PRNGKey(0), cfg, run)
    _, m_sh = jax.jit(make_train_step(cfg, run, plan))(s1, batch)
    np.testing.assert_allclose(float(m_sh["loss"]), float(m_ref["loss"]),
                               rtol=2e-3, atol=2e-3)


@check("int8 error-feedback cross-pod grad sync ~= exact mean")
def _():
    from repro.optim.compression import compress_sync_tree
    mesh = make_test_mesh((2, 4), (POD_AXIS, DATA_AXIS))
    gs = jax.random.normal(ks[0], (2, 64, 64)) * 1e-3   # per-pod grads
    e0 = jnp.zeros((2, 64, 64))

    def body(g_, e_):
        s, e = compress_sync_tree(g_[0], e_[0], pod_axis=POD_AXIS)
        return s, e[None]

    synced, err = jax.jit(_shard_map(
        body, mesh=mesh, in_specs=(P(POD_AXIS), P(POD_AXIS)),
        out_specs=(P(), P(POD_AXIS)), axis_names={POD_AXIS},
        check_vma=False))(gs, e0)
    exact = jnp.mean(gs, axis=0)
    rel = float(jnp.max(jnp.abs(synced - exact))
                / (jnp.max(jnp.abs(exact)) + 1e-12))
    assert rel < 0.02, f"compression error too large: {rel}"
    # exactness identity: mean(g) == synced + mean(error feedback)
    np.testing.assert_allclose(np.asarray(synced + jnp.mean(err, 0)),
                               np.asarray(exact), rtol=1e-5, atol=1e-8)


@check("mini dry-run: lower+compile a smoke train cell on the 4x2 mesh")
def _():
    from repro.configs import get_smoke
    from repro.launch.cells import build_cell
    mesh = make_test_mesh((4, 2), (DATA_AXIS, MODEL_AXIS))
    cell = build_cell("hymba-1.5b", "train_4k", mesh,
                      cfg_override=get_smoke("hymba-1.5b"))
    compiled = cell.lower().compile()
    assert compiled.memory_analysis() is not None
    from repro.core.compat import cost_analysis
    assert cost_analysis(compiled).get("flops", 0) > 0


@check("hybrid (LASP-2H) train step == flash custom_vjp == xla backend")
def _():
    """Model-level proof of the Pallas hybrid hot path: a 2-layer
    linear+softmax hybrid trains on a (1, 8) SP mesh with
    kernel_backend="interpret" — every softmax layer runs the flash
    custom_vjp inside the manual train-step shard_map with the traced
    rank offset — and its 2-step losses match the xla backend and the
    single-device oracle."""
    from repro.configs.base import (LayerSpec, LinearAttnConfig,
                                    ModelConfig, RunConfig)
    from repro.data.pipeline import SyntheticLM
    from repro.sharding.rules import local_plan, make_plan
    from repro.train.step import init_state, make_train_step

    cfg = ModelConfig(
        name="hybrid-smoke", family="hybrid", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, d_ff=160, vocab_size=512,
        pattern=(LayerSpec(mixer="linear"), LayerSpec(mixer="softmax")),
        linear_attn=LinearAttnConfig(feature_map="identity", decay="none"))
    run = RunConfig(num_microbatches=1, remat="none", total_steps=10,
                    warmup_steps=2, learning_rate=1e-3)
    data = SyntheticLM(cfg.vocab_size, 64, 8, seed=5)

    def losses(backend, sharded):
        if sharded:
            plan = make_plan(make_training_mesh(1, 8), "train",
                             global_batch=8, n_kv_heads=cfg.n_kv_heads,
                             backend=backend)
        else:
            plan = local_plan(backend)
        state = init_state(jax.random.PRNGKey(0), cfg, run, plan)
        step = jax.jit(make_train_step(cfg, run, plan))
        out = []
        for i in range(2):
            state, m = step(state, data.microbatched(i, 1))
            out.append(float(m["loss"]))
        return out

    l_int = losses("interpret", sharded=True)
    l_xla = losses("xla", sharded=True)
    l_ref = losses(None, sharded=False)
    assert all(np.isfinite(l_int)), l_int
    np.testing.assert_allclose(l_int, l_xla, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(l_int, l_ref, rtol=2e-3, atol=2e-3)


# --- DP×SP(×TP) training (data × sequence × model mesh) ---------------------

from repro.comm.spec import CommSpec                         # noqa: E402
from repro.configs import get_smoke                          # noqa: E402
from repro.configs.base import RunConfig                     # noqa: E402
from repro.data.pipeline import SyntheticLM                  # noqa: E402
from repro.sharding.rules import local_plan, make_plan       # noqa: E402
from repro.train.step import init_state, make_train_step     # noqa: E402

DP, SP, TP = _env_mesh()
_TAG = f"({DP},{SP})" if TP == 1 else f"({DP},{SP},{TP})"
_cfg2d = get_smoke("linear-llama3-1b")
_data2d = SyntheticLM(_cfg2d.vocab_size, 64, 8, seed=3)


def _run_steps(dp, sp_deg, run, n_steps=3, zero1=True, comm_dtype="fp32",
               tp=1):
    """Train ``n_steps`` on a (dp, sp[, tp]) mesh; (1, 1) = single device."""
    if (dp, sp_deg, tp) == (1, 1, 1):
        plan = local_plan()
        mesh = None
    else:
        mesh = make_training_mesh(dp, sp_deg, tp)
        plan = make_plan(mesh, "train", global_batch=8,
                         n_kv_heads=_cfg2d.n_kv_heads,
                         n_heads=_cfg2d.n_heads, zero1=zero1,
                         comm=CommSpec(dtype=comm_dtype))
    state = init_state(jax.random.PRNGKey(0), _cfg2d, run, plan)
    step = jax.jit(make_train_step(_cfg2d, run, plan))
    losses = []
    for i in range(n_steps):
        state, m = step(state, _data2d.microbatched(i, run.num_microbatches))
        losses.append(float(m["loss"]))
    return state, losses


# microbatch rows (8 / A) must divide dp — dp=8 forces A=1
_A2D = 2 if (8 // 2) % DP == 0 else 1
_RUN2D = RunConfig(num_microbatches=_A2D, remat="none", total_steps=10,
                   warmup_steps=2, learning_rate=1e-3)


@check(f"{_TAG} DP×SP(×TP) == (1,8) SP-only == single device (3-step loss)", section="2d")
def _():
    _, l_ref = _run_steps(1, 1, _RUN2D)
    _, l_sp = _run_steps(1, 8, _RUN2D)
    _, l_2d = _run_steps(DP, SP, _RUN2D, tp=TP)
    # same global batch, same math — only the reduction grouping differs
    np.testing.assert_allclose(l_2d, l_sp, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(l_2d, l_ref, rtol=2e-3, atol=2e-3)


@check(f"--comm-dtype bf16 loss trajectory ~= fp32 on {_TAG}", section="2d")
def _():
    """Training with bf16 exchange payloads tracks the fp32-wire loss:
    the wire dtype only rounds the state gathers (combines stay fp32),
    so a 3-step trajectory stays within bf16 payload tolerance — the
    sanity check behind shipping --comm-dtype bf16 as a perf knob."""
    _, l_fp32 = _run_steps(DP, SP, _RUN2D, tp=TP)
    _, l_bf16 = _run_steps(DP, SP, _RUN2D, comm_dtype="bf16", tp=TP)
    np.testing.assert_allclose(l_bf16, l_fp32, rtol=2e-2, atol=2e-2)
    if SP * TP == 1:
        # no sequence sharding → no SP exchange → bit-identical
        np.testing.assert_allclose(l_bf16, l_fp32, rtol=0, atol=0)


@check(f"ZeRO-1 sharded AdamW == replicated AdamW on {_TAG}", section="2d")
def _():
    s_z, l_z = _run_steps(DP, SP, _RUN2D, n_steps=2, zero1=True, tp=TP)
    s_r, l_r = _run_steps(DP, SP, _RUN2D, n_steps=2, zero1=False, tp=TP)
    np.testing.assert_allclose(l_z, l_r, rtol=1e-6, atol=1e-6)
    for a, b in zip(jax.tree.leaves(s_z["params"]),
                    jax.tree.leaves(s_r["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-6, atol=1e-7)
    if DP * TP > 1:
        from repro.optim.adamw import Zero1AdamState
        assert isinstance(s_z["opt"], Zero1AdamState)


@check(f"non-finite step skipped on {_TAG}: params+opt.count frozen", section="2d")
def _():
    mesh = make_training_mesh(DP, SP, TP)
    plan = make_plan(mesh, "train", global_batch=8,
                     n_kv_heads=_cfg2d.n_kv_heads,
                     n_heads=_cfg2d.n_heads)
    state = init_state(jax.random.PRNGKey(0), _cfg2d, _RUN2D, plan)
    step = jax.jit(make_train_step(_cfg2d, _RUN2D, plan))
    state["params"]["embed"]["table"] = \
        state["params"]["embed"]["table"].at[0, 0].set(jnp.nan)
    before = np.asarray(state["params"]["embed"]["table"])
    new_state, metrics = step(state, _data2d.microbatched(0, _A2D))
    assert float(metrics["skipped"]) == 1.0
    np.testing.assert_array_equal(
        before[1:], np.asarray(new_state["params"]["embed"]["table"])[1:])
    assert int(new_state["opt"].count) == 0, \
        "skipped step must not advance the Adam step count"


@check(f"{_TAG} step HLO: per-axis collective budget holds exactly", section="2d")
def _():
    from repro.comm.budget import (assert_axis_budget,
                                   train_step_axis_budget)
    run = RunConfig(num_microbatches=1, remat="none", total_steps=10,
                    warmup_steps=2, scan_unroll=True)
    mesh = make_training_mesh(DP, SP, TP)
    plan = make_plan(mesh, "train", global_batch=8,
                     n_kv_heads=_cfg2d.n_kv_heads,
                     n_heads=_cfg2d.n_heads)
    state = init_state(jax.random.PRNGKey(0), _cfg2d, run, plan)
    step = make_train_step(_cfg2d, run, plan)
    txt = jax.jit(step).lower(
        state, _data2d.microbatched(0, 1)).compile().as_text()
    # SyntheticLM packs documents → resets → the autodiff backward:
    # per layer 1 fwd all-gather + 1 bwd reduce-scatter, sequence-only;
    # 1 packed gradient all-reduce; 1 ZeRO-1 param all-gather over data.
    budget = train_step_axis_budget(
        mesh, n_sp_layers=_cfg2d.n_layers, microbatches=1,
        backward="autodiff", zero1=plan.zero1_axis is not None)
    assert_axis_budget(txt, mesh, budget)


@check(f"{_TAG} flight recorder: tape == expected bytes, drift flags",
       section="2d")
def _():
    """The compile-time flight recorder (docs/observability.md) on a
    REAL (DP,SP) train step: the CommRecord tape captured while lowering
    is the 'expected' collective view, the compiled HLO the 'measured'
    one. The snapshot's expected bytes must equal the tape total and the
    genuine program must not flag drift (autodiff's extra collectives
    are tolerated by design); an injected fake tape record must."""
    from repro.comm import tape
    from repro.comm.primitives import CommRecord, tape_summary
    from repro.obs import FlightRecorder, InMemorySink

    run = RunConfig(num_microbatches=1, remat="none", total_steps=10,
                    warmup_steps=2)
    mesh = make_training_mesh(DP, SP, TP)
    plan = make_plan(mesh, "train", global_batch=8,
                     n_kv_heads=_cfg2d.n_kv_heads,
                     n_heads=_cfg2d.n_heads)
    state = init_state(jax.random.PRNGKey(0), _cfg2d, run, plan)
    step = jax.jit(make_train_step(_cfg2d, run, plan))
    with tape() as records:
        lowered = step.lower(state, _data2d.microbatched(0, 1))
    hlo = lowered.compile().as_text()

    sink = InMemorySink()
    fr = FlightRecorder(sink)
    snap = fr.on_compile(records=records, hlo_text=hlo, total_devices=8)
    expect = tape_summary(records)
    assert snap.expected_bytes_per_step == expect["total_bytes"]
    assert snap.expected_steps_per_step == expect["total_steps"]
    if SP > 1:
        # sequence sharding ⇒ the layers' state gathers are on the tape
        assert snap.tape_counts.get("all-gather", 0) >= 1
        assert snap.hlo_counts.get("all-gather", 0) >= \
            snap.tape_counts["all-gather"]
    assert snap.drift == [], snap.drift
    (rec,) = sink.by_kind("compile")
    assert rec["expected_collective_bytes"] == expect["total_bytes"]

    # inject drift: a collective the compiled program does not carry
    bad = list(records) + [CommRecord("all-to-all", 10, 70, 1, 8)]
    snap2 = FlightRecorder(InMemorySink()).on_compile(
        records=bad, hlo_text=hlo, total_devices=8)
    assert any("all-to-all" in d for d in snap2.drift), \
        "injected tape record must flag drift"


@check(f"{_TAG} instrumented train: step records on the training mesh",
       section="2d")
def _():
    """train(sink=...) on the DP×SP mesh: the AOT-compiled instrumented
    path matches the uninstrumented losses and every step record carries
    the throughput + comm fields the report renders."""
    from repro.obs import InMemorySink
    from repro.train.loop import train

    mesh = make_training_mesh(DP, SP, TP)
    plan = make_plan(mesh, "train", global_batch=8,
                     n_kv_heads=_cfg2d.n_kv_heads,
                     n_heads=_cfg2d.n_heads)
    sink = InMemorySink()
    kw = dict(log_every=10 ** 9, log_fn=lambda *_: None, max_steps=2)
    _, hist = train(_cfg2d, _RUN2D, _data2d, plan=plan, sink=sink, **kw)
    _, ref = train(_cfg2d, _RUN2D, _data2d, plan=plan, **kw)
    np.testing.assert_allclose([h["loss"] for h in hist],
                               [h["loss"] for h in ref], rtol=0, atol=0)
    (comp,) = sink.by_kind("compile")
    assert comp["drift"] == []
    if SP > 1:
        assert comp["expected_collective_bytes"] > 0
    steps = sink.by_kind("step")
    assert len(steps) == 2
    for r in steps:
        assert {"step_s", "data_s", "wall_s", "tokens_per_s", "mfu",
                "expected_collective_bytes", "hlo_collective_bytes",
                "straggler"} <= set(r)
        assert r["tokens"] == 8 * 64


@check(f"{_TAG} compiled-program sanitizer: SAN201-205 clean",
       section="2d")
def _():
    """The static-analysis layer-2 invariants (docs/static_analysis.md)
    hold on this leg's mesh split: no host transfers, no f64, bf16 on
    the sequence-axis wire, donation aliased, deterministic lowering."""
    from repro.analysis.sanitizer import sanitize_train_step

    findings = sanitize_train_step(DP, SP, TP, comm_dtype="bf16")
    assert not findings, "\n".join(str(f) for f in findings)


@check(f"{_TAG} guard ON: axis budget unchanged, clean losses bitwise",
       section="2d")
def _():
    """The resilience tentpole invariant (docs/resilience.md): the
    numerical health guard adds ZERO collectives — the guarded step
    compiles to exactly the same per-axis collective budget as the
    unguarded one (the health scalar rides the packed gradient
    all-reduce) — and on clean steps the guarded loss trajectory is
    bit-identical to guard-off."""
    from repro.comm.budget import (assert_axis_budget,
                                   train_step_axis_budget)
    base = dict(num_microbatches=1, remat="none", total_steps=10,
                warmup_steps=2, scan_unroll=True)
    run_g = RunConfig(guard=True, **base)
    mesh = make_training_mesh(DP, SP, TP)
    plan = make_plan(mesh, "train", global_batch=8,
                     n_kv_heads=_cfg2d.n_kv_heads,
                     n_heads=_cfg2d.n_heads)
    state = init_state(jax.random.PRNGKey(0), _cfg2d, run_g, plan)
    txt = jax.jit(make_train_step(_cfg2d, run_g, plan)).lower(
        state, _data2d.microbatched(0, 1)).compile().as_text()
    budget = train_step_axis_budget(
        mesh, n_sp_layers=_cfg2d.n_layers, microbatches=1,
        backward="autodiff", zero1=plan.zero1_axis is not None)
    assert_axis_budget(txt, mesh, budget)   # same budget as guard-off

    _, l_plain = _run_steps(DP, SP, RunConfig(**base), tp=TP)
    _, l_guard = _run_steps(DP, SP, run_g, tp=TP)
    np.testing.assert_allclose(l_guard, l_plain, rtol=0, atol=0)


@check(f"{_TAG} SIGTERM mid-run → resume: bitwise trajectory parity",
       section="2d")
def _():
    """Preemption path end-to-end on the training mesh: SIGTERM delivered
    during step 3's data fetch → the loop finishes the step, saves, and
    exits; the resumed run (guard state restored from the checkpoint)
    recomputes steps 4..5 bitwise-identical to an uninterrupted run."""
    import tempfile

    from repro.resilience import chaos
    from repro.train.loop import train

    mesh = make_training_mesh(DP, SP, TP)
    plan = make_plan(mesh, "train", global_batch=8,
                     n_kv_heads=_cfg2d.n_kv_heads,
                     n_heads=_cfg2d.n_heads)
    run = RunConfig(num_microbatches=_A2D, remat="none", total_steps=6,
                    warmup_steps=2, learning_rate=1e-3, guard=True)
    kw = dict(log_every=10 ** 9, log_fn=lambda *_: None)
    _, ref = train(_cfg2d, run, _data2d, plan=plan, **kw)
    with tempfile.TemporaryDirectory() as td:
        data = chaos.InterruptData(_data2d, at_step=3)
        _, h1 = train(_cfg2d, run, data, plan=plan, ckpt_dir=td,
                      ckpt_every=2, **kw)
        assert [h["step"] for h in h1] == [0, 1, 2, 3]
        _, h2 = train(_cfg2d, run, _data2d, plan=plan, ckpt_dir=td,
                      ckpt_every=2, **kw)
        assert [h["step"] for h in h2] == [4, 5]
    np.testing.assert_allclose([h["loss"] for h in h1 + h2],
                               [h["loss"] for h in ref], rtol=0, atol=0)


@check(f"{_TAG} corrupt latest → fallback restore onto a different mesh",
       section="2d")
def _():
    """Checkpoint hardening across mesh shapes: after the latest
    checkpoint is corrupted on disk, ``restore_latest_valid`` falls back
    to the older verified step, and the path-matched {"params"} subtree
    device_puts onto a DIFFERENT mesh split (elastic resharding — params
    are saved as global host arrays, so any valid plan can load them)."""
    import tempfile

    from jax.sharding import NamedSharding

    from repro.checkpoint.manager import CheckpointManager
    from repro.resilience import chaos
    from repro.sharding.rules import param_specs
    from repro.train.loop import train

    mesh = make_training_mesh(DP, SP, TP)
    plan = make_plan(mesh, "train", global_batch=8,
                     n_kv_heads=_cfg2d.n_kv_heads,
                     n_heads=_cfg2d.n_heads)
    run = RunConfig(num_microbatches=1, remat="none", total_steps=4,
                    warmup_steps=2, learning_rate=1e-3, guard=True)
    with tempfile.TemporaryDirectory() as td:
        train(_cfg2d, run, _data2d, plan=plan, ckpt_dir=td, ckpt_every=2,
              log_every=10 ** 9, log_fn=lambda *_: None)
        mgr = CheckpointManager(td)
        assert mgr.latest_step() == 4
        zeros = {"params": jax.tree.map(
            jnp.zeros_like,
            init_state(jax.random.PRNGKey(0), _cfg2d, run)["params"])}
        oracle = mgr.restore(2, zeros)
        chaos.corrupt_checkpoint(td)            # corrupts latest (step 4)

        alt = (1, 8, 1) if (DP, SP, TP) == (8, 1, 1) else (8, 1, 1)
        mesh2 = make_training_mesh(*alt)
        plan2 = make_plan(mesh2, "train", global_batch=8,
                          n_kv_heads=_cfg2d.n_kv_heads,
                          n_heads=_cfg2d.n_heads)
        specs = param_specs(zeros["params"], plan2)
        shard = {"params": jax.tree.map(
            lambda x, s: NamedSharding(mesh2, s), zeros["params"], specs)}
        step, out, rejected = mgr.restore_latest_valid(zeros, shard)
    assert step == 2
    assert [s for s, _ in rejected] == [4]
    leaf = jax.tree.leaves(out["params"])[0]
    assert leaf.sharding.mesh.shape == mesh2.shape
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), out["params"], oracle["params"])


# --- 3D DP×SP×TP + ulysses head-parallel All-to-All (docs/parallelism.md) ---
# Fixed (1,4,2)/(2,2,2) meshes independent of the env split, so these run
# once (base section) on the default leg; the 2x2x2 CI leg re-runs the
# whole mesh-split-dependent section above on a real 3D mesh.

from repro.configs.base import (LayerSpec, LinearAttnConfig,  # noqa: E402
                                ModelConfig)

_cfg3d = ModelConfig(
    name="hybrid-smoke", family="hybrid", n_layers=2, d_model=64,
    n_heads=4, n_kv_heads=2, d_ff=160, vocab_size=512,
    pattern=(LayerSpec(mixer="linear"), LayerSpec(mixer="softmax")),
    linear_attn=LinearAttnConfig(feature_map="identity", decay="none"))
_data3d = SyntheticLM(_cfg3d.vocab_size, 64, 8, seed=5)
_RUN3D = RunConfig(num_microbatches=1, remat="none", total_steps=10,
                   warmup_steps=2, learning_rate=1e-3)


def _plan3d(dims, strategy="allgather"):
    mesh = make_training_mesh(*dims)
    return mesh, make_plan(mesh, "train", global_batch=8,
                           n_kv_heads=_cfg3d.n_kv_heads,
                           n_heads=_cfg3d.n_heads,
                           comm=CommSpec(strategy=strategy))


def _run_hybrid(dims, strategy="allgather", n_steps=3):
    if dims == (1, 1, 1):
        plan = local_plan()
    else:
        _, plan = _plan3d(dims, strategy)
    state = init_state(jax.random.PRNGKey(0), _cfg3d, _RUN3D, plan)
    step = jax.jit(make_train_step(_cfg3d, _RUN3D, plan))
    losses = []
    for i in range(n_steps):
        state, m = step(state, _data3d.microbatched(i, 1))
        losses.append(float(m["loss"]))
    return losses


@check("3D ulysses (1,4,2)/(2,2,2) == (1,8,1) allgather == single device")
def _():
    """The tentpole parity proof: the hybrid model trains identically
    whether the softmax layers reach full-sequence context by gathering
    K/V over the sequence axis (allgather CP) or by All-to-All head
    repartition over the model axis (ulysses), through autodiff, on
    every verified 3D split — and both match the single-device oracle."""
    l_ref = _run_hybrid((1, 1, 1))
    l_ag = _run_hybrid((1, 8, 1), "allgather")
    np.testing.assert_allclose(l_ag, l_ref, rtol=2e-3, atol=2e-3)
    for dims in ((1, 4, 2), (2, 2, 2)):
        l_u = _run_hybrid(dims, "ulysses")
        np.testing.assert_allclose(l_u, l_ag, rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(l_u, l_ref, rtol=2e-3, atol=2e-3)


@check("ulysses fwd HLO: exactly 2 model-axis All-to-Alls per hybrid layer")
def _():
    """Forward-only lowering of the hybrid model under the (1,4,2)
    ulysses plan: the one hybrid layer costs exactly two model-axis
    All-to-Alls (seq→head in, head→seq out) — no gathers or permutes
    ride along on the model axis."""
    from repro.launch.hlo_analysis import collective_axis_counts
    from repro.models import model as M

    mesh, plan = _plan3d((1, 4, 2), "ulysses")
    params = M.init_params(jax.random.PRNGKey(0), _cfg3d)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (8, 64), 0,
                                _cfg3d.vocab_size)

    def fwd(p, t):
        logits, _ = M.forward(p, t, _cfg3d, plan, remat="none")
        return logits

    txt = jax.jit(_shard_map(
        fwd, mesh=mesh, in_specs=(P(), P(None, (SEQ_AXIS, MODEL_AXIS))),
        out_specs=P(None, (SEQ_AXIS, MODEL_AXIS), None),
        axis_names=set(plan.manual_axes),
        check_vma=False)).lower(params, tokens).compile().as_text()
    counts = collective_axis_counts(txt, mesh)
    n_hybrid = sum(1 for s in _cfg3d.pattern if s.mixer == "softmax")
    assert counts.get(("all-to-all", (MODEL_AXIS,)), 0) == 2 * n_hybrid, \
        counts
    # model-ONLY traffic is the a2a pair and nothing else (the linear
    # layer's state gather spans the combined (sequence, model) token
    # axis — that is sequence-parallel traffic, not head-parallel)
    for (op, axes), n in counts.items():
        if axes == (MODEL_AXIS,) and op != "all-to-all":
            raise AssertionError(
                f"unexpected model-axis collective {op} x{n}: {counts}")


@check("3D ulysses step HLO: per-axis budget holds on (1,4,2) + (2,2,2)")
def _():
    """Full train-step per-axis ceiling on both CI-verified 3D splits:
    4 model-axis All-to-Alls per hybrid layer per step (2 fwd + 2 bwd
    from the mirrored custom_vjp pair), the linear layers' gathers on
    the combined (sequence, model) token axis, ZeRO-1 over
    (data, model) — nothing else."""
    from repro.comm.budget import (assert_axis_budget,
                                   train_step_axis_budget)
    from repro.launch.hlo_analysis import collective_axis_counts

    run = RunConfig(num_microbatches=1, remat="none", total_steps=10,
                    warmup_steps=2, scan_unroll=True)
    for dims in ((1, 4, 2), (2, 2, 2)):
        mesh, plan = _plan3d(dims, "ulysses")
        state = init_state(jax.random.PRNGKey(0), _cfg3d, run, plan)
        txt = jax.jit(make_train_step(_cfg3d, run, plan)).lower(
            state, _data3d.microbatched(0, 1)).compile().as_text()
        budget = train_step_axis_budget(
            mesh, n_sp_layers=1, n_hybrid_layers=1,
            comm_strategy="ulysses", microbatches=1,
            backward="autodiff", zero1=plan.zero1_axis is not None)
        assert_axis_budget(txt, mesh, budget)
        counts = collective_axis_counts(txt, mesh)
        assert counts.get(("all-to-all", (MODEL_AXIS,)), 0) == 4, \
            (dims, counts)


@check("ulysses hybrid wire bytes < allgather K/V bytes at tp=2 (tape)")
def _():
    """The reason ulysses exists: on the (2,2,2) split the hybrid
    layer's forward exchange (2 All-to-Alls + the residual 2-wide K/V
    sequence gathers) moves fewer wire bytes than gathering K/V across
    all 4 context ranks. Forward-only lowerings so both tapes cover the
    same legs (allgather's autodiff backward is JAX-generated, untaped).
    Holds at the smoke config's 2:1 GQA ratio — see
    docs/communication.md for where extreme GQA flips it."""
    from repro.comm import tape
    from repro.models import model as M

    params = M.init_params(jax.random.PRNGKey(0), _cfg3d)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (8, 64), 0,
                                _cfg3d.vocab_size)

    def hybrid_bytes(strategy, prefix):
        mesh, plan = _plan3d((2, 2, 2), strategy)

        def fwd(p, t):
            logits, _ = M.forward(p, t, _cfg3d, plan, remat="none")
            return logits

        with tape() as recs:
            jax.jit(_shard_map(
                fwd, mesh=mesh,
                in_specs=(P(), P(DATA_AXIS, (SEQ_AXIS, MODEL_AXIS))),
                out_specs=P(DATA_AXIS, (SEQ_AXIS, MODEL_AXIS), None),
                axis_names=set(plan.manual_axes),
                check_vma=False)).lower(params, tokens)
        return sum(r.traffic_bytes for r in recs
                   if r.tag.startswith(prefix))

    uly = hybrid_bytes("ulysses", "ulysses.")
    ag = hybrid_bytes("allgather", "lasp2h.")
    assert 0 < uly < ag, (uly, ag)


if __name__ == "__main__":
    extra = f" ({len(SKIPPED)} base checks skipped: 2D-only)" \
        if SKIPPED else ""
    print(f"ALL {len(PASSED)} DISTRIBUTED CHECKS PASSED{extra}")
