"""starcoder2-15b — GQA, RoPE [arXiv:2402.19173; hf]."""
from repro.configs.base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-15b", family="dense",
    n_layers=40, d_model=6144, n_heads=48, n_kv_heads=4,
    d_ff=24576, vocab_size=49152,
    rope_theta=100000.0, norm_eps=1e-5, mlp_act="gelu",
    pattern=(LayerSpec(mixer="softmax", mlp="dense"),),
    source="[arXiv:2402.19173; hf]",
)

SMOKE = ModelConfig(
    name="starcoder2-15b-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=160,
    vocab_size=512, rope_theta=100000.0,
    pattern=(LayerSpec(mixer="softmax", mlp="dense"),),
)
