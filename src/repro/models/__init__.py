from repro.models import model, blocks, layers  # noqa: F401
