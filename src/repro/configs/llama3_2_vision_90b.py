"""llama-3.2-vision-90b — cross-attn image layers
[hf:meta-llama/Llama-3.2-11B-Vision (arch); unverified].

100 layers = 20 x (4 self-attention + 1 image cross-attention). The vision
frontend is a stub: input_specs() provides (B, 1601, d_model) patch
embeddings (one 560px tile).
"""
from repro.configs.base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-90b", family="vlm",
    n_layers=100, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=28672, vocab_size=128256,
    rope_theta=500000.0, norm_eps=1e-5,
    pattern=(
        LayerSpec(mixer="softmax", mlp="dense"),
        LayerSpec(mixer="softmax", mlp="dense"),
        LayerSpec(mixer="softmax", mlp="dense"),
        LayerSpec(mixer="softmax", mlp="dense"),
        LayerSpec(mixer="cross", mlp="dense"),
    ),
    n_image_tokens=1601,
    source="[hf:meta-llama/Llama-3.2-11B-Vision; unverified]",
)

SMOKE = ModelConfig(
    name="llama-3.2-vision-90b-smoke", family="vlm",
    n_layers=5, d_model=64, n_heads=4, n_kv_heads=2, d_ff=160,
    vocab_size=512,
    pattern=(
        LayerSpec(mixer="softmax", mlp="dense"),
        LayerSpec(mixer="softmax", mlp="dense"),
        LayerSpec(mixer="softmax", mlp="dense"),
        LayerSpec(mixer="softmax", mlp="dense"),
        LayerSpec(mixer="cross", mlp="dense"),
    ),
    n_image_tokens=8,
)
