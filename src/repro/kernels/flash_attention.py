"""Pallas TPU kernels: blockwise online-softmax (flash) GQA attention, fwd+bwd.

Used by the standard-attention layers of hybrid models (LASP-2H's local
compute after the K/V AllGather — paper Alg. 7 line 7) and by prefill.

Forward grid = ``(B, Hq, nq, kv_band)``; the kv axis is the innermost
sequential axis; ``(m, l, acc)`` live in VMEM scratch and are reset when
the band index is 0. The per-row softmax statistics ``lse = m + log l``
are written out as a second output — the backward residuals of the
standard flash scheme (Dao 2023; Lightning Attention-2 keeps the same
tile loop resident on-chip for its backward, the pattern followed here).

Causal grid trimming: the kv grid axis is a *band*, not the full kv
extent — for each q block the index maps offset by that block's first
needed kv block (``sliding_window`` lower bound) and clamp to its last
needed one (causal diagonal / ``kv_len``), so blocks strictly above the
diagonal are never fetched from HBM: the band is sized to the widest
per-q-block extent, clamped steps re-serve the already-resident diagonal
block (Pallas issues a copy only when the block index changes), and
their compute is skipped. With a sliding window the band is narrower
than the kv axis, so sub-window blocks are not even scheduled; fully
right-padded kv blocks (``kv_len``) are likewise never scheduled.

The backward follows FlashAttention-2's two-pass scheme:

* ``dq`` — same grid/band as the forward; ``p = exp(s - lse)`` is
  recomputed blockwise from the saved stats, ``ds = p (dO·V^T − delta)``
  with ``delta_i = dO_i·o_i`` precomputed rowwise, and ``dq += ds K``
  accumulates in VMEM scratch across the kv band.
* ``dk/dv`` — kv-major grid ``(B, Hkv, nkv, rep, q_band)`` iterating the
  *transposed* band (the reverse orientation of the forward loop): each
  kv tile stays resident while the q-head group (``rep`` = GQA ratio)
  and its q band stream by, so dk/dv are accumulated across the whole
  q-head group in fp32 scratch and written once — KV tiles are fetched
  once per group instead of once per q head.

GQA is expressed in the K/V index maps (``hq // rep``), so KV tiles are
fetched once per q-head group without materializing repeated heads.

:func:`flash_attention` wraps the three pallas_calls in a
``jax.custom_vjp`` — what ``repro.kernels.ops.flash_attention_op``
dispatches to, making the hybrid (LASP-2H) softmax path trainable on the
Pallas backends. ``q_offset`` may be a traced scalar (the SP rank offset
``t·C`` inside ``shard_map``): masking then uses the runtime value and
the band conservatively covers the full kv extent.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import compat as _compat

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128


def mask_value(dtype) -> float:
    """Finite large-negative for masked logits, derived from ``dtype``'s
    ``finfo`` so reduced-precision score dtypes (bf16/fp16) cannot
    overflow to ``-inf``/NaN the way a ``-1e30`` literal does in fp16."""
    return float(jnp.finfo(jnp.dtype(dtype)).min) * 0.5


# ---------------------------------------------------------------------------
# Static band extents + shared masking.
# ---------------------------------------------------------------------------

def _kv_band(*, nq: int, nkv_real: int, block_q: int, block_k: int,
             q_offset: Optional[int], causal: bool, sliding_window):
    """Per-q-block kv block extents ``[lo(iq), hi(iq)]`` + band width.

    ``lo``/``hi`` accept traced block indices (static python constants
    baked in); ``width`` is the static kv grid-axis length. A traced
    ``q_offset`` (``None`` here) degrades to the untrimmed full extent —
    masking alone carries correctness there.
    """
    if q_offset is None:
        return (lambda iq: 0), (lambda iq: nkv_real - 1), max(nkv_real, 1)

    def lo(iq):
        if sliding_window is None:
            return 0
        return jnp.maximum(
            0, (q_offset + iq * block_q - (sliding_window - 1)) // block_k)

    def hi(iq):
        h = nkv_real - 1
        if causal:
            h = jnp.minimum(h, (q_offset + (iq + 1) * block_q - 1)
                            // block_k)
        return h

    def lo_py(iq):
        if sliding_window is None:
            return 0
        return max(0, (q_offset + iq * block_q - (sliding_window - 1))
                   // block_k)

    def hi_py(iq):
        h = nkv_real - 1
        if causal:
            h = min(h, (q_offset + (iq + 1) * block_q - 1) // block_k)
        return h

    width = max(max((hi_py(i) - lo_py(i) + 1 for i in range(nq)),
                    default=1), 1)
    return lo, hi, min(width, max(nkv_real, 1))


def _q_band(*, nq: int, nkv: int, block_q: int, block_k: int,
            q_offset: Optional[int], causal: bool, sliding_window):
    """Transposed band for the dk/dv pass: per-kv-block q extents."""
    if q_offset is None:
        return (lambda ik: 0), (lambda ik: nq - 1), max(nq, 1)

    def lo(ik):
        if not causal:
            return 0
        return jnp.maximum(0, (ik * block_k - q_offset) // block_q)

    def hi(ik):
        h = nq - 1
        if sliding_window is not None:
            h = jnp.minimum(h, (ik * block_k + block_k - 2 + sliding_window
                                - q_offset) // block_q)
        return h

    def lo_py(ik):
        return max(0, (ik * block_k - q_offset) // block_q) if causal else 0

    def hi_py(ik):
        h = nq - 1
        if sliding_window is not None:
            h = min(h, (ik * block_k + block_k - 2 + sliding_window
                        - q_offset) // block_q)
        return h

    width = max(max((hi_py(i) - lo_py(i) + 1 for i in range(nkv)),
                    default=1), 1)
    return lo, hi, min(width, max(nq, 1))


def _block_mask(qoff, q_start, k_start, block_q, block_k, *, causal,
                sliding_window, kv_len):
    """(block_q, block_k) validity mask in *global* coordinates.

    Query row i of a block sits at global position ``qoff + q_start + i``
    (``qoff = sk - sq`` for prefill-with-cache / ring-decode shapes, the
    SP rank offset under LASP-2H; key positions are global already).
    ``kv_len`` masks right-padded keys (awkward-length dispatch).
    """
    qpos = qoff + q_start + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0)
    kpos = k_start + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)
    mask = kpos < kv_len
    if causal:
        mask &= qpos >= kpos
    if sliding_window is not None:
        mask &= (qpos - kpos) < sliding_window
    return mask


def _block_needed(qoff, q_start, k_start, block_q, block_k, *, causal,
                  sliding_window, kv_len):
    """Block-granularity version of :func:`_block_mask` (any pair valid)."""
    needed = jnp.asarray(k_start < kv_len)
    if causal:
        needed &= k_start <= qoff + q_start + block_q - 1
    if sliding_window is not None:
        needed &= (qoff + q_start - (k_start + block_k - 1)) \
            < sliding_window
    return needed


# ---------------------------------------------------------------------------
# Forward kernel.
# ---------------------------------------------------------------------------

def _fwd_kernel(qoff_ref, q_ref, k_ref, v_ref, o_ref, lse_ref,
                m_scr, l_scr, acc_scr, *, scale, causal, sliding_window,
                q_offset, kv_len, kv_lo, kv_hi, kv_band, block_q, block_k):
    iq = pl.program_id(2)
    ikb = pl.program_id(3)
    neg = mask_value(jnp.float32)

    @pl.when(ikb == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, neg)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    qoff = q_offset if q_offset is not None else qoff_ref[0, 0]
    lo = kv_lo(iq)
    ik = jnp.clip(lo + ikb, 0, jnp.maximum(kv_hi(iq), 0))
    q_start = iq * block_q
    k_start = ik * block_k
    # in_extent kills the clamped band tail (repeats of the diagonal
    # block, already accumulated); the positional predicate kills
    # dynamically-dead blocks when q_offset is traced (band untrimmed).
    needed = jnp.logical_and(
        lo + ikb <= kv_hi(iq),
        _block_needed(qoff, q_start, k_start, block_q, block_k,
                      causal=causal, sliding_window=sliding_window,
                      kv_len=kv_len))

    @pl.when(needed)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)       # (bq, dh)
        k = k_ref[0, 0].astype(jnp.float32)       # (bk, dh)
        v = v_ref[0, 0].astype(jnp.float32)       # (bk, dh)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale       # (bq, bk)
        mask = _block_mask(qoff, q_start, k_start, block_q, block_k,
                           causal=causal, sliding_window=sliding_window,
                           kv_len=kv_len)
        s = jnp.where(mask, s, neg)

        m_prev = m_scr[:, 0]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(mask, p, 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_scr[:, 0] = l_scr[:, 0] * corr + jnp.sum(p, axis=-1)
        acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[:, 0] = m_new

    @pl.when(ikb == kv_band - 1)
    def _finalize():
        l = jnp.maximum(l_scr[:, 0], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)
        lse_ref[0, 0] = m_scr[:, 0] + jnp.log(l)


def _fwd_call(q, k, v, qoff_arr, *, causal, sliding_window, scale,
              q_offset, kv_len, block_q, block_k, interpret):
    b, hq, sq, dh = q.shape
    hkv, sk = k.shape[1], k.shape[2]
    rep = hq // hkv
    nq = sq // block_q
    nkv_real = -(-kv_len // block_k)
    kv_lo, kv_hi, kv_band = _kv_band(
        nq=nq, nkv_real=nkv_real, block_q=block_q, block_k=block_k,
        q_offset=q_offset, causal=causal, sliding_window=sliding_window)

    kernel = functools.partial(
        _fwd_kernel, scale=scale, causal=causal,
        sliding_window=sliding_window, q_offset=q_offset, kv_len=kv_len,
        kv_lo=kv_lo, kv_hi=kv_hi, kv_band=kv_band, block_q=block_q,
        block_k=block_k)

    def kv_im(b_, h, iq, ikb, rep_=rep):
        ik = jnp.clip(kv_lo(iq) + ikb, 0, jnp.maximum(kv_hi(iq), 0))
        return (b_, h // rep_, ik, 0)

    return pl.pallas_call(
        kernel,
        grid=(b, hq, nq, kv_band),
        in_specs=[
            pl.BlockSpec((1, 1), lambda b_, h, iq, ikb: (0, 0)),
            pl.BlockSpec((1, 1, block_q, dh),
                         lambda b_, h, iq, ikb: (b_, h, iq, 0)),
            pl.BlockSpec((1, 1, block_k, dh), kv_im),
            pl.BlockSpec((1, 1, block_k, dh), kv_im),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, block_q, dh),
                         lambda b_, h, iq, ikb: (b_, h, iq, 0)),
            pl.BlockSpec((1, 1, block_q),
                         lambda b_, h, iq, ikb: (b_, h, iq)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, hq, sq, dh), q.dtype),
            jax.ShapeDtypeStruct((b, hq, sq), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, dh), jnp.float32),
        ],
        compiler_params=_compat.tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
        name="flash_attention_fwd",
    )(qoff_arr, q, k, v)


# ---------------------------------------------------------------------------
# Backward kernels: dq pass (q-major, forward band) and dk/dv pass
# (kv-major, transposed band, GQA-group accumulation).
# ---------------------------------------------------------------------------

def _bwd_dq_kernel(qoff_ref, q_ref, k_ref, v_ref, do_ref, lse_ref,
                   delta_ref, dq_ref, dq_scr, *, scale, causal,
                   sliding_window, q_offset, kv_len, kv_lo, kv_hi, kv_band,
                   block_q, block_k):
    iq = pl.program_id(2)
    ikb = pl.program_id(3)

    @pl.when(ikb == 0)
    def _init():
        dq_scr[...] = jnp.zeros_like(dq_scr)

    qoff = q_offset if q_offset is not None else qoff_ref[0, 0]
    lo = kv_lo(iq)
    ik = jnp.clip(lo + ikb, 0, jnp.maximum(kv_hi(iq), 0))
    q_start = iq * block_q
    k_start = ik * block_k
    needed = jnp.logical_and(
        lo + ikb <= kv_hi(iq),
        _block_needed(qoff, q_start, k_start, block_q, block_k,
                      causal=causal, sliding_window=sliding_window,
                      kv_len=kv_len))

    @pl.when(needed)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)        # (bq, dh)
        k = k_ref[0, 0].astype(jnp.float32)        # (bk, dh)
        v = v_ref[0, 0].astype(jnp.float32)        # (bk, dh)
        do = do_ref[0, 0].astype(jnp.float32)      # (bq, dh)
        lse = lse_ref[0, 0]                        # (bq,)
        delta = delta_ref[0, 0]                    # (bq,)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale
        mask = _block_mask(qoff, q_start, k_start, block_q, block_k,
                           causal=causal, sliding_window=sliding_window,
                           kv_len=kv_len)
        p = jnp.where(mask, jnp.exp(s - lse[:, None]), 0.0)   # (bq, bk)
        dp = jax.lax.dot_general(
            do, v, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)                # (bq, bk)
        ds = p * (dp - delta[:, None])
        dq_scr[...] = dq_scr[...] + jax.lax.dot_general(
            ds, k, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(ikb == kv_band - 1)
    def _finalize():
        dq_ref[0, 0] = (dq_scr[...] * scale).astype(dq_ref.dtype)


def _bwd_dkv_kernel(qoff_ref, q_ref, k_ref, v_ref, do_ref, lse_ref,
                    delta_ref, dk_ref, dv_ref, dk_scr, dv_scr, *, scale,
                    causal, sliding_window, q_offset, kv_len, q_lo, q_hi,
                    q_band, rep, block_q, block_k):
    ik = pl.program_id(2)
    ig = pl.program_id(3)
    iqb = pl.program_id(4)

    @pl.when(jnp.logical_and(ig == 0, iqb == 0))
    def _init():
        dk_scr[...] = jnp.zeros_like(dk_scr)
        dv_scr[...] = jnp.zeros_like(dv_scr)

    qoff = q_offset if q_offset is not None else qoff_ref[0, 0]
    lo = q_lo(ik)
    iq = jnp.clip(lo + iqb, 0, jnp.maximum(q_hi(ik), 0))
    q_start = iq * block_q
    k_start = ik * block_k
    needed = jnp.logical_and(
        lo + iqb <= q_hi(ik),
        _block_needed(qoff, q_start, k_start, block_q, block_k,
                      causal=causal, sliding_window=sliding_window,
                      kv_len=kv_len))

    @pl.when(needed)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)        # (bq, dh)
        k = k_ref[0, 0].astype(jnp.float32)        # (bk, dh)
        v = v_ref[0, 0].astype(jnp.float32)        # (bk, dh)
        do = do_ref[0, 0].astype(jnp.float32)      # (bq, dh)
        lse = lse_ref[0, 0]                        # (bq,)
        delta = delta_ref[0, 0]                    # (bq,)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale
        mask = _block_mask(qoff, q_start, k_start, block_q, block_k,
                           causal=causal, sliding_window=sliding_window,
                           kv_len=kv_len)
        p = jnp.where(mask, jnp.exp(s - lse[:, None]), 0.0)   # (bq, bk)
        dv_scr[...] = dv_scr[...] + jax.lax.dot_general(
            p, do, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)                # (bk, dh)
        dp = jax.lax.dot_general(
            do, v, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)                # (bq, bk)
        ds = p * (dp - delta[:, None])
        dk_scr[...] = dk_scr[...] + jax.lax.dot_general(
            ds, q, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)                # (bk, dh)

    @pl.when(jnp.logical_and(ig == rep - 1, iqb == q_band - 1))
    def _finalize():
        dk_ref[0, 0] = (dk_scr[...] * scale).astype(dk_ref.dtype)
        dv_ref[0, 0] = dv_scr[...].astype(dv_ref.dtype)


def _bwd_call(q, k, v, qoff_arr, o, lse, do, *, causal, sliding_window,
              scale, q_offset, kv_len, block_q, block_k, interpret):
    b, hq, sq, dh = q.shape
    hkv, sk = k.shape[1], k.shape[2]
    rep = hq // hkv
    nq, nkv = sq // block_q, sk // block_k
    nkv_real = -(-kv_len // block_k)
    delta = jnp.sum(do.astype(jnp.float32) * o.astype(jnp.float32), axis=-1)

    kv_lo, kv_hi, kv_band = _kv_band(
        nq=nq, nkv_real=nkv_real, block_q=block_q, block_k=block_k,
        q_offset=q_offset, causal=causal, sliding_window=sliding_window)

    def kv_im(b_, h, iq, ikb, rep_=rep):
        ik = jnp.clip(kv_lo(iq) + ikb, 0, jnp.maximum(kv_hi(iq), 0))
        return (b_, h // rep_, ik, 0)

    q_im = lambda b_, h, iq, ikb: (b_, h, iq, 0)
    stat_im = lambda b_, h, iq, ikb: (b_, h, iq)

    dq = pl.pallas_call(
        functools.partial(
            _bwd_dq_kernel, scale=scale, causal=causal,
            sliding_window=sliding_window, q_offset=q_offset,
            kv_len=kv_len, kv_lo=kv_lo, kv_hi=kv_hi, kv_band=kv_band,
            block_q=block_q, block_k=block_k),
        grid=(b, hq, nq, kv_band),
        in_specs=[
            pl.BlockSpec((1, 1), lambda b_, h, iq, ikb: (0, 0)),
            pl.BlockSpec((1, 1, block_q, dh), q_im),
            pl.BlockSpec((1, 1, block_k, dh), kv_im),
            pl.BlockSpec((1, 1, block_k, dh), kv_im),
            pl.BlockSpec((1, 1, block_q, dh), q_im),
            pl.BlockSpec((1, 1, block_q), stat_im),
            pl.BlockSpec((1, 1, block_q), stat_im),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, dh), q_im),
        out_shape=jax.ShapeDtypeStruct((b, hq, sq, dh), q.dtype),
        scratch_shapes=[pltpu.VMEM((block_q, dh), jnp.float32)],
        compiler_params=_compat.tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
        name="flash_attention_bwd_dq",
    )(qoff_arr, q, k, v, do, lse, delta)

    q_lo, q_hi, q_band = _q_band(
        nq=nq, nkv=nkv, block_q=block_q, block_k=block_k,
        q_offset=q_offset, causal=causal, sliding_window=sliding_window)

    def qg_im(b_, g, ik, ig, iqb, rep_=rep):
        iq = jnp.clip(q_lo(ik) + iqb, 0, jnp.maximum(q_hi(ik), 0))
        return (b_, g * rep_ + ig, iq, 0)

    def statg_im(b_, g, ik, ig, iqb, rep_=rep):
        iq = jnp.clip(q_lo(ik) + iqb, 0, jnp.maximum(q_hi(ik), 0))
        return (b_, g * rep_ + ig, iq)

    kvg_im = lambda b_, g, ik, ig, iqb: (b_, g, ik, 0)

    dk, dv = pl.pallas_call(
        functools.partial(
            _bwd_dkv_kernel, scale=scale, causal=causal,
            sliding_window=sliding_window, q_offset=q_offset,
            kv_len=kv_len, q_lo=q_lo, q_hi=q_hi, q_band=q_band, rep=rep,
            block_q=block_q, block_k=block_k),
        grid=(b, hkv, nkv, rep, q_band),
        in_specs=[
            pl.BlockSpec((1, 1), lambda b_, g, ik, ig, iqb: (0, 0)),
            pl.BlockSpec((1, 1, block_q, dh), qg_im),
            pl.BlockSpec((1, 1, block_k, dh), kvg_im),
            pl.BlockSpec((1, 1, block_k, dh), kvg_im),
            pl.BlockSpec((1, 1, block_q, dh), qg_im),
            pl.BlockSpec((1, 1, block_q), statg_im),
            pl.BlockSpec((1, 1, block_q), statg_im),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, block_k, dh), kvg_im),
            pl.BlockSpec((1, 1, block_k, dh), kvg_im),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, hkv, sk, dh), k.dtype),
            jax.ShapeDtypeStruct((b, hkv, sk, dh), v.dtype),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_k, dh), jnp.float32),
            pltpu.VMEM((block_k, dh), jnp.float32),
        ],
        compiler_params=_compat.tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary", "arbitrary")),
        interpret=interpret,
        name="flash_attention_bwd_dkv",
    )(qoff_arr, q, k, v, do, lse, delta)
    return dq, dk, dv


# ---------------------------------------------------------------------------
# Differentiable entry point (custom_vjp over the three Pallas passes).
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6, 7, 8, 9, 10, 11))
def _flash(q, k, v, qoff_arr, causal, sliding_window, scale, q_offset,
           kv_len, block_q, block_k, interpret):
    o, _ = _fwd_call(q, k, v, qoff_arr, causal=causal,
                     sliding_window=sliding_window, scale=scale,
                     q_offset=q_offset, kv_len=kv_len, block_q=block_q,
                     block_k=block_k, interpret=interpret)
    return o


def _flash_vjp_fwd(q, k, v, qoff_arr, causal, sliding_window, scale,
                   q_offset, kv_len, block_q, block_k, interpret):
    o, lse = _fwd_call(q, k, v, qoff_arr, causal=causal,
                       sliding_window=sliding_window, scale=scale,
                       q_offset=q_offset, kv_len=kv_len, block_q=block_q,
                       block_k=block_k, interpret=interpret)
    return o, (q, k, v, qoff_arr, o, lse)


def _flash_vjp_bwd(causal, sliding_window, scale, q_offset, kv_len,
                   block_q, block_k, interpret, res, do):
    q, k, v, qoff_arr, o, lse = res
    dq, dk, dv = _bwd_call(
        q, k, v, qoff_arr, o, lse, do, causal=causal,
        sliding_window=sliding_window, scale=scale, q_offset=q_offset,
        kv_len=kv_len, block_q=block_q, block_k=block_k,
        interpret=interpret)
    # q_offset is integer data — its cotangent is the symbolic float0 zero
    return dq, dk, dv, np.zeros(qoff_arr.shape, jax.dtypes.float0)


_flash.defvjp(_flash_vjp_fwd, _flash_vjp_bwd)


def flash_attention(q, k, v, *, causal: bool = True, sliding_window=None,
                    scale=None, q_offset=None, kv_len: Optional[int] = None,
                    block_q: int = DEFAULT_BLOCK_Q,
                    block_k: int = DEFAULT_BLOCK_K, interpret: bool = False):
    """GQA flash attention (differentiable). q: (B,Hq,S,dh), k/v: (B,Hkv,Sk,dh).

    ``q_offset``: global position of query row 0 (keys are global
    already). Defaults to ``sk - sq`` — the prefill-with-cache convention
    shared with the XLA mask fallback in ``repro.kernels.ops``. A python
    int keeps the causal band trimming static; a traced scalar (the SP
    rank offset under LASP-2H) is supported with the untrimmed band.

    ``kv_len``: number of valid (unpadded) key positions, for callers
    that right-pad ``sk`` to a block multiple. Defaults to ``sk``.

    Gradients flow to q/k/v through the two-pass Pallas backward
    (``jax.custom_vjp``).
    """
    b, hq, sq, dh = q.shape
    hkv, sk = k.shape[1], k.shape[2]
    if scale is None:
        scale = dh ** -0.5
    if q_offset is None:
        q_offset = sk - sq
    if kv_len is None:
        kv_len = sk
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    if sq % block_q or sk % block_k:
        raise ValueError(f"sq={sq}, sk={sk} not divisible by blocks "
                         f"({block_q}, {block_k})")
    if isinstance(q_offset, (int, np.integer)):
        q_off_static, qoff_arr = int(q_offset), \
            jnp.full((1, 1), int(q_offset), jnp.int32)
    else:   # traced (SP rank offset): band untrimmed, masked at runtime
        q_off_static = None
        qoff_arr = jnp.asarray(q_offset, jnp.int32).reshape(1, 1)
    return _flash(q, k, v, qoff_arr, causal, sliding_window, float(scale),
                  q_off_static, int(kv_len), block_q, block_k, interpret)
