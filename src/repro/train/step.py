"""Train-step factory: grad accumulation (scan), AdamW, clipping, skip-on-
non-finite, optional cross-pod int8 gradient compression.

``train_step(state, batch)``:
  state = {"params", "opt": AdamState | Zero1AdamState, "step", ["err"]}
  batch = {"tokens"/"labels"/"resets": (A, B/A, S), [frames|img]: (A, ...)}
Returns (new_state, metrics). Designed for jit with donated state.

Two step flavours, selected by the plan:

* **GSPMD step** (the default): plain jit — XLA places the collectives
  from the plan's sharding constraints.
* **Manual DP×SP(×TP) step** (``plan.manual_axes``, docs/parallelism.md):
  the whole step runs inside ONE fully-manual shard_map over the
  ``(data, sequence)`` mesh — or ``(data, sequence, model)`` on 3D
  plans, where tokens shard over the combined (sequence, model) width —
  so every collective on the wire is explicit and HLO-countable
  (``repro.comm.budget.train_step_axis_budget``):

    - per LASP-2 layer: the strategy's state exchange over the
      sequence-carrying axes only (1 forward all-gather for
      "allgather"); hybrid layers under "ulysses" add the head-parallel
      All-to-All pair over ``model``,
    - per step: exactly ONE gradient reduction touching ``data`` — all
      microbatch-accumulated gradients plus the loss/token counters are
      raveled into a single fp32 vector and psum'd across the mesh,
    - ZeRO-1 (``plan.zero1_axis``): each rank Adam-updates its
      1/zero_deg flat parameter slice and ONE all-gather over the zero
      axes re-assembles the params (the all-gather-on-update path).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.flatten_util import ravel_pytree
from jax.sharding import PartitionSpec as P

from repro.core.compat import shard_map as _shard_map

from repro.comm import primitives as comm_primitives
from repro.configs.base import ModelConfig, RunConfig
from repro.launch.mesh import POD_AXIS
from repro.models import model as M
from repro.optim import adamw
from repro.optim.compression import compress_sync_tree
from repro.resilience import guard as health
from repro.sharding.rules import Parallelism, _axis_size

MOE_AUX_COEF = 0.01


def init_state(key, cfg: ModelConfig, run: RunConfig,
               plan: Optional[Parallelism] = None):
    params = M.init_params(key, cfg)
    if run.bf16_params:
        # §Perf: bf16 weight storage — halves FSDP gather traffic and
        # removes per-use f32→bf16 converts; Adam moments stay fp32 (the
        # usual production mixed-precision recipe).
        params = jax.tree.map(
            lambda x: x.astype(jnp.bfloat16)
            if (x.dtype == jnp.float32 and x.ndim >= 2) else x, params)
    if plan is not None and plan.zero1_axis is not None:
        opt = adamw.zero1_init(params, _axis_size(plan.mesh,
                                                  plan.zero1_axis))
    else:
        opt = adamw.init(params)
    state = {"params": params, "opt": opt,
             "step": jnp.zeros((), jnp.int32)}
    if run.guard:
        state["guard"] = health.guard_init(run.guard_window)
    if run.grad_compression:
        from repro.optim.compression import init_error_buffer
        state["err"] = init_error_buffer(params)
    return state


def make_loss_fn(cfg: ModelConfig, run: RunConfig, plan: Parallelism):
    def loss_fn(params, micro):
        kwargs = {}
        if "frames" in micro:
            kwargs["enc_frames"] = micro["frames"]
        if "img" in micro:
            kwargs["img_emb"] = micro["img"]
        logits, aux = M.forward(params, micro["tokens"], cfg, plan,
                                remat=run.remat, unroll=run.scan_unroll,
                                resets=micro.get("resets"), **kwargs)
        loss = M.lm_loss(logits, micro["labels"])
        return loss + MOE_AUX_COEF * aux, loss
    return loss_fn


def _accum_grads(loss_fn, params, batch, unroll=False, plan=None):
    """Scan over the leading microbatch dim, averaging grads in fp32.

    §Perf: the fp32 accumulators are CONSTRAINED to the parameter sharding
    (FSDP over "data", TP over "model"). Without this, XLA keeps the
    accumulator replicated and moves the FULL fp32 gradient per microbatch
    (measured as 14.9 GiB/layer of f32 all-gathers on qwen110b×train_4k);
    with it, each microbatch contributes a reduce-scatter into the shard —
    the ZeRO-2 gradient flow."""
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def constrain(tree):
        if plan is None or plan.mesh is None:
            return tree
        from jax.sharding import NamedSharding
        from repro.sharding.rules import param_specs
        specs = param_specs(tree, plan)
        return jax.tree.map(
            lambda x, sp: jax.lax.with_sharding_constraint(
                x, NamedSharding(plan.mesh, sp)),
            tree, specs, is_leaf=lambda x: hasattr(x, "shape"))

    def body(acc, micro):
        (total, ce), g = grad_fn(params, micro)
        acc = jax.tree.map(lambda a, b: a + b.astype(jnp.float32), acc, g)
        return constrain(acc), ce

    zeros = constrain(jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params))
    grads, ces = jax.lax.scan(body, zeros, batch,
                              unroll=True if unroll else 1)
    a = ces.shape[0]
    grads = jax.tree.map(lambda g: g / a, grads)
    return grads, jnp.mean(ces)


def _cast_tree(params, dtype):
    """bf16 copies of matrix params (norm scales and 1-D params stay
    fp32). The cast sits OUTSIDE the microbatch scan, so FSDP gathers move
    bf16 (half the bytes) and the gather result is reusable across
    microbatches (§Perf hillclimb #1)."""
    return jax.tree.map(
        lambda x: x.astype(dtype)
        if (x.dtype == jnp.float32 and x.ndim >= 2) else x, params)


# ---------------------------------------------------------------------------
# Manual 2D DP×SP step (data × sequence mesh).
# ---------------------------------------------------------------------------

def _local_objective_fn(cfg: ModelConfig, run: RunConfig, plan: Parallelism):
    """Per-rank objective for the manual step: UNNORMALIZED local CE sum
    (+ n-weighted MoE aux), so the cross-replica normalization can happen
    AFTER the single gradient reduction (the token count rides in the
    same packed psum)."""

    def objective(params, micro):
        if "frames" in micro or "img" in micro:
            raise NotImplementedError(
                "encoder/VLM aux inputs are not supported on the 2D DP×SP "
                "training plan yet")
        logits, aux = M.forward(params, micro["tokens"], cfg, plan,
                                remat=run.remat, unroll=run.scan_unroll,
                                resets=micro.get("resets"))
        ce_sum, n_valid, _ = M.lm_loss_sum(logits, micro["labels"])
        n = n_valid.astype(jnp.float32)
        # n-weighted aux: after global normalization this is the
        # token-weighted mean of the per-shard aux losses (== the global
        # aux when shards agree; the standard DP decomposition).
        obj = ce_sum + MOE_AUX_COEF * aux * n
        return obj, (ce_sum, n)

    return objective


def _make_manual_train_step(cfg: ModelConfig, run: RunConfig,
                            plan: Parallelism):
    if run.grad_compression:
        raise NotImplementedError(
            "grad_compression targets pod meshes; not supported on the "
            "2D DP×SP plan")
    mesh = plan.mesh
    axes = tuple(plan.manual_axes)
    dp_ax = plan.rules.get("batch")
    seq_ax = plan.sp.sp_axis if plan.sp is not None else None
    tp_ax = plan.sp.tp_axis if plan.sp is not None else None
    zero_ax = plan.zero1_axis
    zero_deg = _axis_size(mesh, zero_ax)
    dp = mesh.shape[dp_ax] if dp_ax is not None else 1
    world = 1
    for a in axes:
        world *= mesh.shape[a]
    objective = _local_objective_fn(cfg, run, plan)

    def body(state, batch):
        params = state["params"]
        if run.cast_params_once:
            compute_params = _cast_tree(params, jnp.dtype(cfg.dtype))
        else:
            compute_params = params

        grad_fn = jax.value_and_grad(objective, has_aux=True)

        def micro_body(acc, micro):
            (_, (ce, n)), g = grad_fn(compute_params, micro)
            acc = jax.tree.map(lambda a, b: a + b.astype(jnp.float32),
                               acc, g)
            return acc, (ce, n)

        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                             compute_params)
        grads, (ces, ns) = jax.lax.scan(
            micro_body, zeros, batch, unroll=True if run.scan_unroll else 1)

        # THE single gradient reduction: flat grads ‖ [ce_sum, n_sum] in
        # one all-reduce across the whole mesh (data and sequence partial
        # sums combine in the same collective). With the guard on, one
        # extra fp32 scalar (this rank's loss-health indicator) rides in
        # the same vector — every rank reaches the same verdict with
        # ZERO additional collectives (docs/resilience.md).
        flat, unravel_grads = ravel_pytree(grads)
        flat = health.chaos_poison_nan(flat, state["step"],
                                       run.chaos_nan_steps)
        tail = [jnp.sum(ces), jnp.sum(ns)]
        if run.guard:
            # The piggybacked health scalar checks only the tiny local
            # loss vector. Gradient non-finiteness needs NO local pass:
            # NaN/Inf are absorbing under the psum, so the post-reduce
            # gnorm/ce checks below catch any rank's bad contribution —
            # a local isfinite sweep over the raveled grads would force
            # the concat to materialize twice (~5% more step bytes).
            local_bad = jnp.logical_not(jnp.all(jnp.isfinite(ces)))
            tail.append(local_bad.astype(jnp.float32))
        packed = jnp.concatenate([flat, jnp.stack(tail)])
        packed = comm_primitives.psum_packed(
            packed, axes if len(axes) > 1 else axes[0], group_size=world,
            tag="train.grads")
        k = len(tail)
        ce_tot = packed[-k]
        n_tot = jnp.maximum(packed[-k + 1], 1.0)  # all-masked batch → loss 0
        gflat = packed[:-k] / n_tot

        gnorm = jnp.sqrt(jnp.sum(gflat * gflat))
        if run.guard:
            nonfinite = (packed[-1] > 0) \
                | jnp.logical_not(jnp.isfinite(gnorm)) \
                | jnp.logical_not(jnp.isfinite(ce_tot)) \
                | health.chaos_hit(state["step"], run.chaos_skip_steps)
            scale, finite, new_guard, ginfo = health.guard_verdict(
                state["guard"], gnorm, nonfinite,
                grad_clip=run.grad_clip,
                spike_factor=run.guard_spike_factor)
            # where (not scale·0): NaN grads must not propagate as NaN·0
            gflat = jnp.where(finite, gflat * scale, 0.0)
        else:
            scale = jnp.minimum(
                1.0, run.grad_clip / jnp.maximum(gnorm, 1e-9))
            finite = jnp.isfinite(gnorm)
            # Fault tolerance: a non-finite step is skipped, not applied.
            gflat = jnp.where(finite, gflat * scale, 0.0)
        lr = adamw.cosine_schedule(
            state["step"], base_lr=run.learning_rate,
            warmup_steps=run.warmup_steps, total_steps=run.total_steps,
            min_lr=run.min_lr)

        opt = state["opt"]
        if zero_ax is not None:
            # ZeRO-1: update this rank's 1/zero_deg flat slice, gather
            # params. On 3D plans ``zero_ax`` is the combined
            # (data, model) tuple — ``multi_axis_index`` linearizes it in
            # the same major-first order the all-gather concatenates.
            pflat, unravel_params = ravel_pytree(params)
            n_params = pflat.size
            padded = adamw.zero1_padded_size(params, zero_deg)
            shard = padded // zero_deg
            pad = padded - n_params

            def padded_slice(vec):
                vec = jnp.concatenate(
                    [vec.astype(jnp.float32),
                     jnp.zeros((pad,), jnp.float32)])
                ix = comm_primitives.multi_axis_index(zero_ax) * shard
                return jax.lax.dynamic_slice(vec, (ix,), (shard,))

            g_sh = padded_slice(gflat)
            p_sh = padded_slice(pflat)
            d_sh = padded_slice(adamw.decay_mask(params))
            count = opt.count + 1
            new_p_sh, new_m, new_v = adamw.zero1_update_shard(
                g_sh, opt.m, opt.v, p_sh, d_sh, count, lr=lr,
                b1=run.adam_b1, b2=run.adam_b2,
                weight_decay=run.weight_decay)
            new_p_sh = jnp.where(finite, new_p_sh, p_sh)
            new_m = jnp.where(finite, new_m, opt.m)
            new_v = jnp.where(finite, new_v, opt.v)
            count = jnp.where(finite, count, opt.count)
            # ZeRO-1's all-gather-on-update: the only other collective
            # touching the data axis.
            gathered = comm_primitives.allgather_states(
                new_p_sh, zero_ax, axis_size=zero_deg, gather_axis=0,
                tiled=True, tag="zero1.param_gather")
            new_params = unravel_params(gathered[:n_params])
            new_opt = adamw.Zero1AdamState(new_m, new_v, count)
        else:
            grads_tree = unravel_grads(gflat)
            new_params, new_opt = adamw.update(
                grads_tree, opt, params, lr=lr, b1=run.adam_b1,
                b2=run.adam_b2, weight_decay=run.weight_decay)
            new_params = jax.tree.map(
                lambda nw, o: jnp.where(finite, nw, o), new_params, params)
            new_opt = jax.tree.map(
                lambda nw, o: jnp.where(finite, nw, o), new_opt, opt)

        new_state = {"params": new_params, "opt": new_opt,
                     "step": state["step"] + 1}
        metrics = {"loss": ce_tot / n_tot, "grad_norm": gnorm, "lr": lr,
                   "skipped": (~finite).astype(jnp.float32)}
        if run.guard:
            new_state["guard"] = new_guard
            metrics.update(ginfo)
        return new_state, metrics

    def train_step(state, batch):
        rows = jax.tree.leaves(batch)[0].shape[1]
        seq = jax.tree.leaves(batch)[0].shape[2]
        sp = mesh.shape[seq_ax] if seq_ax is not None else 1
        tp = mesh.shape[tp_ax] if tp_ax is not None else 1
        if rows % dp or seq % (sp * tp):
            raise ValueError(
                f"DP×SP step needs microbatch rows ({rows}) divisible "
                f"by dp ({dp}) and seq len ({seq}) by sp×tp ({sp}×{tp})")
        # Tokens shard over the COMBINED (sequence, model) axes on 3D
        # plans — sequence-major, matching SPConfig.exchange_axes.
        token_ax = seq_ax if tp_ax is None else (seq_ax, tp_ax)
        bspec = jax.tree.map(lambda _: P(None, dp_ax, token_ax), batch)
        sspec = jax.tree.map(lambda _: P(), state)
        if zero_ax is not None:
            sspec["opt"] = adamw.Zero1AdamState(
                m=P(zero_ax), v=P(zero_ax), count=P())
        mspec = {"loss": P(), "grad_norm": P(), "lr": P(), "skipped": P()}
        if run.guard:
            mspec.update({key: P() for key in health.GUARD_METRICS})
        return _shard_map(
            body, mesh=mesh, in_specs=(sspec, bspec),
            out_specs=(sspec, mspec), axis_names=set(axes),
            check_vma=False)(state, batch)

    return train_step


def make_train_step(cfg: ModelConfig, run: RunConfig, plan: Parallelism):
    if plan.manual_axes:
        return _make_manual_train_step(cfg, run, plan)
    loss_fn = make_loss_fn(cfg, run, plan)

    def train_step(state, batch):
        params = state["params"]
        if run.cast_params_once:
            compute_params = _cast_tree(params, jnp.dtype(cfg.dtype))
        else:
            compute_params = params

        if run.grad_compression and plan.mesh is not None \
                and POD_AXIS in plan.mesh.axis_names:
            # per-pod local grads → int8 error-feedback cross-pod sync
            def body(params_, batch_, err_):
                g, ce = _accum_grads(loss_fn, params_, batch_,
                                     run.scan_unroll, plan)
                g, new_err = compress_sync_tree(g, err_, pod_axis=POD_AXIS)
                return g, jax.lax.pmean(ce, POD_AXIS), new_err

            nb = jax.tree.map(lambda x: P(None, POD_AXIS), batch)
            grads, ce, new_err = _shard_map(
                body, mesh=plan.mesh,
                in_specs=(P(), nb, P()), out_specs=(P(), P(), P()),
                axis_names={POD_AXIS}, check_vma=False)(
                    compute_params, batch, state["err"])
        else:
            grads, ce = _accum_grads(loss_fn, compute_params, batch,
                                     run.scan_unroll, plan)
            new_err = state.get("err")
        if run.cast_params_once:
            # d(loss)/d(master fp32) == d(loss)/d(bf16 copy) cast back
            grads = jax.tree.map(
                lambda g, p: g.astype(jnp.float32)
                if g.dtype != p.dtype else g, grads, params)

        if run.chaos_nan_steps:
            bad = health.chaos_hit(state["step"], run.chaos_nan_steps)
            grads = jax.tree.map(
                lambda g: jnp.where(bad, jnp.full_like(g, jnp.nan), g),
                grads)
        if run.guard:
            gnorm = adamw.global_norm(grads)
            nonfinite = jnp.logical_not(jnp.isfinite(gnorm)) \
                | jnp.logical_not(jnp.isfinite(ce)) \
                | health.chaos_hit(state["step"], run.chaos_skip_steps)
            gscale, finite, new_guard, ginfo = health.guard_verdict(
                state["guard"], gnorm, nonfinite,
                grad_clip=run.grad_clip,
                spike_factor=run.guard_spike_factor)
            grads = jax.tree.map(
                lambda g: jnp.where(finite, g * gscale, jnp.zeros_like(g)),
                grads)
        else:
            grads, gnorm = adamw.clip_by_global_norm(grads, run.grad_clip)
            finite = jnp.isfinite(gnorm)
            # Fault tolerance: a non-finite step is skipped, not applied.
            grads = jax.tree.map(
                lambda g: jnp.where(finite, g, jnp.zeros_like(g)), grads)
        lr = adamw.cosine_schedule(
            state["step"], base_lr=run.learning_rate,
            warmup_steps=run.warmup_steps, total_steps=run.total_steps,
            min_lr=run.min_lr)
        new_params, new_opt = adamw.update(
            grads, state["opt"], params, lr=lr, b1=run.adam_b1,
            b2=run.adam_b2, weight_decay=run.weight_decay)
        new_params = jax.tree.map(
            lambda n, o: jnp.where(finite, n, o), new_params, params)
        new_opt = jax.tree.map(
            lambda n, o: jnp.where(finite, n, o), new_opt, state["opt"])
        new_state = {"params": new_params, "opt": new_opt,
                     "step": state["step"] + 1}
        if new_err is not None:
            new_state["err"] = new_err
        metrics = {"loss": ce, "grad_norm": gnorm, "lr": lr,
                   "skipped": (~finite).astype(jnp.float32)}
        if run.guard:
            new_state["guard"] = new_guard
            metrics.update(ginfo)
        return new_state, metrics

    return train_step
