"""Serving engine: batched generation consistency + constant-state cache."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke
from repro.models import model as M
from repro.serve.engine import ServeEngine


def test_greedy_generation_matches_stepwise(rng):
    cfg = get_smoke("linear-llama3-1b")
    params = M.init_params(rng, cfg)
    engine = ServeEngine(cfg, params, max_len=96)
    prompts = jax.random.randint(rng, (3, 16), 0, cfg.vocab_size)
    out = engine.generate(prompts, 8, temperature=0.0)
    assert out.shape == (3, 8)
    # manual reference: prefill + argmax decode
    logits, cache = jax.jit(lambda p, t: M.prefill(p, t, cfg, max_len=96))(
        params, prompts)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    for i in range(8):
        np.testing.assert_array_equal(out[:, i], np.asarray(tok))
        logits, cache = jax.jit(lambda p, t, c: M.decode_step(
            p, t, c, cfg))(params, tok, cache)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)


def test_generation_deterministic_with_seed(rng):
    cfg = get_smoke("mamba2-2.7b")
    params = M.init_params(rng, cfg)
    engine = ServeEngine(cfg, params, max_len=64)
    prompts = jax.random.randint(rng, (2, 16), 0, cfg.vocab_size)
    o1 = engine.generate(prompts, 8, temperature=0.9, seed=5)
    o2 = engine.generate(prompts, 8, temperature=0.9, seed=5)
    o3 = engine.generate(prompts, 8, temperature=0.9, seed=6)
    np.testing.assert_array_equal(o1, o2)
    assert not np.array_equal(o1, o3)


def test_linear_state_constant_memory(rng):
    """The paper's constant-memory-inference property."""
    cfg = get_smoke("linear-llama3-1b")
    c1 = M.init_cache(cfg, batch=2, max_len=32)
    c2 = M.init_cache(cfg, batch=2, max_len=4096)
    n1 = sum(x.size for x in jax.tree.leaves(c1["layers"]))
    n2 = sum(x.size for x in jax.tree.leaves(c2["layers"]))
    assert n1 == n2, "linear-attention cache must not grow with max_len"


def test_eos_early_stop(rng):
    cfg = get_smoke("linear-llama3-1b")
    params = M.init_params(rng, cfg)
    engine = ServeEngine(cfg, params, max_len=64)
    prompts = jax.random.randint(rng, (2, 8), 0, cfg.vocab_size)
    greedy = engine.generate(prompts, 6, temperature=0.0)
    eos = int(greedy[0, 0])   # force immediate stop for row 0's first token
    out = engine.generate(prompts, 6, temperature=0.0, eos_id=eos)
    assert out.shape == (2, 6)
