#!/usr/bin/env python
"""Render a telemetry JSONL file (``--metrics-out``, docs/observability.md)
into a markdown run report.

The input is what a :class:`repro.obs.JsonlSink` wrote: flat records
tagged ``kind`` ∈ {compile, step, event, request, summary}. The report
covers, when the matching records are present:

* **compile** — the flight recorder's expected-vs-measured collective
  structure (CommRecord tape vs compiled HLO, per op) and any drift;
* **steps** — wall percentiles, tokens/s, MFU, phase breakdown,
  flagged stragglers;
* **serve** — per-request TTFT / latency percentiles and the engine
  summary (queue depth, cache occupancy, eviction counters);
* **events / summary** — resume/signal/straggler events and run totals.

Guarded runs (``--guard``, docs/resilience.md) additionally get a
numerical-guard table (skipped / spike-clipped steps, rolling median)
and their ``guard_skip`` / ``guard_abort`` / ``ckpt_fallback`` events
land in the events table.

Also renders two single-object JSON documents: the static-analysis
findings that ``python -m repro.analysis --json`` writes (a JSON object
with a ``findings`` key, docs/static_analysis.md), and the chaos-drill
report that ``python -m repro.resilience.drill --out`` writes
(``kind: chaos_drill``, docs/resilience.md) — the CI ``analysis`` and
``chaos`` jobs feed their artifacts through here.

  python scripts/report.py metrics.jsonl              # stdout
  python scripts/report.py metrics.jsonl -o report.md
  python scripts/report.py analysis_findings.json -o analysis_report.md
  python scripts/report.py drill_report.json -o drill_report.md
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT / "src"))

from repro.obs import Histogram, read_jsonl  # noqa: E402


def _fmt(v, digits=4):
    if v is None:
        return "-"
    if isinstance(v, float):
        if v == 0:
            return "0"
        if abs(v) >= 1e6 or abs(v) < 1e-3:
            return f"{v:.3g}"
        return f"{v:.{digits}g}"
    return str(v)


def _table(headers, rows):
    out = ["| " + " | ".join(headers) + " |",
           "|" + "|".join("---" for _ in headers) + "|"]
    out += ["| " + " | ".join(_fmt(c) for c in row) + " |" for row in rows]
    return out


def _hist(records, key):
    h = Histogram()
    h.extend(r[key] for r in records if isinstance(r.get(key), (int, float)))
    return h


def render(records) -> str:
    by_kind = {}
    for r in records:
        by_kind.setdefault(r.get("kind", "?"), []).append(r)
    lines = ["# Run report", "",
             f"{len(records)} records: " +
             ", ".join(f"{len(v)} {k}" for k, v in sorted(by_kind.items())),
             ""]

    for comp in by_kind.get("compile", []):
        lines += ["## Compile: expected vs measured collectives", ""]
        if comp.get("note"):
            lines += [f"program: `{comp['note']}`", ""]
        ops = sorted({k.split("/", 1)[1].rsplit("_", 1)[0]
                      for k in comp if k.startswith(("tape/", "hlo/"))})
        if ops:
            rows = [(op,
                     comp.get(f"tape/{op}_count", 0),
                     comp.get(f"tape/{op}_bytes", 0),
                     comp.get(f"hlo/{op}_count", 0),
                     comp.get(f"hlo/{op}_bytes", 0)) for op in ops]
            lines += _table(["op", "tape count", "tape bytes",
                             "hlo count", "hlo bytes"], rows)
        lines += ["",
                  f"expected (tape) bytes/step: "
                  f"{_fmt(comp.get('expected_collective_bytes'))} · "
                  f"measured (hlo) bytes/step: "
                  f"{_fmt(comp.get('hlo_collective_bytes'))}", ""]
        drift = comp.get("drift") or []
        if drift:
            lines += ["**DRIFT FLAGGED:**", ""]
            lines += [f"- {d}" for d in drift] + [""]
        else:
            lines += ["no drift: every collective the tape promises is in "
                      "the compiled HLO.", ""]

    steps = by_kind.get("step", [])
    if steps:
        lines += ["## Steps", ""]
        wall = _hist(steps, "wall_s")
        rows = [("wall_s", *[wall.summary()[k]
                             for k in ("count", "mean", "p50", "p90",
                                       "p99")])]
        for key in ("tokens_per_s", "mfu", "loss"):
            h = _hist(steps, key)
            if h.count:
                rows.append((key, *[h.summary()[k]
                                    for k in ("count", "mean", "p50",
                                              "p90", "p99")]))
        lines += _table(["metric", "n", "mean", "p50", "p90", "p99"], rows)
        lines += [""]

        phase_keys = sorted({k for r in steps for k in r
                             if k.endswith("_s") and k not in
                             ("wall_s", "expected_wall_s",
                              "tokens_per_s")})
        if phase_keys:
            lines += ["### Phase breakdown", ""]
            total_wall = wall.total or 1.0
            rows = []
            for k in phase_keys:
                h = _hist(steps, k)
                rows.append((k, _fmt(h.mean), _fmt(h.percentile(50)),
                             _fmt(h.percentile(99)),
                             f"{h.total / total_wall:.1%}"))
            lines += _table(["phase", "mean", "p50", "p99",
                             "share of wall"], rows) + [""]

        stragglers = [r for r in steps if r.get("straggler")]
        if stragglers:
            lines += ["### Stragglers", ""]
            lines += _table(
                ["step", "wall_s", "expected_wall_s"],
                [(r.get("step"), r.get("wall_s"),
                  r.get("expected_wall_s")) for r in stragglers]) + [""]
        else:
            lines += ["no straggler steps flagged.", ""]

        guarded = [r for r in steps if "skipped_steps" in r]
        if guarded:
            last = guarded[-1]
            skipped_at = [r.get("step") for r in steps if r.get("skipped")]
            spiked_at = [r.get("step") for r in steps
                         if r.get("guard_spike")]
            lines += ["### Numerical guard", ""]
            lines += _table(
                ["metric", "value"],
                [("steps skipped", int(last.get("skipped_steps", 0))),
                 ("skipped at",
                  ", ".join(str(s) for s in skipped_at) or "-"),
                 ("spike-clipped at",
                  ", ".join(str(s) for s in spiked_at) or "-"),
                 ("max consecutive skips",
                  int(max((r.get("consecutive_skips", 0)
                           for r in guarded), default=0))),
                 ("rolling median ‖g‖ (final)",
                  last.get("guard_median"))]) + [""]

        comm = [r for r in steps if "expected_collective_bytes" in r]
        if comm:
            r = comm[-1]
            lines += [f"collective bytes/step: expected "
                      f"{_fmt(r['expected_collective_bytes'])}, measured "
                      f"{_fmt(r.get('hlo_collective_bytes'))}"
                      + (f" · {_fmt(r['comm_bytes_per_token'])} B/token"
                         if r.get("comm_bytes_per_token") else ""), ""]

    reqs = by_kind.get("request", [])
    if reqs:
        lines += ["## Serve requests", ""]
        rows = []
        for key in ("ttft_s", "wall_s", "new_tokens", "prompt_len"):
            h = _hist(reqs, key)
            if h.count:
                rows.append((key, *[h.summary()[k]
                                    for k in ("count", "mean", "p50",
                                              "p90", "p99")]))
        lines += _table(["metric", "n", "mean", "p50", "p90", "p99"], rows)
        reasons = {}
        for r in reqs:
            reasons[r.get("finish_reason")] = \
                reasons.get(r.get("finish_reason"), 0) + 1
        lines += ["", "finish reasons: " +
                  ", ".join(f"{k}={v}" for k, v in sorted(reasons.items())),
                  ""]

    events = by_kind.get("event", [])
    if events:
        lines += ["## Events", ""]
        lines += _table(["event", "details"],
                        [(r.get("event"),
                          "; ".join(f"{k}={_fmt(v)}" for k, v in
                                    sorted(r.items())
                                    if k not in ("kind", "event")))
                         for r in events]) + [""]

    for summ in by_kind.get("summary", []):
        name = summ.get("component", "run")
        lines += [f"## Summary ({name})", ""]
        lines += _table(["field", "value"],
                        [(k, v) for k, v in sorted(summ.items())
                         if k not in ("kind", "component")
                         and not isinstance(v, (list, dict))]) + [""]

    return "\n".join(lines).rstrip() + "\n"


def _detail_cell(detail: dict) -> str:
    """Compact scalar/short-list view of a drill finding's detail."""
    parts = []
    for k, v in sorted((detail or {}).items()):
        if isinstance(v, dict):
            continue
        if isinstance(v, list):
            if len(v) > 6 or any(isinstance(x, (dict, list)) for x in v):
                continue
            v = "[" + ", ".join(_fmt(x) for x in v) + "]"
        parts.append(f"{k}={_fmt(v)}")
    return "; ".join(parts) or "-"


def render_drill(doc: dict) -> str:
    """Markdown for a ``python -m repro.resilience.drill --out`` report."""
    findings = doc.get("findings") or []
    n_ok = sum(bool(f.get("ok")) for f in findings)
    lines = ["# Chaos drill report", "",
             ("**PASS**" if doc.get("passed") else "**FAIL**")
             + f" — {n_ok}/{len(findings)} findings on the "
             f"{doc.get('mesh', '?')} mesh (loss-parity rtol "
             f"{_fmt(doc.get('rtol'))})", ""]
    lines += _table(
        ["finding", "ok", "detail"],
        [(f.get("name"), "✓" if f.get("ok") else "✗ FAIL",
          _detail_cell(f.get("detail"))) for f in findings]) + [""]
    fallbacks = [e for f in findings
                 for e in (f.get("detail") or {}).get("fallback_events", [])]
    if fallbacks:
        lines += ["## Checkpoint fallbacks", ""]
        lines += _table(
            ["bad step", "restored step", "rejected", "error"],
            [(e.get("bad_step"), e.get("restored_step"),
              _fmt(str(e.get("rejected", "-"))),
              str(e.get("error", "-"))[:80]) for e in fallbacks]) + [""]
    return "\n".join(lines).rstrip() + "\n"


def render_analysis(doc: dict) -> str:
    """Markdown for a ``python -m repro.analysis --json`` document."""
    checked = ", ".join(f"{v} {k}" for k, v in sorted(
        (doc.get("checked") or {}).items()))
    lines = ["# Static-analysis report", "",
             ("**PASS**" if doc.get("ok") else "**FAIL**")
             + (f" — checked {checked}" if checked else ""), ""]
    findings = doc.get("findings") or []
    if findings:
        counts = doc.get("counts") or {}
        lines += [", ".join(f"{k}×{v}" for k, v in sorted(counts.items())),
                  ""]
        lines += _table(
            ["code", "location", "message"],
            [(f["code"],
              f"{f['path']}:{f['line']}" if f.get("line") else f["path"],
              f["message"]) for f in findings]) + [""]
    else:
        lines += ["no findings.", ""]
    suppressed = doc.get("suppressed") or []
    if suppressed:
        lines += [f"## Suppressed ({len(suppressed)})", ""]
        lines += _table(
            ["code", "location", "message"],
            [(f["code"],
              f"{f['path']}:{f['line']}" if f.get("line") else f["path"],
              f["message"]) for f in suppressed]) + [""]
    return "\n".join(lines).rstrip() + "\n"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("jsonl", help="telemetry JSONL (--metrics-out file) "
                                  "or a repro.analysis findings JSON")
    ap.add_argument("-o", "--out", default=None,
                    help="write markdown here (default: stdout)")
    args = ap.parse_args()

    # A findings document is one (possibly pretty-printed, so multi-line)
    # JSON object — try whole-file parse before the line-based JSONL path.
    doc = None
    try:
        with open(args.jsonl) as f:
            doc = json.load(f)
    except (json.JSONDecodeError, UnicodeDecodeError):
        pass
    if isinstance(doc, dict) and doc.get("kind") == "chaos_drill":
        md = render_drill(doc)
        records = [doc]
    elif isinstance(doc, dict) and "findings" in doc:
        md = render_analysis(doc)
        records = [doc]
    else:
        records = read_jsonl(args.jsonl)
        if not records:
            print(f"error: no records in {args.jsonl}", file=sys.stderr)
            sys.exit(1)
        md = render(records)
    if args.out:
        with open(args.out, "w") as f:
            f.write(md)
        print(f"wrote {args.out} ({len(records)} records)")
    else:
        print(md)


if __name__ == "__main__":
    main()
