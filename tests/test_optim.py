"""Optimizer: AdamW vs naive reference, schedule, clipping."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import adamw


def test_adamw_matches_reference(rng):
    p = {"w1": jax.random.normal(rng, (8, 8)),
         "norm": {"scale": jnp.ones((8,))}}
    g = jax.tree.map(lambda x: jnp.full_like(x, 0.1), p)
    st = adamw.init(p)
    lr, b1, b2, eps, wd = 1e-2, 0.9, 0.95, 1e-8, 0.1
    new_p, new_st = adamw.update(g, st, p, lr=lr, b1=b1, b2=b2, eps=eps,
                                 weight_decay=wd)
    # hand-rolled single-step reference
    m = 0.1 * (1 - b1)
    v = 0.01 * (1 - b2)
    step = (m / (1 - b1)) / (np.sqrt(v / (1 - b2)) + eps)
    want_w1 = np.asarray(p["w1"]) - lr * (step + wd * np.asarray(p["w1"]))
    np.testing.assert_allclose(new_p["w1"], want_w1, rtol=1e-5, atol=1e-6)
    # no weight decay on norm scales
    want_scale = 1.0 - lr * step
    np.testing.assert_allclose(new_p["norm"]["scale"],
                               np.full(8, want_scale), rtol=1e-5)
    assert int(new_st.count) == 1


def test_cosine_schedule():
    lr0 = adamw.cosine_schedule(jnp.int32(0), base_lr=1e-3,
                                warmup_steps=10, total_steps=100)
    lr_w = adamw.cosine_schedule(jnp.int32(5), base_lr=1e-3,
                                 warmup_steps=10, total_steps=100)
    lr_mid = adamw.cosine_schedule(jnp.int32(55), base_lr=1e-3,
                                   warmup_steps=10, total_steps=100)
    lr_end = adamw.cosine_schedule(jnp.int32(100), base_lr=1e-3,
                                   warmup_steps=10, total_steps=100,
                                   min_lr=1e-6)
    assert float(lr0) == 0.0
    np.testing.assert_allclose(float(lr_w), 5e-4, rtol=1e-5)
    assert 1e-6 < float(lr_mid) < 1e-3
    np.testing.assert_allclose(float(lr_end), 1e-6, rtol=1e-4)


def test_zero1_flat_update_matches_replicated(rng):
    """The ZeRO-1 flat shard update is the replicated AdamW, elementwise:
    gather the per-shard results and compare against adamw.update."""
    import numpy as np
    from jax.flatten_util import ravel_pytree

    p = {"w1": jax.random.normal(rng, (8, 6)),
         "norm": {"scale": jnp.ones((5,))}}          # 53 params, pad to 56
    g = jax.tree.map(lambda x: jnp.full_like(x, 0.1), p)
    n_shards = 4
    st = adamw.zero1_init(p, n_shards)
    L = adamw.zero1_padded_size(p, n_shards)
    assert L % n_shards == 0 and st.m.shape == (L,)

    pflat, unravel = ravel_pytree(p)
    gflat, _ = ravel_pytree(g)
    pad = L - pflat.size
    padv = lambda x: jnp.concatenate([x, jnp.zeros((pad,), jnp.float32)])
    mask = padv(adamw.decay_mask(p))
    pp, gp = padv(pflat), padv(gflat)

    shard = L // n_shards
    outs = []
    for i in range(n_shards):
        sl = slice(i * shard, (i + 1) * shard)
        new_p, _, _ = adamw.zero1_update_shard(
            gp[sl], st.m[sl], st.v[sl], pp[sl], mask[sl], st.count + 1,
            lr=1e-2)
        outs.append(new_p)
    gathered = unravel(jnp.concatenate(outs)[:pflat.size])

    ref_p, _ = adamw.update(g, adamw.init(p), p, lr=1e-2)
    for a, b in zip(jax.tree.leaves(gathered), jax.tree.leaves(ref_p)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-7, atol=1e-8)


def test_zero1_decay_mask_matches_decayable_rule(rng):
    p = {"w1": jax.random.normal(rng, (4, 4)),
         "norm": {"scale": jnp.ones((4,))},
         "bias": jnp.zeros((3,))}
    mask = adamw.decay_mask(p)
    # ravel order is the tree-flatten order: bias, norm/scale, w1
    import numpy as np
    np.testing.assert_array_equal(np.asarray(mask[:3]), 0.0)    # bias
    np.testing.assert_array_equal(np.asarray(mask[3:7]), 0.0)   # scale
    np.testing.assert_array_equal(np.asarray(mask[7:]), 1.0)    # w1


def test_clip_by_global_norm(rng):
    g = {"a": jnp.full((10,), 3.0), "b": jnp.full((10,), 4.0)}
    clipped, norm = adamw.clip_by_global_norm(g, 1.0)
    np.testing.assert_allclose(float(norm), np.sqrt(90 + 160), rtol=1e-5)
    cn = adamw.global_norm(clipped)
    np.testing.assert_allclose(float(cn), 1.0, rtol=1e-5)
    # under the limit: unchanged
    g2 = {"a": jnp.full((4,), 1e-3)}
    c2, _ = adamw.clip_by_global_norm(g2, 1.0)
    np.testing.assert_allclose(c2["a"], g2["a"], rtol=1e-6)
