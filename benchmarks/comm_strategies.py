"""SP communication-strategy sweep (paper Table 6-style, on 8 virtual
host devices).

For every exchange strategy in ``repro/comm`` — AllGather with overlap on
and off (the A/B the paper's overlap claim rests on), the LASP-1-style
ring, and the ZeCO-style pipelined ring — plus the LASP-1 baseline layer,
this bench measures wall-clock (median/p90), reads the CommRecord tape
(bytes/steps on the wire), counts the compiled HLO collectives, and
asserts each strategy's collective budget. A second sweep covers the
LASP-2H softmax context exchange — K/V AllGather vs the ulysses
head-parallel All-to-All pair vs the Ring Attention baseline — and
asserts the ulysses per-device wire bytes beat the K/V gather at the
MHA head ratio. The sweep carries a
``comm_dtype`` column: the allgather strategy is measured with the fp32
and the bf16 wire (same single collective, half the bytes — the byte
ceiling is asserted against the dtype-true tape, since XLA-CPU's
float-normalization upcasts bf16 collectives in compiled HLO). Writes
``BENCH_comm.json`` at the repo root (schema in docs/communication.md).

The key derived quantity is the paper's: LASP-2's gather traffic is the
same at every sequence length (state bytes only), while the per-step ring
dependency chain is what stretches LASP-1.
"""

from __future__ import annotations

from benchmarks.common import (emit, run_subprocess_bench, telemetry_block,
                               write_bench_json)

BENCH_NAME = "comm"

_CODE = r"""
import json, time
import jax, jax.numpy as jnp
from repro.core.lasp2 import lasp2, SPConfig
from repro.core.baselines import lasp1
from repro.comm import tape, tape_summary
from repro.comm.budget import (assert_budget, lasp2_budget,
                               packed_state_bytes, ring_baseline_budget)
from repro.comm.primitives import auto_slices
from repro.launch.hlo_analysis import collective_counts, parse_collectives
from repro.launch.mesh import SEQ_AXIS, make_sp_mesh

W = 8
mesh = make_sp_mesh(W)
sp = SPConfig(mesh=mesh, sp_axis=SEQ_AXIS)
B, H, d = 1, 8, 64

from benchmarks.common import percentile

def bench(f, args, iters=5, warmup=2):
    for _ in range(warmup):
        f(*args).block_until_ready()
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        f(*args).block_until_ready()
        times.append((time.perf_counter() - t0) * 1e6)
    return {"median_us": percentile(times, 50),
            "p90_us": percentile(times, 90), "iters": iters}

res = {"world": W, "cases": []}
for S in (8192, 32768):
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, H, S, d), jnp.bfloat16) * 0.3
    k = jax.random.normal(ks[1], (B, H, S, d), jnp.bfloat16) * 0.3
    v = jax.random.normal(ks[2], (B, H, S, d), jnp.bfloat16) * 0.5
    sb32 = packed_state_bytes(B, H, d, d, "fp32")
    sb16 = packed_state_bytes(B, H, d, d, "bf16")
    cases = {
        "lasp2_allgather_overlap":
            (lambda a, b, c: lasp2(a, b, c, sp=sp, overlap="overlap"),
             lasp2_budget("allgather", W, state_bytes=sb32), "fp32"),
        "lasp2_allgather_no_overlap":
            (lambda a, b, c: lasp2(a, b, c, sp=sp, overlap="none"),
             lasp2_budget("allgather", W, state_bytes=sb32), "fp32"),
        # the comm_dtype column: same single collective, half the bytes
        # (ceiling asserted against the dtype-true CommRecord tape)
        "lasp2_allgather_bf16":
            (lambda a, b, c: lasp2(a, b, c, sp=sp, comm_dtype="bf16"),
             lasp2_budget("allgather", W, state_bytes=sb16), "bf16"),
        "lasp2_ring":
            (lambda a, b, c: lasp2(a, b, c, sp=sp, comm_strategy="ring"),
             lasp2_budget("ring", W), "fp32"),
        "lasp2_pipelined":
            (lambda a, b, c: lasp2(a, b, c, sp=sp,
                                   comm_strategy="pipelined"),
             lasp2_budget("pipelined", W, n_slices=auto_slices(d)), "fp32"),
        "lasp1_baseline":
            (lambda a, b, c: lasp1(a, b, c, sp=sp),
             ring_baseline_budget(W), "fp32"),
    }
    for name, (fn, budget, comm_dtype) in cases.items():
        jf = jax.jit(fn)
        with tape() as recs:
            compiled = jf.lower(q, k, v).compile()
        hlo = compiled.as_text()
        # every case stays on-budget: HLO counts + tape byte ceilings
        assert_budget(hlo, budget, W, records=recs)
        res["cases"].append({
            # seq_len in the name: cases must be unique per name so the
            # bench gate's row matching (scripts/bench_gate.py) never
            # collides entries across sequence lengths
            "name": f"{name}@S{S}", "seq_len": S,
            "comm_dtype": comm_dtype,
            "wall": bench(jf, (q, k, v)),
            "comm": tape_summary(recs),
            "hlo_collectives": collective_counts(hlo, W),
            # measured (ring-model) bytes of the compiled HLO, next to
            # the tape's expected bytes in "comm" (observability)
            "hlo_bytes": sum(c.traffic_bytes
                             for c in parse_collectives(hlo, W)),
        })

# --- LASP-2H hybrid context sweep: ulysses vs allgather vs ring -------------
# The softmax layers' context exchange on the same 8-wide axis: K/V
# AllGather (Alg. 7), the ulysses head-parallel All-to-All pair, and the
# Ring Attention baseline. MHA heads (8 = world) so the classic ulysses
# repartition divides; per-device wire bytes for ulysses are
# (hq+2·hkv)/w-scaled vs allgather's 2·hkv·(w-1) — the byte win the
# strategy exists for (docs/communication.md has the GQA caveat).
from repro.comm.budget import CollectiveBudget, hybrid_context_budget
from repro.core.baselines import ring_attention
from repro.core.lasp2h import (allgather_context_attention,
                               ulysses_context_attention)

Sh, Hq, Hkv, dh = 4096, 8, 8, 64
ks = jax.random.split(jax.random.PRNGKey(1), 3)
qh = jax.random.normal(ks[0], (B, Hq, Sh, dh), jnp.bfloat16) * 0.3
kh = jax.random.normal(ks[1], (B, Hkv, Sh, dh), jnp.bfloat16) * 0.3
vh = jax.random.normal(ks[2], (B, Hkv, Sh, dh), jnp.bfloat16) * 0.5
hdims = dict(b=B, hq=Hq, hkv=Hkv, c=Sh // W, dh=dh, compute_itemsize=2)
hybrid_cases = {
    "hybrid_allgather":
        (lambda a, b, c: allgather_context_attention(a, b, c, sp=sp),
         hybrid_context_budget("allgather", W, sp=1, **hdims), "fp32"),
    "hybrid_ulysses":
        (lambda a, b, c: ulysses_context_attention(a, b, c, sp=sp),
         hybrid_context_budget("ulysses", W, sp=1, **hdims), "fp32"),
    "hybrid_ring_baseline":
        (lambda a, b, c: ring_attention(a, b, c, sp=sp),
         # the K and V rotation ops of the scanned ring (W-1 sequential
         # steps each on the tape)
         CollectiveBudget({"collective-permute": 2}), "fp32"),
}
hbytes = {}
for name, (fn, budget, comm_dtype) in hybrid_cases.items():
    jf = jax.jit(fn)
    with tape() as recs:
        compiled = jf.lower(qh, kh, vh).compile()
    hlo = compiled.as_text()
    assert_budget(hlo, budget, W, records=recs)
    hbytes[name] = tape_summary(recs).get("total_bytes", 0)
    res["cases"].append({
        "name": f"{name}@S{Sh}", "seq_len": Sh,
        "comm_dtype": comm_dtype,
        "wall": bench(jf, (qh, kh, vh)),
        "comm": tape_summary(recs),
        "hlo_collectives": collective_counts(hlo, W),
        "hlo_bytes": sum(c.traffic_bytes
                         for c in parse_collectives(hlo, W)),
    })
# the acceptance inequality: ulysses per-device wire bytes beat the K/V
# allgather at this head ratio (and both are budget-asserted above)
assert 0 < hbytes["hybrid_ulysses"] < hbytes["hybrid_allgather"], hbytes
print(json.dumps(res))
"""


def analytic_rows():
    """Paper §3.4 framing for the sweep: per-device exchange traffic is
    sequence-length-independent for every state-exchange strategy (the
    state is dk×dv per head) — what distinguishes them is the number of
    *sequential* steps on the critical path."""
    w = 8
    return [
        ("derived/allgather_steps", 0, 1),
        ("derived/ring_steps", 0, w - 1),
        ("derived/pipelined_steps", 0,
         f"{w - 1}-deep x k independent slice chains"),
        ("derived/traffic_vs_seqlen", 0, "constant (state bytes only)"),
    ]


def main():
    res = run_subprocess_bench(_CODE, devices=8, timeout=2400)
    rows = []
    for case in res["cases"]:
        wall = case["wall"]
        comm = case["comm"]
        rows.append((
            f"comm/{case['name']}",
            wall["median_us"],
            f"p90={wall['p90_us']:.0f}us;"
            f"bytes={comm.get('total_bytes', 0)};"
            f"steps={comm.get('total_steps', 0)};"
            f"dtype={case.get('comm_dtype', 'fp32')}"))
    rows += [(f"comm/{n}", u, d) for n, u, d in analytic_rows()]
    emit(rows)
    # benchmarks.run writes BENCH_comm.json from this payload (the
    # __main__ path below covers standalone invocation)
    return {
        "world": res["world"],
        "cases": res["cases"],
        "rows": [{"name": n, "us_per_call": us, "derived": d}
                 for n, us, d in rows],
        "budgets_verified": True,   # assert_budget ran inside the sweep
        # expected = CommRecord tape, measured = compiled-HLO ring-model
        # bytes, summed over the sweep (per-case splits live in "cases")
        "telemetry": telemetry_block(
            expected_collective_bytes=sum(
                c["comm"].get("total_bytes", 0) for c in res["cases"]),
            measured_collective_bytes=sum(
                c.get("hlo_bytes", 0) for c in res["cases"])),
    }


if __name__ == "__main__":
    write_bench_json(BENCH_NAME, main())
