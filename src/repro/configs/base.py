"""Config dataclasses: model architecture, input shapes, run/parallelism.

Every assigned architecture instantiates :class:`ModelConfig` in its own
``repro/configs/<id>.py``. Shapes are global (arch-independent) and defined
here. A "cell" = (arch × shape); the dry-run and roofline iterate cells.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclass(frozen=True)
class LinearAttnConfig:
    """Linear-attention variant settings (paper §4 modules)."""

    feature_map: str = "identity"   # identity | elu1 | silu | relu | taylor
    decay: str = "none"             # none | retention | lightning | data
    backward: str = "faithful"      # faithful (Alg. 3/4) | autodiff
    block_size: int = 128


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 8
    top_k: int = 2
    capacity_factor: float = 1.25
    n_shared_experts: int = 0       # dense "shared" experts (Moonlight-style)
    router_z_coef: float = 1e-3


@dataclass(frozen=True)
class MambaConfig:
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    headdim: int = 64
    ngroups: int = 1


@dataclass(frozen=True)
class LayerSpec:
    """One layer of the repeating pattern.

    mixer: softmax | linear | mamba2 | hymba | cross
    mlp:   dense | moe | none
    """

    mixer: str = "softmax"
    mlp: str = "dense"
    sliding_window: Optional[int] = None   # softmax/hymba attention window
    is_global: bool = True                 # hymba: full-attention layer?


@dataclass(frozen=True)
class EncoderConfig:
    """Auxiliary encoder stack (Whisper). Frontend is a stub: the model
    consumes precomputed frame embeddings of shape (B, n_frames, d_model)."""

    n_layers: int = 6
    n_frames: int = 1500


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False

    # layer pattern: `pattern` repeated `n_layers / len(pattern)` times.
    pattern: Tuple[LayerSpec, ...] = (LayerSpec(),)

    linear_attn: LinearAttnConfig = field(default_factory=LinearAttnConfig)
    moe: Optional[MoEConfig] = None
    mamba: Optional[MambaConfig] = None
    encoder: Optional[EncoderConfig] = None
    # VLM: number of (stub) image tokens cross-attended by "cross" layers.
    n_image_tokens: int = 0

    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    mlp_act: str = "swiglu"         # swiglu | gelu (whisper)

    # padded for TP divisibility / MXU alignment
    vocab_pad_multiple: int = 128

    # provenance note: [source; verified-tier]
    source: str = ""

    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        if self.n_layers % len(self.pattern):
            raise ValueError(
                f"{self.name}: n_layers={self.n_layers} not divisible by "
                f"pattern length {len(self.pattern)}")

    @property
    def padded_vocab(self) -> int:
        return _round_up(self.vocab_size, self.vocab_pad_multiple)

    @property
    def n_groups(self) -> int:
        return self.n_layers // len(self.pattern)

    @property
    def subquadratic(self) -> bool:
        """True if no layer does full (unwindowed) softmax attention over
        the *text* sequence — the ``long_500k`` eligibility rule. Hymba's
        three global layers are decode-time linear-per-step, so hymba
        counts as sub-quadratic for the decode-only long shape."""
        for s in self.pattern:
            if s.mixer == "softmax" and s.sliding_window is None:
                return False
        return True

    def linearize(self, hybrid_every: int = 0) -> "ModelConfig":
        """Paper's Linear-X recipe: replace softmax mixers with linear
        attention; ``hybrid_every=4`` keeps every 4th *softmax* layer as
        softmax (the paper's 1/4 hybrid). Kept softmax layers get a sliding
        window so the hybrid stays sub-quadratic for long_500k. Non-softmax
        mixers (cross/mamba2/hymba) are preserved."""
        unit = self.pattern
        if hybrid_every and len(unit) == 1:
            unit = unit * hybrid_every   # expand so every k-th can differ
        count = 0
        new = []
        for spec in unit:
            if spec.mixer != "softmax":
                new.append(spec)
                continue
            count += 1
            if hybrid_every and count % hybrid_every == 0:
                new.append(dataclasses.replace(spec, sliding_window=2048))
            else:
                new.append(dataclasses.replace(spec, mixer="linear",
                                               sliding_window=None))
        if self.n_layers % len(new):
            raise ValueError(
                f"n_layers={self.n_layers} not divisible by expanded "
                f"pattern {len(new)}")
        suffix = f"-hybrid{hybrid_every}" if hybrid_every else "-linear"
        return dataclasses.replace(self, name=self.name + suffix,
                                   pattern=tuple(new))

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + blocks), for sanity
        tests against the sizes in the architecture names."""
        d, dh = self.d_model, self.head_dim
        n = self.padded_vocab * d  # embed
        if not self.tie_embeddings:
            n += self.padded_vocab * d
        for spec in self.pattern:
            per = 2 * d  # two norms
            if spec.mixer in ("softmax", "linear"):
                per += d * (self.n_heads * dh) + 2 * d * (self.n_kv_heads * dh)
                per += (self.n_heads * dh) * d
            elif spec.mixer == "cross":
                per += d * (self.n_heads * dh) + 2 * d * (self.n_kv_heads * dh)
                per += (self.n_heads * dh) * d
            elif spec.mixer in ("mamba2", "hymba"):
                mb = self.mamba or MambaConfig()
                d_in = mb.expand * d if spec.mixer == "mamba2" else d
                nh = d_in // mb.headdim
                conv_ch = d_in + 2 * mb.ngroups * mb.d_state
                per += d * (2 * d_in + 2 * mb.ngroups * mb.d_state + nh)
                per += conv_ch * mb.d_conv + d_in * d + 2 * nh + d_in
                if spec.mixer == "hymba":
                    per += d * (self.n_heads * dh) \
                        + 2 * d * (self.n_kv_heads * dh) \
                        + (self.n_heads * dh) * d
            n_mats = 2 if self.mlp_act == "gelu" else 3
            if spec.mlp == "dense":
                per += n_mats * d * self.d_ff
            elif spec.mlp == "moe":
                moe = self.moe
                per += d * moe.num_experts  # router
                per += moe.num_experts * 3 * d * self.d_ff
                if moe.n_shared_experts:
                    per += n_mats * d * self.d_ff * moe.n_shared_experts
            n += per * self.n_groups
        if self.encoder is not None:
            enc_per = 2 * d + d * (self.n_heads * dh) \
                + 2 * d * (self.n_kv_heads * dh) + (self.n_heads * dh) * d \
                + (2 if self.mlp_act == "gelu" else 3) * d * self.d_ff
            n += enc_per * self.encoder.n_layers
        return n

    def active_param_count(self) -> int:
        """Active (per-token) parameters — MoE counts top_k + shared experts."""
        if self.moe is None:
            return self.param_count()
        moe = self.moe
        full = self.param_count()
        n_moe_layers = sum(1 for s in self.pattern if s.mlp == "moe") \
            * self.n_groups
        inactive = (moe.num_experts - moe.top_k) * 3 * self.d_model \
            * self.d_ff * n_moe_layers
        return full - inactive


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                       # train | prefill | decode

    @property
    def is_train(self) -> bool:
        return self.kind == "train"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


@dataclass(frozen=True)
class RunConfig:
    """Per-run knobs resolved by the launcher (overridable via CLI)."""

    num_microbatches: int = 1        # gradient accumulation steps
    remat: str = "full"              # full | dots | none
    use_pallas: Optional[bool] = None
    learning_rate: float = 3e-4
    min_lr: float = 1e-6             # paper §4.1
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1        # paper §4.1
    grad_clip: float = 1.0           # paper §4.1
    adam_b1: float = 0.9             # paper §4.1
    adam_b2: float = 0.95            # paper §4.1
    seed: int = 0
    zero1: bool = True               # shard optimizer state over data axis
    scan_unroll: bool = False        # unroll layer/microbatch scans (roofline cost extrapolation)
    cast_params_once: bool = False   # §Perf: bf16-cast params once per step (halves FSDP gather traffic)
    infer_bf16: bool = True          # inference cells hold bf16 params
    infer_fsdp_budget_gb: float = 6.0  # drop FSDP at inference if params fit
    banded_windows: bool = True      # §Perf: banded sliding-window attention
    bf16_params: bool = False        # §Perf: bf16 weight storage (f32 Adam moments)
    microbatch_tokens: int = 4096    # per-device per-microbatch token target
    grad_compression: bool = False   # error-feedback bf16 cross-pod allreduce
    # SP communication subsystem (repro/comm, docs/communication.md).
    # The CLI-facing string triple; ``comm_spec()`` folds it into the
    # one validated ``repro.comm.CommSpec`` the plan factory consumes.
    comm_strategy: str = "allgather"   # allgather | ring | pipelined | ulysses
    comm_overlap: str = "overlap"      # overlap | none (A/B benchmarking)
    comm_dtype: str = "fp32"           # fp32 | bf16 exchange payloads
    #   (bf16 halves SP state/KV all-gather bytes; combines stay fp32)
    # DP×SP(×TP) training mesh (docs/parallelism.md): dp_degree ×
    # sp_degree × tp_degree devices, batch over "data" × sequence over
    # "sequence" (and "model" when tp_degree > 1 — the 3D ulysses
    # deployment). 0 = unset (launchers fall back to single-device or
    # the legacy 1-D mesh; tp_degree 0 means 1).
    dp_degree: int = 0
    sp_degree: int = 0
    tp_degree: int = 0
    # Kernel dispatch (repro/kernels/ops.py): intra-chunk/attention compute
    # path — "xla" | "pallas" | "interpret"; None = platform default
    # (pallas on TPU, xla elsewhere).
    kernel_backend: Optional[str] = None
    # Numerical health guard (repro/resilience, docs/resilience.md):
    # in-graph finite check over loss+grads piggybacked on the packed
    # gradient all-reduce (zero extra collectives), rolling-median
    # grad-norm spike clipping, skip-step counters and a consecutive-skip
    # abort. Opt-in so the default compiled step (and its committed bench
    # baselines) is bit-identical with the guard absent.
    guard: bool = False
    guard_window: int = 32           # rolling grad-norm window (per-step medians)
    guard_spike_factor: float = 4.0  # clip to spike_factor × median on spikes
    guard_max_consecutive_skips: int = 8   # loop aborts (GuardAbort) past this
    # Verify per-array SHA-256 checksums on restore; on a corrupt latest
    # checkpoint the loop falls back to the newest VALID one.
    ckpt_verify: bool = True
    # Deterministic fault injection (drill/tests only, compiled into the
    # step): poison the local grads with NaN at these steps / force a
    # skip verdict at these steps.
    chaos_nan_steps: Tuple[int, ...] = ()
    chaos_skip_steps: Tuple[int, ...] = ()

    def comm_spec(self):
        """The validated ``repro.comm.CommSpec`` for this run — the one
        object that threads strategy/overlap/wire-dtype to the plan."""
        from repro.comm.spec import CommSpec
        return CommSpec(strategy=self.comm_strategy,
                        overlap=self.comm_overlap, dtype=self.comm_dtype)
