"""Production meshes.

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run sets ``XLA_FLAGS`` for 512 host devices before any jax
initialization; tests and benches see the default single device).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 16×16 = 256 chips, ("data", "model").
    Multi-pod: 2×16×16 = 512 chips, ("pod", "data", "model")."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_test_mesh(shape=(4, 2), axes=("data", "model")):
    """Small mesh for in-repo distributed tests (8 host devices)."""
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
