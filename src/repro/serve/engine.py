"""Constant-memory serving engine with continuous batching.

The decode cache holds, per linear/SSM layer, only the fp32 ``dk × dv``
recurrent state plus its cumulative log decay — the paper's
constant-memory-inference property, O(1) in context length — and, per
softmax layer of a LASP-2H hybrid, a ring-buffer KV cache whose length is
the layer's sliding window (also O(1) for windowed layers). Prefill reuses
the chunked scan (Pallas ``lasp2_chunk`` kernel on TPU) and lands the final
per-layer states directly in the cache; decode advances every sequence by
one ``recurrent_step`` — the prefix is never re-scanned.

Scheduling is continuous: a fixed grid of ``max_batch`` decode slots,
with per-step admission of waiting requests (batched prefill, grouped by
bucketed prompt length) and per-step eviction of finished ones
(:mod:`repro.serve.scheduler`). Per-request RNG streams make sampled
output independent of how requests were batched together.

API::

    engine = ServeEngine(cfg, params, max_len=2048, max_batch=8)
    uid = engine.submit([1, 2, 3], max_new_tokens=32, temperature=0.8)
    results = engine.run()          # {uid: np.ndarray of generated tokens}

    # or the one-shot batch form (ragged prompts welcome):
    outs = engine.generate(prompts, max_new_tokens=32)
"""

from __future__ import annotations

import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import model as M
from repro.obs.metrics import Metrics, as_sink
from repro.serve.scheduler import ContinuousScheduler, PrefillBatch, Request
from repro.sharding.rules import Parallelism, local_plan


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, *,
                 plan: Optional[Parallelism] = None, max_len: int = 2048,
                 max_batch: int = 8, bucket_lengths: Optional[bool] = None,
                 sink=None, max_queue: Optional[int] = None,
                 finished_timeout: Optional[float] = None):
        self.cfg = cfg
        self.params = params
        self.plan = plan or local_plan()
        self.max_len = max_len
        self.max_batch = max_batch
        # Telemetry (docs/observability.md): one Metrics registry shared
        # with the scheduler; per-request records go to ``sink`` as each
        # request finishes. All host-side — no device ops are added.
        self.sink = as_sink(sink)
        self.metrics = Metrics()
        self._submit_t: Dict[int, float] = {}
        self._ttft: Dict[int, float] = {}
        # Length bucketing left-pads prompts, which is only exact for pure
        # recurrent stacks; hybrids fall back to exact-length groups.
        self.bucket_lengths = M.pad_safe(cfg) if bucket_lengths is None \
            else bucket_lengths
        # Degradation knobs (docs/resilience.md): bounded admission
        # queue (submit raises QueueFullError when full) and eviction of
        # uncollected finished results.
        self.sched = ContinuousScheduler(max_batch, max_len,
                                         bucket_lengths=self.bucket_lengths,
                                         metrics=self.metrics,
                                         max_queue=max_queue,
                                         finished_timeout=finished_timeout)

        self._cache = M.init_cache(cfg, max_batch, max_len)
        self._tok = np.zeros((max_batch,), np.int32)
        self._temps = np.zeros((max_batch,), np.float32)
        self._keys = np.zeros((max_batch, 2), np.uint32)

        def _prefill(params_, tokens, pad_lens):
            return M.prefill(params_, tokens, cfg, self.plan,
                             max_len=max_len, pad_lens=pad_lens)

        def _prefill_exact(params_, tokens):
            return M.prefill(params_, tokens, cfg, self.plan,
                             max_len=max_len)

        def _decode(params_, tok, cache):
            return M.decode_step(params_, tok, cache, cfg, self.plan)

        def _insert(cache, small, slots):
            layers = jax.tree.map(
                lambda b, s: b.at[:, slots].set(s.astype(b.dtype),
                                                mode="drop"),
                cache["layers"], small["layers"])
            pos = cache["pos"].at[slots].set(small["pos"], mode="drop")
            return {"layers": layers, "pos": pos}

        def _sample(logits, temps, base_keys, steps):
            def one(lg, t, k, s):
                kk = jax.random.fold_in(k, s)
                g = jax.random.categorical(kk, lg / jnp.maximum(t, 1e-6))
                return jnp.where(t <= 0.0,
                                 jnp.argmax(lg, -1), g).astype(jnp.int32)
            return jax.vmap(one)(logits, temps, base_keys, steps)

        def _prefill_static(params_, tokens, img_emb, enc_frames):
            return M.prefill(params_, tokens, cfg, self.plan,
                             max_len=max_len, img_emb=img_emb,
                             enc_frames=enc_frames)

        def _decode_static(params_, tok, cache, img_emb, enc_out):
            return M.decode_step(params_, tok, cache, cfg, self.plan,
                                 img_emb=img_emb, enc_out=enc_out)

        self._prefill = jax.jit(_prefill)
        self._prefill_exact = jax.jit(_prefill_exact)
        self._decode = jax.jit(_decode, donate_argnums=(2,))
        self._insert = jax.jit(_insert, donate_argnums=(0,))
        self._sample = jax.jit(_sample)
        # static-batch (encoder / image) path: jitted once, reused across
        # generate() calls
        self._prefill_static = jax.jit(_prefill_static)
        self._decode_static = jax.jit(_decode_static, donate_argnums=(2,))
        self._encode = jax.jit(
            lambda p, f: M.encode(p, f, cfg, self.plan)) \
            if cfg.encoder is not None else None

        for kind, nbytes in self.cache_stats().items():
            if not kind.endswith("_arrays"):
                self.metrics.gauge(f"cache_bytes_{kind}", nbytes)

    # -- request API --------------------------------------------------------

    def submit(self, prompt, max_new_tokens: int, *,
               temperature: float = 0.0, eos_id: Optional[int] = None,
               seed: int = 0, stream: int = 0,
               deadline_s: Optional[float] = None) -> int:
        """Queue one request; returns its uid. Work happens in step().

        ``(seed, stream)`` names the request's RNG stream — sampling is
        deterministic in it, independent of how requests get batched.
        ``deadline_s``: evict the request (``finish_reason="deadline"``,
        partial tokens kept) if it hasn't finished this many seconds
        after submission. Raises
        :class:`repro.serve.scheduler.QueueFullError` when the bounded
        admission queue is full."""
        uid = self.sched.submit(prompt, max_new_tokens,
                                temperature=temperature, eos_id=eos_id,
                                seed=seed, stream=stream,
                                deadline_s=deadline_s)
        self._submit_t[uid] = time.perf_counter()
        return uid

    def step(self) -> List[Request]:
        """One scheduler tick: admit + prefill waiting requests into free
        slots, decode all active slots by one token. Returns the requests
        that finished this tick."""
        finished: List[Request] = list(self.sched.expire())
        for batch in self.sched.admit():
            finished += self._admit(batch)
        if self.sched.active:
            t0 = time.perf_counter()
            with jax.named_scope("decode"):
                logits, self._cache = self._decode(
                    self.params, jnp.asarray(self._tok), self._cache)
                steps = np.array([len(r.tokens) if r is not None else 0
                                  for r in self.sched.slots], np.int32)
                tok = np.asarray(self._sample(
                    logits, jnp.asarray(self._temps),
                    jnp.asarray(self._keys), jnp.asarray(steps)))
            active = [i for i, r in enumerate(self.sched.slots)
                      if r is not None]
            # np.asarray above blocked on the device, so the wall is fenced
            self.metrics.observe("decode_step_s", time.perf_counter() - t0)
            self.metrics.inc("decode_steps")
            self.metrics.inc("decode_tokens", len(active))
            self._tok[active] = tok[active]
            finished += self.sched.record_step(tok)
        n_active = len(self.sched.active)
        self.metrics.gauge("active_slots", n_active)
        self.metrics.gauge("cache_occupancy", n_active / self.max_batch)
        for r in finished:
            self._finish(r)
        return finished

    def run(self) -> Dict[int, np.ndarray]:
        """Drive step() until all submitted requests finished; returns
        {uid: generated tokens}."""
        done: List[Request] = []
        while self.sched.has_work():
            done += self.step()
        return {r.uid: np.asarray(r.tokens, np.int32) for r in done}

    def _admit(self, batch: PrefillBatch) -> List[Request]:
        t0 = time.perf_counter()
        with jax.named_scope("prefill"):
            if self.bucket_lengths:
                logits, small = self._prefill(
                    self.params, jnp.asarray(batch.prompts),
                    jnp.asarray(batch.pad_lens))
            else:
                logits, small = self._prefill_exact(
                    self.params, jnp.asarray(batch.prompts))
            slots = jnp.asarray(batch.slots)
            self._cache = self._insert(self._cache, small, slots)
            temps = np.array([r.temperature for r in batch.requests],
                             np.float32)
            keys = np.stack([
                np.asarray(jax.random.fold_in(jax.random.PRNGKey(r.seed),
                                              r.stream), np.uint32)
                for r in batch.requests])
            tok = np.asarray(self._sample(
                logits, jnp.asarray(temps), jnp.asarray(keys),
                jnp.zeros((len(batch.requests),), jnp.int32)))
        now = time.perf_counter()
        self.metrics.observe("prefill_s", now - t0)
        self.metrics.inc("prefill_batches")
        self.metrics.inc("prefill_tokens", int(batch.prompts.size))
        for j, r in enumerate(batch.requests):
            self._tok[r.slot] = tok[j]
            self._temps[r.slot] = r.temperature
            self._keys[r.slot] = keys[j]
            # TTFT: submit() → the request's first token, which is sampled
            # right here from the prefill logits (not from the first
            # decode step)
            self._ttft[r.uid] = now - self._submit_t.get(r.uid, t0)
            self.metrics.observe("ttft_s", self._ttft[r.uid])
        return self.sched.record_prefill(batch, tok)

    def _finish(self, req: Request) -> None:
        """Emit the per-request telemetry record (kind="request")."""
        now = time.perf_counter()
        rec: Dict[str, Any] = {
            "kind": "request", "uid": req.uid,
            "prompt_len": req.prompt_len, "new_tokens": len(req.tokens),
            "finish_reason": req.finish_reason,
            "wall_s": now - self._submit_t.pop(req.uid, now),
        }
        ttft = self._ttft.pop(req.uid, None)
        if ttft is not None:
            rec["ttft_s"] = ttft
        self.sink.emit(rec)

    # -- one-shot batch API (back-compat) -----------------------------------

    def generate(self, prompts, max_new_tokens: int, *, temperature=0.0,
                 seed: int = 0, img_emb=None, enc_frames=None,
                 eos_id: Optional[int] = None):
        """prompts: (B, S) int32 (or a ragged list of 1-D prompts).
        Returns (B, max_new_tokens) int32; rows that stop early at EOS are
        padded by repeating their final token."""
        if img_emb is not None or enc_frames is not None:
            return self._generate_static(prompts, max_new_tokens,
                                         temperature=temperature, seed=seed,
                                         img_emb=img_emb,
                                         enc_frames=enc_frames,
                                         eos_id=eos_id)
        assert not self.sched.has_work(), \
            "generate() needs an idle engine; use submit()/run() to mix"
        prompts = [np.asarray(p, np.int32).reshape(-1) for p in prompts]
        uids = [self.submit(p, max_new_tokens, temperature=temperature,
                            eos_id=eos_id, seed=seed, stream=i)
                for i, p in enumerate(prompts)]
        results = self.run()
        out = np.zeros((len(uids), max_new_tokens), np.int32)
        for i, uid in enumerate(uids):
            t = results[uid]
            out[i, :len(t)] = t
            if len(t) < max_new_tokens:      # early EOS: repeat last token
                out[i, len(t):] = t[-1]
        return out

    def _generate_static(self, prompts, max_new_tokens, *, temperature,
                         seed, img_emb, enc_frames, eos_id):
        """Static-batch path for encoder / image-conditioned models (the
        per-request aux inputs don't continuously batch)."""
        prompts = jnp.asarray(prompts, jnp.int32)
        b, s = prompts.shape
        if s + max_new_tokens > self.max_len:
            raise ValueError("max_len too small")
        enc_out = None
        if enc_frames is not None and self._encode is not None:
            enc_out = self._encode(self.params, enc_frames)
        logits, cache = self._prefill_static(self.params, prompts, img_emb,
                                             enc_frames)
        key = jax.random.PRNGKey(seed)
        out = []
        done = np.zeros((b,), bool)
        tok = self._sample_static(logits, temperature, key)
        for i in range(max_new_tokens):
            out.append(np.asarray(tok))
            if eos_id is not None:
                done |= (out[-1] == eos_id)
                if done.all():
                    out.extend([out[-1]] * (max_new_tokens - i - 1))
                    break
            logits, cache = self._decode_static(self.params, tok, cache,
                                                img_emb, enc_out)
            key, sub = jax.random.split(key)
            tok = self._sample_static(logits, temperature, sub)
        return np.stack(out[:max_new_tokens], axis=1)

    @staticmethod
    def _sample_static(logits, temperature, key):
        if temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(
            key, logits / temperature, axis=-1).astype(jnp.int32)

    # -- introspection ------------------------------------------------------

    def stats(self) -> Dict[str, Any]:
        """Flat snapshot of the engine+scheduler telemetry: counters
        (submitted/admitted/evicted/…), gauges (queue_depth,
        cache_occupancy + peaks), and latency histogram summaries
        (``decode_step_s_p50`` … ``ttft_s_p99``), plus the derived
        steady-state decode throughput."""
        out = self.metrics.snapshot()
        dec = self.metrics.histograms.get("decode_step_s")
        if dec is not None and dec.total:
            out["decode_tokens_per_s"] = \
                self.metrics.counters.get("decode_tokens", 0) / dec.total
        return out

    def reset_metrics(self) -> None:
        """Drop accumulated telemetry (e.g. after a compile-warmup pass,
        so percentiles reflect the warm path); the fresh registry is
        re-shared with the scheduler and the static cache gauges
        re-seeded."""
        self.metrics = self.sched.metrics = Metrics()
        for kind, nbytes in self.cache_stats().items():
            if not kind.endswith("_arrays"):
                self.metrics.gauge(f"cache_bytes_{kind}", nbytes)

    def emit_summary(self, **extra) -> Dict[str, Any]:
        """Emit (and return) the run-level ``summary`` record through the
        sink — the serve-side analogue of the train flight recorder's
        summary."""
        rec: Dict[str, Any] = {"kind": "summary", "component": "serve"}
        rec.update(self.stats())
        rec.update(extra)
        self.sink.emit(rec)
        return rec

    def cache_stats(self) -> Dict[str, int]:
        """Decode-cache footprint by kind — byte-accurate totals plus the
        array count per kind (``<kind>_arrays``). ``linear_state`` (+ its
        log decays) is constant in both context length and max_len — the
        paper's claim; ``kv_ring`` scales with the softmax layers' window,
        not the context. Exact expectations (asserted in the serve tests):
        per linear layer ``B·H·(dk·dv + 1)·4`` bytes (fp32 state + log
        decay), per softmax layer ``2·B·n_kv·ring·head_dim·2`` (bf16 K/V)
        ``+ B·ring·4`` (int32 positions)."""
        stats = {"linear_state": 0, "kv_ring": 0, "conv": 0, "other": 0}
        arrays = dict.fromkeys(stats, 0)

        def visit(path, leaf):
            name = path[-1].key if hasattr(path[-1], "key") else ""
            if name in ("m", "log_decay"):
                kind = "linear_state"
            elif name in ("k", "v", "kpos"):
                kind = "kv_ring"
            elif name.startswith("conv_"):
                kind = "conv"
            else:
                kind = "other"
            stats[kind] += leaf.nbytes
            arrays[kind] += 1
            return leaf

        jax.tree_util.tree_map_with_path(visit, self._cache["layers"])
        stats["total"] = sum(stats.values())
        stats.update({f"{k}_arrays": n for k, n in arrays.items()})
        return stats
