"""Chaos drill: the real train loop under a deterministic fault schedule.

  PYTHONPATH=src python -m repro.resilience.drill \\
      --out drill_report.json --metrics-out drill_metrics.jsonl

Runs the (2, 4) DP×SP manual train step (8 virtual CPU devices) through
the fault catalog (docs/resilience.md) and asserts recovery AND loss
parity:

* ``nan_skip_parity`` — NaN gradients injected at step k: the guard
  skips the step, the trajectory before the fault is bitwise the
  fault-free one, and from the fault on it equals a forced-skip
  reference (a NaN step behaves exactly like a no-op step).
* ``corrupt_fallback_resume`` — training interrupted, the LATEST
  checkpoint corrupted on disk: resume falls back to the newest valid
  checkpoint and recomputes to the end; the recomputed losses match the
  uninterrupted reference at rtol ≤ 1e-6 and the fallback is recorded.
* ``save_ioerror_retry`` — transient IOError during save: retried with
  backoff, checkpoint verifies afterwards.
* ``kill_mid_save`` — the writer dies mid-archive: the previous
  checkpoint is untouched, the async error surfaces on ``wait()``, the
  next save succeeds.
* ``straggler_step`` — an injected input-pipeline straggler shows up in
  the step record's data-phase wall.
* ``consecutive_skip_abort`` — a persistent NaN source trips the
  consecutive-skip threshold: the loop raises GuardAbort after saving a
  clean checkpoint.

Exit code 0 iff every finding passed. Findings JSON + the recovery
run's telemetry JSONL are written for CI artifacts
(``scripts/report.py`` renders both).
"""

from __future__ import annotations

import argparse
import json
import os
import sys

# 8 virtual CPU devices for the (2,4) mesh — must land before jax
# initializes its backends (so: before any repro import that pulls jax
# in).
_DEVICE_FLAG = "--xla_force_host_platform_device_count=8"
if _DEVICE_FLAG.split("=")[0] not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = \
        (os.environ.get("XLA_FLAGS", "") + " " + _DEVICE_FLAG).strip()
os.environ.setdefault("JAX_PLATFORMS", "cpu")

NAN_STEP = 5          # fault schedule: NaN grads at this step
TOTAL = 12            # drill run length
INTERRUPT_AT = 8      # resume scenario stops here, then corrupts latest
CKPT_EVERY = 4
RTOL = 1e-6           # acceptance: loss parity on recomputed steps


def _quiet(_msg):
    pass


def _mk(chaos_nan=(), chaos_skip=(), max_skips=8):
    from repro.configs.base import RunConfig
    return RunConfig(num_microbatches=1, remat="none", total_steps=TOTAL,
                     warmup_steps=2, scan_unroll=False, guard=True,
                     chaos_nan_steps=tuple(chaos_nan),
                     chaos_skip_steps=tuple(chaos_skip),
                     guard_max_consecutive_skips=max_skips)


def _train(run, *, dp=2, sp=4, ckpt_dir=None, max_steps=None, sink=None,
           data=None, seq=64, batch=8):
    from repro.configs import get_smoke
    from repro.data.pipeline import SyntheticLM
    from repro.launch.mesh import make_training_mesh
    from repro.sharding.rules import local_plan, make_plan
    from repro.train.loop import train

    cfg = get_smoke("linear-llama3-1b")
    if data is None:
        data = SyntheticLM(cfg.vocab_size, seq, batch, seed=3)
    if dp * sp == 1:
        plan = local_plan()
    else:
        mesh = make_training_mesh(dp, sp)
        plan = make_plan(mesh, "train", global_batch=batch,
                         n_kv_heads=cfg.n_kv_heads, n_heads=cfg.n_heads,
                         comm=run.comm_spec(), zero1=run.zero1)
    return train(cfg, run, data, plan=plan, ckpt_dir=ckpt_dir,
                 ckpt_every=CKPT_EVERY, log_every=1000, log_fn=_quiet,
                 max_steps=max_steps, sink=sink)


def _losses(history):
    return {h["step"]: h["loss"] for h in history}


def _close(a, b):
    import numpy as np
    return bool(np.allclose(a, b, rtol=RTOL, atol=0.0))


def drill_train_scenarios(tmp, metrics_out=None):
    """The three training findings share one set of runs (4 compiles)."""
    import numpy as np

    from repro.obs import InMemorySink, JsonlSink
    from repro.resilience import chaos

    findings = []

    # fault-free + forced-skip references (no checkpointing)
    _, hist_base = _train(_mk())
    _, hist_skip = _train(_mk(chaos_skip=(NAN_STEP,)))
    base, skip = _losses(hist_base), _losses(hist_skip)

    # NaN-injected run, interrupted at INTERRUPT_AT with checkpoints
    ckpt = os.path.join(tmp, "drill_ckpt")
    _, hist1 = _train(_mk(chaos_nan=(NAN_STEP,)), ckpt_dir=ckpt,
                      max_steps=INTERRUPT_AT)
    l1 = _losses(hist1)
    skipped_at = [h["step"] for h in hist1 if h["skipped"]]

    pre_ok = _close([l1[s] for s in range(NAN_STEP)],
                    [base[s] for s in range(NAN_STEP)])
    post_ok = _close([l1[s] for s in range(NAN_STEP, INTERRUPT_AT)],
                     [skip[s] for s in range(NAN_STEP, INTERRUPT_AT)])
    findings.append({
        "name": "nan_skip_parity",
        "ok": skipped_at == [NAN_STEP] and pre_ok and post_ok,
        "detail": {
            "skipped_steps": skipped_at,
            "pre_fault_matches_fault_free": pre_ok,
            "post_fault_matches_forced_skip": post_ok,
            "skipped_total": hist1[-1]["skipped_steps"],
        },
    })

    # corrupt the LATEST checkpoint, resume: must fall back + recompute
    corrupted = chaos.corrupt_checkpoint(ckpt)
    sink = JsonlSink(metrics_out) if metrics_out else InMemorySink()
    state2, hist2 = _train(_mk(chaos_nan=(NAN_STEP,)), ckpt_dir=ckpt,
                           sink=sink)
    if metrics_out:
        sink.close()
        with open(metrics_out) as f:
            records = [json.loads(ln) for ln in f if ln.strip()]
    else:
        records = sink.records
    l2 = _losses(hist2)
    fallback = [r for r in records if r.get("event") == "ckpt_fallback"]
    resumed_from = hist2[0]["step"] if hist2 else None
    steps2 = sorted(l2)
    recompute_ok = _close([l2[s] for s in steps2],
                          [skip[s] for s in steps2])
    reskipped = [h["step"] for h in hist2 if h["skipped"]]
    findings.append({
        "name": "corrupt_fallback_resume",
        "ok": (bool(fallback)
               and fallback[0].get("bad_step") == INTERRUPT_AT
               and fallback[0].get("restored_step") == CKPT_EVERY
               and resumed_from == CKPT_EVERY
               and steps2 == list(range(CKPT_EVERY, TOTAL))
               and recompute_ok
               and reskipped == [NAN_STEP]
               and int(np.asarray(state2["step"])) == TOTAL),
        "detail": {
            "corrupted": os.path.relpath(corrupted, tmp),
            "fallback_events": fallback,
            "resumed_from": resumed_from,
            "recomputed_steps": [steps2[0], steps2[-1]] if steps2 else [],
            "losses_match_reference_rtol": RTOL,
            "recompute_ok": recompute_ok,
            "reskipped": reskipped,
        },
    })
    return findings, records


def drill_save_ioerror(tmp):
    import jax.numpy as jnp

    from repro.checkpoint.manager import CheckpointManager
    from repro.resilience import chaos

    tree = {"w": jnp.arange(16, dtype=jnp.float32)}
    mgr = CheckpointManager(os.path.join(tmp, "flaky"), retries=3,
                            backoff_s=0.01)
    flaky = chaos.FlakySavez(fails=2)
    mgr._savez = flaky
    mgr.save_async(1, tree)
    mgr.wait()                         # retried write: must NOT raise
    out = mgr.restore(1, {"w": jnp.zeros((16,), jnp.float32)})
    ok = (flaky.calls == 3 and mgr.latest_step() == 1
          and float(out["w"][7]) == 7.0)
    return [{"name": "save_ioerror_retry", "ok": ok,
             "detail": {"write_attempts": flaky.calls}}]


def drill_kill_mid_save(tmp):
    import jax.numpy as jnp

    from repro.checkpoint.manager import CheckpointManager
    from repro.resilience import chaos

    tree = {"w": jnp.arange(16, dtype=jnp.float32)}
    mgr = CheckpointManager(os.path.join(tmp, "killed"), backoff_s=0.01)
    mgr.save(1, tree)
    mgr._savez = chaos.KillingSavez()
    mgr.save_async(2, {"w": tree["w"] * 2})
    surfaced = False
    try:
        mgr.wait()                     # the thread's crash must surface
    except chaos.KillSave:
        surfaced = True
    intact = mgr.latest_step() == 1
    mgr._savez = __import__("numpy").savez
    mgr.save(2, {"w": tree["w"] * 2})  # recovery write
    out = mgr.restore(2, {"w": jnp.zeros((16,), jnp.float32)})
    ok = (surfaced and intact and mgr.latest_step() == 2
          and float(out["w"][3]) == 6.0)
    return [{"name": "kill_mid_save", "ok": ok,
             "detail": {"error_surfaced": surfaced,
                        "previous_checkpoint_intact": intact}}]


def drill_straggler():
    from repro.configs import get_smoke
    from repro.data.pipeline import SyntheticLM
    from repro.obs import InMemorySink
    from repro.resilience import chaos

    run = _mk()
    vocab = get_smoke("linear-llama3-1b").vocab_size
    data = chaos.StragglerData(
        SyntheticLM(vocab, 32, 4, seed=3), at_step=TOTAL - 2, sleep_s=0.5)
    sink = InMemorySink()
    _train(run, dp=1, sp=1, data=data, sink=sink, seq=32, batch=4)
    steps = [r for r in sink.records if r.get("kind") == "step"]
    hit = [r for r in steps if r.get("step") == TOTAL - 2]
    ok = bool(hit) and hit[0].get("data_s", 0.0) >= 0.5 \
        and len(steps) == TOTAL
    return [{"name": "straggler_step", "ok": ok,
             "detail": {"data_phase_wall_s": hit[0].get("data_s")
                        if hit else None}}]


def drill_consecutive_abort(tmp):
    from repro.checkpoint.manager import CheckpointManager
    from repro.resilience.guard import GuardAbort

    run = _mk(chaos_nan=tuple(range(2, TOTAL)), max_skips=3)
    ckpt = os.path.join(tmp, "abort_ckpt")
    aborted = False
    try:
        _train(run, dp=1, sp=1, ckpt_dir=ckpt, seq=32, batch=4)
    except GuardAbort:
        aborted = True
    mgr = CheckpointManager(ckpt)
    step = mgr.latest_step()
    ok = aborted and step is not None
    if ok:   # the abort-path checkpoint must verify (params are clean)
        import jax
        import jax.numpy as jnp
        from jax.flatten_util import ravel_pytree

        from repro.configs import get_smoke
        from repro.train.step import init_state
        cfg = get_smoke("linear-llama3-1b")
        target = init_state(jax.random.PRNGKey(0), cfg, run)
        restored = mgr.restore(step, target)
        ok = bool(jnp.isfinite(ravel_pytree(restored["params"])[0]).all())
    return [{"name": "consecutive_skip_abort", "ok": ok,
             "detail": {"aborted": aborted, "checkpoint_step": step}}]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.resilience.drill",
        description="fault-injection drill over the real train loop")
    ap.add_argument("--out", default="drill_report.json",
                    help="findings JSON (CI artifact)")
    ap.add_argument("--metrics-out", default=None,
                    help="telemetry JSONL of the recovery run (render "
                         "with scripts/report.py)")
    ap.add_argument("--tmp", default=None,
                    help="scratch dir for drill checkpoints (default: a "
                         "fresh TemporaryDirectory)")
    args = ap.parse_args(argv)

    import tempfile

    # JsonlSink appends (crash-safe); a re-run must not accumulate the
    # previous drill's records or the parity checks read stale events.
    if args.metrics_out and os.path.exists(args.metrics_out):
        os.remove(args.metrics_out)

    findings = []
    with tempfile.TemporaryDirectory() as td:
        tmp = args.tmp or td
        f, _records = drill_train_scenarios(tmp, args.metrics_out)
        findings += f
        findings += drill_save_ioerror(tmp)
        findings += drill_kill_mid_save(tmp)
        findings += drill_straggler()
        findings += drill_consecutive_abort(tmp)

    n_bad = sum(not f["ok"] for f in findings)
    doc = {"kind": "chaos_drill", "mesh": "2x4", "rtol": RTOL,
           "passed": n_bad == 0, "findings": findings}
    with open(args.out, "w") as f:
        json.dump(doc, f, indent=2, default=str)
    for fd in findings:
        print(f"[{'ok' if fd['ok'] else 'FAIL'}] {fd['name']}")
        if not fd["ok"]:
            print(f"       {fd['detail']}")
    if n_bad:
        print(f"CHAOS DRILL FAILED: {n_bad}/{len(findings)} findings",
              file=sys.stderr)
        return 1
    print(f"ALL {len(findings)} CHAOS DRILL FINDINGS PASSED")
    return 0


if __name__ == "__main__":
    sys.exit(main())
