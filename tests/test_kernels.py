"""Per-kernel Pallas sweeps (interpret mode) vs the ref.py oracles,
plus the kernel-gradient battery for the custom_vjp backward kernels."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops
from repro.kernels.flash_attention import flash_attention
from repro.kernels.lasp2_chunk import lasp2_chunk_fwd
from repro.kernels.ref import flash_attention_ref, linear_attention_ref

TOL = {jnp.float32: 3e-4, jnp.bfloat16: 4e-2}
GRAD_TOL = 1e-3


@pytest.mark.parametrize("s,dk,dv", [(256, 64, 64), (512, 128, 128),
                                     (256, 32, 64), (128, 128, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("decay", [False, True])
def test_lasp2_chunk_kernel_sweep(rng, s, dk, dv, dtype, decay):
    bh = 3
    ks = jax.random.split(rng, 4)
    q = (jax.random.normal(ks[0], (bh, s, dk)) * 0.3).astype(dtype)
    k = (jax.random.normal(ks[1], (bh, s, dk)) * 0.3).astype(dtype)
    v = (jax.random.normal(ks[2], (bh, s, dv)) * 0.5).astype(dtype)
    la = (-jnp.abs(jax.random.normal(ks[3], (bh, s))) * 0.03) if decay \
        else jnp.zeros((bh, s))
    o, st, ld = lasp2_chunk_fwd(q, k, v, la, block_size=128, interpret=True)
    oref, stref = linear_attention_ref(q, k, v, la)
    t = TOL[dtype]
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(oref, np.float32), rtol=t, atol=t)
    np.testing.assert_allclose(st, stref, rtol=t, atol=t)
    np.testing.assert_allclose(ld, jnp.sum(la, -1), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("sq,sk,hq,hkv,dh", [
    (256, 256, 4, 2, 64), (128, 128, 8, 1, 64), (256, 256, 4, 4, 128),
    (128, 256, 4, 2, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal,window", [(True, None), (False, None),
                                           (True, 64), (False, 64)])
def test_flash_kernel_sweep(rng, sq, sk, hq, hkv, dh, dtype, causal,
                            window):
    b = 2
    ks = jax.random.split(rng, 3)
    q = (jax.random.normal(ks[0], (b, hq, sq, dh)) * 0.4).astype(dtype)
    k = (jax.random.normal(ks[1], (b, hkv, sk, dh)) * 0.4).astype(dtype)
    v = (jax.random.normal(ks[2], (b, hkv, sk, dh)) * 0.5).astype(dtype)
    o = flash_attention(q, k, v, causal=causal, sliding_window=window,
                        block_q=64, block_k=64, interpret=True)
    oref = flash_attention_ref(q, k, v, causal=causal,
                               sliding_window=window)
    t = TOL[dtype]
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(oref, np.float32), rtol=t, atol=t)


@pytest.mark.parametrize("dk,dv", [(32, 32), (64, 128), (128, 64)])
@pytest.mark.parametrize("decay", [False, True])
def test_lasp2_decode_kernel_sweep(rng, dk, dv, decay):
    """Single-step recurrent decode kernel == oracle recurrence, and
    chaining steps from a chunked-prefill state continues the scan."""
    from repro.core import linear_attention as la
    from repro.kernels.lasp2_chunk import lasp2_chunk_fwd

    bh, s, split = 4, 32, 24
    ks = jax.random.split(rng, 4)
    q = jax.random.normal(ks[0], (bh, s, dk)) * 0.3
    k = jax.random.normal(ks[1], (bh, s, dk)) * 0.3
    v = jax.random.normal(ks[2], (bh, s, dv)) * 0.5
    la_ = (-jnp.abs(jax.random.normal(ks[3], (bh, s))) * 0.05) if decay \
        else jnp.zeros((bh, s))
    ref = la.sequential_oracle(q, k, v, la_)
    # prefill the first `split` tokens with the chunked kernel...
    _, st, ld = lasp2_chunk_fwd(q[:, :split], k[:, :split], v[:, :split],
                                la_[:, :split], block_size=8,
                                interpret=True)
    # ...then decode the rest one step at a time
    from repro.kernels.lasp2_decode import lasp2_decode_step
    outs = []
    for t in range(split, s):
        o, st, ld = lasp2_decode_step(q[:, t], k[:, t], v[:, t], la_[:, t],
                                      st, ld, interpret=True)
        outs.append(o)
    o_dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(o_dec, np.asarray(ref.o)[:, split:],
                               rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(st, ref.state, rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(ld, ref.log_decay, rtol=1e-5, atol=1e-5)


def test_linear_decode_op_dispatch(rng):
    ks = jax.random.split(rng, 4)
    b, h, dk, dv = 2, 4, 32, 64
    q = jax.random.normal(ks[0], (b, h, dk)) * 0.3
    k = jax.random.normal(ks[1], (b, h, dk)) * 0.3
    v = jax.random.normal(ks[2], (b, h, dv)) * 0.5
    la_ = -jnp.abs(jax.random.normal(ks[3], (b, h))) * 0.05
    st = jax.random.normal(ks[0], (b, h, dk, dv)).astype(jnp.float32)
    ld = jnp.zeros((b, h), jnp.float32)
    o1, s1, l1 = ops.linear_decode_op(q, k, v, la_, st, ld, backend="xla")
    o2, s2, l2 = ops.linear_decode_op(q, k, v, la_, st, ld,
                                      backend="interpret")
    np.testing.assert_allclose(o1, o2, rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(s1, s2, rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(l1, l2, rtol=1e-6, atol=1e-6)


def test_ops_dispatch_linear(rng):
    ks = jax.random.split(rng, 3)
    q = jax.random.normal(ks[0], (2, 4, 256, 32)) * 0.3
    k = jax.random.normal(ks[1], (2, 4, 256, 32)) * 0.3
    v = jax.random.normal(ks[2], (2, 4, 256, 32)) * 0.5
    o_xla, st_xla, _ = ops.linear_attention_op(q, k, v, backend="xla")
    o_int, st_int, _ = ops.linear_attention_op(q, k, v, backend="interpret")
    np.testing.assert_allclose(o_xla, o_int, rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(st_xla, st_int, rtol=3e-4, atol=3e-4)


@pytest.mark.parametrize("s", [17, 129, 251])
def test_ops_linear_awkward_lengths(rng, s):
    """Arbitrary (incl. prime) prompt lengths must keep full-size blocks
    via zero right-padding — output, state and log decay stay exact."""
    from repro.core import linear_attention as la
    ks = jax.random.split(rng, 4)
    q = jax.random.normal(ks[0], (1, 2, s, 16)) * 0.3
    k = jax.random.normal(ks[1], (1, 2, s, 16)) * 0.3
    v = jax.random.normal(ks[2], (1, 2, s, 24)) * 0.5
    la_ = -jnp.abs(jax.random.normal(ks[3], (1, 2, s))) * 0.05
    ref = la.sequential_oracle(q, k, v, la_)
    for backend in ("xla", "interpret"):
        o, st, ld = ops.linear_attention_op(q, k, v, la_, block_size=128,
                                            backend=backend)
        assert o.shape[-2] == s
        np.testing.assert_allclose(o, ref.o, rtol=5e-4, atol=5e-4)
        np.testing.assert_allclose(st, ref.state, rtol=5e-4, atol=5e-4)
        np.testing.assert_allclose(ld, ref.log_decay, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("causal,window", [(True, None), (False, None),
                                           (True, 64), (False, 64)])
def test_ops_dispatch_flash(rng, causal, window):
    ks = jax.random.split(rng, 3)
    q = jax.random.normal(ks[0], (2, 4, 256, 64)) * 0.4
    k = jax.random.normal(ks[1], (2, 2, 256, 64)) * 0.4
    v = jax.random.normal(ks[2], (2, 2, 256, 64)) * 0.5
    o_xla = ops.flash_attention_op(q, k, v, causal=causal,
                                   sliding_window=window, backend="xla")
    o_int = ops.flash_attention_op(q, k, v, causal=causal,
                                   sliding_window=window,
                                   backend="interpret")
    np.testing.assert_allclose(o_xla, o_int, rtol=3e-4, atol=3e-4)


# ---------------------------------------------------------------------------
# Kernel gradients: the lasp2_chunk custom_vjp backward kernels.
# ---------------------------------------------------------------------------

def _grad_case(rng, s=256, dk=32, dv=48, scale=0.05):
    ks = jax.random.split(rng, 7)
    b, h = 2, 3
    q = jax.random.normal(ks[0], (b, h, s, dk)) * 0.3
    k = jax.random.normal(ks[1], (b, h, s, dk)) * 0.3
    v = jax.random.normal(ks[2], (b, h, s, dv)) * 0.5
    la_ = -jnp.abs(jax.random.normal(ks[3], (b, h, s))) * scale
    cot = (jax.random.normal(ks[4], (b, h, s, dv)),       # dO
           jax.random.normal(ks[5], (b, h, dk, dv)),      # dM (state)
           jax.random.normal(ks[6], (b, h)))              # dA (log decay)
    return q, k, v, la_, cot


def _op_loss(backend, cot, block_size=64):
    co, cs, cl = cot

    def loss(q, k, v, la_):
        o, st, ld = ops.linear_attention_op(q, k, v, la_,
                                            block_size=block_size,
                                            backend=backend)
        return (jnp.sum(o.astype(jnp.float32) * co) + jnp.sum(st * cs)
                + jnp.sum(ld * cl))

    return loss


@pytest.mark.parametrize("decay", [False, True])
def test_lasp2_chunk_grads_match_chunk_scan_autodiff(rng, decay):
    """jax.grad through the Pallas custom_vjp (interpret) == XLA autodiff
    of chunk_scan, pulling on ALL THREE outputs (o, state, log_decay) —
    the faithful SP backward pulls on o and state; data-dependent decay
    additionally needs d log_a."""
    q, k, v, la_, cot = _grad_case(rng)
    if not decay:
        la_ = jnp.zeros_like(la_)
    g_int = jax.grad(_op_loss("interpret", cot), argnums=(0, 1, 2, 3))(
        q, k, v, la_)
    g_xla = jax.grad(_op_loss("xla", cot), argnums=(0, 1, 2, 3))(
        q, k, v, la_)
    for name, gi, gx in zip("q k v log_a".split(), g_int, g_xla):
        np.testing.assert_allclose(gi, gx, rtol=GRAD_TOL, atol=GRAD_TOL,
                                   err_msg=f"d{name}")


def test_lasp2_chunk_grads_match_sequential_oracle(rng):
    """Same gradients vs the O(S) oracle (independent derivation)."""
    from repro.core import linear_attention as la
    q, k, v, la_, cot = _grad_case(rng, s=128)
    co, cs, cl = cot

    def oracle_loss(q_, k_, v_, a_):
        out = la.sequential_oracle(q_, k_, v_, a_)
        return (jnp.sum(out.o.astype(jnp.float32) * co)
                + jnp.sum(out.state * cs) + jnp.sum(out.log_decay * cl))

    g_int = jax.grad(_op_loss("interpret", cot), argnums=(0, 1, 2, 3))(
        q, k, v, la_)
    g_ref = jax.grad(oracle_loss, argnums=(0, 1, 2, 3))(q, k, v, la_)
    for name, gi, gr in zip("q k v log_a".split(), g_int, g_ref):
        np.testing.assert_allclose(gi, gr, rtol=GRAD_TOL, atol=GRAD_TOL,
                                   err_msg=f"d{name}")


def test_lasp2_chunk_grads_state_cotangent_only(rng):
    """Pulling ONLY on the end-of-chunk state (the Alg. 4 dM path)."""
    q, k, v, la_, cot = _grad_case(rng, s=128)
    cot = (jnp.zeros_like(cot[0]), cot[1], jnp.zeros_like(cot[2]))
    g_int = jax.grad(_op_loss("interpret", cot), argnums=(0, 1, 2, 3))(
        q, k, v, la_)
    g_xla = jax.grad(_op_loss("xla", cot), argnums=(0, 1, 2, 3))(
        q, k, v, la_)
    assert float(jnp.max(jnp.abs(g_int[0]))) == 0.0   # dq: o untouched
    for name, gi, gx in zip("q k v log_a".split(), g_int, g_xla):
        np.testing.assert_allclose(gi, gx, rtol=GRAD_TOL, atol=GRAD_TOL,
                                   err_msg=f"d{name}")


@pytest.mark.parametrize("s", [97, 130])
def test_lasp2_chunk_grads_padding_path(rng, s):
    """Awkward (non-block-multiple) lengths differentiate through the
    zero-padding path in ops.linear_attention_op."""
    q, k, v, la_, _ = _grad_case(rng, s=s, dk=16, dv=16)
    ks = jax.random.split(rng, 2)
    co = jax.random.normal(ks[0], q.shape[:-1] + (16,))
    cs = jax.random.normal(ks[1], q.shape[:2] + (16, 16))
    cot = (co, cs, jnp.zeros(q.shape[:2]))
    g_int = jax.grad(_op_loss("interpret", cot), argnums=(0, 1, 2, 3))(
        q, k, v, la_)
    g_xla = jax.grad(_op_loss("xla", cot), argnums=(0, 1, 2, 3))(
        q, k, v, la_)
    for name, gi, gx in zip("q k v log_a".split(), g_int, g_xla):
        np.testing.assert_allclose(gi, gx, rtol=GRAD_TOL, atol=GRAD_TOL,
                                   err_msg=f"d{name}")


def test_lasp2_chunk_grad_bf16_inputs(rng):
    """bf16 q/k/v: cotangents flow back in bf16 with fp32 kernel math."""
    q, k, v, la_, cot = _grad_case(rng, s=128)
    qb, kb, vb = (x.astype(jnp.bfloat16) for x in (q, k, v))
    g_int = jax.grad(_op_loss("interpret", cot), argnums=(0, 1, 2))(
        qb, kb, vb, la_)
    g_xla = jax.grad(_op_loss("xla", cot), argnums=(0, 1, 2))(
        qb, kb, vb, la_)
    for gi, gx in zip(g_int, g_xla):
        assert gi.dtype == jnp.bfloat16
        np.testing.assert_allclose(np.asarray(gi, np.float32),
                                   np.asarray(gx, np.float32),
                                   rtol=4e-2, atol=4e-2)


# ---------------------------------------------------------------------------
# Flash-attention causal offset (sq != sk shapes).
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("sq,sk,window", [(128, 256, None), (64, 256, None),
                                          (128, 256, 96)])
def test_flash_offset_matches_xla_mask(rng, sq, sk, window):
    """Regression: for sq < sk (prefill-with-cache / ring-decode shapes)
    query row i sits at global position (sk - sq) + i. The Pallas kernel
    used to mask with LOCAL q indices — each query then saw only the
    first sq keys instead of its full causal prefix."""
    from repro.core.lasp2h import _softmax_attend, causal_mask
    b, hq, hkv, dh = 2, 4, 2, 64
    ks = jax.random.split(rng, 3)
    q = jax.random.normal(ks[0], (b, hq, sq, dh)) * 0.4
    k = jax.random.normal(ks[1], (b, hkv, sk, dh)) * 0.4
    v = jax.random.normal(ks[2], (b, hkv, sk, dh)) * 0.5
    mask = causal_mask(sq, sk, q_offset=sk - sq,
                       sliding_window=window)[None, None]
    ref = _softmax_attend(q, k, v, scale=dh ** -0.5, mask=mask)
    o_int = ops.flash_attention_op(q, k, v, causal=True,
                                   sliding_window=window, block_q=64,
                                   block_k=64, backend="interpret")
    np.testing.assert_allclose(o_int, ref, rtol=3e-4, atol=3e-4)
    # the XLA fallback and the kernel now share one mask convention
    o_xla = ops.flash_attention_op(q, k, v, causal=True,
                                   sliding_window=window, backend="xla")
    np.testing.assert_allclose(o_int, o_xla, rtol=3e-4, atol=3e-4)
    # sanity: with the bug, the last query ignored keys in
    # [sq, q_offset + row] — perturbing one of those must change o.
    if window is None:
        v2 = v.at[:, :, sk - 2].add(1.0)
        o2 = ops.flash_attention_op(q, k, v2, causal=True, block_q=64,
                                    block_k=64, backend="interpret")
        assert float(jnp.max(jnp.abs(o2 - o_int))) > 1e-3


def test_kernel_vmem_footprint_static():
    """BlockSpec tiles must fit VMEM (16 MB/core budget, fp32 scratch)."""
    bq, bk, dh, dkv = 128, 128, 128, 128
    flash_tiles = (bq * dh + 2 * bk * dh + bq * dh) * 4 + bq * dh * 4
    chunk_tiles = (2 * 128 * dkv + 2 * 128 * dkv) * 4 + dkv * dkv * 4
    # flash bwd dkv pass: q/k/v/do tiles + lse/delta rows + 2 accumulators
    flash_bwd_tiles = (2 * bq * dh + 2 * bk * dh + 2 * bq) * 4 \
        + 2 * bk * dh * 4
    assert flash_tiles < 16 * 2 ** 20
    assert chunk_tiles < 16 * 2 ** 20
    assert flash_bwd_tiles < 16 * 2 ** 20


# ---------------------------------------------------------------------------
# Flash-attention gradients: the custom_vjp two-pass backward kernels.
# ---------------------------------------------------------------------------

def _flash_case(rng, sq, sk, hq, hkv, dh, dtype=jnp.float32):
    ks = jax.random.split(rng, 4)
    b = 2
    q = (jax.random.normal(ks[0], (b, hq, sq, dh)) * 0.4).astype(dtype)
    k = (jax.random.normal(ks[1], (b, hkv, sk, dh)) * 0.4).astype(dtype)
    v = (jax.random.normal(ks[2], (b, hkv, sk, dh)) * 0.5).astype(dtype)
    co = jax.random.normal(ks[3], (b, hq, sq, dh))
    return q, k, v, co


def _flash_loss(backend, co, causal, window, **kw):
    def loss(q, k, v):
        o = ops.flash_attention_op(q, k, v, causal=causal,
                                   sliding_window=window, backend=backend,
                                   **kw)
        return jnp.sum(o.astype(jnp.float32) * co)
    return loss


@pytest.mark.parametrize("hq,hkv", [(4, 4), (4, 2), (8, 1)])
@pytest.mark.parametrize("causal,window", [(True, None), (False, None),
                                           (True, 64)])
def test_flash_grads_match_xla_autodiff(rng, hq, hkv, causal, window):
    """jax.grad through the flash custom_vjp (interpret) == XLA autodiff
    of the masked-softmax fallback, across GQA ratios and windows."""
    q, k, v, co = _flash_case(rng, 256, 256, hq, hkv, 64)
    kw = dict(block_q=64, block_k=64)
    g_int = jax.grad(_flash_loss("interpret", co, causal, window, **kw),
                     argnums=(0, 1, 2))(q, k, v)
    g_xla = jax.grad(_flash_loss("xla", co, causal, window),
                     argnums=(0, 1, 2))(q, k, v)
    for name, gi, gx in zip("q k v".split(), g_int, g_xla):
        np.testing.assert_allclose(gi, gx, rtol=GRAD_TOL, atol=GRAD_TOL,
                                   err_msg=f"d{name}")


@pytest.mark.parametrize("sq,sk,window", [(128, 256, None), (64, 256, 96),
                                          (128, 512, None)])
def test_flash_grads_offset_shapes(rng, sq, sk, window):
    """sq != sk (prefill-with-cache q_offset = sk - sq) backward parity."""
    q, k, v, co = _flash_case(rng, sq, sk, 4, 2, 64)
    kw = dict(block_q=64, block_k=64)
    g_int = jax.grad(_flash_loss("interpret", co, True, window, **kw),
                     argnums=(0, 1, 2))(q, k, v)
    g_xla = jax.grad(_flash_loss("xla", co, True, window),
                     argnums=(0, 1, 2))(q, k, v)
    for name, gi, gx in zip("q k v".split(), g_int, g_xla):
        np.testing.assert_allclose(gi, gx, rtol=GRAD_TOL, atol=GRAD_TOL,
                                   err_msg=f"d{name}")


@pytest.mark.parametrize("sq,sk", [(100, 100), (129, 257), (251, 251)])
def test_flash_grads_awkward_lengths(rng, sq, sk):
    """Odd (non-block-multiple) lengths run the Pallas path via the
    mask-safe pad+slice in ops.flash_attention_op — forward AND backward
    (padded-key grads masked to zero, padded-query cotangents sliced)."""
    q, k, v, co = _flash_case(rng, sq, sk, 4, 2, 32)
    kw = dict(block_q=64, block_k=64)
    o_int = ops.flash_attention_op(q, k, v, backend="interpret", **kw)
    o_xla = ops.flash_attention_op(q, k, v, backend="xla")
    assert o_int.shape[-2] == sq
    np.testing.assert_allclose(o_int, o_xla, rtol=3e-4, atol=3e-4)
    g_int = jax.grad(_flash_loss("interpret", co, True, None, **kw),
                     argnums=(0, 1, 2))(q, k, v)
    g_xla = jax.grad(_flash_loss("xla", co, True, None),
                     argnums=(0, 1, 2))(q, k, v)
    for name, gi, gx in zip("q k v".split(), g_int, g_xla):
        np.testing.assert_allclose(gi, gx, rtol=GRAD_TOL, atol=GRAD_TOL,
                                   err_msg=f"d{name}")


def test_flash_grads_traced_q_offset(rng):
    """The LASP-2H sharded path passes the rank offset t·C as a traced
    scalar: the kernel masks at runtime (band untrimmed) and the
    custom_vjp returns a float0 cotangent for it."""
    q, k, v, co = _flash_case(rng, 64, 256, 4, 2, 32)
    for off in (0, 64, 192):
        gi = jax.jit(jax.grad(
            lambda a, b, c, o_: jnp.sum(ops.flash_attention_op(
                a, b, c, causal=True, backend="interpret", block_q=64,
                block_k=64, q_offset=o_) * co), argnums=(0, 1, 2)))(
                    q, k, v, jnp.int32(off))
        gx = jax.grad(
            lambda a, b, c: jnp.sum(ops.flash_attention_op(
                a, b, c, causal=True, backend="xla", q_offset=off) * co),
            argnums=(0, 1, 2))(q, k, v)
        for name, a_, b_ in zip("q k v".split(), gi, gx):
            np.testing.assert_allclose(a_, b_, rtol=GRAD_TOL, atol=GRAD_TOL,
                                       err_msg=f"d{name} @offset {off}")


def test_flash_grads_bf16_inputs(rng):
    """bf16 q/k/v: cotangents flow back in bf16 with fp32 kernel math."""
    q, k, v, co = _flash_case(rng, 128, 128, 4, 2, 64, dtype=jnp.bfloat16)
    kw = dict(block_q=64, block_k=64)
    g_int = jax.grad(_flash_loss("interpret", co, True, None, **kw),
                     argnums=(0, 1, 2))(q, k, v)
    g_xla = jax.grad(_flash_loss("xla", co, True, None),
                     argnums=(0, 1, 2))(q, k, v)
    for gi, gx in zip(g_int, g_xla):
        assert gi.dtype == jnp.bfloat16
        np.testing.assert_allclose(np.asarray(gi, np.float32),
                                   np.asarray(gx, np.float32),
                                   rtol=4e-2, atol=4e-2)


def test_flash_mask_value_dtype_aware():
    """The masked-logit fill is finfo-derived (no -1e30 literal): finite
    in every float dtype, including fp16 where -1e30 overflows."""
    from repro.kernels.flash_attention import mask_value
    for dt in (jnp.float32, jnp.bfloat16, jnp.float16):
        mv = mask_value(dt)
        assert np.isfinite(np.asarray(mv, dt)), dt
        assert mv < -1e4
    with np.errstate(over="ignore"):
        assert not np.isfinite(np.float16(-1e30))   # the literal it replaces


def test_flash_causal_band_static_trim():
    """Causal grid trimming: the kv band never schedules blocks strictly
    above the diagonal — with a sliding window the band is narrower than
    the kv axis; fully-padded kv blocks are excluded via kv_len."""
    from repro.kernels.flash_attention import _kv_band, _q_band
    # causal, no window, q_offset=0: widest extent = full prefix
    lo, hi, w = _kv_band(nq=4, nkv_real=4, block_q=64, block_k=64,
                         q_offset=0, causal=True, sliding_window=None)
    assert w == 4 and int(hi(0)) == 0 and int(hi(3)) == 3
    # sliding window 64: each q block needs <= 2 kv blocks — real trim
    lo, hi, w = _kv_band(nq=8, nkv_real=8, block_q=64, block_k=64,
                         q_offset=0, causal=True, sliding_window=64)
    assert w == 2
    assert int(lo(4)) == 3 and int(hi(4)) == 4
    # right-padded keys (kv_len < sk): padded blocks never scheduled
    lo, hi, w = _kv_band(nq=2, nkv_real=2, block_q=64, block_k=64,
                         q_offset=0, causal=False, sliding_window=None)
    assert w == 2
    # transposed (dk/dv) band under a window is likewise narrow
    lo, hi, w = _q_band(nq=8, nkv=8, block_q=64, block_k=64, q_offset=0,
                        causal=True, sliding_window=64)
    assert w == 2
