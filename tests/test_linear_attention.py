"""Core linear-attention math: chunked == sequential oracle (all variants)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import linear_attention as la


def make_qkv(key, b=2, h=3, s=256, dk=32, dv=48, dtype=jnp.float32):
    ks = jax.random.split(key, 4)
    q = (jax.random.normal(ks[0], (b, h, s, dk)) * 0.3).astype(dtype)
    k = (jax.random.normal(ks[1], (b, h, s, dk)) * 0.3).astype(dtype)
    v = (jax.random.normal(ks[2], (b, h, s, dv)) * 0.5).astype(dtype)
    log_a = -jnp.abs(jax.random.normal(ks[3], (b, h, s))) * 0.05
    return q, k, v, log_a


@pytest.mark.parametrize("block", [32, 64, 128, 256])
@pytest.mark.parametrize("decay", [False, True])
def test_chunk_scan_matches_oracle(rng, block, decay):
    q, k, v, log_a = make_qkv(rng)
    la_in = log_a if decay else None
    ref = la.sequential_oracle(q, k, v, la_in)
    out = la.chunk_scan(q, k, v, la_in, block_size=block)
    np.testing.assert_allclose(out.o, ref.o, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(out.state, ref.state, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(out.log_decay, ref.log_decay,
                               rtol=1e-5, atol=1e-5)


def test_chunk_summaries_match_state(rng):
    q, k, v, log_a = make_qkv(rng)
    ref = la.sequential_oracle(q, k, v, log_a)
    m, ld = la.chunk_summaries(k, v, log_a, block_size=64)
    np.testing.assert_allclose(m, ref.state, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(ld, ref.log_decay, rtol=1e-5, atol=1e-5)


def test_chunk_summaries_rejects_indivisible_block(rng):
    """S % block_size != 0 must raise the same clear ValueError as
    chunk_scan (chunk_summaries used to fall through to an opaque
    reshape failure instead of validating)."""
    q, k, v, log_a = make_qkv(rng, s=100)
    with pytest.raises(ValueError, match="not divisible"):
        la.chunk_summaries(k, v, log_a, block_size=64)
    with pytest.raises(ValueError, match="not divisible"):
        la.chunk_scan(q, k, v, log_a, block_size=64)


def test_initial_state_continuation(rng):
    """Semigroup: processing two halves with carried state == full pass."""
    q, k, v, log_a = make_qkv(rng)
    h = q.shape[-2] // 2
    r1 = la.chunk_scan(q[..., :h, :], k[..., :h, :], v[..., :h, :],
                       log_a[..., :h], block_size=64)
    r2 = la.chunk_scan(q[..., h:, :], k[..., h:, :], v[..., h:, :],
                       log_a[..., h:], initial_state=r1.state, block_size=64)
    full = la.chunk_scan(q, k, v, log_a, block_size=64)
    np.testing.assert_allclose(jnp.concatenate([r1.o, r2.o], axis=-2),
                               full.o, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(r2.state, full.state, rtol=2e-4, atol=2e-4)


def test_doc_reset_equals_separate_docs(rng):
    """Paper §A.4.2: packing with decay-reset == independent documents."""
    q, k, v, log_a = make_qkv(rng)
    h = q.shape[-2] // 2
    off = h + 17   # reset NOT on a block boundary
    la_reset = log_a.at[..., off].set(la.RESET_LOG_A)
    packed = la.chunk_scan(q, k, v, la_reset, block_size=64)
    oracle = la.sequential_oracle(q, k, v, la_reset)
    np.testing.assert_allclose(packed.o, oracle.o, rtol=2e-4, atol=2e-4)
    # tail after the reset behaves like a fresh document
    sep = la.sequential_oracle(
        q[..., off:, :], k[..., off:, :], v[..., off:, :],
        log_a[..., off:].at[..., 0].set(0.0))
    np.testing.assert_allclose(packed.o[..., off:, :], sep.o,
                               rtol=2e-4, atol=2e-4)


def test_bidirectional_oracle(rng):
    q, k, v, _ = make_qkv(rng)
    ref = la.sequential_oracle(q, k, v, None, causal=False)
    m = jnp.einsum("bhsk,bhsv->bhkv", k, v)
    direct = jnp.einsum("bhsk,bhkv->bhsv", q, m)
    np.testing.assert_allclose(ref.o, direct, rtol=1e-4, atol=1e-4)


def test_bf16_inputs_fp32_state(rng):
    q, k, v, log_a = make_qkv(rng, dtype=jnp.bfloat16)
    out = la.chunk_scan(q, k, v, log_a, block_size=64)
    assert out.o.dtype == jnp.bfloat16
    assert out.state.dtype == jnp.float32
    ref = la.sequential_oracle(q, k, v, log_a)
    np.testing.assert_allclose(np.asarray(out.o, np.float32),
                               np.asarray(ref.o, np.float32),
                               rtol=3e-2, atol=3e-2)


@pytest.mark.parametrize("fm", ["identity", "elu1", "silu", "relu",
                                "taylor"])
def test_feature_maps(rng, fm):
    x = jax.random.normal(rng, (2, 3, 8, 16))
    y = la.feature_map(x, fm)
    assert np.isfinite(np.asarray(y)).all()
    if fm == "taylor":
        assert y.shape[-1] == 1 + 16 + 16 * 16
    else:
        assert y.shape == x.shape


def test_decay_kinds():
    for kind in ("none", "retention", "lightning"):
        d = la.decay_log_a(kind, heads=4, s=16)
        assert d.shape == (4, 16)
        assert np.all(np.asarray(d) <= 0)
