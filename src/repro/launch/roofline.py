import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=512")

import argparse          # noqa: E402
import dataclasses      # noqa: E402
import json              # noqa: E402
import subprocess        # noqa: E402
import sys               # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402

"""Roofline analysis per (arch × shape) on the single-pod mesh.

Methodology (DESIGN/EXPERIMENTS): XLA's ``cost_analysis`` counts each
``while`` (scan) body ONCE, so instead of trusting the full-depth compile
we lower reduced-depth *unrolled* programs and solve the exact cost model

    cost(A, G) = c0 + A·(c1 + G·c2)        (train; A = microbatches,
                                            G = layer-group count)
    cost(G)    = c0 + G·c1                 (prefill / decode)

which is exact because every layer group is identical by construction.
FLOPs / HBM bytes come from ``cost_analysis`` (per-device, post-SPMD);
collective bytes from parsing the compiled HLO (ring cost model, see
``hlo_analysis``). Terms are reported in seconds against TPU v5e peaks.

  python -m repro.launch.roofline --arch mamba2-2.7b --shape prefill_32k
  python -m repro.launch.roofline --all
"""


def _measure_cell(arch, shape_name, mesh, *, n_units, microbatches=None,
                  cfg_override=None, overrides=None):
    """Lower+compile a reduced-depth unrolled cell; return CostVector."""
    from repro.configs.base import RunConfig
    from repro.launch import hlo_analysis as H
    from repro.launch.cells import build_cell, reduced_depth_config, \
        resolve_config

    cfg, _note = (cfg_override, "override") if cfg_override is not None \
        else resolve_config(arch, shape_name)
    cfg_small = reduced_depth_config(cfg, n_units)
    run = RunConfig(scan_unroll=True, **(overrides or {}))
    cell = build_cell(arch, shape_name, mesh, run=run,
                      cfg_override=cfg_small)
    if microbatches is not None and cell.shape.kind == "train":
        # rebuild with a forced microbatch count
        run = dataclasses.replace(run, num_microbatches=microbatches)
        cell = _rebuild_train_cell(arch, shape_name, mesh, cfg_small, run)
    compiled = cell.lower().compile()
    return H.measure(compiled, mesh.size)


def _rebuild_train_cell(arch, shape_name, mesh, cfg, run):
    import jax
    import jax.numpy as jnp

    from repro.configs.base import SHAPES
    from repro.launch.cells import (Cell, _batch_sharding_tree, _sds,
                                    _state_shardings, aux_input_specs)
    from repro.sharding.rules import make_plan
    from repro.train.step import init_state, make_train_step

    shape = SHAPES[shape_name]
    plan = make_plan(mesh, shape.kind, global_batch=shape.global_batch,
                     n_kv_heads=cfg.n_kv_heads)
    plan.banded_windows = run.banded_windows
    a = run.num_microbatches
    # per-µb rows fixed to the production cell's value so the per-µb cost
    # c1 + G·c2 measured here matches the production program exactly
    from repro.launch.cells import choose_microbatches
    import numpy as np
    dp = int(np.prod([mesh.shape[ax] for ax in plan.dp_axes
                      if ax in mesh.axis_names]))
    a_prod = choose_microbatches(shape, dp, target=run.microbatch_tokens)
    bm = shape.global_batch // a_prod
    state_shapes = jax.eval_shape(
        lambda: init_state(jax.random.PRNGKey(0), cfg, run))
    batch = {"tokens": _sds((a, bm, shape.seq_len), jnp.int32),
             "labels": _sds((a, bm, shape.seq_len), jnp.int32),
             "resets": _sds((a, bm, shape.seq_len), jnp.bool_)}
    batch.update(aux_input_specs(cfg, bm, lead=(a,)))
    fn = make_train_step(cfg, run, plan)
    sspec = _state_shardings(state_shapes, plan)
    bspec = _batch_sharding_tree(batch, plan, lead_micro=True)
    return Cell(arch, shape, cfg, plan, run, fn, (state_shapes, batch),
                (sspec, bspec), (0,))


# single source of truth moved to hlo_analysis (import-side-effect-free)
# so repro.obs can reuse it; re-exported here for back-compat.
from repro.launch.hlo_analysis import model_flops  # noqa: E402, F401


def run_one(arch: str, shape_name: str, out_dir: str, *,
            overrides=None, tag=""):
    from repro.configs.base import SHAPES
    from repro.launch import hlo_analysis as H
    from repro.launch.cells import choose_microbatches, resolve_config
    from repro.launch.mesh import make_production_mesh
    from repro.sharding.rules import make_plan
    import numpy as np

    mesh = make_production_mesh(multi_pod=False)
    shape = SHAPES[shape_name]
    cfg, note = resolve_config(arch, shape_name)
    rec = {"arch": arch, "shape": shape_name, "config": cfg.name,
           "note": note, "mesh": "16x16", "status": "running",
           "overrides": overrides or {}, "tag": tag}
    t0 = time.time()
    try:
        if shape.kind == "train":
            f11 = _measure_cell(arch, shape_name, mesh, n_units=1,
                                microbatches=1, overrides=overrides)
            f12 = _measure_cell(arch, shape_name, mesh, n_units=2,
                                microbatches=1, overrides=overrides)
            f21 = _measure_cell(arch, shape_name, mesh, n_units=1,
                                microbatches=2, overrides=overrides)
            c2 = f12 - f11
            c1 = (f21 - f11) - c2
            c0 = f11 - c1 - c2
            plan = make_plan(mesh, "train",
                             global_batch=shape.global_batch,
                             n_kv_heads=cfg.n_kv_heads)
            dp = int(np.prod([mesh.shape[ax] for ax in plan.dp_axes
                              if ax in mesh.axis_names]))
            from repro.configs.base import RunConfig as _RC
            a = choose_microbatches(
                shape, dp, target=_RC(**(overrides or {})).microbatch_tokens)
            g = cfg.n_groups
            total = c0 + (c1 + c2.scale(g)).scale(a)
            rec["extrapolation"] = {"A": a, "G": g}
        else:
            f1 = _measure_cell(arch, shape_name, mesh, n_units=1,
                               overrides=overrides)
            f2 = _measure_cell(arch, shape_name, mesh, n_units=2,
                               overrides=overrides)
            c1 = f2 - f1
            c0 = f1 - c1
            g = cfg.n_groups
            total = c0 + c1.scale(g)
            rec["extrapolation"] = {"G": g}

        terms = H.roofline_terms(total)
        mf = model_flops(cfg, shape)
        hlo_flops_global = total.flops * mesh.size
        ideal_s = mf / H.PEAK_FLOPS / mesh.size   # perfect-MFU step time
        bound_s = max(terms["compute_s"], terms["memory_s"],
                      terms["collective_s"])
        rec.update({
            "status": "ok",
            "per_device": {"flops": total.flops,
                           "hbm_bytes": total.hbm_bytes,
                           "collective_bytes": total.coll_bytes,
                           "coll_by_op": total.coll_by_op},
            "terms": terms,
            "model_flops": mf,
            "hlo_flops_global": hlo_flops_global,
            "useful_flops_ratio": mf / hlo_flops_global
            if hlo_flops_global else 0.0,
            # how close the roofline-bound step time is to perfect MFU
            "roofline_fraction": ideal_s / bound_s if bound_s else 0.0,
        })
        print(f"[roofline] {arch} x {shape_name}: "
              f"compute {terms['compute_s']*1e3:.2f}ms "
              f"memory {terms['memory_s']*1e3:.2f}ms "
              f"collective {terms['collective_s']*1e3:.2f}ms "
              f"-> {terms['dominant']}-bound; "
              f"useful-FLOPs {rec['useful_flops_ratio']:.2%}; "
              f"roofline-fraction {rec['roofline_fraction']:.2%}")
    except Exception as e:  # noqa: BLE001
        rec["status"] = "fail"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
        print(f"[roofline] {arch} x {shape_name}: FAIL {e}",
              file=sys.stderr)
    rec["total_s"] = round(time.time() - t0, 1)
    os.makedirs(out_dir, exist_ok=True)
    fname = f"{arch}__{shape_name}".replace("/", "_") \
        + (f"__{tag}" if tag else "")
    with open(os.path.join(out_dir, fname + ".json"), "w") as f:
        json.dump(rec, f, indent=1, default=str)
    return rec["status"] == "ok"


def run_all(out_dir: str, timeout: int = 2400):
    from repro.configs import ARCH_IDS
    from repro.configs.base import SHAPES
    results = {}
    for arch in ARCH_IDS:
        for shape in SHAPES:
            tag = f"{arch}__{shape}"
            path = os.path.join(out_dir, tag.replace("/", "_") + ".json")
            if os.path.exists(path):
                with open(path) as f:
                    if json.load(f).get("status") == "ok":
                        results[tag] = "cached"
                        continue
            cmd = [sys.executable, "-m", "repro.launch.roofline",
                   "--arch", arch, "--shape", shape, "--out", out_dir]
            try:
                proc = subprocess.run(cmd, timeout=timeout,
                                      capture_output=True, text=True)
                results[tag] = "ok" if proc.returncode == 0 else "fail"
            except subprocess.TimeoutExpired:
                results[tag] = "timeout"
            print(f"{tag}: {results[tag]}", flush=True)
    return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/roofline")
    ap.add_argument("--set", action="append", default=[],
                    help="RunConfig override key=value (hillclimb variants)")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()
    overrides = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        import ast
        try:
            overrides[k] = ast.literal_eval(v)
        except (ValueError, SyntaxError):
            overrides[k] = v
    if args.all:
        res = run_all(args.out)
        bad = [k for k, v in res.items() if v not in ("ok", "cached")]
        print(f"\n{len(res) - len(bad)}/{len(res)} roofline cells OK")
        sys.exit(1 if bad else 0)
    ok = run_one(args.arch, args.shape, args.out,
                 overrides=overrides or None, tag=args.tag)
    sys.exit(0 if ok else 1)


if __name__ == "__main__":
    main()
