"""Serving benchmark: continuous-batching engine vs naive re-prefill.

Measured (CPU-indicative, smoke-scale models): decode throughput (tokens/s)
of the recurrent-decode engine against a naive baseline that re-runs the
full chunked forward over the whole prefix for every generated token —
what serving without the constant-size recurrent state would cost.

Derived (the paper's constant-memory-inference claim, exact): decode-cache
bytes per linear-attention layer as a function of context length — a flat
line — versus the KV-cache bytes a softmax layer of the same shape would
need, plus the engine's actual cache footprint by kind.

  PYTHONPATH=src python benchmarks/serve_throughput.py
"""

from __future__ import annotations

import dataclasses
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import telemetry_block
from repro.configs import get_smoke
from repro.configs.base import LayerSpec, ShapeConfig
from repro.models import model as M
from repro.obs import InMemorySink
from repro.serve.engine import ServeEngine

BENCH_NAME = "serve"

N_REQUESTS = 8
MAX_BATCH = 4
NEW_TOKENS = 32
MAX_PROMPT = 48


def workload(vocab, seed=0):
    rng = np.random.default_rng(seed)
    lens = rng.integers(MAX_PROMPT // 2, MAX_PROMPT + 1, size=N_REQUESTS)
    return [rng.integers(0, vocab, size=int(n)) for n in lens]


def engine_tokens_per_s(cfg, params, prompts):
    """Returns (tokens/s, the warm engine) — latency percentiles, TTFT
    and occupancy come off ``engine.stats()`` (the sink API)."""
    engine = ServeEngine(cfg, params, max_len=MAX_PROMPT + NEW_TOKENS,
                         max_batch=MAX_BATCH, sink=InMemorySink())
    for i, p in enumerate(prompts):       # warmup: compile on these shapes
        engine.submit(p, NEW_TOKENS, seed=0, stream=i)
    engine.run()
    # drop the warmup pass's compile-skewed latency samples so stats()
    # reports warm-path percentiles
    engine.reset_metrics()
    engine.sink.records.clear()
    # timed run reuses the SAME engine — its jitted closures (and their
    # compile caches) live on the instance, so this measures decode, not XLA
    for i, p in enumerate(prompts):
        engine.submit(p, NEW_TOKENS, seed=0, stream=i)
    t0 = time.perf_counter()
    results = engine.run()
    dt = time.perf_counter() - t0
    total = sum(len(v) for v in results.values())
    return total / dt, engine


def reprefill_tokens_per_s(cfg, params, prompts, steps=4):
    """Naive baseline: no decode cache — every new token re-runs the full
    forward over prompt+generated. The token buffer is FIXED-shape (padded
    to prompt+steps) so the jitted forward compiles once and the timed
    region measures the forward passes; generated tokens are written into
    the buffer and logits read at the growing last position (causal mask
    makes the right-padding invisible). Amortized over a few steps at the
    longest prompt — it only gets worse as the prefix grows."""
    fwd = jax.jit(lambda p, t: M.forward(p, t, cfg, remat="none")[0])
    L = max(len(p) for p in prompts)
    b = min(len(prompts), MAX_BATCH)
    buf = np.zeros((b, L + steps), np.int32)
    for i, p in enumerate(prompts[:b]):
        buf[i, L - len(p):L] = p
    logits = fwd(params, jnp.asarray(buf))           # compile + warmup
    buf[:, L] = np.argmax(np.asarray(logits[:, L - 1]), -1)
    t0 = time.perf_counter()
    for i in range(steps):
        logits = fwd(params, jnp.asarray(buf))
        nxt = np.argmax(np.asarray(logits[:, L - 1 + i]), -1)
        if i + 1 < steps:
            buf[:, L + i + 1] = nxt
    dt = time.perf_counter() - t0
    return (steps * b) / dt


def cache_bytes_vs_context(cfg):
    """Per-layer decode-cache bytes at growing context — the paper's Fig.1
    story in numbers. Linear layers: exact engine allocation (constant).
    Softmax comparison: bf16 KV cache of the same geometry at that length."""
    rows = []
    for ctx in (1024, 8192, 65536, 524288):
        cache = M.init_cache(cfg, batch=1, max_len=ctx)
        linear_bytes = sum(
            leaf.nbytes for leaf in jax.tree.leaves(cache["layers"][0]))
        kv_bytes = 2 * ctx * cfg.n_kv_heads * cfg.head_dim * 2   # bf16 K+V
        rows.append((ctx, linear_bytes, kv_bytes))
    return rows


def main():
    base = get_smoke("linear-llama3-1b")
    pure = base                                         # 2 linear layers
    dense = dataclasses.replace(base, pattern=(LayerSpec(),), n_layers=4,
                                name="smoke-dense")
    hybrid = dense.linearize(hybrid_every=4)            # 3 linear + 1 softmax

    payload = {"rows": [], "configs": {}}
    print("config,engine_tok_s,reprefill_tok_s,speedup,"
          "linear_state_bytes,kv_ring_bytes")
    for cfg in (pure, hybrid):
        params = M.init_params(jax.random.PRNGKey(0), cfg)
        prompts = workload(cfg.vocab_size)
        eng_tps, engine = engine_tokens_per_s(cfg, params, prompts)
        stats = engine.cache_stats()
        s = engine.stats()
        base_tps = reprefill_tokens_per_s(cfg, params, prompts)
        print(f"{cfg.name},{eng_tps:.1f},{base_tps:.1f},"
              f"{eng_tps / base_tps:.1f}x,{stats['linear_state']},"
              f"{stats['kv_ring']}")
        decode_p50 = s.get("decode_step_s_p50") or 0.0
        payload["rows"].append({
            "name": f"serve/{cfg.name}",
            "us_per_call": decode_p50 * 1e6,    # warm decode-step median
            "derived": f"engine_tok_s={eng_tps:.1f};"
                       f"reprefill_tok_s={base_tps:.1f};"
                       f"speedup={eng_tps / base_tps:.2f}x"})
        # warm-path latency story off the sink API: TTFT + decode/prefill
        # percentiles, queue/occupancy peaks, per-kind cache bytes, and
        # the decode-step MFU (2·N_active·B model FLOPs per step)
        shape = ShapeConfig("serve-decode", 1, MAX_BATCH, "decode")
        from repro.launch.hlo_analysis import model_flops
        payload["configs"][cfg.name] = {
            "engine_tokens_per_s": eng_tps,
            "reprefill_tokens_per_s": base_tps,
            "cache_stats": stats,
            "telemetry": telemetry_block(
                phases={"prefill_s": s.get("prefill_s_mean", 0) *
                        s.get("prefill_s_count", 0),
                        "decode_s": s.get("decode_step_s_mean", 0) *
                        s.get("decode_step_s_count", 0)},
                model_flops_per_call=model_flops(cfg, shape),
                wall_s=decode_p50 or None,
                ttft_s_p50=s.get("ttft_s_p50"),
                ttft_s_p99=s.get("ttft_s_p99"),
                decode_step_s_p50=s.get("decode_step_s_p50"),
                decode_step_s_p99=s.get("decode_step_s_p99"),
                queue_depth_peak=s.get("queue_depth_peak"),
                cache_occupancy_peak=s.get("cache_occupancy_peak"),
                requests=int(s.get("evicted", 0))),
        }

    print()
    print("context_len,linear_layer_cache_bytes,softmax_kv_cache_bytes")
    rows = cache_bytes_vs_context(pure)
    for ctx, lin, kv in rows:
        print(f"{ctx},{lin},{kv}")
        payload["rows"].append({
            "name": f"serve/cache@ctx{ctx}", "us_per_call": 0,
            "derived": f"linear_layer_bytes={lin};softmax_kv_bytes={kv}"})
    spread = {lin for _, lin, _ in rows}
    assert len(spread) == 1, \
        f"linear-layer cache must be constant in context length, got {spread}"
    print("# linear-layer decode cache is CONSTANT in context length "
          "(paper's claim); softmax KV grows linearly")
    return payload


if __name__ == "__main__":
    from benchmarks.common import write_bench_json
    write_bench_json(BENCH_NAME, main())
