"""Data pipeline determinism + checkpoint atomicity/resume/resharding."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.data.pipeline import SyntheticLM, doc_segments


def test_data_determinism():
    d1 = SyntheticLM(1000, 128, 8, seed=7)
    d2 = SyntheticLM(1000, 128, 8, seed=7)
    b1, b2 = d1.batch(5), d2.batch(5)
    for k in b1:
        np.testing.assert_array_equal(b1[k], b2[k])
    b3 = d1.batch(6)
    assert not np.array_equal(b1["tokens"], b3["tokens"])


def test_data_labels_shifted():
    d = SyntheticLM(1000, 64, 2, seed=0, pack_documents=False)
    b = d.batch(0)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])
    assert (b["labels"][:, -1] == -1).all()


def test_packing_resets_and_segments():
    d = SyntheticLM(1000, 256, 4, seed=1, mean_doc_len=64)
    b = d.batch(0)
    assert b["resets"][:, 0].all()
    segs = doc_segments(b["resets"])
    assert (np.diff(segs, axis=1) >= 0).all()
    assert segs.max() >= 2   # actually packed multiple docs


def test_microbatched_shapes():
    d = SyntheticLM(1000, 32, 8, seed=0)
    mb = d.microbatched(0, 4)
    assert mb["tokens"].shape == (4, 2, 32)
    with pytest.raises(ValueError):
        d.microbatched(0, 3)


def test_checkpoint_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = {"params": {"w": jnp.arange(12.0).reshape(3, 4)},
            "step": jnp.int32(7)}
    mgr.save(7, tree)
    assert mgr.latest_step() == 7
    out = mgr.restore(7, jax.tree.map(jnp.zeros_like, tree))
    np.testing.assert_array_equal(out["params"]["w"], tree["params"]["w"])
    assert int(out["step"]) == 7


def test_checkpoint_keep_k_and_atomic(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = {"w": jnp.zeros((4,))}
    for s in (1, 2, 3, 4):
        mgr.save(s, tree)
    assert mgr.all_steps() == [3, 4]
    # a stale tmp dir must not be listed as a checkpoint
    os.makedirs(os.path.join(str(tmp_path), "step_00000099.tmp"))
    assert mgr.latest_step() == 4


def test_checkpoint_async(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    tree = {"w": jnp.ones((128, 128))}
    mgr.save_async(11, tree)
    mgr.wait()
    out = mgr.restore(11, {"w": jnp.zeros((128, 128))})
    np.testing.assert_array_equal(out["w"], tree["w"])


def test_checkpoint_shape_mismatch_raises(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, {"w": jnp.zeros((4,))})
    with pytest.raises(ValueError):
        mgr.restore(1, {"w": jnp.zeros((5,))})


def test_checkpoint_elastic_reshard(tmp_path):
    """Checkpoint written unsharded restores under explicit shardings
    (the elastic-scaling path: any mesh can adopt the state)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    mgr = CheckpointManager(str(tmp_path))
    tree = {"w": jnp.arange(16.0).reshape(4, 4)}
    mgr.save(3, tree)
    from repro.launch.mesh import DATA_AXIS, auto_axis_types
    mesh = jax.make_mesh((1,), (DATA_AXIS,), **auto_axis_types(1))
    sh = {"w": NamedSharding(mesh, P(DATA_AXIS, None))}
    out = mgr.restore(3, jax.tree.map(jnp.zeros_like, tree), shardings=sh)
    np.testing.assert_array_equal(out["w"], tree["w"])
    assert out["w"].sharding == sh["w"]
