"""moonshot-v1-16b-a3b — kimi/moonlight MoE, 64 experts top-6
[hf:moonshotai/Moonlight-16B-A3B; hf].

Deviation noted in DESIGN.md: Moonlight's first layer is dense; we model
all 48 layers as MoE (+2 shared experts) to keep the scanned stack
homogeneous.
"""
from repro.configs.base import LayerSpec, MoEConfig, ModelConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b", family="moe",
    n_layers=48, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=1408, vocab_size=163840,
    rope_theta=50000.0, norm_eps=1e-5,
    pattern=(LayerSpec(mixer="softmax", mlp="moe"),),
    moe=MoEConfig(num_experts=64, top_k=6, capacity_factor=1.25,
                  n_shared_experts=2),
    source="[hf:moonshotai/Moonlight-16B-A3B; hf]",
)

SMOKE = ModelConfig(
    name="moonshot-v1-16b-a3b-smoke", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=96,
    vocab_size=512,
    pattern=(LayerSpec(mixer="softmax", mlp="moe"),),
    # capacity_factor = E/k ⇒ cap == T: drop-free routing, so smoke
    # parity tests (prefill+decode == forward) are exact.
    moe=MoEConfig(num_experts=8, top_k=2, capacity_factor=4.0,
                  n_shared_experts=2),
)
