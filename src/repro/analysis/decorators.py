"""Allowlist markers the AST lint recognizes.

Import-light on purpose: hot-path modules (``repro.obs``) import this at
module load, so it must not pull in jax.
"""

from __future__ import annotations

HOST_SYNC_ATTR = "__jaxlint_host_sync_allowed__"


def host_sync_allowed(fn):
    """Mark a function as a *deliberate* host-sync site (JL102 exempt).

    The only legitimate holders are the observability fencing helpers
    (``repro.obs.metrics``): they exist to synchronize on device values so
    phase walls attribute async-dispatched work to the right phase
    (docs/observability.md). The lint recognizes the decorator *textually*
    (any ``@host_sync_allowed`` on the enclosing ``def``), so applying it
    is reviewable in the diff; the runtime marker attribute is set too so
    tooling can discover allowed sites by import.
    """
    setattr(fn, HOST_SYNC_ATTR, True)
    return fn
