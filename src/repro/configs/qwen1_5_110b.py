"""qwen1.5-110b — QKV bias [hf:Qwen/Qwen1.5-0.5B (family); hf]."""
from repro.configs.base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-110b", family="dense",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=49152, vocab_size=152064,
    qkv_bias=True, rope_theta=1_000_000.0, norm_eps=1e-6,
    pattern=(LayerSpec(mixer="softmax", mlp="dense"),),
    source="[hf:Qwen/Qwen1.5-110B (dims); hf]",
)

SMOKE = ModelConfig(
    name="qwen1.5-110b-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=8, n_kv_heads=2, d_ff=192,
    vocab_size=512, qkv_bias=True, rope_theta=1_000_000.0, norm_eps=1e-6,
    pattern=(LayerSpec(mixer="softmax", mlp="dense"),),
)
