"""Runs the 8-virtual-device distributed battery in a subprocess (so this
pytest process keeps its single default device)."""

import os
import subprocess
import sys

def test_distributed_battery():
    script = os.path.join(os.path.dirname(__file__),
                          "distributed_checks.py")
    proc = subprocess.run([sys.executable, script], capture_output=True,
                          text=True, timeout=2400)
    sys.stdout.write(proc.stdout)
    sys.stderr.write(proc.stderr[-3000:])
    assert proc.returncode == 0, "distributed checks failed"
    assert "ALL" in proc.stdout and "PASSED" in proc.stdout
