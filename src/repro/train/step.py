"""Train-step factory: grad accumulation (scan), AdamW, clipping, skip-on-
non-finite, optional cross-pod int8 gradient compression.

``train_step(state, batch)``:
  state = {"params", "opt": AdamState, "step", ["err"]}
  batch = {"tokens"/"labels"/"resets": (A, B/A, S), [frames|img]: (A, ...)}
Returns (new_state, metrics). Designed for jit with donated state.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.compat import shard_map as _shard_map

from repro.configs.base import ModelConfig, RunConfig
from repro.models import model as M
from repro.optim import adamw
from repro.optim.compression import compress_sync_tree
from repro.sharding.rules import Parallelism

MOE_AUX_COEF = 0.01


def init_state(key, cfg: ModelConfig, run: RunConfig):
    params = M.init_params(key, cfg)
    if run.bf16_params:
        # §Perf: bf16 weight storage — halves FSDP gather traffic and
        # removes per-use f32→bf16 converts; Adam moments stay fp32 (the
        # usual production mixed-precision recipe).
        params = jax.tree.map(
            lambda x: x.astype(jnp.bfloat16)
            if (x.dtype == jnp.float32 and x.ndim >= 2) else x, params)
    state = {"params": params, "opt": adamw.init(params),
             "step": jnp.zeros((), jnp.int32)}
    if run.grad_compression:
        from repro.optim.compression import init_error_buffer
        state["err"] = init_error_buffer(params)
    return state


def make_loss_fn(cfg: ModelConfig, run: RunConfig, plan: Parallelism):
    def loss_fn(params, micro):
        kwargs = {}
        if "frames" in micro:
            kwargs["enc_frames"] = micro["frames"]
        if "img" in micro:
            kwargs["img_emb"] = micro["img"]
        logits, aux = M.forward(params, micro["tokens"], cfg, plan,
                                remat=run.remat, unroll=run.scan_unroll,
                                resets=micro.get("resets"), **kwargs)
        loss = M.lm_loss(logits, micro["labels"])
        return loss + MOE_AUX_COEF * aux, loss
    return loss_fn


def _accum_grads(loss_fn, params, batch, unroll=False, plan=None):
    """Scan over the leading microbatch dim, averaging grads in fp32.

    §Perf: the fp32 accumulators are CONSTRAINED to the parameter sharding
    (FSDP over "data", TP over "model"). Without this, XLA keeps the
    accumulator replicated and moves the FULL fp32 gradient per microbatch
    (measured as 14.9 GiB/layer of f32 all-gathers on qwen110b×train_4k);
    with it, each microbatch contributes a reduce-scatter into the shard —
    the ZeRO-2 gradient flow."""
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def constrain(tree):
        if plan is None or plan.mesh is None:
            return tree
        from jax.sharding import NamedSharding
        from repro.sharding.rules import param_specs
        specs = param_specs(tree, plan)
        return jax.tree.map(
            lambda x, sp: jax.lax.with_sharding_constraint(
                x, NamedSharding(plan.mesh, sp)),
            tree, specs, is_leaf=lambda x: hasattr(x, "shape"))

    def body(acc, micro):
        (total, ce), g = grad_fn(params, micro)
        acc = jax.tree.map(lambda a, b: a + b.astype(jnp.float32), acc, g)
        return constrain(acc), ce

    zeros = constrain(jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params))
    grads, ces = jax.lax.scan(body, zeros, batch,
                              unroll=True if unroll else 1)
    a = ces.shape[0]
    grads = jax.tree.map(lambda g: g / a, grads)
    return grads, jnp.mean(ces)


def _cast_tree(params, dtype):
    """bf16 copies of matrix params (norm scales and 1-D params stay
    fp32). The cast sits OUTSIDE the microbatch scan, so FSDP gathers move
    bf16 (half the bytes) and the gather result is reusable across
    microbatches (§Perf hillclimb #1)."""
    return jax.tree.map(
        lambda x: x.astype(dtype)
        if (x.dtype == jnp.float32 and x.ndim >= 2) else x, params)


def make_train_step(cfg: ModelConfig, run: RunConfig, plan: Parallelism):
    loss_fn = make_loss_fn(cfg, run, plan)

    def train_step(state, batch):
        params = state["params"]
        if run.cast_params_once:
            compute_params = _cast_tree(params, jnp.dtype(cfg.dtype))
        else:
            compute_params = params

        if run.grad_compression and plan.mesh is not None \
                and "pod" in plan.mesh.axis_names:
            # per-pod local grads → int8 error-feedback cross-pod sync
            def body(params_, batch_, err_):
                g, ce = _accum_grads(loss_fn, params_, batch_,
                                     run.scan_unroll, plan)
                g, new_err = compress_sync_tree(g, err_, pod_axis="pod")
                return g, jax.lax.pmean(ce, "pod"), new_err

            nb = jax.tree.map(lambda x: P(None, "pod"), batch)
            grads, ce, new_err = _shard_map(
                body, mesh=plan.mesh,
                in_specs=(P(), nb, P()), out_specs=(P(), P(), P()),
                axis_names={"pod"}, check_vma=False)(
                    compute_params, batch, state["err"])
        else:
            grads, ce = _accum_grads(loss_fn, compute_params, batch,
                                     run.scan_unroll, plan)
            new_err = state.get("err")
        if run.cast_params_once:
            # d(loss)/d(master fp32) == d(loss)/d(bf16 copy) cast back
            grads = jax.tree.map(
                lambda g, p: g.astype(jnp.float32)
                if g.dtype != p.dtype else g, grads, params)

        grads, gnorm = adamw.clip_by_global_norm(grads, run.grad_clip)
        finite = jnp.isfinite(gnorm)
        # Fault tolerance: a non-finite step is skipped, not applied.
        grads = jax.tree.map(
            lambda g: jnp.where(finite, g, jnp.zeros_like(g)), grads)
        lr = adamw.cosine_schedule(
            state["step"], base_lr=run.learning_rate,
            warmup_steps=run.warmup_steps, total_steps=run.total_steps,
            min_lr=run.min_lr)
        new_params, new_opt = adamw.update(
            grads, state["opt"], params, lr=lr, b1=run.adam_b1,
            b2=run.adam_b2, weight_decay=run.weight_decay)
        new_params = jax.tree.map(
            lambda n, o: jnp.where(finite, n, o), new_params, params)
        new_opt = jax.tree.map(
            lambda n, o: jnp.where(finite, n, o), new_opt, state["opt"])
        new_state = {"params": new_params, "opt": new_opt,
                     "step": state["step"] + 1}
        if new_err is not None:
            new_state["err"] = new_err
        metrics = {"loss": ce, "grad_norm": gnorm, "lr": lr,
                   "skipped": (~finite).astype(jnp.float32)}
        return new_state, metrics

    return train_step
