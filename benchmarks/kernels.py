"""Kernel-backend A/B: pallas(interpret) vs XLA intra-chunk wall time.

Measured: median/p90 per call of ``ops.linear_attention_op`` — the
LASP-2 intra-chunk hot path — on each differentiable backend, forward
and forward+backward (``jax.grad`` pulling on o, state and log_decay,
i.e. what the faithful SP backward pulls on). On this CPU container the
interpret numbers are *indicative only* (Pallas interpret mode is a
jax-level emulator; the TPU "pallas" backend is the target) — the bench
exists so CI tracks that the custom_vjp path stays wired and its
relative cost trajectory across PRs. Derived: fwd/bwd FLOP counts of
the chunked algorithm. Emits ``BENCH_kernels.json``.
"""

from __future__ import annotations

from benchmarks.common import emit, run_subprocess_bench

BENCH_NAME = "kernels"

_CODE = r"""
import json, time
import jax, jax.numpy as jnp
from repro.kernels import ops
from benchmarks.common import percentile

BH, S, D, BS = 4, 2048, 64, 128
key = jax.random.PRNGKey(0)
ks = jax.random.split(key, 4)
q = jax.random.normal(ks[0], (1, BH, S, D)) * 0.3
k = jax.random.normal(ks[1], (1, BH, S, D)) * 0.3
v = jax.random.normal(ks[2], (1, BH, S, D)) * 0.5
la = -jnp.abs(jax.random.normal(ks[3], (1, BH, S))) * 0.03

def make_fwd(backend):
    return jax.jit(lambda a, b, c, d: ops.linear_attention_op(
        a, b, c, d, block_size=BS, backend=backend)[0])

def make_grad(backend):
    def loss(a, b, c, d):
        o, st, ld = ops.linear_attention_op(a, b, c, d, block_size=BS,
                                            backend=backend)
        return jnp.sum(o) + jnp.sum(st) + jnp.sum(ld)
    return jax.jit(jax.grad(loss, argnums=(0, 1, 2, 3)))

# chunked-algorithm FLOPs (per _block_terms: QK^T, scores·V, K^T V + the
# inter-chunk (q·b)@M term), fwd; bwd re-runs ~2x that in the two passes.
flops_fwd = 2 * S * (2 * BS * D + 2 * D * D) * BH
res = {}
for backend in ("xla", "interpret"):
    for tag, fn in (("fwd", make_fwd(backend)), ("grad", make_grad(backend))):
        out = fn(q, k, v, la)
        jax.block_until_ready(out)
        times = []
        for _ in range(5):
            t0 = time.perf_counter()
            jax.block_until_ready(fn(q, k, v, la))
            times.append((time.perf_counter() - t0) * 1e6)
        res[f"{backend}_{tag}"] = {
            "median_us": percentile(times, 50),
            "p90_us": percentile(times, 90),
            "flops_analytic": flops_fwd * (3 if tag == "grad" else 1),
        }
print(json.dumps(res))
"""


def main():
    res = run_subprocess_bench(_CODE, devices=1)
    rows = []
    for name, r in sorted(res.items()):
        rows.append((f"kernels/{name}", r["median_us"],
                     f"p90={r['p90_us']:.0f}us "
                     f"flops={r['flops_analytic']}"))
    emit(rows, header=None)
    xla = res["xla_grad"]["median_us"]
    interp = res["interpret_grad"]["median_us"]
    return {
        "rows": [{"name": n, "us_per_call": us, "derived": d}
                 for n, us, d in rows],
        "shape": {"bh": 4, "s": 2048, "d": 64, "block": 128},
        "interpret_over_xla_grad": interp / max(xla, 1e-9),
        "note": ("interpret backend is a CPU emulator of the Pallas "
                 "kernel — TPU 'pallas' is the production path; tracked "
                 "for wiring + trajectory, not absolute speed"),
    }


if __name__ == "__main__":
    main()
