"""granite-34b — llama-arch, code, MQA (kv=1) [arXiv:2405.04324; hf]."""
from repro.configs.base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="granite-34b", family="dense",
    n_layers=88, d_model=6144, n_heads=48, n_kv_heads=1,
    d_ff=24576, vocab_size=49152,
    rope_theta=10000.0, norm_eps=1e-5, mlp_act="gelu",
    pattern=(LayerSpec(mixer="softmax", mlp="dense"),),
    source="[arXiv:2405.04324; hf]",
)

SMOKE = ModelConfig(
    name="granite-34b-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=1, d_ff=160,
    vocab_size=512, rope_theta=10000.0,
    pattern=(LayerSpec(mixer="softmax", mlp="dense"),),
)
