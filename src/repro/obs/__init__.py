"""Observability: metrics sinks, phase timers, and the comm flight
recorder (docs/observability.md).

Everything here is host-side bookkeeping — enabling a sink never adds
collectives or device ops to a traced program, so the HLO budget checks
hold with instrumentation on or off.
"""

from repro.obs.flight_recorder import CompileSnapshot, FlightRecorder
from repro.obs.metrics import (Fence, Histogram, InMemorySink, JsonlSink,
                               Metrics, MetricsSink, NullSink, PhaseTimer,
                               as_sink, block_until_ready, read_jsonl,
                               render_step, scoped_timer)

__all__ = [
    "CompileSnapshot", "FlightRecorder", "Fence", "Histogram",
    "InMemorySink", "JsonlSink", "Metrics", "MetricsSink", "NullSink",
    "PhaseTimer", "as_sink", "block_until_ready", "read_jsonl",
    "render_step", "scoped_timer",
]
