"""Seeded-violation battery for the static-analysis subsystem.

Every jaxlint rule gets a fixture carrying its bug pattern (must flag)
plus a clean twin (must pass); the sanitizer's pure-text checks get
crafted HLO/StableHLO with injected regressions (fp32 on the bf16 wire,
host transfers, f64, dropped donation, fingerprint drift). The repo-wide
assertions at the bottom are the PR's contract: zero findings, zero
suppressions (docs/static_analysis.md).
"""

from pathlib import Path

import numpy as np
import pytest

from repro.analysis.findings import AnalysisResult, Finding
from repro.analysis.lint import (discover_files, lint_file,
                                 load_suppressions, run_lint)
from repro.analysis.rules import explain
from repro.analysis.sanitizer import check_determinism, sanitize_text
from repro.launch.mesh import DATA_AXIS, MODEL_AXIS, SEQ_AXIS

ROOT = Path(__file__).resolve().parent.parent


def _lint(text, codes, **kw):
    return lint_file(Path("fx.py"), text=text, codes=set(codes), **kw)


def _codes(findings):
    return [f.code for f in findings]


# --- JL101: raw axis-name literals -----------------------------------------

def test_jl101_flags_axis_literals():
    bad = '''
def f(mesh):
    return mesh.shape.get("data", 1)

spec = P("sequence", None)
names = {"model", "pod"}
'''
    assert _codes(_lint(bad, ["JL101"])) == ["JL101"] * 4


def test_jl101_clean_twin_passes():
    clean = '''
from repro.launch.mesh import DATA_AXIS, SEQ_AXIS

def f(mesh):
    return mesh.shape.get(DATA_AXIS, 1)

spec = P(SEQ_AXIS, None)
'''
    assert _lint(clean, ["JL101"]) == []


def test_jl101_denied_contexts_not_flagged():
    # the axis words as decay kinds / phase-timer labels are legitimate
    denied = '''
if cfg.linear_attn.decay == "data":
    pass
cfg2 = LinearAttnConfig("data", kind="sequence")
with timer.phase("sequence"):
    pass
g(decay="model")
'''
    assert _lint(denied, ["JL101"]) == []


def test_jl101_model_axis_is_live():
    """Since the 3D DP×SP×TP mesh landed, MODEL_AXIS carries real
    ulysses traffic: a raw "model" literal in mesh/spec positions is a
    budget-classification hazard, the constant is the clean spelling,
    and the rule's explanation says so."""
    bad = '''
mesh = make_training_mesh(2, 2, 2)
spec = P(None, ("sequence", "model"))
deg = mesh.shape["model"]
'''
    assert _codes(_lint(bad, ["JL101"])) == ["JL101"] * 3
    clean = '''
from repro.launch.mesh import MODEL_AXIS, SEQ_AXIS

mesh = make_training_mesh(2, 2, 2)
spec = P(None, (SEQ_AXIS, MODEL_AXIS))
deg = mesh.shape[MODEL_AXIS]
'''
    assert _lint(clean, ["JL101"]) == []
    assert "LIVE training axis" in explain("JL101")


# --- JL102: host syncs in traced hot-path modules --------------------------

_JL102_BAD = '''
import jax
import numpy as np

def f(x):
    print(x)
    jax.block_until_ready(x)
    jax.device_get(x)
    np.asarray(x)
    return x.item()
'''


def test_jl102_flags_host_syncs_in_scope():
    assert _codes(_lint(_JL102_BAD, ["JL102"], sync_scope=True)) \
        == ["JL102"] * 5


def test_jl102_out_of_scope_silent():
    # host-side drivers own their sync points — rule scoped off
    assert _lint(_JL102_BAD, ["JL102"], sync_scope=False) == []


def test_jl102_decorator_exempts():
    fenced = '''
import jax
from repro.analysis.decorators import host_sync_allowed

@host_sync_allowed
def fence(x):
    return jax.block_until_ready(x)
'''
    assert _lint(fenced, ["JL102"], sync_scope=True) == []


# --- JL103: Tracer isinstance ----------------------------------------------

def test_jl103_flags_tracer_isinstance():
    bad = '''
import jax

def f(x):
    if isinstance(x, jax.core.Tracer):
        return 1
    return isinstance(x, Tracer)
'''
    assert _codes(_lint(bad, ["JL103"])) == ["JL103"] * 2


def test_jl103_clean_twin_passes():
    clean = '''
from repro.core.compat import is_tracer

def f(x):
    return is_tracer(x) or isinstance(x, float)
'''
    assert _lint(clean, ["JL103"]) == []


# --- JL104: nondeterminism in traced code ----------------------------------

def test_jl104_flags_nondeterminism_in_scope():
    bad = '''
import time
from random import shuffle
import numpy as np

def f(x):
    return x + np.random.normal() + time.time()
'''
    # import time, from random, np.random attribute (time.time() is
    # reached via the import finding; the attribute walk only matches
    # numpy aliases)
    assert _codes(_lint(bad, ["JL104"], det_scope=True)) == ["JL104"] * 3


def test_jl104_clean_twin_passes():
    clean = '''
import jax

def f(key, x):
    return x + jax.random.normal(key, x.shape)
'''
    assert _lint(clean, ["JL104"], det_scope=True) == []
    # out of scope: host drivers may use clocks
    assert _lint("import time\n", ["JL104"], det_scope=False) == []


# --- JL105: Pallas debug debris --------------------------------------------

def test_jl105_flags_debris():
    bad = '''
from jax.experimental import pallas as pl

def kern(x_ref, o_ref):
    pl.debug_print("x = {}", x_ref[...])
    o_ref[...] = x_ref[...]

def run(x):
    return pl.pallas_call(kern, out_shape=x, interpret=True)(x)
'''
    assert _codes(_lint(bad, ["JL105"])) == ["JL105"] * 2


def test_jl105_interpret_via_knob_passes():
    clean = '''
from jax.experimental import pallas as pl

def run(x, interpret):
    return pl.pallas_call(kern, out_shape=x, interpret=interpret)(x)
'''
    assert _lint(clean, ["JL105"]) == []


# --- JL106: unmasked dynamic pl.load/store ---------------------------------

def test_jl106_flags_unmasked_dynamic():
    bad = '''
from jax.experimental import pallas as pl

def kern(ref, o_ref, i):
    x = pl.load(ref, (pl.ds(i, 4),))
    pl.store(o_ref, (pl.ds(i, 4),), x)
'''
    assert _codes(_lint(bad, ["JL106"])) == ["JL106"] * 2


def test_jl106_masked_twin_passes():
    clean = '''
from jax.experimental import pallas as pl

def kern(ref, o_ref, i, m):
    x = pl.load(ref, (pl.ds(i, 4),), mask=m, other=0.0)
    pl.store(o_ref, (pl.ds(i, 4),), x, mask=m)
    y = pl.load(ref, (slice(None),))          # static: no mask needed
'''
    assert _lint(clean, ["JL106"]) == []


# --- suppression mechanisms -------------------------------------------------

def test_inline_disable_routes_to_suppressed(tmp_path):
    p = tmp_path / "fx.py"
    p.write_text('ax = "sequence"  # jaxlint: disable=JL101\n')
    res = run_lint([p], suppressions=[])
    assert res.findings == [] and _codes(res.suppressed) == ["JL101"]


def test_suppression_file_routes_to_suppressed(tmp_path):
    p = tmp_path / "fx.py"
    p.write_text('ax = "sequence"\n')
    sup = tmp_path / "suppressions.txt"
    sup.write_text("# comment\nfx.py JL101\n")
    res = run_lint([p], suppressions=load_suppressions(sup))
    assert res.findings == [] and _codes(res.suppressed) == ["JL101"]
    # a different code still surfaces
    res2 = run_lint([p], suppressions=[("fx.py", "JL102")])
    assert _codes(res2.findings) == ["JL101"]


def test_bad_suppression_line_raises(tmp_path):
    sup = tmp_path / "suppressions.txt"
    sup.write_text("fx.py JL101 extra-token\n")
    with pytest.raises(ValueError, match="bad suppression line"):
        load_suppressions(sup)


def test_explain_known_and_unknown():
    assert "axis-name" in explain("jl101")
    with pytest.raises(KeyError, match="unknown rule code"):
        explain("JL999")


# --- repo-wide contract -----------------------------------------------------

def test_repo_lint_clean_and_suppressions_empty():
    """The PR's acceptance bar: zero surviving findings repo-wide AND an
    empty suppression file (nothing grandfathered, hot path or not)."""
    res = run_lint()
    assert res.ok, "\n".join(str(f) for f in res.findings)
    assert load_suppressions() == []
    assert res.suppressed == []


def test_discovery_skips_pycache():
    files = discover_files(ROOT)
    assert files, "discovery found nothing"
    assert not [p for p in files if "__pycache__" in p.parts]


# --- PAL301: Pallas index-map grid bounds -----------------------------------

def _pallas_runner(idx_fn):
    import jax
    import jax.numpy as jnp
    from jax.experimental import pallas as pl

    def kern(x_ref, o_ref):
        o_ref[...] = x_ref[...]

    def run(x):
        return pl.pallas_call(
            kern, grid=(4,),
            in_specs=[pl.BlockSpec((8,), idx_fn)],
            out_specs=pl.BlockSpec((8,), lambda i: i),
            out_shape=jax.ShapeDtypeStruct((32,), jnp.float32))(x)
    return run


def test_pal301_catches_out_of_bounds_index_map():
    import jax
    import jax.numpy as jnp

    from repro.analysis.pallas_check import check_fn
    sds = jax.ShapeDtypeStruct((32,), jnp.float32)
    bad = check_fn(_pallas_runner(lambda i: i + 1), sds, name="bad")
    assert _codes(bad) == ["PAL301"] and "outside [0, 4)" in bad[0].message
    assert check_fn(_pallas_runner(lambda i: i), sds, name="good") == []


def test_pal301_repo_kernel_battery_clean():
    from repro.analysis.pallas_check import check_repo_kernels
    findings, n_entries = check_repo_kernels()
    assert findings == [], "\n".join(str(f) for f in findings)
    assert n_entries == 7


# --- sanitizer: crafted-program regressions ---------------------------------

_CLEAN_HLO = """\
HloModule jit_step, input_output_alias={ {0}: (0, {}, may-alias) }
  %x = f32[4]{0} add(f32[4] %a, f32[4] %b)
  ROOT %t = (f32[4]) tuple(f32[4] %x)
"""


def test_san201_injected_host_transfers_flagged():
    dirty = _CLEAN_HLO + """\
  %i = token[] infeed(token[] %tok)
  %s = (f32[4]) send(f32[4] %x), is_host_transfer=true
  %c = f32[4] custom-call(f32[4] %x), custom_call_target="HostCallback"
"""
    out = sanitize_text("fx", compiled_text=dirty)
    assert _codes(out) == ["SAN201"] * 3
    assert sanitize_text("fx", compiled_text=_CLEAN_HLO) == []


def test_san202_injected_f64_flagged():
    dirty = _CLEAN_HLO + "  %d = f64[4]{0} convert(f32[4] %x)\n"
    out = sanitize_text("fx", compiled_text=dirty)
    assert _codes(out) == ["SAN202"] and "f64" in out[0].message
    # f64 inside a metadata attribute is not a program buffer
    meta = _CLEAN_HLO + \
        '  %m = f32[4] add(%a, %b), metadata={op_name="f64[cast]"}\n'
    assert sanitize_text("fx", compiled_text=meta) == []


def test_san204_missing_donation_flagged():
    undonated = _CLEAN_HLO.replace(
        ", input_output_alias={ {0}: (0, {}, may-alias) }", "")
    out = sanitize_text("fx", compiled_text=undonated, expect_donation=True)
    assert _codes(out) == ["SAN204"]
    assert sanitize_text("fx", compiled_text=_CLEAN_HLO,
                         expect_donation=True) == []


class _FakeDev:
    def __init__(self, i):
        self.id = i


class _FakeMesh:
    """A (2, 4) (DATA_AXIS, SEQ_AXIS) mesh: device (d, s) = id d*4+s."""

    axis_names = (DATA_AXIS, SEQ_AXIS)
    shape = {DATA_AXIS: 2, SEQ_AXIS: 4}

    @property
    def devices(self):
        return np.array([[_FakeDev(d * 4 + s) for s in range(4)]
                         for d in range(2)])


def _stablehlo(gather_dtype):
    # a seq-axis state gather (comm_dtype contract) + the ZeRO-1
    # data-axis param gather (fp32 by design, exempt)
    return f"""\
module @jit_step {{
  func.func public @main(%arg0: tensor<1x4x4x257x{gather_dtype}>) {{
    %0 = "stablehlo.all_gather"(%arg0) <{{all_gather_dim = 2 : i64,
      replica_groups = dense<[[0, 1, 2, 3], [4, 5, 6, 7]]> :
      tensor<2x4xi64>}}> : (tensor<1x4x4x257x{gather_dtype}>) ->
      tensor<1x4x16x257x{gather_dtype}>
    %1 = "stablehlo.all_gather"(%arg1) <{{replica_groups =
      dense<[[0, 4], [1, 5], [2, 6], [3, 7]]> : tensor<4x2xi64>}}> :
      (tensor<80032xf32>) -> tensor<160064xf32>
    return
  }}
}}
"""


def test_san203_fp32_wire_regression_flagged():
    out = sanitize_text("fx", lowered_text=_stablehlo("f32"),
                        mesh=_FakeMesh(), comm_dtype="bf16")
    assert _codes(out) == ["SAN203"] and "carries f32" in out[0].message
    # the honest bf16 wire passes; the data-axis f32 gather stays exempt
    assert sanitize_text("fx", lowered_text=_stablehlo("bf16"),
                         mesh=_FakeMesh(), comm_dtype="bf16") == []
    # comm_dtype=fp32 accepts the f32 wire
    assert sanitize_text("fx", lowered_text=_stablehlo("f32"),
                         mesh=_FakeMesh(), comm_dtype="fp32") == []


def test_san203_vacuous_program_flagged():
    # sp > 1 but no seq-axis exchange at all: the check must not pass
    # silently (the LASP-2 path failed to compile in)
    out = sanitize_text("fx", lowered_text="module @jit_step {}",
                        mesh=_FakeMesh(), comm_dtype="bf16")
    assert _codes(out) == ["SAN203"] and "vacuous" in out[0].message


class _FakeMesh3D:
    """A (2, 2, 2) (DATA, SEQ, MODEL) mesh: device (d, s, m) = d*4+s*2+m."""

    axis_names = (DATA_AXIS, SEQ_AXIS, MODEL_AXIS)
    shape = {DATA_AXIS: 2, SEQ_AXIS: 2, MODEL_AXIS: 2}

    @property
    def devices(self):
        return np.array([[[_FakeDev(d * 4 + s * 2 + m) for m in range(2)]
                          for s in range(2)] for d in range(2)])


def _stablehlo_3d(gather_dtype, a2a_dtype="f32"):
    # the ulysses hybrid layer on the 3D mesh: a model-axis All-to-All
    # (head repartition, compute-dtype wire by design), the linear
    # layers' state gather over the COMBINED (seq, model) token axis
    # (comm_dtype contract), and the ZeRO-1 (data, model) param gather
    # (fp32 by design, exempt)
    return f"""\
module @jit_step {{
  func.func public @main(%arg0: tensor<4x8x16x16x{a2a_dtype}>) {{
    %0 = "stablehlo.all_to_all"(%arg0) <{{split_dimension = 1 : i64,
      concat_dimension = 2 : i64, split_count = 2 : i64,
      replica_groups = dense<[[0, 1], [2, 3], [4, 5], [6, 7]]> :
      tensor<4x2xi64>}}> : (tensor<4x8x16x16x{a2a_dtype}>) ->
      tensor<4x4x32x16x{a2a_dtype}>
    %1 = "stablehlo.all_gather"(%arg1) <{{all_gather_dim = 0 : i64,
      replica_groups = dense<[[0, 1, 2, 3], [4, 5, 6, 7]]> :
      tensor<2x4xi64>}}> : (tensor<1x4x4x257x{gather_dtype}>) ->
      tensor<4x4x4x257x{gather_dtype}>
    %2 = "stablehlo.all_gather"(%arg2) <{{replica_groups =
      dense<[[0, 1, 4, 5], [2, 3, 6, 7]]> : tensor<2x4xi64>}}> :
      (tensor<80032xf32>) -> tensor<320128xf32>
    return
  }}
}}
"""


def test_san203_3d_model_axis_alltoall_legitimate():
    """On the 3D ulysses mesh the model-axis All-to-All is the head
    repartition — a legitimate mixed-dtype wire, never a SAN203 hit —
    while the combined (seq, model) token-axis gather IS the sequence
    wire: it satisfies the vacuity check and must honor comm_dtype."""
    # bf16 combined gather + f32 model a2a: clean under comm_dtype=bf16
    assert sanitize_text("fx", lowered_text=_stablehlo_3d("bf16"),
                         mesh=_FakeMesh3D(), comm_dtype="bf16") == []
    # the combined-axis gather regressing to f32 still flags
    out = sanitize_text("fx", lowered_text=_stablehlo_3d("f32"),
                        mesh=_FakeMesh3D(), comm_dtype="bf16")
    assert _codes(out) == ["SAN203"] and "carries f32" in out[0].message


def test_san205_fingerprint_drift_flagged():
    texts = [_stablehlo("bf16"), _stablehlo("f32")]
    out = check_determinism("fx", lambda: texts.pop(0))
    assert _codes(out) == ["SAN205"]
    assert check_determinism("fx", lambda: _stablehlo("bf16")) == []


# --- sanitizer: real single-device program ----------------------------------

def test_decode_step_sanitizes_clean():
    """The serve decode jit (donated cache) passes SAN201/202/204 — runs
    on the default single device; the 8-device train-step legs run in
    tests/distributed_checks.py."""
    from repro.analysis.sanitizer import sanitize_decode_step
    findings = sanitize_decode_step()
    assert findings == [], "\n".join(str(f) for f in findings)


# --- findings document + report rendering -----------------------------------

def test_findings_json_roundtrip_and_report(tmp_path):
    import json
    import subprocess
    import sys

    res = AnalysisResult(
        findings=[Finding(code="SAN203", path="train_step[dp=2,sp=4]",
                          line=0, message="carries f32")],
        checked={"programs": 3})
    doc = json.loads(res.to_json())
    assert doc["ok"] is False and doc["counts"] == {"SAN203": 1}
    p = tmp_path / "findings.json"
    p.write_text(res.to_json())
    out = subprocess.run(
        [sys.executable, str(ROOT / "scripts" / "report.py"), str(p)],
        capture_output=True, text=True, check=True)
    assert "Static-analysis report" in out.stdout
    assert "**FAIL**" in out.stdout and "SAN203" in out.stdout
