"""Paper Table 2: convergence of Linear-Llama3 variants (pure vs 1/4
hybrid) against the softmax baseline, at laptop scale.

Columns mirror the paper: attention module × {pure, 1/4 hybrid} →
(throughput tokens/s, final loss). Expectation (paper's finding): pure
linear modules land slightly above the softmax baseline's loss; hybrids
close most of the gap. Run on synthetic skewed data, 120 steps.
"""

from __future__ import annotations

import dataclasses
import time

from benchmarks.common import emit

STEPS = 120
SEQ = 256
BATCH = 8


def _base_cfg():
    from repro.configs.base import LayerSpec, ModelConfig
    return ModelConfig(
        name="llama3-tiny", family="dense", n_layers=4, d_model=128,
        n_heads=4, n_kv_heads=4, d_ff=352, vocab_size=2048,
        pattern=(LayerSpec(),))


def _variant(module: str, hybrid: bool):
    from repro.configs.base import LinearAttnConfig
    cfg = _base_cfg()
    lac = {
        "basic": LinearAttnConfig("identity", "none", "faithful"),
        "lightning": LinearAttnConfig("silu", "lightning", "faithful"),
        "retention": LinearAttnConfig("identity", "retention", "faithful"),
        "gla": LinearAttnConfig("silu", "data", "autodiff"),
        "based": LinearAttnConfig("taylor", "none", "autodiff"),
        "rebased": LinearAttnConfig("taylor", "none", "autodiff"),
    }[module]
    cfg = cfg.linearize(hybrid_every=4 if hybrid else 0)
    cfg = dataclasses.replace(
        cfg, linear_attn=lac,
        name=f"linear-llama3-tiny-{module}{'-h4' if hybrid else ''}")
    return cfg


def _train(cfg):
    from repro.configs.base import RunConfig
    from repro.data.pipeline import SyntheticLM
    from repro.train.loop import train
    run = RunConfig(num_microbatches=1, total_steps=STEPS,
                    warmup_steps=10, learning_rate=1e-3, remat="none")
    data = SyntheticLM(cfg.vocab_size, SEQ, BATCH, seed=0)
    t0 = time.perf_counter()
    _, hist = train(cfg, run, data, log_every=10 ** 9,
                    log_fn=lambda *_: None)
    dt = time.perf_counter() - t0
    last = sum(h["loss"] for h in hist[-10:]) / 10
    thpt = STEPS * SEQ * BATCH / dt
    return last, thpt, dt


def main():
    rows = []
    base_loss, base_thpt, base_dt = _train(_base_cfg())
    rows.append(("table2/softmax-baseline",
                 base_dt / STEPS * 1e6,
                 f"loss={base_loss:.3f};thpt={base_thpt:.0f}tok/s"))
    for module in ("basic", "lightning", "retention", "gla", "based"):
        for hybrid in (False, True):
            cfg = _variant(module, hybrid)
            loss, thpt, dt = _train(cfg)
            tag = f"table2/{module}{'-hybrid4' if hybrid else '-pure'}"
            rows.append((tag, dt / STEPS * 1e6,
                         f"loss={loss:.3f};thpt={thpt:.0f}tok/s"))
    emit(rows)
    return rows


if __name__ == "__main__":
    main()
