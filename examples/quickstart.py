"""Quickstart: train a tiny Linear-Llama3 (the paper's model family) on
synthetic data for 60 steps and watch the loss fall.

  PYTHONPATH=src python examples/quickstart.py
"""

import sys

sys.path.insert(0, "src")

from repro.configs import get_smoke
from repro.configs.base import RunConfig
from repro.data.pipeline import SyntheticLM
from repro.train.loop import train


def main():
    cfg = get_smoke("linear-llama3-1b")     # linear attention, tiny dims
    run = RunConfig(num_microbatches=2, total_steps=60, warmup_steps=5,
                    learning_rate=1e-3, remat="none")
    data = SyntheticLM(cfg.vocab_size, seq_len=128, global_batch=8, seed=0)
    state, history = train(cfg, run, data, log_every=10)
    first, last = history[0]["loss"], history[-1]["loss"]
    print(f"\nquickstart: loss {first:.3f} -> {last:.3f} "
          f"({'OK: learning' if last < first - 0.2 else 'WARN: no drop'})")


if __name__ == "__main__":
    main()
