"""Logical-axis sharding rules (MaxText-style) for the production meshes.

Parallelism story (DESIGN.md §4):

* ``"pod"``   — pure data parallelism across pods (gradient all-reduce).
* ``"data"``  — FSDP/ZeRO-3 weight sharding + either batch DP (training,
  decode) or **sequence parallelism** (prefill / long context) — the
  paper's SP axis.
* ``"model"`` — tensor parallelism: attention heads, d_ff, vocab, experts
  (EP); for decode with few KV heads it instead shards the KV-cache
  sequence dim (flash-decoding merge in ``repro.core.lasp2h``).

Every rule degrades gracefully: an axis is applied to a tensor dim only if
the dim is divisible by the axis size (``fit_spec``), otherwise that dim is
replicated (e.g. whisper-base's 8 heads on a 16-way "model" axis — the
redundant compute is noted in DESIGN.md).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.comm.spec import CommSpec, resolve_comm_spec
from repro.core.lasp2 import SPConfig
from repro.launch.mesh import DATA_AXIS, MODEL_AXIS, POD_AXIS, SEQ_AXIS


def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, (tuple, list)):
        return int(np.prod([mesh.shape[a] for a in axis]))
    return mesh.shape[axis]


def fit_spec(mesh: Mesh, shape, spec: P) -> P:
    """Drop spec entries whose mesh-axis size does not divide the dim."""
    entries = list(spec) + [None] * (len(shape) - len(spec))
    fitted = []
    for dim, ax in zip(shape, entries):
        if ax is None:
            fitted.append(None)
        elif dim % _axis_size(mesh, ax) == 0:
            fitted.append(ax)
        elif isinstance(ax, (tuple, list)):
            # try prefixes of the compound axis
            kept = None
            for cut in range(len(ax) - 1, 0, -1):
                sub = tuple(ax[:cut])
                if dim % _axis_size(mesh, sub) == 0:
                    kept = sub if len(sub) > 1 else sub[0]
                    break
            fitted.append(kept)
        else:
            fitted.append(None)
    return P(*fitted)


@dataclass
class Parallelism:
    """Everything the model needs to know about distribution.

    ``rules`` maps logical activation dims to mesh axes. ``sp`` is set when
    the sequence dim is sharded (LASP-2 / LASP-2H paths activate).
    """

    mesh: Optional[Mesh] = None
    rules: dict = field(default_factory=dict)
    sp: Optional[SPConfig] = None
    backend: Optional[str] = None          # kernels backend override
    fsdp_axis: Optional[str] = DATA_AXIS
    tp_axis: Optional[str] = MODEL_AXIS
    dp_axes: tuple = (POD_AXIS, DATA_AXIS)
    decode_cache_axis: Optional[str] = None  # shard KV-cache seq dim here
    banded_windows: bool = True    # banded sliding-window attention (§Perf)
    # 2D DP×SP training (docs/parallelism.md): when set, the whole train
    # step runs inside ONE fully-manual shard_map over these mesh axes —
    # ``rules`` then describe only the jit-level INPUT placement, and
    # ``act`` is a no-op (sharding constraints cannot appear inside the
    # manual region; the step's collectives are all explicit).
    manual_axes: tuple = ()
    # ZeRO-1: mesh axis (or tuple of axes — 3D plans shard over the
    # combined (data, model) width) the flat optimizer state is sharded
    # over (manual plans only; None = replicated optimizer state).
    zero1_axis: Optional[object] = None  # str | tuple[str, ...] | None

    def act(self, x, *dims):
        """with_sharding_constraint by logical dim names (None = replicate)."""
        if self.mesh is None or self.manual_axes:
            return x
        spec = P(*[self.rules.get(d) for d in dims])
        spec = fit_spec(self.mesh, x.shape, spec)
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, spec))

    def sp_for(self, seq_len: int):
        """The SP config iff the sequence length is divisible by the SP
        degree (e.g. whisper's 1500 encoder frames stay local).

        Under a manual 2D plan the caller's ``seq_len`` is already the
        per-shard length (the split happened at the step's shard_map), so
        the divisibility check does not apply — the SP config is returned
        whenever the SP axis is non-trivial."""
        if self.sp is None:
            return None
        if self.sp.manual:
            return self.sp if self.sp.degree > 1 else None
        if seq_len % self.sp.degree == 0:
            return self.sp
        return None

    def tp_size(self) -> int:
        if self.mesh is None or self.tp_axis is None:
            return 1
        return self.mesh.shape[self.tp_axis]

    def divisible(self, n: int) -> bool:
        return n % max(self.tp_size(), 1) == 0


def local_plan(backend: Optional[str] = None) -> Parallelism:
    """Single-device plan (tests, smoke configs)."""
    return Parallelism(mesh=None, backend=backend)


# ---------------------------------------------------------------------------
# Parameter partition specs (by path name).
# ---------------------------------------------------------------------------

_COL = {"wq", "wk", "wv", "wx", "wz", "w1", "w3", "w_gate", "w_up"}
_ROW = {"wo", "w2", "wout", "w_down"}


def _spec_for(path: str, shape, plan: Parallelism) -> P:
    """Partition spec for one parameter. ``path`` is '/'-joined key names.

    Column-parallel weights: (fsdp, tp); row-parallel: (tp, fsdp);
    embeddings: (tp on vocab, fsdp); MoE experts carry a leading expert dim
    sharded on tp (expert parallelism); biases/norms replicate.
    """
    fsdp, tp = plan.fsdp_axis, plan.tp_axis
    name = path.split("/")[-1]
    parts = set(path.split("/"))
    stacked = "groups" in parts          # leading layer-group dim (scan)

    def with_stack(spec_dims):
        return P(*(([None] if stacked else []) + spec_dims))

    base = [None] * (len(shape) - (1 if stacked else 0))
    if name in ("table", "lm_head"):
        spec = with_stack([tp, fsdp])
    elif "experts" in parts and name in _COL:
        spec = with_stack([tp, fsdp, None])
    elif "experts" in parts and name in _ROW:
        spec = with_stack([tp, None, fsdp])
    elif name in _COL:
        spec = with_stack([fsdp, tp])
    elif name in _ROW:
        spec = with_stack([tp, fsdp])
    elif name in ("wb", "wc", "router"):
        spec = with_stack([fsdp, None])
    elif name.startswith("conv_x"):
        spec = with_stack([None, tp])
    elif name in ("a_log", "d_skip", "dt_bias") and len(base) == 1:
        spec = with_stack([tp])
    elif name == "wdt":
        spec = with_stack([fsdp, tp])
    else:
        spec = with_stack(base)          # norms, biases, scalars
    return fit_spec(plan.mesh, shape, spec)


def param_specs(params_tree, plan: Parallelism):
    """Tree of PartitionSpec matching ``params_tree`` (shapes or arrays)."""

    def visit(path, leaf):
        keys = "/".join(
            k.key if hasattr(k, "key") else str(k) for k in path)
        shape = leaf.shape
        return _spec_for(keys, shape, plan)

    return jax.tree_util.tree_map_with_path(visit, params_tree)


def param_shardings(params_tree, plan: Parallelism):
    specs = param_specs(params_tree, plan)
    return jax.tree.map(lambda s: NamedSharding(plan.mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# Plan factory per (shape kind × mesh).
# ---------------------------------------------------------------------------

def make_plan(mesh: Optional[Mesh], shape_kind: str, *,
              global_batch: int = 1, n_kv_heads: int = 8,
              n_heads: Optional[int] = None,
              params_bytes: Optional[int] = None,
              backend: Optional[str] = None,
              comm: Optional[CommSpec] = None,
              comm_strategy: Optional[str] = None,
              comm_overlap: Optional[str] = None,
              comm_dtype: Optional[str] = None,
              zero1: bool = True) -> Parallelism:
    """Resolve the activation rules for a cell.

    ``comm`` is the validated :class:`repro.comm.CommSpec` selecting the
    SP state-exchange strategy, the comm/compute overlap mode, and the
    wire dtype (fp32 | bf16 payloads, fp32 combines) for every
    LASP-2/2H layer run under the plan (``repro/comm``; threaded from
    ``RunConfig.comm`` by the launchers). The loose
    ``comm_strategy``/``comm_overlap``/``comm_dtype`` kwargs are
    DEPRECATED aliases for the corresponding ``CommSpec`` fields and
    warn once per process; passing both forms raises.

    ``backend`` is the kernel backend (``xla | pallas | interpret``,
    ``None`` = platform default) — it becomes both ``plan.backend`` (the
    per-op dispatch in ``repro/kernels/ops.py``) and
    ``SPConfig.kernel_backend`` (the intra-chunk compute inside the
    LASP-2 ``shard_map`` bodies), so one knob moves the whole hot path.

    train   — on a 2D (data, sequence) mesh: the paper's DP×SP deployment
              (batch over "data" × sequence over "sequence", params
              replicated, ZeRO-1 optimizer state over "data" when
              ``zero1``) — a *manual* plan: the whole step runs inside
              one fully-manual shard_map (``repro.train.step``).
              Otherwise: batch over ("pod","data") [plain DP+FSDP], no SP.
    prefill — sequence over "data" (LASP-2/2H SP), batch over "pod".
    decode  — batch over ("pod","data"); KV-cache seq over "model" when
              the KV heads don't fill the TP axis (flash-decoding).

    §Perf (hillclimb #3, iter 4): when attention heads don't divide the
    TP axis (hymba's 25, whisper's 8), head-sharding degrades to FULL
    replication — every "model" rank recomputes every head. If the batch
    divides the TP axis and the weights are small enough to replicate,
    prefill shards BATCH over "model" instead (tp_size× less activation
    traffic per device; measured on hymba×prefill_32k).
    """
    spec = resolve_comm_spec(comm, strategy=comm_strategy,
                             overlap=comm_overlap, dtype=comm_dtype,
                             where="make_plan")
    if mesh is None:
        return local_plan(backend)
    axes = mesh.axis_names
    has_pod = POD_AXIS in axes
    seq_ax = SEQ_AXIS if SEQ_AXIS in axes else None

    if shape_kind == "train" and seq_ax is not None:
        # 2D DP×SP training (paper §4 / Table 6), or — when the mesh
        # names a non-trivial MODEL_AXIS — the 3D DP×SP×TP deployment:
        # tokens shard over the COMBINED (sequence, model) axes
        # (sequence-major), params stay replicated, and the model axis
        # additionally carries the ulysses head-parallel All-to-All for
        # hybrid softmax layers (docs/parallelism.md §3D). The single
        # gradient reduction and the ZeRO-1 update gather run over the
        # remaining width ("data", and "model" on 3D meshes).
        dp_ax = DATA_AXIS if DATA_AXIS in axes else None
        tp_ax = MODEL_AXIS if (MODEL_AXIS in axes
                               and mesh.shape[MODEL_AXIS] > 1) else None
        if tp_ax is not None:
            if spec.strategy not in ("allgather", "ulysses"):
                raise ValueError(
                    f"comm strategy {spec.strategy!r} does not support the "
                    f"3D DP×SP×TP mesh (the ring/pipelined exchanges are "
                    f"wired for a single sequence axis); use 'allgather' "
                    f"or 'ulysses'")
            if spec.strategy == "ulysses" and n_heads is not None:
                from repro.core.lasp2h import check_ulysses_heads
                check_ulysses_heads(n_heads, n_kv_heads,
                                    mesh.shape[tp_ax], tp_ax)
        plan = Parallelism(
            mesh=mesh, backend=backend, fsdp_axis=None, tp_axis=None,
            dp_axes=(dp_ax,) if dp_ax else (),
            manual_axes=tuple(a for a in (dp_ax, seq_ax, tp_ax)
                              if a is not None),
            rules={"batch": dp_ax, "seq": seq_ax, "residual_seq": seq_ax,
                   "heads": None, "kv_heads": None, "ff": None,
                   "vocab": None, "experts": None, "cache_seq": None})
        plan.sp = SPConfig(mesh=mesh, sp_axis=seq_ax, tp_axis=tp_ax,
                           manual=True, comm=spec, kernel_backend=backend)
        zero_axes = tuple(a for a in (dp_ax, tp_ax)
                          if a is not None and mesh.shape[a] > 1)
        if zero1 and zero_axes:
            plan.zero1_axis = (zero_axes if len(zero_axes) > 1
                               else zero_axes[0])
        return plan

    dp = (POD_AXIS, DATA_AXIS) if has_pod else (DATA_AXIS,)
    tp = MODEL_AXIS if MODEL_AXIS in axes else None
    plan = Parallelism(mesh=mesh, backend=backend,
                       fsdp_axis=DATA_AXIS if DATA_AXIS in axes else None,
                       tp_axis=tp, dp_axes=dp)

    # The SP axis: the canonical SEQ_AXIS when the mesh names one,
    # otherwise DATA_AXIS (the production inference meshes, where the
    # data axis does double duty for prefill SP).
    sp_ax = seq_ax or DATA_AXIS
    sp_size = mesh.shape.get(sp_ax, 1)
    tp_size = mesh.shape.get(MODEL_AXIS, 1) if tp else 1

    if (shape_kind == "prefill" and tp is not None and n_heads is not None
            and n_heads % tp_size != 0 and global_batch % tp_size == 0
            and params_bytes is not None
            and params_bytes <= 6 * 2 ** 30):
        plan.tp_axis = None          # weights replicated on the TP axis
        plan.fsdp_axis = DATA_AXIS if DATA_AXIS in axes else None
        plan.rules = {"batch": (POD_AXIS, MODEL_AXIS) if has_pod
                      else MODEL_AXIS,
                      "seq": sp_ax, "residual_seq": sp_ax,
                      "heads": None, "kv_heads": None,
                      "ff": None, "vocab": None, "experts": None,
                      "cache_seq": sp_ax}
        if sp_size > 1:
            plan.sp = SPConfig(mesh=mesh, sp_axis=sp_ax,
                               comm=spec,
                               kernel_backend=backend)
        return plan

    if shape_kind == "train":
        plan.rules = {"batch": dp, "seq": None, "heads": tp, "kv_heads": tp,
                      "ff": tp, "vocab": tp, "experts": tp,
                      "cache_seq": None}
        # NOTE (§Perf, refuted): Megatron-style sequence-sharded residuals
        # ("residual_seq": tp) were measured on qwen110b×train_4k and made
        # the collective term 1.7× WORSE (85s → 148s) — XLA re-gathers
        # around every projection, not just attention. Not enabled.
        # batch not divisible by full dp → fall back to sequence parallelism
        if global_batch % _axis_size(mesh, dp) != 0:
            plan.rules.update({"batch": POD_AXIS if has_pod else None,
                               "seq": sp_ax})
            plan.sp = SPConfig(mesh=mesh, sp_axis=sp_ax,
                               comm=spec,
                               kernel_backend=backend)
    elif shape_kind == "prefill":
        plan.rules = {"batch": POD_AXIS if has_pod else None, "seq": sp_ax,
                      "residual_seq": sp_ax,
                      "heads": tp, "kv_heads": tp, "ff": tp, "vocab": tp,
                      "experts": tp, "cache_seq": sp_ax}
        if sp_size > 1:
            plan.sp = SPConfig(mesh=mesh, sp_axis=sp_ax,
                               comm=spec,
                               kernel_backend=backend)
    elif shape_kind == "decode":
        cache_axis = tp if (tp and n_kv_heads % tp_size != 0) else None
        plan.rules = {"batch": dp, "seq": None, "heads": tp,
                      "kv_heads": tp, "ff": tp, "vocab": tp, "experts": tp,
                      "cache_seq": cache_axis}
        plan.decode_cache_axis = cache_axis
    else:
        raise ValueError(shape_kind)
    return plan
