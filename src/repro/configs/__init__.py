"""Architecture registry: ``get_config(arch_id)`` / ``get_smoke(arch_id)``.

The 10 assigned architectures + the paper's own Linear-Llama3 variants.
``--linearize`` variants (paper's recipe) are available for every arch via
``get_config(arch_id, linearize=...)``.
"""

from __future__ import annotations

import dataclasses
import importlib

from repro.configs.base import (LayerSpec, LinearAttnConfig, MambaConfig,
                                ModelConfig, MoEConfig, RunConfig,
                                ShapeConfig, SHAPES)

_MODULES = {
    "codeqwen1.5-7b": "codeqwen1_5_7b",
    "qwen1.5-110b": "qwen1_5_110b",
    "granite-34b": "granite_34b",
    "starcoder2-15b": "starcoder2_15b",
    "hymba-1.5b": "hymba_1_5b",
    "mamba2-2.7b": "mamba2_2_7b",
    "llama-3.2-vision-90b": "llama3_2_vision_90b",
    "moonshot-v1-16b-a3b": "moonshot_v1_16b_a3b",
    "phi3.5-moe-42b-a6.6b": "phi3_5_moe_42b_a6_6b",
    "whisper-base": "whisper_base",
    "linear-llama3-1b": "linear_llama3_1b",
}

ARCH_IDS = [k for k in _MODULES if k != "linear-llama3-1b"]
ALL_IDS = list(_MODULES)


def _module(arch_id: str):
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_MODULES)}")
    return importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")


def get_config(arch_id: str, *, linearize: int | None = None) -> ModelConfig:
    """``linearize``: None = native stack; 0 = pure linear attention;
    k>0 = 1/k hybrid (paper's recipe, every k-th layer stays softmax with a
    sliding window)."""
    cfg = _module(arch_id).CONFIG
    if linearize is not None:
        cfg = cfg.linearize(hybrid_every=linearize)
    return cfg


def get_smoke(arch_id: str) -> ModelConfig:
    return _module(arch_id).SMOKE


def get_variant(arch_id: str, variant: str) -> ModelConfig:
    """Named variants exported by a config module (e.g. HYBRID, DENSE)."""
    return getattr(_module(arch_id), variant)
