"""Paper Table 5 (Appendix A.5.3): varying split sizes of gathering.

The paper splits the memory-state AllGather into 1/4/16/64 chunked
gathers and finds throughput nearly unchanged — evidence that the
*workflow reorganization*, not merely the collective choice, delivers the
win. We reproduce: time LASP-2 with its state gather split into k
sequential all-gathers, k ∈ {1, 4, 16}.
"""

from __future__ import annotations

from benchmarks.common import emit, run_subprocess_bench

_CODE = r"""
import json, time
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.core.linear_attention import chunk_scan, chunk_summaries

from repro.launch.mesh import SEQ_AXIS, make_sp_mesh
mesh = make_sp_mesh(8)
B, H, S, d = 1, 16, 65536, 128
key = jax.random.PRNGKey(0)
ks = jax.random.split(key, 3)
q = jax.random.normal(ks[0], (B, H, S, d), jnp.bfloat16) * 0.3
k = jax.random.normal(ks[1], (B, H, S, d), jnp.bfloat16) * 0.3
v = jax.random.normal(ks[2], (B, H, S, d), jnp.bfloat16) * 0.5

def lasp2_split(n_splits):
    def local(q_, k_, v_):
        m_loc, _ = chunk_summaries(k_, v_, None, block_size=128)
        parts = jnp.split(m_loc, n_splits, axis=1)  # split over heads
        gathered = [jax.lax.all_gather(p, SEQ_AXIS) for p in parts]
        ms = jnp.concatenate(gathered, axis=2)      # (W,B,H,d,d)
        t = jax.lax.axis_index(SEQ_AXIS)
        w_idx = jnp.arange(8)
        wmask = (w_idx < t).astype(jnp.float32).reshape(8, 1, 1, 1, 1)
        m_prev = jnp.sum(ms * wmask, axis=0)
        out = chunk_scan(q_, k_, v_, None, block_size=128)
        o = out.o.astype(jnp.float32) + jnp.einsum(
            "bhsk,bhkv->bhsv", q_.astype(jnp.float32), m_prev)
        return o.astype(q_.dtype)
    spec = P(None, None, SEQ_AXIS, None)
    return jax.jit(jax.shard_map(local, mesh=mesh, in_specs=(spec,)*3,
                                 out_specs=spec, axis_names={SEQ_AXIS},
                                 check_vma=False))

res = {}
for n_splits in (1, 4, 16):
    f = lasp2_split(n_splits)
    f(q, k, v).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(3):
        out = f(q, k, v)
    out.block_until_ready()
    res[f"splits_{n_splits}"] = (time.perf_counter() - t0) / 3 * 1e6
print(json.dumps(res))
"""


def main():
    res = run_subprocess_bench(_CODE, devices=8, timeout=1200)
    base = res["splits_1"]
    rows = [(f"table5/{k}", us,
             f"tokens/s={round(65536 / (us / 1e6))};rel={us / base:.3f}")
            for k, us in sorted(res.items())]
    emit(rows)
    return rows


if __name__ == "__main__":
    main()
