"""Per-run communication flight recorder.

The repo can already *prove* LASP-2's comm claims at trace time — the
``CommRecord`` tape (``repro.comm.primitives``) says what the Python
source put on the wire, and the HLO budget checks
(``repro.comm.budget``) say what the compiled program actually emits.
The flight recorder is the runtime third leg: it snapshots both static
views ONCE at compile, cross-validates them (tape vs compiled HLO —
"expected vs measured" collective structure), and then stamps every
logged step with the run's throughput story:

* tokens/s and achieved FLOP/s → **MFU** (model FLOPs over
  ``n_devices × peak``, reusing ``launch.roofline.model_flops`` — the
  single FLOP model the roofline uses, via its import-side-effect-free
  home in ``launch.hlo_analysis``),
* expected collective bytes per step (from the tape) next to the
  HLO-derived bytes, so a report can show comm volume per token,
* step-wall drift against a rolling expectation (the runtime analogue
  of the watchdog, attributed per phase when phase walls are given).

Drift at compile time (a collective op the tape promised but the HLO
lacks, or tape traffic the HLO cannot carry) is flagged in the
``compile`` record and kept on ``drift_events`` — the distributed test
battery injects a fake tape record and asserts the flag fires.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

from repro.launch.hlo_analysis import PEAK_FLOPS
from repro.obs.metrics import Histogram, MetricsSink, as_sink


@dataclass
class CompileSnapshot:
    """Static expectations captured once per compile."""

    # tape view (what the source promised)
    tape_bytes_by_op: Dict[str, float] = field(default_factory=dict)
    tape_counts: Dict[str, int] = field(default_factory=dict)
    expected_bytes_per_step: float = 0.0
    expected_steps_per_step: int = 0
    # HLO view (what the compiled program carries)
    hlo_counts: Dict[str, int] = field(default_factory=dict)
    hlo_bytes_by_op: Dict[str, float] = field(default_factory=dict)
    hlo_bytes_per_step: float = 0.0
    drift: List[str] = field(default_factory=list)

    def as_record(self) -> Dict[str, Any]:
        rec: Dict[str, Any] = {"kind": "compile",
                               "expected_collective_bytes":
                                   self.expected_bytes_per_step,
                               "expected_comm_steps":
                                   self.expected_steps_per_step,
                               "hlo_collective_bytes":
                                   self.hlo_bytes_per_step,
                               "drift": list(self.drift)}
        for op, n in sorted(self.tape_counts.items()):
            rec[f"tape/{op}_count"] = n
        for op, b in sorted(self.tape_bytes_by_op.items()):
            rec[f"tape/{op}_bytes"] = b
        for op, n in sorted(self.hlo_counts.items()):
            rec[f"hlo/{op}_count"] = n
        for op, b in sorted(self.hlo_bytes_by_op.items()):
            rec[f"hlo/{op}_bytes"] = b
        return rec


class FlightRecorder:
    """Runtime telemetry for one compiled program (train step, decode
    step, bench case).

    Parameters
    ----------
    sink: where records go (``None`` → dropped).
    model_flops_per_step: model-level FLOPs one step performs (use
        ``launch.roofline.model_flops`` with the run's shape); enables
        achieved-FLOP/s + MFU fields on step records.
    n_devices: devices the program spans (MFU denominator).
    peak_flops: per-device peak (default: the roofline's TPU v5e bf16
        constant — MFU is then "fraction of the machine we target").
    wall_factor / wall_window / wall_warmup: rolling-median step-wall
        drift detection; the first ``wall_warmup`` steps (compile /
        resume spikes) are excluded from the window and never flagged.
    """

    def __init__(self, sink: Optional[MetricsSink] = None, *,
                 model_flops_per_step: Optional[float] = None,
                 n_devices: int = 1, peak_flops: float = PEAK_FLOPS,
                 wall_factor: float = 3.0, wall_window: int = 50,
                 wall_warmup: int = 1):
        self.sink = as_sink(sink)
        self.model_flops_per_step = model_flops_per_step
        self.n_devices = max(int(n_devices), 1)
        self.peak_flops = peak_flops
        self.wall_factor = wall_factor
        self.wall_window = wall_window
        self.wall_warmup = wall_warmup
        self.snapshot: Optional[CompileSnapshot] = None
        self.drift_events: List[str] = []
        self.wall_hist = Histogram()
        self._walls: List[float] = []
        self._seen = 0

    # -- compile-time snapshot ----------------------------------------------

    def on_compile(self, *, records=None, hlo_text: Optional[str] = None,
                   total_devices: int = 1,
                   hlo_counts: Optional[Dict[str, int]] = None,
                   hlo_bytes_by_op: Optional[Dict[str, float]] = None,
                   note: str = "") -> CompileSnapshot:
        """Snapshot the trace-time tape and the compiled HLO; emit one
        ``compile`` record; return the snapshot (``snapshot.drift``
        lists expected-vs-compiled mismatches).

        ``records``: the ``CommRecord`` list captured by tracing the
        program inside ``repro.comm.tape()``. ``hlo_text``: compiled
        (post-SPMD) HLO; tests may instead pass precomputed
        ``hlo_counts``/``hlo_bytes_by_op``.

        Drift rules (conservative — autodiff legitimately emits
        collectives the tape never sees, e.g. the reduce-scatter
        transpose of a forward gather, so the HLO may exceed the tape):

        * an op the tape promises more instances of than the HLO
          carries is drift (the program lost a collective the source
          intended — or the tape was tampered with);
        * tape traffic for an op the compiled HLO cannot carry at all
          is drift.
        """
        snap = CompileSnapshot()
        records = list(records) if records else []
        for r in records:
            snap.tape_bytes_by_op[r.op] = \
                snap.tape_bytes_by_op.get(r.op, 0.0) + r.traffic_bytes
            snap.tape_counts[r.op] = snap.tape_counts.get(r.op, 0) + 1
            snap.expected_steps_per_step += r.steps
        snap.expected_bytes_per_step = sum(snap.tape_bytes_by_op.values())

        if hlo_text is not None:
            from repro.launch.hlo_analysis import parse_collectives
            for c in parse_collectives(hlo_text, total_devices):
                snap.hlo_counts[c.op] = snap.hlo_counts.get(c.op, 0) + c.count
                snap.hlo_bytes_by_op[c.op] = \
                    snap.hlo_bytes_by_op.get(c.op, 0.0) + c.traffic_bytes
        if hlo_counts is not None:
            snap.hlo_counts = dict(hlo_counts)
        if hlo_bytes_by_op is not None:
            snap.hlo_bytes_by_op = dict(hlo_bytes_by_op)
        snap.hlo_bytes_per_step = sum(snap.hlo_bytes_by_op.values())

        for op, n in sorted(snap.tape_counts.items()):
            got = snap.hlo_counts.get(op, 0)
            if got < n:
                snap.drift.append(
                    f"{op}: tape promises {n} collective(s), compiled "
                    f"HLO has {got}")
            elif snap.tape_bytes_by_op.get(op, 0.0) > 0 \
                    and snap.hlo_bytes_by_op.get(op, 0.0) == 0 \
                    and snap.hlo_bytes_by_op:
                snap.drift.append(
                    f"{op}: tape promises "
                    f"{snap.tape_bytes_by_op[op]:.0f}B but the compiled "
                    f"HLO carries none")

        self.snapshot = snap
        self.drift_events.extend(snap.drift)
        rec = snap.as_record()
        if note:
            rec["note"] = note
        self.sink.emit(rec)
        return snap

    # -- per-step records ----------------------------------------------------

    def expected_wall_s(self) -> Optional[float]:
        """Rolling-median step wall over the post-warmup window."""
        if not self._walls:
            return None
        xs = sorted(self._walls)
        return xs[len(xs) // 2]

    def on_step(self, step: int, wall_s: float, *,
                tokens: Optional[int] = None,
                phases: Optional[Dict[str, float]] = None,
                metrics: Optional[Dict[str, float]] = None,
                straggler: Optional[bool] = None) -> Dict[str, Any]:
        """Build + emit one ``step`` record; returns it.

        ``phases``: ``{"<name>_s": wall}`` from a ``PhaseTimer.flush()``.
        ``straggler``: an external verdict (the train loop's watchdog);
        if ``None``, the recorder's own rolling-median drift rule
        decides."""
        rec: Dict[str, Any] = {"kind": "step", "step": int(step),
                               "wall_s": float(wall_s)}
        if metrics:
            rec.update({k: float(v) for k, v in metrics.items()})
        if phases:
            rec.update({k: float(v) for k, v in phases.items()})

        expected = self.expected_wall_s()
        self._seen += 1
        warming = self._seen <= self.wall_warmup
        if not warming:
            self._walls.append(float(wall_s))
            self._walls = self._walls[-self.wall_window:]
            self.wall_hist.add(float(wall_s))
        if straggler is None:
            straggler = bool(expected is not None and not warming
                             and wall_s > self.wall_factor * expected)
        rec["straggler"] = bool(straggler)
        if expected is not None:
            rec["expected_wall_s"] = expected

        if tokens:
            rec["tokens"] = int(tokens)
            rec["tokens_per_s"] = tokens / wall_s if wall_s > 0 else 0.0
        if self.model_flops_per_step and wall_s > 0:
            achieved = self.model_flops_per_step / wall_s
            rec["achieved_flops"] = achieved
            rec["mfu"] = achieved / (self.peak_flops * self.n_devices)
        if self.snapshot is not None:
            rec["expected_collective_bytes"] = \
                self.snapshot.expected_bytes_per_step
            rec["hlo_collective_bytes"] = self.snapshot.hlo_bytes_per_step
            if tokens and self.snapshot.expected_bytes_per_step:
                rec["comm_bytes_per_token"] = \
                    self.snapshot.expected_bytes_per_step / tokens
        self.sink.emit(rec)
        return rec

    def event(self, name: str, **fields) -> Dict[str, Any]:
        """Emit a structured ``event`` record (straggler, resume, signal,
        …) — the telemetry form of what used to be a bare print."""
        rec: Dict[str, Any] = {"kind": "event", "event": name}
        rec.update(fields)
        self.sink.emit(rec)
        return rec

    def summary(self, **extra) -> Dict[str, Any]:
        """Emit the run-level ``summary`` record (wall histogram, drift
        count, plus caller extras) and return it."""
        rec: Dict[str, Any] = {"kind": "summary",
                               "steps_recorded": self._seen,
                               "drift_events": len(self.drift_events)}
        for stat, v in self.wall_hist.summary().items():
            rec[f"wall_s_{stat}"] = v
        if self.snapshot is not None:
            rec["expected_collective_bytes"] = \
                self.snapshot.expected_bytes_per_step
        rec.update(extra)
        self.sink.emit(rec)
        return rec
