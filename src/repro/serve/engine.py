"""Batched serving engine: prefill once, decode with cached state.

For linear-attention / SSM layers the "cache" is the constant-size memory
state M (the paper's constant-memory-inference property); for softmax
layers it is a real KV cache, optionally sharded (flash-decoding) per the
plan. Greedy and temperature sampling; per-row stop handling.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import model as M
from repro.sharding.rules import Parallelism, local_plan


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, *,
                 plan: Optional[Parallelism] = None, max_len: int = 2048):
        self.cfg = cfg
        self.params = params
        self.plan = plan or local_plan()
        self.max_len = max_len

        def _prefill(params_, tokens, img_emb, enc_frames):
            return M.prefill(params_, tokens, cfg, self.plan,
                             max_len=max_len, img_emb=img_emb,
                             enc_frames=enc_frames)

        def _decode(params_, tok, cache, img_emb, enc_out):
            return M.decode_step(params_, tok, cache, cfg, self.plan,
                                 img_emb=img_emb, enc_out=enc_out)

        self._prefill = jax.jit(_prefill, static_argnames=())
        self._decode = jax.jit(_decode, donate_argnums=(2,))
        self._encode = jax.jit(
            lambda p, f: M.encode(p, f, cfg, self.plan)) \
            if cfg.encoder is not None else None

    def generate(self, prompts, max_new_tokens: int, *, temperature=0.0,
                 seed: int = 0, img_emb=None, enc_frames=None,
                 eos_id: Optional[int] = None):
        """prompts: (B, S) int32 (right-aligned, no padding support needed
        for the synthetic benches). Returns (B, max_new_tokens) int32."""
        prompts = jnp.asarray(prompts, jnp.int32)
        b, s = prompts.shape
        if s + max_new_tokens > self.max_len:
            raise ValueError("max_len too small")
        enc_out = None
        if enc_frames is not None and self._encode is not None:
            enc_out = self._encode(self.params, enc_frames)
        logits, cache = self._prefill(self.params, prompts, img_emb,
                                      enc_frames)
        key = jax.random.PRNGKey(seed)
        out = []
        done = np.zeros((b,), bool)
        tok = self._sample(logits, temperature, key)
        for i in range(max_new_tokens):
            out.append(np.asarray(tok))
            if eos_id is not None:
                done |= (out[-1] == eos_id)
                if done.all():
                    out.extend([out[-1]] * (max_new_tokens - i - 1))
                    break
            logits, cache = self._decode(self.params, tok, cache, img_emb,
                                         enc_out)
            key, sub = jax.random.split(key)
            tok = self._sample(logits, temperature, sub)
        return np.stack(out[:max_new_tokens], axis=1)

    @staticmethod
    def _sample(logits, temperature, key):
        if temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(
            key, logits / temperature, axis=-1).astype(jnp.int32)
