"""Decode/prefill parity: the recurrent decode path must reproduce the
full chunked forward, greedily, at every step — for pure-linear and hybrid
(LASP-2H style) configs, on CPU, through the continuous-batching engine
(ragged prompts, fewer slots than requests, ring-buffer KV wrap-around)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.configs.base import LayerSpec, LinearAttnConfig
from repro.models import model as M
from repro.serve.engine import ServeEngine

MAX_NEW = 8


def _pure_linear():
    return get_smoke("linear-llama3-1b")


def _pure_linear_decay():
    cfg = get_smoke("linear-llama3-1b")
    return dataclasses.replace(
        cfg, name=cfg.name + "-retention",
        linear_attn=LinearAttnConfig(feature_map="identity",
                                     decay="retention"))


def _hybrid(window):
    base = get_smoke("linear-llama3-1b")
    dense = dataclasses.replace(base, pattern=(LayerSpec(),), n_layers=4,
                                name="smoke-dense")
    cfg = dense.linearize(hybrid_every=4)   # 3 linear + 1 softmax
    pattern = tuple(
        dataclasses.replace(sp, sliding_window=window)
        if sp.mixer == "softmax" else sp for sp in cfg.pattern)
    return dataclasses.replace(cfg, pattern=pattern,
                               name=f"{cfg.name}-w{window}")


def _greedy_reference(cfg, params, prompt, n_new):
    """Argmax continuation via the full chunked forward at every step —
    the ground truth the recurrent decode must reproduce."""
    fwd = jax.jit(lambda p, t: M.forward(p, t, cfg, remat="none")[0])
    toks = list(np.asarray(prompt, np.int32))
    out = []
    for _ in range(n_new):
        logits = fwd(params, jnp.asarray(toks, jnp.int32)[None, :])
        nxt = int(jnp.argmax(logits[0, -1]))
        out.append(nxt)
        toks.append(nxt)
    return out


@pytest.mark.parametrize("make_cfg,lens", [
    (_pure_linear, [5, 9, 16, 23]),          # ragged -> left-pad buckets
    (_pure_linear_decay, [7, 16, 16]),       # decay: log_decay plumbing
    (lambda: _hybrid(2048), [6, 11, 16]),    # hybrid, ring never wraps
    (lambda: _hybrid(16), [6, 20, 20]),      # hybrid, ring WRAPS mid-decode
], ids=["pure-linear", "pure-linear-decay", "hybrid", "hybrid-ring-wrap"])
def test_recurrent_decode_matches_chunked_forward(rng, make_cfg, lens):
    cfg = make_cfg()
    params = M.init_params(rng, cfg)
    prompts = [
        np.asarray(jax.random.randint(jax.random.fold_in(rng, i), (n,), 0,
                                      cfg.vocab_size), np.int32)
        for i, n in enumerate(lens)]

    # fewer slots than requests -> admission/eviction mid-flight
    engine = ServeEngine(cfg, params, max_len=64, max_batch=2)
    uids = [engine.submit(p, MAX_NEW) for p in prompts]
    results = engine.run()

    for uid, prompt in zip(uids, prompts):
        ref = _greedy_reference(cfg, params, prompt, MAX_NEW)
        np.testing.assert_array_equal(
            results[uid], ref,
            err_msg=f"{cfg.name}: recurrent decode diverged from "
                    f"chunked forward (prompt len {len(prompt)})")


def test_linear_cache_constant_and_log_decay_tracked(rng):
    """The cache stores exactly (state, log_decay) per linear layer —
    constant bytes in max_len — and log_decay equals the sum of per-token
    log decays after prefill + decode."""
    cfg = _pure_linear_decay()
    params = M.init_params(rng, cfg)
    engine64 = ServeEngine(cfg, params, max_len=64, max_batch=2)
    engine4k = ServeEngine(cfg, params, max_len=4096, max_batch=2)
    assert engine64.cache_stats()["linear_state"] == \
        engine4k.cache_stats()["linear_state"]

    prompt = np.asarray(
        jax.random.randint(rng, (16,), 0, cfg.vocab_size), np.int32)
    uid = engine64.submit(prompt, 4)
    engine64.run()
    ld = np.asarray(engine64._cache["layers"][0]["mixer"]["log_decay"])
    # retention decay: one log a_h per token that entered the state — the
    # 16 prompt tokens (minus the one whose decay the bucketed prefill's
    # position-0 reset replaced with RESET_LOG_A) plus 3 decode inputs (the
    # 4th sampled token is returned but never fed back).
    from repro.core.linear_attention import RESET_LOG_A, decay_log_a
    la = np.asarray(decay_log_a("retention", heads=cfg.n_heads, s=1))[:, 0]
    expect = la * (15 + 3) + RESET_LOG_A
    np.testing.assert_allclose(ld[0, 0], expect, rtol=1e-4, atol=1e-4)
