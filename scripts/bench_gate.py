#!/usr/bin/env python
"""Bench-regression gate: diff fresh ``BENCH_*.json`` files against the
committed baselines in ``benchmarks/baselines/``.

The benchmarks write machine-readable payloads (see ``benchmarks/common
.write_bench_json``); until now they were write-only — CI uploaded them
as artifacts but nothing failed when a PR regressed them. This gate
closes the loop:

* **wall time** — any ``median_us`` / ``us_per_call`` metric more than
  ``--wall-tol`` (default 25%) above its baseline fails. Timings under
  ``--wall-floor-us`` (default 1000) are skipped as noise.
* **collective traffic** — any ``*bytes*`` metric or ``hlo_collectives``
  /``*_count``/``*_steps`` counter ABOVE its baseline fails outright
  (these are deterministic; an increase means the comm structure
  regressed).
* **absolute ceilings** — a baseline payload may carry a top-level
  ``gate_ceilings: {"<flattened metric path>": <max>}`` map; the
  current run's value at each path must not exceed the ceiling. This
  gates derived quantities that have a hard acceptance bound rather
  than a baseline-relative one (e.g. ``BENCH_guard.json`` pins
  ``guard_overhead_pct`` at 2%).

Rows inside ``rows``/``cases`` lists are matched by their ``name`` field,
so reordering does not break the diff; metrics present only in the
current payload (new cases) are ignored, metrics present only in the
baseline fail as "missing" unless ``--allow-missing``.

  python scripts/bench_gate.py                     # gate everything
  python scripts/bench_gate.py --require comm,kernels
  python scripts/bench_gate.py --update            # refresh baselines
"""

from __future__ import annotations

import argparse
import json
import shutil
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
BASELINE_DIR = ROOT / "benchmarks" / "baselines"

WALL_KEYS = ("median_us", "us_per_call")
COUNT_KEYS = ("_count", "_steps")


def _flatten(obj, prefix=""):
    """path -> numeric value; list items keyed by their "name" field when
    present (order-independent row matching). Duplicate names within one
    list get a positional suffix so colliding entries cannot silently
    overwrite each other (they then match by order, not name)."""
    out = {}
    if isinstance(obj, dict):
        for k, v in sorted(obj.items()):
            out.update(_flatten(v, f"{prefix}{k}/"))
    elif isinstance(obj, list):
        seen = {}
        for i, item in enumerate(obj):
            key = item.get("name", str(i)) if isinstance(item, dict) \
                else str(i)
            if key in seen:
                seen[key] += 1
                key = f"{key}#{seen[key]}"
            else:
                seen[key] = 0
            out.update(_flatten(item, f"{prefix}{key}/"))
    elif isinstance(obj, bool):
        pass
    elif isinstance(obj, (int, float)):
        out[prefix.rstrip("/")] = float(obj)
    return out


def _classify(path: str):
    """'wall' | 'traffic' | None (ungated metric)."""
    leaf = path.rsplit("/", 1)[-1]
    if leaf in WALL_KEYS:
        return "wall"
    if "bytes" in leaf or leaf.endswith(COUNT_KEYS) \
            or "/hlo_collectives/" in f"/{path}/":
        return "traffic"
    return None


def gate_one(name: str, baseline: dict, current: dict, *, wall_tol: float,
             wall_floor_us: float, allow_missing: bool):
    # ceilings are read from the COMMITTED baseline (so a regressing PR
    # can't relax the bound by editing its own fresh payload) and
    # stripped from both sides before flattening — they are gate config,
    # not metrics.
    ceilings = baseline.pop("gate_ceilings", None) or {}
    current.pop("gate_ceilings", None)
    base, cur = _flatten(baseline), _flatten(current)
    failures, checked = [], 0
    for path, ceiling in sorted(ceilings.items()):
        if path not in cur:
            if not allow_missing:
                failures.append(
                    f"{name}: ceiling metric {path} missing from "
                    f"current run")
            continue
        checked += 1
        if cur[path] > float(ceiling):
            failures.append(
                f"{name}: {path} = {cur[path]:.3f} exceeds ceiling "
                f"{float(ceiling):.3f}")
    for path, bval in base.items():
        kind = _classify(path)
        if kind is None:
            continue
        if path not in cur:
            if not allow_missing:
                failures.append(f"{name}: {path} missing from current run")
            continue
        cval = cur[path]
        checked += 1
        if kind == "wall":
            if bval < wall_floor_us:
                continue
            if cval > bval * (1.0 + wall_tol):
                failures.append(
                    f"{name}: {path} wall-time regression "
                    f"{bval:.0f} -> {cval:.0f} us "
                    f"(+{(cval / bval - 1) * 100:.0f}% > "
                    f"{wall_tol * 100:.0f}%)")
        else:   # traffic: any increase fails
            if cval > bval + 0.5:
                failures.append(
                    f"{name}: {path} collective increase "
                    f"{bval:.0f} -> {cval:.0f}")
    return failures, checked


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline-dir", default=str(BASELINE_DIR))
    ap.add_argument("--current-dir", default=str(ROOT),
                    help="where the fresh BENCH_*.json live")
    ap.add_argument("--wall-tol", type=float, default=0.25,
                    help="relative median wall-time regression allowed")
    ap.add_argument("--wall-floor-us", type=float, default=1000.0,
                    help="skip wall metrics whose baseline is below this")
    ap.add_argument("--require", default=None,
                    help="comma-separated bench names that MUST be "
                         "present in the current run (e.g. comm,kernels); "
                         "other baselines are gated only if present")
    ap.add_argument("--allow-missing", action="store_true",
                    help="ignore metrics present only in the baseline")
    ap.add_argument("--update", action="store_true",
                    help="copy current BENCH_*.json over the baselines")
    args = ap.parse_args()

    baseline_dir = Path(args.baseline_dir)
    current_dir = Path(args.current_dir)
    required = set(args.require.split(",")) if args.require else None

    if args.update:
        baseline_dir.mkdir(parents=True, exist_ok=True)
        n = 0
        for p in sorted(current_dir.glob("BENCH_*.json")):
            shutil.copy(p, baseline_dir / p.name)
            n += 1
        print(f"updated {n} baseline(s) in {baseline_dir}")
        return 0

    baselines = sorted(baseline_dir.glob("BENCH_*.json"))
    if not baselines:
        print(f"no baselines in {baseline_dir}; run with --update first")
        return 1

    failures, n_checked = [], 0
    seen = set()
    for bpath in baselines:
        name = bpath.stem.replace("BENCH_", "")
        seen.add(name)
        cpath = current_dir / bpath.name
        if not cpath.exists():
            if required is not None and name in required:
                failures.append(f"{name}: required bench produced no "
                                f"{bpath.name}")
            else:
                print(f"  - {name}: no current run, skipped")
            continue
        with open(bpath) as f:
            baseline = json.load(f)
        with open(cpath) as f:
            current = json.load(f)
        fails, checked = gate_one(
            name, baseline, current, wall_tol=args.wall_tol,
            wall_floor_us=args.wall_floor_us,
            allow_missing=args.allow_missing)
        failures += fails
        n_checked += checked
        print(f"  - {name}: {checked} gated metrics, "
              f"{len(fails)} failure(s)")
    if required is not None:
        for name in sorted(required - seen):
            failures.append(f"{name}: required bench has no committed "
                            f"baseline")

    if failures:
        print(f"\nBENCH GATE FAILED ({len(failures)}):")
        print("\n".join(f"  ✗ {f}" for f in failures))
        return 1
    print(f"\nBENCH GATE OK: {n_checked} metrics within budget.")
    return 0


if __name__ == "__main__":
    sys.exit(main())
