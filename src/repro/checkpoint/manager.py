"""Checkpointing: atomic, async, keep-k, mesh-independent (elastic resume).

Layout: ``<dir>/step_<n>/`` containing ``manifest.json`` (tree structure,
shapes, dtypes) and ``arrays.npz``. Arrays are saved as host numpy in a
fully-replicated layout, so a checkpoint written on one mesh can be
restored onto any other mesh/devices count — the loader re-shards with
whatever shardings the new run provides (tested in tests/test_checkpoint).

Writes are atomic (tmp dir + ``os.replace``) so a crash mid-save never
corrupts the latest checkpoint; ``save_async`` offloads the host transfer
+ serialization to a daemon thread so the train loop keeps stepping.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # -- write --------------------------------------------------------------

    def save(self, step: int, tree: Any):
        leaves, treedef = _flatten(tree)
        host = [np.asarray(jax.device_get(l)) for l in leaves]
        self._write(step, host, treedef)

    def save_async(self, step: int, tree: Any):
        """Device→host copy happens synchronously (cheap, avoids racing the
        next update-in-place); disk serialization runs on a thread."""
        leaves, treedef = _flatten(tree)
        host = [np.asarray(jax.device_get(l)) for l in leaves]
        self.wait()
        self._thread = threading.Thread(
            target=self._write, args=(step, host, treedef), daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, host_leaves, treedef):
        final = os.path.join(self.dir, f"step_{step:08d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        np.savez(os.path.join(tmp, "arrays.npz"),
                 **{f"a{i}": l for i, l in enumerate(host_leaves)})
        manifest = {
            "step": step,
            "n_leaves": len(host_leaves),
            "shapes": [list(l.shape) for l in host_leaves],
            "dtypes": [str(l.dtype) for l in host_leaves],
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)
        self._gc()

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    # -- read ---------------------------------------------------------------

    def all_steps(self):
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                try:
                    out.append(int(name[5:]))
                except ValueError:
                    pass
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, target_tree: Any, shardings: Any = None):
        """Restore into the structure of ``target_tree``. ``shardings`` is
        an optional matching tree of jax.sharding.Sharding — this is where
        elastic resharding happens (host numpy → any mesh)."""
        path = os.path.join(self.dir, f"step_{step:08d}")
        data = np.load(os.path.join(path, "arrays.npz"))
        leaves, treedef = _flatten(target_tree)
        loaded = [data[f"a{i}"] for i in range(len(leaves))]
        for got, want in zip(loaded, leaves):
            if tuple(got.shape) != tuple(want.shape):
                raise ValueError(
                    f"checkpoint shape {got.shape} != target {want.shape}")
        if shardings is not None:
            flat_sh, _ = _flatten(shardings)
            loaded = [jax.device_put(np.asarray(l, w.dtype), s)
                      for l, w, s in zip(loaded, leaves, flat_sh)]
        else:
            loaded = [jax.device_put(np.asarray(l, w.dtype))
                      for l, w in zip(loaded, leaves)]
        return jax.tree_util.tree_unflatten(treedef, loaded)
