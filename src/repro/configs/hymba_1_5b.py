"""hymba-1.5b — parallel attn+mamba heads [arXiv:2411.13676; hf].

Attention heads run full attention only in a few layers; all others use a
sliding window (the arch's sub-quadratic trick). We mark globals
*statically* in an 8-position pattern (every 8th layer: 0/8/16/24) so the
banded sliding-window fast path applies (§Perf); Hymba's exact global
placement (first/middle/last) is approximated — noted in DESIGN.md. (An
XLA-CPU combiner pass mis-lowers scan bodies holding >11 of these mixers,
so the pattern is kept at 8 positions — see EXPERIMENTS.md §Dry-run.)
"""
from repro.configs.base import LayerSpec, MambaConfig, ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b", family="hybrid",
    n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5,
    d_ff=5504, vocab_size=32001, head_dim=64,
    rope_theta=10000.0, norm_eps=1e-5,
    pattern=(LayerSpec(mixer="hymba", mlp="dense", is_global=True),)
    + tuple(LayerSpec(mixer="hymba", mlp="dense", sliding_window=1024,
                      is_global=False) for _ in range(7)),
    mamba=MambaConfig(d_state=16, d_conv=4, expand=1, headdim=64, ngroups=1),
    source="[arXiv:2411.13676; hf]",
)

SMOKE = ModelConfig(
    name="hymba-1.5b-smoke", family="hybrid",
    n_layers=4, d_model=64, n_heads=4, n_kv_heads=2, d_ff=160,
    vocab_size=512, head_dim=16,
    pattern=(LayerSpec(mixer="hymba", mlp="dense", sliding_window=16),),
    mamba=MambaConfig(d_state=8, d_conv=4, expand=1, headdim=16, ngroups=1),
)
