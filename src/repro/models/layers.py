"""Primitive layers (pure functions over param dicts).

Parameter naming matters: ``repro.sharding.rules`` assigns partition specs
by the leaf names used here (wq/wk/wv/wo column/row-parallel, w1/w3/w2 for
MLPs, table/lm_head for embeddings, ...).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def normal(key, shape, scale, dtype=jnp.float32):
    return (scale * jax.random.normal(key, shape)).astype(dtype)


def dense_init(key, d_in, d_out, scale=None, dtype=jnp.float32):
    scale = scale if scale is not None else d_in ** -0.5
    return normal(key, (d_in, d_out), scale, dtype)


# --- norms -----------------------------------------------------------------

def rmsnorm_init(d):
    return {"scale": jnp.ones((d,), jnp.float32)}


def rmsnorm(params, x, eps=1e-5):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps) * params["scale"]
    return y.astype(x.dtype)


# --- rotary ----------------------------------------------------------------

def rope(x, positions, theta=10000.0):
    """x: (B, H, S, dh); positions: (S,) or (B, S) global token positions."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    if positions.ndim == 1:
        ang = positions.astype(jnp.float32)[:, None] * freqs[None, :]
        ang = ang[None, None]                        # (1,1,S,half)
    else:
        ang = positions.astype(jnp.float32)[..., None] * freqs
        ang = ang[:, None]                           # (B,1,S,half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    xr = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return xr.astype(x.dtype)


# --- MLPs ------------------------------------------------------------------

def mlp_init(key, d, d_ff, act="swiglu"):
    k1, k2, k3 = jax.random.split(key, 3)
    p = {"w1": dense_init(k1, d, d_ff), "w2": dense_init(k2, d_ff, d)}
    if act == "swiglu":
        p["w3"] = dense_init(k3, d, d_ff)
    return p


def mlp_apply(params, x, plan, act="swiglu"):
    dt = x.dtype
    h = x @ params["w1"].astype(dt)
    if act == "swiglu":
        h = jax.nn.silu(h) * (x @ params["w3"].astype(dt))
    else:
        h = jax.nn.gelu(h)
    h = plan.act(h, "batch", "seq", "ff")
    return h @ params["w2"].astype(dt)


# --- embeddings ------------------------------------------------------------

def embed_init(key, vocab, d, tie=False):
    k1, k2 = jax.random.split(key)
    p = {"table": normal(k1, (vocab, d), 0.02)}
    if not tie:
        p["lm_head"] = normal(k2, (vocab, d), 0.02)
    return p


def embed_lookup(params, tokens, dtype):
    return params["table"].astype(dtype)[tokens]


def logits_out(params, x, plan, vocab_size):
    table = params.get("lm_head", params["table"])
    logits = x @ table.astype(x.dtype).T
    logits = plan.act(logits, "batch", "seq", "vocab")
    # mask padded vocab rows
    pad = logits.shape[-1] - vocab_size
    if pad:
        neg = jnp.full((pad,), -1e30, logits.dtype)
        logits = logits.at[..., vocab_size:].set(neg)
    return logits


def sinusoidal_positions(n, d):
    pos = jnp.arange(n, dtype=jnp.float32)[:, None]
    dim = jnp.arange(0, d, 2, dtype=jnp.float32)[None, :]
    ang = pos / jnp.power(10000.0, dim / d)
    pe = jnp.zeros((n, d), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(ang))
    pe = pe.at[:, 1::2].set(jnp.cos(ang[:, : (d + 1) // 2]))
    return pe
