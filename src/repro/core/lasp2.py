"""LASP-2: sequence parallelism for linear attention (paper Algorithms 1–4).

The public entry point is :func:`lasp2` — chunked (decay-generalized) linear
attention whose sequence dimension may be sharded over a mesh axis. When it
is, the *only* cross-device communication is

  * forward:  one ``all_gather`` of the per-chunk memory states
              ``M_t in R^{dk x dv}`` (+ per-chunk cumulative log-decays
              ``A_t``, a scalar per head — the decay generalization),
  * backward: one ``all_gather`` of the state gradients ``dM_t``
              (paper Algorithms 3/4),

both independent of sequence length — the paper's central claim.

Two backward modes:

* ``backward="faithful"``: ``custom_vjp`` implementing the paper's
  Algorithm 3/4 communication pattern literally (AllGather on ``dM_t``,
  local decayed suffix sums). Decay is treated as a constant (no gradient)
  — matching the paper, which assumes basic linear attention. Use for
  basic / Retention / Lightning (non-learned decay) variants.
* ``backward="autodiff"``: plain XLA autodiff of the forward. The AD of the
  forward ``all_gather`` is a ``reduce_scatter`` — mathematically identical,
  with (W-1)/W× *less* backward traffic than the paper's AllGather. Required
  for data-dependent decays (GLA-lite / Mamba-2 SSD) and recorded in
  EXPERIMENTS.md as a beyond-paper variant.

Sharding integration: we use partial-manual ``jax.shard_map`` —
``axis_names={sp_axis}`` makes only the sequence axis manual; batch/head
dimensions stay auto-sharded by GSPMD (tensor parallelism over ``"model"``,
batch over ``"pod"`` compose transparently).

Communication goes through the pluggable subsystem in ``repro/comm/``:
the inter-chunk state exchange is a :class:`repro.comm.strategy`
("allgather" — the paper; "ring" — LASP-1's pattern; "pipelined" — a
ZeCO-style sliced ring), scheduled against the intra-chunk kernel by the
double-buffered overlap scheduler, and pinned to an exact HLO collective
budget by ``repro.comm.budget`` (see docs/communication.md).

Intra-chunk compute dispatches through ``repro.kernels.ops`` under the
``kernel_backend`` knob (``xla`` — the ``chunk_scan`` block scan;
``pallas`` — the fused TPU kernel, differentiable via its two-pass
backward; ``interpret`` — the Pallas kernel in interpret mode, used by
the CPU test batteries). ``None`` resolves to the platform default.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.compat import shard_map as _shard_map

from repro.comm import primitives as comm_primitives
from repro.comm.overlap import DoubleBufferedScheduler
from repro.comm.spec import CommSpec, resolve_comm_spec
from repro.comm.strategy import get_strategy
from repro.core.linear_attention import (ChunkOutputs, chunk_summaries,
                                         pick_block, suffix_grad_combine)
from repro.kernels import ops as _ops
from repro.launch.mesh import SEQ_AXIS


@dataclass(frozen=True)
class SPConfig:
    """How the sequence dimension is sharded for LASP-2 style layers.

    ``comm`` is the single :class:`repro.comm.CommSpec` carrying the
    exchange strategy / overlap mode / wire dtype (overridable per call
    on :func:`lasp2`); see ``repro/comm/strategy.py`` for the matrix.
    The loose ``comm_strategy`` / ``overlap`` / ``comm_dtype`` keywords
    are the DEPRECATED spelling — they still construct, fold into
    ``comm``, and warn once per process — and the attributes of the same
    names keep reading as plain strings for compatibility.
    ``kernel_backend`` picks the intra-chunk compute path
    (``xla | pallas | interpret``; ``None`` = platform default).

    ``tp_axis`` (3D meshes): a second, head-parallel mesh axis the
    sequence dimension is ALSO split over — tokens shard over the
    combined ``(sp_axis, tp_axis)`` with ``sp_axis`` major, so this
    rank's global chunk index is ``idx(sp_axis)·|tp_axis| +
    idx(tp_axis)``. Linear-layer state exchanges span the combined axes;
    the ulysses strategy All-to-Alls over ``tp_axis`` alone.

    ``manual=True`` means the caller is ALREADY inside a fully-manual
    shard_map over the exchange axes (the DP×SP(×TP) train step in
    ``repro.train.step``): inputs are per-shard chunks and :func:`lasp2`
    must run its local body directly — issuing the same collectives over
    those axes — instead of opening a nested shard_map (nested manual
    regions do not compose on the pinned jax).
    """

    mesh: Mesh
    sp_axis: str = SEQ_AXIS    # mesh axis the sequence dim is split over
    comm_strategy: Optional[str] = None   # DEPRECATED → comm.strategy
    overlap: Optional[str] = None         # DEPRECATED → comm.overlap
    comm_dtype: Optional[str] = None      # DEPRECATED → comm.dtype
    kernel_backend: Optional[str] = None   # xla | pallas | interpret
    manual: bool = False     # caller already inside a manual region
    comm: Optional[CommSpec] = None       # the one comm spec
    tp_axis: Optional[str] = None  # head-parallel axis (3D meshes)

    def __post_init__(self):
        spec = resolve_comm_spec(
            self.comm, strategy=self.comm_strategy, overlap=self.overlap,
            dtype=self.comm_dtype, where="SPConfig")
        object.__setattr__(self, "comm", spec)
        # Legacy attribute reads keep working as plain strings.
        object.__setattr__(self, "comm_strategy", spec.strategy)
        object.__setattr__(self, "overlap", spec.overlap)
        object.__setattr__(self, "comm_dtype", spec.dtype)

    @property
    def exchange_axes(self) -> tuple:
        """Mesh axes the sequence dimension is sharded over, major
        first — what linear-layer state exchanges span."""
        if self.tp_axis is not None:
            return (self.sp_axis, self.tp_axis)
        return (self.sp_axis,)

    @property
    def exchange_axis(self):
        """The ``axis_name`` to hand a collective: the bare axis on 1D/2D
        configs, the ``(sp_axis, tp_axis)`` tuple on 3D."""
        axes = self.exchange_axes
        return axes if len(axes) > 1 else axes[0]

    @property
    def degree(self) -> int:
        """TOTAL sequence-sharding width (product over exchange axes)."""
        d = 1
        for a in self.exchange_axes:
            d *= self.mesh.shape[a]
        return d

    def chunk_index(self):
        """This rank's global sequence-chunk index ``t`` (traced; valid
        inside the manual region / shard_map body)."""
        return comm_primitives.multi_axis_index(self.exchange_axis)


def _cumulative_decay(log_a):
    """Inclusive in-chunk cumulative decay b_i = exp(sum_{j<=i} log_a_j)."""
    return jnp.exp(jnp.cumsum(log_a.astype(jnp.float32), axis=-1))


def _intra_chunk(q, k, v, log_a, block_size, kernel_backend) -> ChunkOutputs:
    """Intra-chunk pass, dispatched through the kernel backend
    (``repro.kernels.ops``): the XLA ``chunk_scan`` or the (differentiable)
    Pallas chunk kernel."""
    o, state, log_decay = _ops.linear_attention_op(
        q, k, v, log_a, block_size=block_size, backend=kernel_backend)
    return ChunkOutputs(o, state, log_decay)


# ---------------------------------------------------------------------------
# Local (per-shard) forward bodies.
# ---------------------------------------------------------------------------

def _causal_fwd_local(q, k, v, log_a, sp_axis, block_size, axis_size,
                      strategy="allgather", overlap="overlap",
                      kernel_backend=None, comm_dtype="fp32"):
    """Runs on each device's sequence shard. Returns output + residual pack.

    Ordering mirrors paper Alg. 2: the cheap chunk-summary pass produces
    the exchange payload first; the strategy's collective is then issued
    *around* the heavy intra-chunk kernel (``_intra_chunk`` — XLA scan or
    Pallas, per ``kernel_backend``) by the double-buffered scheduler —
    with ``overlap="overlap"`` the two are dataflow independent and the
    gather's wire time hides behind the intra-chunk kernel (the paper's
    comm/compute overlap), with ``"none"`` the exchange is barriered
    behind compute for A/B benchmarking.
    """
    bs = pick_block(q.shape[-2], block_size)
    # (1) cheap summary pass: M_t, A_t — only K/V/decay.
    m_loc, a_loc = chunk_summaries(k, v, log_a, block_size=bs)
    # (2) + (3): the strategy's exchange, overlapped with the intra-chunk
    # kernel by the scheduler. For "allgather" this is THE single
    # collective of LASP-2.
    t = comm_primitives.multi_axis_index(sp_axis)
    ex = get_strategy(strategy, comm_dtype).prefix(
        m_loc, a_loc, sp_axis, axis_size, t,
        DoubleBufferedScheduler(overlap),
        lambda: _intra_chunk(q, k, v, log_a, bs, kernel_backend))
    # (4) local prefix combine + inter-chunk output.
    b = _cumulative_decay(log_a)
    o_inter = jnp.einsum(
        "...sk,...kv->...sv", q.astype(jnp.float32) * b[..., None],
        ex.m_prev)
    o = ex.intra.o.astype(jnp.float32) + o_inter
    return o.astype(q.dtype), (ex.m_prev, ex.cum, t)


def _noncausal_fwd_local(q, k, v, sp_axis, block_size, axis_size,
                         comm_dtype="fp32"):
    """Paper Alg. 1: no mask — every position reads the full-sequence state."""
    del block_size
    kf, vf = k.astype(jnp.float32), v.astype(jnp.float32)
    m_loc = jnp.einsum("...sk,...sv->...kv", kf, vf)
    ms = comm_primitives.allgather_states(
        m_loc.astype(comm_primitives.wire_dtype(comm_dtype)), sp_axis,
        axis_size=axis_size, tag="lasp2.noncausal")
    m_tot = jnp.sum(comm_primitives.upcast_gathered(ms), axis=0)
    o = jnp.einsum("...sk,...kv->...sv", q.astype(jnp.float32), m_tot)
    return o.astype(q.dtype), m_tot


# ---------------------------------------------------------------------------
# Paper-faithful custom_vjp (Algorithms 3/4).
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6, 7, 8, 9))
def _lasp2_causal_faithful(q, k, v, log_a, sp_axis, block_size, axis_size,
                           overlap, kernel_backend, comm_dtype):
    o, _ = _causal_fwd_local(q, k, v, log_a, sp_axis, block_size, axis_size,
                             "allgather", overlap, kernel_backend,
                             comm_dtype)
    return o


def _faithful_fwd(q, k, v, log_a, sp_axis, block_size, axis_size, overlap,
                  kernel_backend, comm_dtype):
    o, (m_prev, cum, t) = _causal_fwd_local(
        q, k, v, log_a, sp_axis, block_size, axis_size, "allgather", overlap,
        kernel_backend, comm_dtype)
    return o, (q, k, v, log_a, m_prev, cum, t)


def _faithful_bwd(sp_axis, block_size, axis_size, overlap, kernel_backend,
                  comm_dtype, res, do):
    q, k, v, log_a, m_prev, cum, t = res
    bs = pick_block(q.shape[-2], block_size)
    dof = do.astype(jnp.float32)
    b = _cumulative_decay(log_a)
    qb = q.astype(jnp.float32) * b[..., None]
    # Alg. 4 line 3: dM_t = (Q_t~)^T dO_t  (decay-weighted in our general form)
    dm_up = jnp.einsum("...sk,...sv->...kv", qb, dof)
    # Alg. 4 line 4: the single backward AllGather (comm_dtype on the
    # wire; the suffix combine below stays fp32).
    dms = comm_primitives.upcast_gathered(comm_primitives.allgather_states(
        dm_up.astype(comm_primitives.wire_dtype(comm_dtype)), sp_axis,
        axis_size=axis_size, tag="lasp2.dstates"))
    # Alg. 4 line 9: decayed suffix sum, local.
    dm_loc = suffix_grad_combine(dms, cum, t)

    # Intra-chunk + local state-contribution gradients (Alg. 4 lines 5–7,
    # 10–11). Computed by re-running the local chunk pass under VJP — the
    # recompute mirrors the paper's activation-checkpointing remark. The
    # pullback pulls on BOTH outputs (o and the end-of-chunk state) — on
    # the Pallas backends this hits the chunk kernel's custom_vjp.
    def local_parts(q_, k_, v_):
        out = _intra_chunk(q_, k_, v_, log_a, bs, kernel_backend)
        return out.o, out.state

    _, pull = jax.vjp(local_parts, q, k, v)
    dq_i, dk_i, dv_i = pull((do, dm_loc))
    # Alg. 4 line 8: dQ_inter = dO_t M_{1:t-1}^T (decay-weighted).
    dq_inter = jnp.einsum("...sv,...kv->...sk", dof, m_prev) * b[..., None]
    dq = (dq_i.astype(jnp.float32) + dq_inter).astype(q.dtype)
    # Faithful path: decay is a non-learned constant → zero cotangent.
    return dq, dk_i, dv_i, jnp.zeros_like(log_a)


_lasp2_causal_faithful.defvjp(_faithful_fwd, _faithful_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def _lasp2_noncausal_faithful(q, k, v, sp_axis, block_size, axis_size,
                              comm_dtype):
    o, _ = _noncausal_fwd_local(q, k, v, sp_axis, block_size, axis_size,
                                comm_dtype)
    return o


def _nc_fwd(q, k, v, sp_axis, block_size, axis_size, comm_dtype):
    o, m_tot = _noncausal_fwd_local(q, k, v, sp_axis, block_size, axis_size,
                                    comm_dtype)
    return o, (q, k, v, m_tot)


def _nc_bwd(sp_axis, block_size, axis_size, comm_dtype, res, do):
    q, k, v, m_tot = res
    dof = do.astype(jnp.float32)
    # Alg. 3: dM_t = Q_t^T dO_t; AllGather; combine.
    dm_up = jnp.einsum("...sk,...sv->...kv", q.astype(jnp.float32), dof)
    dms = comm_primitives.upcast_gathered(comm_primitives.allgather_states(
        dm_up.astype(comm_primitives.wire_dtype(comm_dtype)), sp_axis,
        axis_size=axis_size, tag="lasp2.nc.dstates"))
    # NOTE: paper Alg. 3 line 5 writes Sum([dM]_{t+1}^T) — a suffix sum — but
    # in the unmasked form every chunk's state feeds every output, so the
    # correct cotangent sums over *all* chunks (verified against autodiff in
    # tests/test_distributed checks). We implement the correct full sum.
    dm_tot = jnp.sum(dms, axis=0)
    dq = jnp.einsum("...sv,...kv->...sk", dof, m_tot).astype(q.dtype)
    dk = jnp.einsum("...sv,...kv->...sk", v.astype(jnp.float32), dm_tot
                    ).astype(k.dtype)
    dv = jnp.einsum("...sk,...kv->...sv", k.astype(jnp.float32), dm_tot
                    ).astype(v.dtype)
    return dq, dk, dv


_lasp2_noncausal_faithful.defvjp(_nc_fwd, _nc_bwd)


# ---------------------------------------------------------------------------
# Autodiff-path forwards (plain functions; XLA derives the backward).
# ---------------------------------------------------------------------------

def _lasp2_causal_autodiff(q, k, v, log_a, sp_axis, block_size, axis_size,
                           strategy, overlap, kernel_backend,
                           comm_dtype="fp32"):
    o, _ = _causal_fwd_local(q, k, v, log_a, sp_axis, block_size, axis_size,
                             strategy, overlap, kernel_backend, comm_dtype)
    return o


def lasp2_with_state(q, k, v, log_a=None, *, sp: Optional[SPConfig] = None,
                     block_size: int = 128,
                     kernel_backend: Optional[str] = None):
    """Causal LASP-2 forward that also returns the end-of-sequence memory
    state (used by prefill to seed the decode cache). No custom_vjp —
    prefill is inference-only. Always the "allgather" strategy: the end
    state needs every chunk's contribution, which the gather provides
    for free."""
    if log_a is None:
        log_a = jnp.zeros(q.shape[:-1], dtype=jnp.float32)
    if kernel_backend is None and sp is not None:
        kernel_backend = sp.kernel_backend
    if sp is None or sp.degree == 1:
        out = _intra_chunk(q, k, v, log_a,
                           pick_block(q.shape[-2], block_size),
                           kernel_backend)
        return out.o, out.state

    axis = sp.exchange_axis
    w = sp.degree

    def local_fn(q_, k_, v_, la_):
        bs = pick_block(q_.shape[-2], block_size)
        m_loc, a_loc = chunk_summaries(k_, v_, la_, block_size=bs)
        t = comm_primitives.multi_axis_index(axis)
        ex = get_strategy("allgather", sp.comm_dtype).prefix(
            m_loc, a_loc, axis, w, t, DoubleBufferedScheduler(sp.overlap),
            lambda: _intra_chunk(q_, k_, v_, la_, bs, kernel_backend))
        b = _cumulative_decay(la_)
        o = ex.intra.o.astype(jnp.float32) + jnp.einsum(
            "...sk,...kv->...sv", q_.astype(jnp.float32) * b[..., None],
            ex.m_prev)
        # global end state: decayed combine of all chunks (same on all ranks)
        logw = ex.cum[-1][None] - ex.cum
        m_end = jnp.einsum("w...,w...kv->...kv",
                           jnp.exp(jnp.minimum(logw, 0.0)), ex.states)
        return o.astype(q_.dtype), m_end

    if sp.manual:
        return local_fn(q, k, v, log_a)

    nd = q.ndim
    spec_qkv = P(*([None] * (nd - 2)), axis, None)
    spec_a = P(*([None] * (nd - 2)), axis)
    spec_state = P(*([None] * nd))
    return _shard_map(
        local_fn, mesh=sp.mesh,
        in_specs=(spec_qkv, spec_qkv, spec_qkv, spec_a),
        out_specs=(spec_qkv, spec_state), axis_names=set(sp.exchange_axes),
        check_vma=False)(q, k, v, log_a)


# ---------------------------------------------------------------------------
# Public API.
# ---------------------------------------------------------------------------

def lasp2(q, k, v, log_a=None, *, sp: Optional[SPConfig] = None,
          causal: bool = True, block_size: int = 128,
          backward: str = "faithful",
          comm: Optional[CommSpec] = None,
          comm_strategy: Optional[str] = None,
          overlap: Optional[str] = None,
          comm_dtype: Optional[str] = None,
          kernel_backend: Optional[str] = None):
    """Chunked linear attention with LASP-2 sequence parallelism.

    Args:
      q, k: ``(..., S, dk)``; v: ``(..., S, dv)`` — global (logical) shapes.
      log_a: optional per-token log decays ``(..., S)`` (see
        ``repro.core.linear_attention``). ``None`` = basic linear attention.
      sp: sequence-parallel config; ``None`` or degree 1 → purely local
        chunked scan (no communication).
      causal: causal (paper Alg. 2) vs bidirectional (paper Alg. 1).
      backward: "faithful" (paper Alg. 3/4 custom_vjp) or "autodiff".
        Learned/data-dependent ``log_a`` requires "autodiff".
      comm: per-call :class:`repro.comm.CommSpec` override — strategy
        ("allgather" — the paper; "ring" — LASP-1's pattern; "pipelined"
        — ZeCO-style sliced ring; "ulysses" — allgather here, the
        All-to-All lives on the softmax context path), overlap mode
        ("overlap" double-buffered | "none" barriered A/B baseline), and
        wire dtype ("fp32" | "bf16": payload cast before the collective,
        prefix combine in fp32 — bf16 halves the per-layer exchange
        bytes with collective *counts* untouched, asserted by the
        dtype-aware budgets). ``None`` → ``sp.comm``. The faithful
        backward is the paper's AllGather algorithm, so non-"allgather"
        strategies always differentiate via autodiff (their permutes
        transpose to permutes).
      comm_strategy / overlap / comm_dtype: DEPRECATED loose spellings of
        the same three knobs; folded into ``comm`` with a once-per-process
        warning.
      kernel_backend: intra-chunk compute path — "xla" (``chunk_scan``),
        "pallas" (fused TPU kernel, trainable via its two-pass backward),
        "interpret" (Pallas interpret mode, for CPU tests).
        ``None`` → ``sp.kernel_backend``, then the platform default.
        Collectives are untouched by this knob (the HLO budget tests pin
        that: still exactly one forward all-gather per layer).
    """
    if log_a is None:
        log_a = jnp.zeros(q.shape[:-1], dtype=jnp.float32)
    if kernel_backend is None and sp is not None:
        kernel_backend = sp.kernel_backend
    kb = _ops.resolve_backend(kernel_backend)
    if sp is None or sp.degree == 1:
        if causal:
            return _intra_chunk(q, k, v, log_a,
                                pick_block(q.shape[-2], block_size), kb).o
        m_tot, _ = chunk_summaries(
            k, v, None, block_size=pick_block(q.shape[-2], block_size))
        # no-decay bidirectional total state
        return jnp.einsum("...sk,...kv->...sv", q.astype(jnp.float32),
                          m_tot).astype(q.dtype)

    axis = sp.exchange_axis
    w = sp.degree
    cs = resolve_comm_spec(comm, strategy=comm_strategy, overlap=overlap,
                           dtype=comm_dtype, base=sp.comm, where="lasp2()")
    strategy, ovl, cdt = cs.strategy, cs.overlap, cs.dtype
    if strategy == "ulysses":
        # ulysses only changes the softmax context path; the linear-layer
        # state exchange under it IS LASP-2's allgather.
        strategy = "allgather"
    if sp.tp_axis is not None and strategy != "allgather":
        raise ValueError(
            f"comm_strategy={strategy!r} does not support the combined "
            f"(sequence, model) exchange of a 3D mesh — use 'allgather' "
            f"or 'ulysses'")
    if strategy != "allgather" and backward == "faithful":
        backward = "autodiff"   # faithful == the paper's AllGather pattern
    if not causal and strategy != "allgather":
        # The bidirectional form (Alg. 1/3) consumes the TOTAL state, not a
        # rank-dependent prefix — a ring prefix-scan does not apply. Fail
        # loudly rather than silently benchmarking the wrong thing.
        raise ValueError(
            f"comm_strategy={strategy!r} is causal-only; the bidirectional "
            "path always uses the allgather exchange")
    if sp.manual:
        # Already inside the train step's fully-manual shard_map: q/k/v
        # are this rank's sequence chunks; the local bodies issue the
        # exchange over ``axis`` directly.
        if causal:
            if backward == "faithful":
                return _lasp2_causal_faithful(q, k, v, log_a, axis,
                                              block_size, w, ovl, kb, cdt)
            return _lasp2_causal_autodiff(q, k, v, log_a, axis, block_size,
                                          w, strategy, ovl, kb, cdt)
        if backward == "faithful":
            return _lasp2_noncausal_faithful(q, k, v, axis, block_size, w,
                                             cdt)
        return _noncausal_fwd_local(q, k, v, axis, block_size, w, cdt)[0]

    nd = q.ndim
    spec_qkv = P(*([None] * (nd - 2)), axis, None)
    spec_a = P(*([None] * (nd - 2)), axis)

    if causal:
        if backward == "faithful":
            def mapped(q_, k_, v_, la_):
                return _lasp2_causal_faithful(q_, k_, v_, la_, axis,
                                              block_size, w, ovl, kb, cdt)
        else:
            def mapped(q_, k_, v_, la_):
                return _lasp2_causal_autodiff(q_, k_, v_, la_, axis,
                                              block_size, w, strategy, ovl,
                                              kb, cdt)

        return _shard_map(
            mapped, mesh=sp.mesh,
            in_specs=(spec_qkv, spec_qkv, spec_qkv, spec_a),
            out_specs=spec_qkv, axis_names=set(sp.exchange_axes),
            check_vma=False)(q, k, v, log_a)

    if backward == "faithful":
        def mapped_nc(q_, k_, v_):
            return _lasp2_noncausal_faithful(q_, k_, v_, axis, block_size,
                                             w, cdt)
    else:
        def mapped_nc(q_, k_, v_):
            o, _ = _noncausal_fwd_local(q_, k_, v_, axis, block_size, w,
                                        cdt)
            return o

    return _shard_map(
        mapped_nc, mesh=sp.mesh, in_specs=(spec_qkv, spec_qkv, spec_qkv),
        out_specs=spec_qkv, axis_names=set(sp.exchange_axes),
        # check_vma=False: scan carries start as unvarying zeros; the
        # varying-manual-axes static check cannot see that they immediately
        # combine with varying data. Collective placement is verified by the
        # HLO-counting tests instead.
        check_vma=False)(q, k, v)
