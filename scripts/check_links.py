#!/usr/bin/env python
"""Cross-reference checker for the documentation suite.

Verifies that (a) every relative markdown link / image in README.md,
docs/**.md, and the other top-level *.md files points at a file that
exists, and (b) every `path/to/file.py`-style inline-code reference to a
repo file resolves. External (http/…) links are not fetched.

  python scripts/check_links.py        # exit 1 + report on broken refs
"""

from __future__ import annotations

import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)#\s]+)(#[^)\s]*)?\)")
CODEPATH_RE = re.compile(
    r"`([A-Za-z0-9_.-]+(?:/[A-Za-z0-9_.*-]+)+\.(?:py|md|toml|yml|json))`")


SKIP = {"ISSUE.md"}          # transient per-PR task file, not docs

# Inline-code refs may be written relative to any of these roots
# (prose shorthand like `core/lasp2.py` means src/repro/core/lasp2.py).
CODE_ROOTS = ("", "src", "src/repro")


def md_files():
    for p in ROOT.glob("*.md"):
        if p.name not in SKIP:
            yield p
    yield from (ROOT / "docs").rglob("*.md")


def check_file(md: Path):
    errors = []
    text = md.read_text()
    for rx, kind in ((LINK_RE, "link"), (CODEPATH_RE, "code ref")):
        for m in rx.finditer(text):
            target = m.group(1)
            if target.startswith(("http://", "https://", "mailto:")):
                continue
            if "*" in target:            # glob-style mention, not a path
                continue
            line = text[:m.start()].count("\n") + 1
            if kind == "link":
                ok = (md.parent / target).resolve().exists()
            else:
                ok = any((ROOT / r / target).exists() for r in CODE_ROOTS)
            if not ok:
                errors.append(f"{md.relative_to(ROOT)}:{line}: "
                              f"broken {kind} -> {target}")
    return errors


def main() -> int:
    errors = []
    n = 0
    for md in sorted(set(md_files())):
        n += 1
        errors += check_file(md)
    if errors:
        print(f"{len(errors)} broken cross-reference(s) in {n} files:")
        print("\n".join(errors))
        return 1
    print(f"OK: all cross-references resolve ({n} markdown files).")
    return 0


if __name__ == "__main__":
    sys.exit(main())
