"""Error-feedback int8 gradient compression for cross-pod all-reduce.

Beyond-paper distributed-optimization trick: within a pod, gradients
reduce over fast ICI (left to XLA); *across pods* (slow DCN links) we
quantize to int8 with a shared per-tensor scale, psum the int8 payload (in
int32), and dequantize — 4× less cross-pod traffic than fp32, 2× less than
bf16. The quantization error is carried in an error-feedback buffer so the
compression is unbiased over time (Karimireddy et al., 2019 style).

Used by the train step when ``RunConfig.grad_compression`` and the mesh has
a "pod" axis; parity-vs-exact tested in tests/distributed_checks.py.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.launch.mesh import POD_AXIS


def _compress_psum_leaf(g, err, axis):
    gf = g.astype(jnp.float32) + err
    scale_local = jnp.max(jnp.abs(gf))
    scale = jax.lax.pmax(scale_local, axis) / 127.0
    scale = jnp.maximum(scale, 1e-20)
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    deq_local = q.astype(jnp.float32) * scale
    new_err = gf - deq_local
    total = jax.lax.psum(q.astype(jnp.int32), axis).astype(jnp.float32)
    npods = jax.lax.psum(1, axis)
    mean = total * scale / npods
    return mean.astype(g.dtype), new_err


def compress_sync_tree(grads, err_buf, *, pod_axis=POD_AXIS):
    """Mean gradient trees across pods with int8 error-feedback compression.

    Must be called *inside* a ``shard_map`` whose manual axes include
    ``pod_axis`` (the train step wraps its grad computation in one when
    compression is on, so per-pod gradients exist to compress). Returns
    (synced_grads, new_error_buffer).
    """
    pairs = jax.tree.map(
        lambda g, e: _compress_psum_leaf(g, e, pod_axis), grads, err_buf)
    synced = jax.tree.map(lambda t: t[0], pairs,
                          is_leaf=lambda x: isinstance(x, tuple))
    new_err = jax.tree.map(lambda t: t[1], pairs,
                           is_leaf=lambda x: isinstance(x, tuple))
    return synced, new_err


def init_error_buffer(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
