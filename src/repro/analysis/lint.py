"""jaxlint runner: file discovery, rule scoping, suppressions.

Discovery walks the repo's Python roots (``src``, ``tests``, ``scripts``,
``benchmarks``, ``examples``), skipping ``__pycache__``/``.git``/egg-info
debris. Two suppression mechanisms:

* inline: ``# jaxlint: disable=JL101`` (comma-separated codes) on the
  offending line;
* the suppression file ``src/repro/analysis/suppressions.txt`` — lines
  of ``<repo-relative-path> <CODE>`` for grandfathered violations.
  Policy (docs/static_analysis.md): it must stay EMPTY for the hot-path
  modules; entries are for transitional third-tier code only.

Suppressed findings are still collected (``AnalysisResult.suppressed``)
so the CI artifact shows what is being grandfathered.
"""

from __future__ import annotations

import re
from pathlib import Path
from typing import Iterable, List, Optional, Sequence, Set, Tuple

from repro.analysis.findings import AnalysisResult, Finding
from repro.analysis.rules import RULES, FileContext

_SKIP_DIRS = {"__pycache__", ".git", ".pytest_cache", ".ruff_cache",
              "node_modules"}
_PY_ROOTS = ("src", "tests", "scripts", "benchmarks", "examples")

# JL102 scope: traced hot-path modules (src/repro-relative) + the obs
# fencing helpers (whose deliberate sites carry @host_sync_allowed).
_SYNC_PREFIXES = ("core/", "kernels/", "comm/")
_SYNC_FILES = ("train/step.py", "obs/metrics.py")
# JL104 scope: strictly-traced modules only (obs/metrics.py legitimately
# owns host clocks).
_DET_PREFIXES = ("core/", "kernels/", "comm/")
_DET_FILES = ("train/step.py",)

_AXIS_EXEMPT = ("launch/mesh.py",)
_TRACER_EXEMPT = ("core/compat.py",)

_DISABLE_RE = re.compile(r"#\s*jaxlint:\s*disable=([A-Z0-9,\s]+)")

SUPPRESSION_FILE = Path(__file__).resolve().parent / "suppressions.txt"


def repo_root() -> Path:
    return Path(__file__).resolve().parents[3]


def discover_files(root: Optional[Path] = None) -> List[Path]:
    root = Path(root) if root else repo_root()
    out: List[Path] = []
    if root.is_file():
        return [root]
    for sub in _PY_ROOTS:
        base = root / sub
        if not base.is_dir():
            continue
        for p in sorted(base.rglob("*.py")):
            if not any(part in _SKIP_DIRS for part in p.parts):
                out.append(p)
    if not out:       # a directory that is itself a python tree
        out = [p for p in sorted(root.rglob("*.py"))
               if not any(part in _SKIP_DIRS for part in p.parts)]
    return out


def _repro_rel(path: Path, root: Path) -> Optional[str]:
    """src/repro-relative posix path, or None for files outside it."""
    try:
        return path.resolve().relative_to(
            (root / "src" / "repro").resolve()).as_posix()
    except ValueError:
        return None


def _inline_disabled(text: str) -> dict:
    """line number -> set of disabled codes."""
    out = {}
    for i, line in enumerate(text.splitlines(), start=1):
        m = _DISABLE_RE.search(line)
        if m:
            out[i] = {c.strip() for c in m.group(1).split(",") if c.strip()}
    return out


def load_suppressions(path: Optional[Path] = None) -> List[Tuple[str, str]]:
    path = path or SUPPRESSION_FILE
    entries: List[Tuple[str, str]] = []
    if not Path(path).exists():
        return entries
    for raw in Path(path).read_text().splitlines():
        line = raw.split("#", 1)[0].strip()
        if not line:
            continue
        parts = line.split()
        if len(parts) != 2:
            raise ValueError(
                f"{path}: bad suppression line {raw!r} "
                f"(want '<repo-relative-path> <CODE>')")
        entries.append((parts[0], parts[1]))
    return entries


def _suppressed_by_file(f: Finding,
                        entries: Sequence[Tuple[str, str]]) -> bool:
    return any(f.code == code and f.path.endswith(path)
               for path, code in entries)


def make_context(path: Path, *, root: Optional[Path] = None,
                 text: Optional[str] = None,
                 sync_scope: Optional[bool] = None,
                 det_scope: Optional[bool] = None) -> FileContext:
    root = Path(root) if root else repo_root()
    text = path.read_text() if text is None else text
    rel = _repro_rel(path, root)
    try:
        display = path.resolve().relative_to(root.resolve()).as_posix()
    except ValueError:
        display = str(path)
    auto_sync = rel is not None and (rel.startswith(_SYNC_PREFIXES)
                                     or rel in _SYNC_FILES)
    auto_det = rel is not None and (rel.startswith(_DET_PREFIXES)
                                    or rel in _DET_FILES)
    return FileContext(
        path=display, text=text,
        sync_scope=auto_sync if sync_scope is None else sync_scope,
        det_scope=auto_det if det_scope is None else det_scope,
        axis_exempt=rel in _AXIS_EXEMPT,
        tracer_exempt=rel in _TRACER_EXEMPT)


def lint_file(path: Path, *, root: Optional[Path] = None,
              text: Optional[str] = None,
              sync_scope: Optional[bool] = None,
              det_scope: Optional[bool] = None,
              codes: Optional[Set[str]] = None) -> List[Finding]:
    """All raw findings for one file (no suppression filtering)."""
    ctx = make_context(Path(path), root=root, text=text,
                       sync_scope=sync_scope, det_scope=det_scope)
    findings: List[Finding] = []
    for code, (_title, rule) in RULES.items():
        if codes is not None and code not in codes:
            continue
        findings.extend(rule(ctx))
    return findings


def run_lint(paths: Optional[Iterable[Path]] = None, *,
             root: Optional[Path] = None,
             suppressions: Optional[Sequence[Tuple[str, str]]] = None
             ) -> AnalysisResult:
    root = Path(root) if root else repo_root()
    files = [Path(p) for p in paths] if paths else discover_files(root)
    if suppressions is None:
        suppressions = load_suppressions()
    result = AnalysisResult()
    for path in files:
        text = path.read_text()
        disabled = _inline_disabled(text)
        for f in lint_file(path, root=root, text=text):
            if f.code in disabled.get(f.line, ()) \
                    or _suppressed_by_file(f, suppressions):
                result.suppressed.append(f)
            else:
                result.findings.append(f)
    result.checked["files"] = len(files)
    return result
