"""whisper-base — enc-dec, conv frontend (stub) [arXiv:2212.04356; unverified].

6 encoder layers (bidirectional) + 6 decoder layers; one decoder layer =
(self-attn, cross-attn + MLP) = two pattern entries, so n_layers=12 with a
length-2 pattern. The audio conv frontend is a stub: input_specs()
provides (B, 1500, d_model) frame embeddings. Deviations noted in
DESIGN.md: RMSNorm instead of biased LayerNorm, RoPE instead of learned
positions.
"""
from repro.configs.base import EncoderConfig, LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="whisper-base", family="audio",
    n_layers=12, d_model=512, n_heads=8, n_kv_heads=8,
    d_ff=2048, vocab_size=51865,
    rope_theta=10000.0, norm_eps=1e-5, mlp_act="gelu",
    tie_embeddings=True,
    pattern=(LayerSpec(mixer="softmax", mlp="none"),
             LayerSpec(mixer="cross", mlp="dense")),
    encoder=EncoderConfig(n_layers=6, n_frames=1500),
    source="[arXiv:2212.04356; unverified]",
)

SMOKE = ModelConfig(
    name="whisper-base-smoke", family="audio",
    n_layers=4, d_model=64, n_heads=4, n_kv_heads=4, d_ff=160,
    vocab_size=512, mlp_act="gelu",
    pattern=(LayerSpec(mixer="softmax", mlp="none"),
             LayerSpec(mixer="cross", mlp="dense")),
    encoder=EncoderConfig(n_layers=2, n_frames=16),
)
