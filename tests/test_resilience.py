"""Fault-tolerance subsystem unit tests: numerical guard verdicts,
hardened checkpoints (checksums, fallback, async-error surfacing,
retry), chaos injectors, and serving degradation (bounded queue,
deadlines, finished-result eviction). The end-to-end recovery scenarios
live in ``repro.resilience.drill`` and tests/distributed_checks.py."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import (CheckpointCorruptError,
                                      CheckpointError, CheckpointManager)
from repro.resilience import chaos
from repro.resilience.guard import (GUARD_METRICS, guard_init,
                                    guard_verdict, rolling_median)
from repro.serve.scheduler import ContinuousScheduler, QueueFullError


# -- guard units ------------------------------------------------------------

def test_rolling_median_partial_and_full_window():
    w = jnp.zeros((4,), jnp.float32).at[:2].set(jnp.array([3.0, 1.0]))
    assert float(rolling_median(w, jnp.int32(0))) == 0.0
    assert float(rolling_median(w, jnp.int32(1))) == 3.0
    # two entries -> lower middle; unfilled zeros must not contribute
    assert float(rolling_median(w, jnp.int32(2))) == 1.0
    full = jnp.array([4.0, 2.0, 8.0, 6.0])
    assert float(rolling_median(full, jnp.int32(4))) == 4.0
    # count beyond the window length saturates at the window
    assert float(rolling_median(full, jnp.int32(100))) == 4.0


def _verdict(guard, gnorm, nonfinite=False, **kw):
    kw.setdefault("grad_clip", 1.0)
    kw.setdefault("spike_factor", 4.0)
    return guard_verdict(guard, jnp.float32(gnorm),
                         jnp.asarray(nonfinite), **kw)


def test_guard_skip_zeroes_scale_and_counts():
    g = guard_init(8)
    scale, ok, g, info = _verdict(g, jnp.nan, nonfinite=True)
    assert float(scale) == 0.0 and not bool(ok)
    assert int(g["skipped_steps"]) == 1
    assert int(g["consecutive_skips"]) == 1
    assert int(g["window_count"]) == 0      # skips never enter the window
    scale, ok, g, info = _verdict(g, 0.5)
    assert bool(ok) and float(scale) == 1.0
    assert int(g["consecutive_skips"]) == 0  # reset on a good step
    assert int(g["skipped_steps"]) == 1      # total is monotone
    assert set(info) == set(GUARD_METRICS)


def test_guard_spike_clips_to_median_multiple_after_warmup():
    g = guard_init(16)
    for _ in range(8):                       # warm up: gnorm 0.1 median
        _, _, g, _ = _verdict(g, 0.1)
    scale, ok, g2, info = _verdict(g, 10.0)  # 100x the median: spike
    assert bool(ok)
    assert float(info["guard_spike"]) == 1.0
    # clipped to spike_factor * median = 0.4 -> scale 0.04
    assert float(scale) == pytest.approx(0.4 / 10.0)
    assert float(info["guard_median"]) == pytest.approx(0.1)
    # the window recorded the POST-clip norm, so the median holds
    _, _, _, info2 = _verdict(g2, 0.1)
    assert float(info2["guard_median"]) == pytest.approx(0.1)


def test_guard_below_warmup_never_spikes():
    g = guard_init(8)
    _, _, g, _ = _verdict(g, 0.1)
    scale, ok, _, info = _verdict(g, 50.0)   # huge, but detector unarmed
    assert bool(ok) and float(info["guard_spike"]) == 0.0
    # plain grad_clip still applies
    assert float(scale) == pytest.approx(1.0 / 50.0)


# -- checkpoint hardening ---------------------------------------------------

def _tree(k=1.0):
    return {"params": {"w": jnp.arange(8.0) * k, "b": jnp.ones((3,)) * k},
            "step": jnp.int32(int(k))}


def test_async_save_error_surfaces_on_wait(tmp_path):
    mgr = CheckpointManager(str(tmp_path), retries=2, backoff_s=0.0)
    mgr._savez = chaos.FlakySavez(fails=99)   # every attempt fails
    mgr.save_async(1, _tree())
    with pytest.raises(OSError):
        mgr.wait()
    assert mgr.latest_step() is None
    mgr.wait()                                # error raised once, then clear


def test_async_save_error_surfaces_on_next_save(tmp_path):
    mgr = CheckpointManager(str(tmp_path), retries=1, backoff_s=0.0)
    mgr._savez = chaos.FlakySavez(fails=99)
    mgr.save_async(1, _tree())
    import numpy as _np
    import time
    for _ in range(100):                      # let the thread fail
        if mgr._thread is None or not mgr._thread.is_alive():
            break
        time.sleep(0.01)
    mgr._savez = _np.savez
    with pytest.raises(OSError):
        mgr.save_async(2, _tree(2.0))         # surfaces the step-1 error
    mgr.save_async(2, _tree(2.0))
    mgr.wait()
    assert mgr.latest_step() == 2


def test_save_retries_transient_ioerror(tmp_path):
    mgr = CheckpointManager(str(tmp_path), retries=3, backoff_s=0.0)
    flaky = chaos.FlakySavez(fails=2)
    mgr._savez = flaky
    mgr.save(5, _tree())
    assert flaky.calls == 3
    out = mgr.restore(5, jax.tree.map(jnp.zeros_like, _tree()))
    np.testing.assert_array_equal(out["params"]["w"], _tree()["params"]["w"])


def test_kill_mid_save_leaves_previous_checkpoint(tmp_path):
    mgr = CheckpointManager(str(tmp_path), backoff_s=0.0)
    mgr.save(1, _tree())
    mgr._savez = chaos.KillingSavez()
    mgr.save_async(2, _tree(2.0))
    with pytest.raises(chaos.KillSave):
        mgr.wait()
    assert mgr.latest_step() == 1             # atomic: torn write invisible
    out = mgr.restore(1, jax.tree.map(jnp.zeros_like, _tree()))
    np.testing.assert_array_equal(out["params"]["w"], _tree()["params"]["w"])


def test_restore_missing_step_lists_available(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(3, _tree())
    with pytest.raises(CheckpointError, match=r"\[3\]"):
        mgr.restore(7, _tree())


def test_restore_corrupt_arrays_raises_corrupt_error(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, _tree())
    chaos.corrupt_checkpoint(str(tmp_path), 1)
    with pytest.raises(CheckpointCorruptError):
        mgr.restore(1, _tree())


def test_restore_truncated_manifest_raises_corrupt_error(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, _tree())
    chaos.truncate_manifest(str(tmp_path), 1)
    with pytest.raises(CheckpointCorruptError, match="manifest"):
        mgr.restore(1, _tree())


def test_restore_missing_arrays_file_is_actionable(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, _tree())
    os.remove(tmp_path / "step_00000001" / "arrays.npz")
    with pytest.raises(CheckpointCorruptError, match="arrays.npz"):
        mgr.restore(1, _tree())


def test_checksum_verification_can_be_disabled(tmp_path):
    """--no-ckpt-verify: a flipped payload byte that still unzips loads
    without the checksum error (the escape hatch, not the default)."""
    mgr = CheckpointManager(str(tmp_path), verify=True)
    big = {"w": jnp.ones((4096,), jnp.float32)}
    mgr.save(1, big)
    # flip bytes inside the (stored, uncompressed) payload
    chaos.corrupt_checkpoint(str(tmp_path), 1, n_bytes=4, offset_frac=0.5)
    with pytest.raises((CheckpointCorruptError, ValueError)):
        mgr.restore(1, big)
    try:
        out = mgr.restore(1, big, verify=False)
        assert out["w"].shape == (4096,)
    except CheckpointCorruptError:
        # the flip may land on zip structure rather than payload bytes;
        # then even unverified reads fail — also acceptable
        pass


def test_restore_latest_valid_falls_back(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, _tree(1.0))
    mgr.save(2, _tree(2.0))
    chaos.corrupt_checkpoint(str(tmp_path), 2)
    step, out, rejected = mgr.restore_latest_valid(
        jax.tree.map(jnp.zeros_like, _tree()))
    assert step == 1
    assert [s for s, _ in rejected] == [2]
    np.testing.assert_array_equal(out["params"]["w"], _tree()["params"]["w"])


def test_restore_latest_valid_none_valid_raises(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, _tree())
    chaos.corrupt_checkpoint(str(tmp_path), 1)
    with pytest.raises(CheckpointError):
        mgr.restore_latest_valid(_tree())


def test_subtree_restore_by_path(tmp_path):
    """v2 manifests match leaves BY PATH: restoring only {"params": ...}
    from a full train-state checkpoint loads the params leaves, not
    whatever happened to be first in flattening order (the latent
    positional-restore bug the serve launcher used to have)."""
    mgr = CheckpointManager(str(tmp_path))
    full = {"opt": {"m": jnp.full((8,), 3.0), "v": jnp.full((8,), 4.0)},
            "params": {"w": jnp.arange(8.0)},
            "step": jnp.int32(9)}
    mgr.save(9, full)
    out = mgr.restore(9, {"params": {"w": jnp.zeros((8,))}})
    np.testing.assert_array_equal(out["params"]["w"], jnp.arange(8.0))


def test_subtree_restore_missing_path_raises(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, {"a": jnp.zeros((2,))})
    with pytest.raises(CheckpointError, match="nope"):
        mgr.restore(1, {"nope": jnp.zeros((2,))})


def test_pre_v2_manifest_positional_fallback(tmp_path):
    """Checkpoints written before checksum manifests (no paths/checksums)
    still restore positionally."""
    mgr = CheckpointManager(str(tmp_path))
    tree = {"a": jnp.arange(4.0), "b": jnp.ones((2,))}
    mgr.save(1, tree)
    mpath = tmp_path / "step_00000001" / "manifest.json"
    with open(mpath) as f:
        manifest = json.load(f)
    for k in ("format_version", "paths", "checksums"):
        manifest.pop(k, None)
    with open(mpath, "w") as f:
        json.dump(manifest, f)
    out = mgr.restore(1, jax.tree.map(jnp.zeros_like, tree))
    np.testing.assert_array_equal(out["a"], tree["a"])
    np.testing.assert_array_equal(out["b"], tree["b"])


# -- serving degradation ----------------------------------------------------

class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def test_bounded_queue_rejects_when_full():
    s = ContinuousScheduler(max_batch=2, max_len=64, max_queue=3)
    for _ in range(3):
        s.submit(np.arange(4), 4)
    with pytest.raises(QueueFullError):
        s.submit(np.arange(4), 4)
    assert s.metrics.snapshot()["rejected"] == 1
    s.admit()                                 # drains 2 into slots
    s.submit(np.arange(4), 4)                 # room again


def test_deadline_evicts_waiting_and_active():
    clk = FakeClock()
    s = ContinuousScheduler(max_batch=1, max_len=64, clock=clk)
    active = s.submit(np.arange(4), 8, deadline_s=5.0)
    waiting = s.submit(np.arange(4), 8, deadline_s=5.0)
    safe = s.submit(np.arange(4), 8)          # no deadline
    s.admit()                                 # first request takes the slot
    clk.t = 4.0
    assert s.expire() == []
    clk.t = 6.0
    evicted = s.expire()
    assert sorted(r.uid for r in evicted) == sorted([active, waiting])
    assert all(r.finish_reason == "deadline" for r in evicted)
    assert s.free_slots() == [0]              # slot freed for `safe`
    assert [r.uid for r in s.waiting] == [safe]
    assert active in s.finished and waiting in s.finished


def test_deadline_keeps_partial_tokens():
    clk = FakeClock()
    s = ContinuousScheduler(max_batch=1, max_len=64, clock=clk)
    uid = s.submit(np.arange(4), 8, deadline_s=1.0)
    (b,) = s.admit()
    s.record_prefill(b, np.array([7]))        # one token generated
    clk.t = 2.0
    (r,) = s.expire()
    assert r.uid == uid and r.tokens == [7]


def test_finished_timeout_prunes_uncollected_results():
    clk = FakeClock()
    s = ContinuousScheduler(max_batch=2, max_len=64, finished_timeout=10.0,
                            clock=clk)
    uid = s.submit(np.arange(4), 1)
    (b,) = s.admit()
    s.record_prefill(b, np.array([5]))        # finishes (length budget 1)
    assert uid in s.finished
    clk.t = 5.0
    s.expire()
    assert uid in s.finished                  # within timeout
    clk.t = 11.0
    s.expire()
    assert uid not in s.finished
    assert s.metrics.snapshot()["finished_expired"] == 1


# -- chaos injectors --------------------------------------------------------

def test_interrupt_data_raises_signal_at_exact_step():
    import signal
    d = chaos.InterruptData(_FakeData(), at_step=3, signum=signal.SIGUSR1)
    hits = []
    old = signal.signal(signal.SIGUSR1, lambda *_: hits.append(1))
    try:
        d.batch(2)
        assert hits == []
        d.batch(3)
        assert hits == [1]
    finally:
        signal.signal(signal.SIGUSR1, old)


class _FakeData:
    def batch(self, step):
        return {"step": step}

    def microbatched(self, step, a):
        return {"step": step, "a": a}


def test_data_wrapper_delegates():
    d = chaos.StragglerData(_FakeData(), at_step=99, sleep_s=0.0)
    assert d.batch(0) == {"step": 0}
    assert d.microbatched(1, 2) == {"step": 1, "a": 2}
