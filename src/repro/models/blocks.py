"""Transformer-layer bodies: mixers (softmax/linear/mamba2/hymba/cross) and
layer glue, with train/prefill (full-sequence) and decode (single-token +
cache) entry points.

Interface per mixer ``<kind>``:
  ``<kind>_init(key, cfg, spec) -> params``
  ``<kind>_apply(params, x, ctx) -> y``                  (full sequence)
  ``<kind>_decode(params, x, cache, ctx) -> (y, cache)`` (one token)
  ``<kind>_cache(cfg, spec, batch, max_len) -> cache``

``ctx`` is a :class:`Ctx` carrying the plan (sharding / SP), config,
positions, and modality inputs. All mixers consume/produce ``(B, S, d)``.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.compat import shard_map as _shard_map

from repro.configs.base import LayerSpec, MambaConfig, ModelConfig
from repro.core import linear_attention as la_core
from repro.core.lasp2 import lasp2
from repro.core.lasp2h import (allgather_context_attention,
                               ring_decode_attention,
                               sharded_decode_attention)
from repro.kernels import ops
from repro.models.layers import dense_init, mlp_apply, mlp_init, normal, \
    rmsnorm, rmsnorm_init, rope
from repro.sharding.rules import Parallelism


@dataclass
class Ctx:
    cfg: ModelConfig
    plan: Parallelism
    positions: Any = None          # (S,) or (B, S) global positions
    img_emb: Any = None            # (B, n_img, d) stub patch embeddings
    enc_out: Any = None            # (B, n_frames, d) encoder output
    is_global: Any = None          # hymba per-layer flag (traced scalar)
    causal: bool = True
    decode_pos: Any = None         # scalar position during decode
    resets: Any = None             # (B, S) document-start flags (packing)


def _heads_split(x, n_heads, head_dim):
    b, s, _ = x.shape
    return x.reshape(b, s, n_heads, head_dim).transpose(0, 2, 1, 3)


def _heads_merge(x):
    b, h, s, dh = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b, s, h * dh)


# ===========================================================================
# Softmax (GQA) attention mixer
# ===========================================================================

def softmax_init(key, cfg: ModelConfig, spec: LayerSpec):
    d, dh = cfg.d_model, cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {"wq": dense_init(ks[0], d, cfg.n_heads * dh),
         "wk": dense_init(ks[1], d, cfg.n_kv_heads * dh),
         "wv": dense_init(ks[2], d, cfg.n_kv_heads * dh),
         "wo": dense_init(ks[3], cfg.n_heads * dh, d)}
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.n_heads * dh,), jnp.float32)
        p["bk"] = jnp.zeros((cfg.n_kv_heads * dh,), jnp.float32)
        p["bv"] = jnp.zeros((cfg.n_kv_heads * dh,), jnp.float32)
    return p


def _qkv(p, x, cfg, positions=None):
    dt = x.dtype
    q = x @ p["wq"].astype(dt)
    k = x @ p["wk"].astype(dt)
    v = x @ p["wv"].astype(dt)
    if "bq" in p:
        q = q + p["bq"].astype(dt)
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    q = _heads_split(q, cfg.n_heads, cfg.head_dim)
    k = _heads_split(k, cfg.n_kv_heads, cfg.head_dim)
    v = _heads_split(v, cfg.n_kv_heads, cfg.head_dim)
    if positions is not None:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    return q, k, v


def softmax_apply(params, x, ctx: Ctx, *, window=None, kv_override=None):
    cfg, plan = ctx.cfg, ctx.plan
    q, k, v = _qkv(params, x, cfg, ctx.positions)
    if kv_override is not None:
        k, v = kv_override
    q = plan.act(q, "batch", "heads", "seq", None)
    sp = plan.sp_for(q.shape[-2])
    s_len = q.shape[-2]
    banded_ok = (plan.banded_windows and isinstance(window, int)
                 and ctx.causal and s_len % window == 0
                 and not (sp is not None and sp.manual)
                 and (sp is None or (s_len // sp.degree) % window == 0))
    if banded_ok:
        # §Perf: banded sliding-window attention — O(S·2w) scores instead
        # of O(S²). Under SP the chunked form shifts only the O(w·d) halo
        # across shards; see banded_attention_chunked for why neither the
        # naive global block shift nor shard_map ppermute is used.
        from repro.core.lasp2h import banded_attention_chunked
        nc = sp.degree if sp is not None else 1
        o = banded_attention_chunked(q, k, v, window, nc)
    elif sp is not None and sp.comm.strategy == "ulysses":
        # LASP-2H × Ulysses: All-to-All head-parallel repartition instead
        # of the K/V gather (docs/communication.md §Ulysses).
        from repro.core.lasp2h import ulysses_context_attention
        o = ulysses_context_attention(
            q, k, v, sp=sp, causal=ctx.causal, sliding_window=window)
    elif sp is not None:
        # LASP-2H: AllGather-based context parallelism (paper Alg. 7).
        o = allgather_context_attention(
            q, k, v, sp=sp, causal=ctx.causal, sliding_window=window)
    else:
        o = ops.flash_attention_op(q, k, v, causal=ctx.causal,
                                   sliding_window=window,
                                   backend=plan.backend)
    o = _heads_merge(o)
    return o @ params["wo"].astype(x.dtype)


def softmax_ring_len(spec: LayerSpec, max_len: int) -> int:
    """Ring-buffer length for a softmax layer's decode KV cache.

    Sliding-window layers (the softmax layers of LASP-2H hybrids) only ever
    attend the last ``window`` tokens, so the cache holds exactly that many
    slots — constant in context length. Full-attention layers need the
    whole history."""
    if spec.sliding_window:
        return min(max_len, spec.sliding_window)
    return max_len


def _decode_positions(ctx: Ctx, batch: int):
    """Per-row decode positions (B,) — scalar positions broadcast (all rows
    at the same offset); vectors pass through (continuous batching)."""
    pos = ctx.decode_pos
    return jnp.broadcast_to(jnp.atleast_1d(pos), (batch,)).astype(jnp.int32)


def softmax_cache(cfg: ModelConfig, spec: LayerSpec, batch, max_len,
                  dtype=jnp.bfloat16, ring=None):
    r = ring if ring is not None else softmax_ring_len(spec, max_len)
    shape = (batch, cfg.n_kv_heads, r, cfg.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype),
            "kpos": jnp.full((batch, r), -1, jnp.int32)}


def softmax_prefill_cache(params, x, ctx: Ctx, max_len, ring=None):
    """Compute K/V for the prompt and place them in a fresh ring cache.

    Ring slot ``i`` receives the prompt token at the highest position
    ``p <= last`` with ``p % ring == i`` (the same ``slot = pos % ring``
    rule decode uses), tagged with its absolute position in ``kpos``.
    Handles per-row position offsets (left-padded length-bucketed prefill):
    padding columns carry negative positions and land as empty slots."""
    cfg = ctx.cfg
    _, k, v = _qkv(params, x, cfg, ctx.positions)
    b, s = x.shape[0], k.shape[2]
    r = ring if ring is not None else softmax_ring_len(ctx._spec, max_len)
    pos2d = jnp.broadcast_to(jnp.atleast_2d(ctx.positions),
                             (b, s)).astype(jnp.int32)
    last = pos2d[:, -1]                                   # (B,)
    i = jnp.arange(r)[None, :]                            # (1, R)
    p_i = last[:, None] - jnp.mod(last[:, None] - i, r)   # (B, R)
    col = jnp.clip(p_i - pos2d[:, :1], 0, s - 1)          # position -> column
    valid = p_i >= 0
    idx = col[:, None, :, None]
    kr = jnp.take_along_axis(k, idx, axis=2)
    vr = jnp.take_along_axis(v, idx, axis=2)
    kpos = jnp.where(valid, p_i, -1)
    kr = ctx.plan.act(kr, "batch", "kv_heads", "cache_seq", None)
    vr = ctx.plan.act(vr, "batch", "kv_heads", "cache_seq", None)
    return {"k": kr.astype(jnp.bfloat16), "v": vr.astype(jnp.bfloat16),
            "kpos": kpos}


def softmax_decode(params, x, cache, ctx: Ctx, *, window=None):
    cfg, plan = ctx.cfg, ctx.plan
    posv = _decode_positions(ctx, x.shape[0])             # (B,)
    q, k, v = _qkv(params, x, cfg, None)
    q = rope(q, posv[:, None], cfg.rope_theta)
    k = rope(k, posv[:, None], cfg.rope_theta)
    r = cache["k"].shape[2]
    hit = jnp.arange(r)[None, :] == jnp.mod(posv, r)[:, None]   # (B, R)
    kc = jnp.where(hit[:, None, :, None], k.astype(cache["k"].dtype),
                   cache["k"])
    vc = jnp.where(hit[:, None, :, None], v.astype(cache["v"].dtype),
                   cache["v"])
    kpos = jnp.where(hit, posv[:, None], cache["kpos"])
    kc = plan.act(kc, "batch", "kv_heads", "cache_seq", None)
    vc = plan.act(vc, "batch", "kv_heads", "cache_seq", None)
    sp = None
    if plan.decode_cache_axis is not None:
        from repro.core.lasp2 import SPConfig
        sp = SPConfig(mesh=plan.mesh, sp_axis=plan.decode_cache_axis)
    o = ring_decode_attention(q, kc, vc, kpos, posv,
                              sliding_window=window, sp=sp)
    o = _heads_merge(o)
    y = o @ params["wo"].astype(x.dtype)
    return y, {"k": kc, "v": vc, "kpos": kpos}


# ===========================================================================
# Linear attention mixer (the paper's module; LASP-2 under SP)
# ===========================================================================

def linear_init(key, cfg: ModelConfig, spec: LayerSpec):
    p = softmax_init(key, cfg, spec)
    if cfg.linear_attn.decay == "data":
        kg = jax.random.fold_in(key, 7)
        p["wdt"] = dense_init(kg, cfg.d_model, cfg.n_heads, scale=0.01)
    return p


def _linear_qkv(params, x, ctx: Ctx):
    cfg = ctx.cfg
    lac = cfg.linear_attn
    q, k, v = _qkv(params, x, cfg,
                   ctx.positions if lac.feature_map != "taylor" else None)
    # GQA → full heads for the linear recurrence (state is per q-head)
    rep = cfg.n_heads // cfg.n_kv_heads
    if rep > 1:
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    q = la_core.feature_map(q, lac.feature_map)
    k = la_core.feature_map(k, lac.feature_map)
    q = q * (q.shape[-1] ** -0.5)
    if lac.decay == "data":
        gate = (x @ params["wdt"].astype(x.dtype)).astype(jnp.float32)
        log_a = jax.nn.log_sigmoid(gate).transpose(0, 2, 1)   # (B,H,S)
    elif lac.decay == "none":
        log_a = None
    else:
        b, _, s, _ = q.shape
        log_a = jnp.broadcast_to(
            la_core.decay_log_a(lac.decay, heads=cfg.n_heads, s=s)[None],
            (b, cfg.n_heads, s))
    if ctx.resets is not None:
        # Document packing (paper §A.4.2): zero the state at doc starts.
        b_, _, s_, _ = q.shape
        base = log_a if log_a is not None \
            else jnp.zeros((b_, cfg.n_heads, s_), jnp.float32)
        log_a = jnp.where(ctx.resets[:, None, :], la_core.RESET_LOG_A, base)
    return q, k, v, log_a


def linear_apply(params, x, ctx: Ctx):
    cfg, plan = ctx.cfg, ctx.plan
    lac = cfg.linear_attn
    q, k, v, log_a = _linear_qkv(params, x, ctx)
    q = plan.act(q, "batch", "heads", "seq", None)
    sp = plan.sp_for(q.shape[-2])
    if sp is not None:
        o = lasp2(q, k, v, log_a, sp=sp, causal=ctx.causal,
                  block_size=lac.block_size,
                  backward="autodiff" if lac.decay == "data"
                  or ctx.resets is not None else lac.backward)
    elif ctx.causal:
        o, _, _ = ops.linear_attention_op(q, k, v, log_a,
                                          block_size=lac.block_size,
                                          backend=plan.backend)
    else:
        o = lasp2(q, k, v, log_a, sp=None, causal=False)
    o = _heads_merge(o.astype(x.dtype))
    return o @ params["wo"].astype(x.dtype)


def linear_cache(cfg: ModelConfig, spec: LayerSpec, batch, max_len):
    lac = cfg.linear_attn
    dk = cfg.head_dim
    if lac.feature_map == "taylor":
        dk = 1 + dk + dk * dk
    # Constant-size memory state — the paper's selling point: no KV cache.
    # The cumulative log decay rides along (fp32 scalar per head): it is
    # what prefill's chunk summaries emit and keeps the recurrent decode a
    # pure continuation of the chunked scan.
    return {"m": jnp.zeros((batch, cfg.n_heads, dk, cfg.head_dim),
                           jnp.float32),
            "log_decay": jnp.zeros((batch, cfg.n_heads), jnp.float32)}


def linear_decode(params, x, cache, ctx: Ctx):
    # ctx.positions carries the decode position → RoPE offset inside _qkv.
    q, k, v, log_a = _linear_qkv(params, x, ctx)   # S == 1
    o, m, ld = ops.linear_decode_op(
        q[..., 0, :], k[..., 0, :], v[..., 0, :],
        log_a[..., 0] if log_a is not None else None,
        cache["m"], cache["log_decay"], backend=ctx.plan.backend)
    o = _heads_merge(o[:, :, None, :].astype(x.dtype))
    y = o @ params["wo"].astype(x.dtype)
    return y, {"m": m, "log_decay": ld}


# ===========================================================================
# Mamba-2 (SSD) mixer — chunked decayed linear attention under the hood
# ===========================================================================

def _mamba_dims(cfg: ModelConfig, spec: LayerSpec):
    mb = cfg.mamba or MambaConfig()
    d_in = (mb.expand * cfg.d_model) if spec.mixer == "mamba2" \
        else cfg.d_model
    nh = d_in // mb.headdim
    return mb, d_in, nh


def mamba2_init(key, cfg: ModelConfig, spec: LayerSpec):
    mb, d_in, nh = _mamba_dims(cfg, spec)
    d = cfg.d_model
    ks = jax.random.split(key, 10)
    gd = mb.ngroups * mb.d_state
    p = {
        "wx": dense_init(ks[0], d, d_in),
        "wz": dense_init(ks[1], d, d_in),
        "wb": dense_init(ks[2], d, gd),
        "wc": dense_init(ks[3], d, gd),
        "wdt": dense_init(ks[4], d, nh, scale=0.01),
        "dt_bias": jnp.log(jnp.expm1(
            jnp.exp(jax.random.uniform(ks[5], (nh,), jnp.float32,
                                       jnp.log(1e-3), jnp.log(1e-1))))),
        "a_log": jnp.log(jnp.arange(1, nh + 1, dtype=jnp.float32)),
        "d_skip": jnp.ones((nh,), jnp.float32),
        "conv_x": normal(ks[6], (mb.d_conv, d_in), 0.2),
        "conv_b": normal(ks[7], (mb.d_conv, gd), 0.2),
        "conv_c": normal(ks[8], (mb.d_conv, gd), 0.2),
        "gnorm": rmsnorm_init(d_in),
        "wo": dense_init(ks[9], d_in, d),
    }
    return p


def _causal_conv(x, w, cache=None):
    """Depthwise causal conv. x: (B, S, C); w: (K, C).

    Returns (y (B,S,C), new_cache (B, K-1, C)) — cache carries the last
    K-1 inputs for streaming decode.
    """
    k = w.shape[0]
    if cache is None:
        pad = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    else:
        pad = cache.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    y = sum(xp[:, i:i + x.shape[1], :] * w[i].astype(x.dtype)
            for i in range(k))
    new_cache = xp[:, -(k - 1):, :] if k > 1 else None
    return jax.nn.silu(y), new_cache


def _mamba_core(p, x, ctx: Ctx, conv_caches=None):
    """Shared full-sequence/decode body. Returns q,k,v,log_a,(xh),caches."""
    cfg = ctx.cfg
    mb, d_in, nh = _mamba_dims(cfg, ctx._spec)
    dt_ = x.dtype
    xs = x @ p["wx"].astype(dt_)
    bs = x @ p["wb"].astype(dt_)
    cs = x @ p["wc"].astype(dt_)
    cc = conv_caches or {"x": None, "b": None, "c": None}
    xs, ccx = _causal_conv(xs, p["conv_x"], cc["x"])
    bs, ccb = _causal_conv(bs, p["conv_b"], cc["b"])
    cs, ccc = _causal_conv(cs, p["conv_c"], cc["c"])
    dt = jax.nn.softplus((x @ p["wdt"].astype(dt_)).astype(jnp.float32)
                         + p["dt_bias"])                     # (B,S,nh)
    log_a = (-jnp.exp(p["a_log"]) * dt).transpose(0, 2, 1)   # (B,nh,S)
    if ctx.resets is not None:
        log_a = jnp.where(ctx.resets[:, None, :], la_core.RESET_LOG_A,
                          log_a)
    xh = _heads_split(xs, nh, mb.headdim)                    # (B,nh,S,hd)
    v = xh * dt.transpose(0, 2, 1)[..., None].astype(dt_)
    rep = nh // mb.ngroups
    k = jnp.repeat(_heads_split(bs, mb.ngroups, mb.d_state), rep, axis=1)
    q = jnp.repeat(_heads_split(cs, mb.ngroups, mb.d_state), rep, axis=1)
    caches = {"x": ccx, "b": ccb, "c": ccc}
    return q, k, v, log_a, xh, caches


def mamba2_apply(params, x, ctx: Ctx):
    cfg, plan = ctx.cfg, ctx.plan
    mb, d_in, nh = _mamba_dims(cfg, ctx._spec)
    q, k, v, log_a, xh, _ = _mamba_core(params, x, ctx)
    q = plan.act(q, "batch", "heads", "seq", None)
    sp = plan.sp_for(q.shape[-2])
    if sp is not None:
        # SSD *is* decayed linear attention — LASP-2 applies exactly.
        y = lasp2(q, k, v, log_a, sp=sp,
                  block_size=cfg.linear_attn.block_size,
                  backward="autodiff")
    else:
        y, _, _ = ops.linear_attention_op(
            q, k, v, log_a, block_size=cfg.linear_attn.block_size,
            backend=plan.backend)
    y = y + params["d_skip"][None, :, None, None].astype(y.dtype) * xh
    y = _heads_merge(y.astype(x.dtype))
    z = x @ params["wz"].astype(x.dtype)
    y = y * jax.nn.silu(z)
    y = rmsnorm(params["gnorm"], y, cfg.norm_eps)
    return y @ params["wo"].astype(x.dtype)


def mamba2_cache(cfg: ModelConfig, spec: LayerSpec, batch, max_len):
    mb, d_in, nh = _mamba_dims(cfg, spec)
    gd = mb.ngroups * mb.d_state
    return {
        "m": jnp.zeros((batch, nh, mb.d_state, mb.headdim), jnp.float32),
        "log_decay": jnp.zeros((batch, nh), jnp.float32),
        "conv_x": jnp.zeros((batch, mb.d_conv - 1, d_in), jnp.bfloat16),
        "conv_b": jnp.zeros((batch, mb.d_conv - 1, gd), jnp.bfloat16),
        "conv_c": jnp.zeros((batch, mb.d_conv - 1, gd), jnp.bfloat16),
    }


def mamba2_decode(params, x, cache, ctx: Ctx):
    cfg = ctx.cfg
    conv_caches = {"x": cache["conv_x"], "b": cache["conv_b"],
                   "c": cache["conv_c"]}
    q, k, v, log_a, xh, cc = _mamba_core(params, x, ctx, conv_caches)
    y, m, ld = ops.linear_decode_op(
        q[..., 0, :], k[..., 0, :], v[..., 0, :], log_a[..., 0],
        cache["m"], cache["log_decay"], backend=ctx.plan.backend)
    y = y[:, :, None, :]
    y = y.astype(x.dtype) + params["d_skip"][None, :, None, None
                                             ].astype(x.dtype) * xh
    y = _heads_merge(y)
    z = x @ params["wz"].astype(x.dtype)
    y = y * jax.nn.silu(z)
    y = rmsnorm(params["gnorm"], y, cfg.norm_eps)
    y = y @ params["wo"].astype(x.dtype)
    new_cache = {"m": m, "log_decay": ld,
                 "conv_x": cc["x"].astype(jnp.bfloat16),
                 "conv_b": cc["b"].astype(jnp.bfloat16),
                 "conv_c": cc["c"].astype(jnp.bfloat16)}
    return y, new_cache


# ===========================================================================
# Hymba: parallel softmax-attention + SSM heads in one mixer
# ===========================================================================

def hymba_init(key, cfg: ModelConfig, spec: LayerSpec):
    k1, k2 = jax.random.split(key)
    return {"attn": softmax_init(k1, cfg, spec),
            "ssm": mamba2_init(k2, cfg, spec)}


def hymba_window(spec: LayerSpec, ctx: Ctx):
    """Static window when the pattern position is statically marked
    (enables the banded §Perf path); traced fallback when per-group
    flags are in play (single-position dynamic patterns)."""
    win = spec.sliding_window or 2048
    if ctx.is_global is not None:                 # dynamic mode
        return jnp.where(ctx.is_global, 1 << 30, win)
    return None if spec.is_global else win        # static mode


def hymba_apply(params, x, ctx: Ctx):
    window = hymba_window(ctx._spec, ctx)
    a = softmax_apply(params["attn"], x, ctx, window=window)
    s = mamba2_apply(params["ssm"], x, ctx)
    return 0.5 * (a + s)


def hymba_cache(cfg: ModelConfig, spec: LayerSpec, batch, max_len):
    # hymba's global/local switch can be a per-group traced flag (dynamic
    # single-position patterns), so the ring must cover the full length;
    # statically-local layers still get the windowed ring via the mask.
    return {"attn": softmax_cache(cfg, spec, batch, max_len, ring=max_len),
            "ssm": mamba2_cache(cfg, spec, batch, max_len)}


def hymba_decode(params, x, cache, ctx: Ctx):
    window = hymba_window(ctx._spec, ctx)
    a, ca = softmax_decode(params["attn"], x, cache["attn"], ctx,
                           window=window)
    s, cs = mamba2_decode(params["ssm"], x, cache["ssm"], ctx)
    return 0.5 * (a + s), {"attn": ca, "ssm": cs}


# ===========================================================================
# Cross-attention mixer (VLM image layers, Whisper decoder cross)
# ===========================================================================

def cross_init(key, cfg: ModelConfig, spec: LayerSpec):
    p = softmax_init(key, cfg, spec)
    p["gate"] = jnp.zeros((), jnp.float32)
    return p


def _cross_kv(params, memory, cfg):
    dt = memory.dtype
    k = _heads_split(memory @ params["wk"].astype(dt), cfg.n_kv_heads,
                     cfg.head_dim)
    v = _heads_split(memory @ params["wv"].astype(dt), cfg.n_kv_heads,
                     cfg.head_dim)
    return k, v


def cross_apply(params, x, ctx: Ctx):
    cfg, plan = ctx.cfg, ctx.plan
    memory = ctx.img_emb if ctx.img_emb is not None else ctx.enc_out
    dt = x.dtype
    q = _heads_split(x @ params["wq"].astype(dt), cfg.n_heads, cfg.head_dim)
    k, v = _cross_kv(params, memory.astype(dt), cfg)
    # memory is replicated across the SP group; each device attends its own
    # query chunk locally — no sequence communication needed.
    o = ops.flash_attention_op(q, k, v, causal=False, backend=plan.backend)
    o = _heads_merge(o)
    y = o @ params["wo"].astype(dt)
    return jnp.tanh(params["gate"]).astype(dt) * y


def cross_cache(cfg: ModelConfig, spec: LayerSpec, batch, max_len):
    n_mem = cfg.n_image_tokens or (cfg.encoder.n_frames if cfg.encoder else 0)
    shape = (batch, cfg.n_kv_heads, max(n_mem, 1), cfg.head_dim)
    return {"k": jnp.zeros(shape, jnp.bfloat16),
            "v": jnp.zeros(shape, jnp.bfloat16)}


def cross_prefill_cache(params, memory, cfg):
    k, v = _cross_kv(params, memory, cfg)
    return {"k": k.astype(jnp.bfloat16), "v": v.astype(jnp.bfloat16)}


def cross_decode(params, x, cache, ctx: Ctx):
    cfg = ctx.cfg
    dt = x.dtype
    q = _heads_split(x @ params["wq"].astype(dt), cfg.n_heads, cfg.head_dim)
    o = sharded_decode_attention(q, cache["k"], cache["v"],
                                 cache["k"].shape[2], sp=None)
    o = _heads_merge(o.astype(dt))
    y = o @ params["wo"].astype(dt)
    return jnp.tanh(params["gate"]).astype(dt) * y, cache


# ===========================================================================
# MoE MLP (token-choice top-k with capacity; EP over the "model" axis)
# ===========================================================================

def moe_init(key, cfg: ModelConfig):
    moe = cfg.moe
    d, ff, e = cfg.d_model, cfg.d_ff, moe.num_experts
    ks = jax.random.split(key, 5)
    p = {"router": dense_init(ks[0], d, e, scale=0.02),
         "experts": {
             "w1": normal(ks[1], (e, d, ff), d ** -0.5),
             "w3": normal(ks[2], (e, d, ff), d ** -0.5),
             "w2": normal(ks[3], (e, ff, d), ff ** -0.5)}}
    if moe.n_shared_experts:
        p["shared"] = mlp_init(ks[4], d, ff * moe.n_shared_experts)
    return p


def _token_manual_axes(plan: Parallelism):
    """Mesh axes that shard the token (batch/seq) dims of activations."""
    axes = []
    for rule in (plan.rules.get("batch"), plan.rules.get("seq")):
        if rule is None:
            continue
        axes.extend(rule if isinstance(rule, (tuple, list)) else [rule])
    return tuple(dict.fromkeys(axes))


def moe_apply(params, x, ctx: Ctx):
    """Capacity-based token-choice routing (drop on overflow).

    §Perf (hillclimb #2): when the token dims are sharded and the expert
    weights are not FSDP-split, the dispatch runs inside a shard_map over
    the token axes — routing/scatter/combine are shard-LOCAL and only the
    expert computation crosses shards (auto-sharded over "model"). The
    naive global scatter instead makes GSPMD all-reduce the full
    (E·cap, d) buffer across data shards — measured 4.4 TB/step on
    moonshot×prefill_32k. Per-shard capacity semantics (standard practice).
    """
    cfg, plan = ctx.cfg, ctx.plan
    manual = _token_manual_axes(plan)
    if manual and plan.mesh is not None and plan.fsdp_axis is None:
        from repro.sharding.rules import fit_spec
        xspec = fit_spec(plan.mesh, x.shape,
                         P(plan.rules.get("batch"), plan.rules.get("seq"),
                           None))
        manual = _token_manual_axes(
            type(plan)(mesh=plan.mesh,
                       rules={"batch": xspec[0], "seq": xspec[1]}))
    if manual and plan.mesh is not None and plan.fsdp_axis is None:
        import copy
        import dataclasses as _dc
        pspec = jax.tree.map(lambda _: P(), params)
        # inside the shard_map only auto (non-manual) axes may appear in
        # sharding constraints — strip manual axes from the local rules
        def _strip(rule):
            if rule is None:
                return None
            axes = rule if isinstance(rule, (tuple, list)) else (rule,)
            kept = tuple(a for a in axes if a not in manual)
            return kept[0] if len(kept) == 1 else (kept or None)
        local_plan_ = _dc.replace(
            plan, rules={k: _strip(v) for k, v in plan.rules.items()})
        local_ctx = copy.copy(ctx)
        local_ctx.plan = local_plan_

        def body(params_, x_):
            y, aux = _moe_dispatch(params_, x_, local_ctx)
            return y, jax.lax.pmean(aux, manual)

        y, aux = _shard_map(
            body, mesh=plan.mesh, in_specs=(pspec, xspec),
            out_specs=(xspec, P()), axis_names=set(manual),
            check_vma=False)(params, x)
        return y, aux
    return _moe_dispatch(params, x, ctx)


def _moe_dispatch(params, x, ctx: Ctx):
    cfg, plan = ctx.cfg, ctx.plan
    moe = cfg.moe
    b, s, d = x.shape
    t = b * s
    e, k = moe.num_experts, moe.top_k
    cap = int(moe.capacity_factor * t * k / e)
    cap = max(cap, k)

    xf = x.reshape(t, d)
    logits = (xf @ params["router"].astype(x.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, k)                  # (t, k)
    gate = gate / jnp.maximum(jnp.sum(gate, -1, keepdims=True), 1e-9)

    flat_e = idx.reshape(-1)                             # (t*k,)
    onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)  # (t*k, e)
    pos = jnp.cumsum(onehot, axis=0) - onehot            # slot per item
    slot = jnp.sum(pos * onehot, axis=-1)                # (t*k,)
    keep = slot < cap
    dest = jnp.where(keep, flat_e * cap + slot, e * cap)

    items = jnp.repeat(xf, k, axis=0)                    # (t*k, d)
    buf = jnp.zeros((e * cap + 1, d), x.dtype).at[dest].add(items)
    buf = buf[:e * cap].reshape(e, cap, d)
    buf = plan.act(buf, "experts", None, None)

    dt_ = x.dtype
    h = jnp.einsum("ecd,edf->ecf", buf, params["experts"]["w1"].astype(dt_))
    g = jnp.einsum("ecd,edf->ecf", buf, params["experts"]["w3"].astype(dt_))
    h = jax.nn.silu(h) * g
    out = jnp.einsum("ecf,efd->ecd", h, params["experts"]["w2"].astype(dt_))
    out = plan.act(out, "experts", None, None)

    out_flat = jnp.concatenate(
        [out.reshape(e * cap, d), jnp.zeros((1, d), x.dtype)], axis=0)
    y = out_flat[dest] * (gate.reshape(-1, 1).astype(x.dtype)
                          * keep[:, None].astype(x.dtype))
    y = y.reshape(t, k, d).sum(axis=1)

    # router z-loss / load-balance aux (stashed for the train loop)
    me = jnp.mean(jax.nn.one_hot(idx, e, dtype=jnp.float32), axis=(0, 1))
    ce = jnp.mean(probs, axis=0)
    aux = e * jnp.sum(me * ce) \
        + moe.router_z_coef * jnp.mean(
            jax.nn.logsumexp(logits, axis=-1) ** 2)
    y = y.reshape(b, s, d)
    if "shared" in params:
        y = y + mlp_apply(params["shared"], x, plan)
    return y, aux


# ===========================================================================
# Layer glue
# ===========================================================================

def layer_init(key, cfg: ModelConfig, spec: LayerSpec):
    ks = jax.random.split(key, 4)
    mix_init = {"softmax": softmax_init, "linear": linear_init,
                "mamba2": mamba2_init, "hymba": hymba_init,
                "cross": cross_init}[spec.mixer]
    p = {"ln1": rmsnorm_init(cfg.d_model),
         "mixer": mix_init(ks[0], cfg, spec)}
    if spec.mlp == "dense":
        p["ln2"] = rmsnorm_init(cfg.d_model)
        p["mlp"] = mlp_init(ks[1], cfg.d_model, cfg.d_ff,
                            act=getattr(cfg, "mlp_act", "swiglu"))
    elif spec.mlp == "moe":
        p["ln2"] = rmsnorm_init(cfg.d_model)
        p["mlp"] = moe_init(ks[1], cfg)
    return p


def layer_apply(params, x, ctx: Ctx, spec: LayerSpec):
    ctx._spec = spec
    mix_apply = {"softmax": softmax_apply, "linear": linear_apply,
                 "mamba2": mamba2_apply, "hymba": hymba_apply,
                 "cross": cross_apply}[spec.mixer]
    h = rmsnorm(params["ln1"], x, ctx.cfg.norm_eps)
    if spec.mixer == "softmax":
        y = mix_apply(params["mixer"], h, ctx, window=spec.sliding_window)
    else:
        y = mix_apply(params["mixer"], h, ctx)
    x = x + y
    aux = 0.0
    if "mlp" in params:
        h = rmsnorm(params["ln2"], x, ctx.cfg.norm_eps)
        if spec.mlp == "moe":
            y, aux = moe_apply(params["mlp"], h, ctx)
        else:
            y = mlp_apply(params["mlp"], h, ctx.plan,
                          act=getattr(ctx.cfg, "mlp_act", "swiglu"))
        x = x + y
    x = ctx.plan.act(x, "batch", "residual_seq", None)
    return x, aux


def layer_cache(cfg: ModelConfig, spec: LayerSpec, batch, max_len):
    mk = {"softmax": softmax_cache, "linear": linear_cache,
          "mamba2": mamba2_cache, "hymba": hymba_cache,
          "cross": cross_cache}[spec.mixer]
    return {"mixer": mk(cfg, spec, batch, max_len)}


def _softmax_prefill(params, x, ctx: Ctx, spec: LayerSpec, max_len):
    y = softmax_apply(params, x, ctx, window=spec.sliding_window)
    cache = softmax_prefill_cache(params, x, ctx, max_len,
                                  ring=softmax_ring_len(spec, max_len))
    return y, cache


def _linear_prefill(params, x, ctx: Ctx, spec: LayerSpec, max_len):
    from repro.core.lasp2 import lasp2_with_state
    cfg, plan = ctx.cfg, ctx.plan
    q, k, v, log_a = _linear_qkv(params, x, ctx)
    b, h = q.shape[0], q.shape[1]
    sp = plan.sp_for(q.shape[-2])
    if sp is not None:
        o, m = lasp2_with_state(q, k, v, log_a, sp=sp,
                                block_size=cfg.linear_attn.block_size)
    else:
        o, m, _ = ops.linear_attention_op(
            q, k, v, log_a, block_size=cfg.linear_attn.block_size,
            backend=plan.backend)
    y = _heads_merge(o.astype(x.dtype)) @ params["wo"].astype(x.dtype)
    ld = (jnp.sum(log_a.astype(jnp.float32), axis=-1) if log_a is not None
          else jnp.zeros((b, h), jnp.float32))
    return y, {"m": m, "log_decay": ld}


def _mamba2_prefill(params, x, ctx: Ctx, spec: LayerSpec, max_len):
    from repro.core.lasp2 import lasp2_with_state
    cfg, plan = ctx.cfg, ctx.plan
    q, k, v, log_a, xh, cc = _mamba_core(params, x, ctx)
    sp = plan.sp_for(q.shape[-2])
    if sp is not None:
        y, m = lasp2_with_state(q, k, v, log_a, sp=sp,
                                block_size=cfg.linear_attn.block_size)
    else:
        y, m, _ = ops.linear_attention_op(
            q, k, v, log_a, block_size=cfg.linear_attn.block_size,
            backend=plan.backend)
    y = y + params["d_skip"][None, :, None, None].astype(y.dtype) * xh
    y = _heads_merge(y.astype(x.dtype))
    z = x @ params["wz"].astype(x.dtype)
    y = rmsnorm(params["gnorm"], y * jax.nn.silu(z), cfg.norm_eps)
    y = y @ params["wo"].astype(x.dtype)
    cache = {"m": m,
             "log_decay": jnp.sum(log_a.astype(jnp.float32), axis=-1),
             "conv_x": cc["x"].astype(jnp.bfloat16),
             "conv_b": cc["b"].astype(jnp.bfloat16),
             "conv_c": cc["c"].astype(jnp.bfloat16)}
    return y, cache


def _hymba_prefill(params, x, ctx: Ctx, spec: LayerSpec, max_len):
    window = hymba_window(spec, ctx)
    a = softmax_apply(params["attn"], x, ctx, window=window)
    ca = softmax_prefill_cache(params["attn"], x, ctx, max_len,
                               ring=max_len)
    s, cs = _mamba2_prefill(params["ssm"], x, ctx, spec, max_len)
    return 0.5 * (a + s), {"attn": ca, "ssm": cs}


def _cross_prefill(params, x, ctx: Ctx, spec: LayerSpec, max_len):
    y = cross_apply(params, x, ctx)
    memory = ctx.img_emb if ctx.img_emb is not None else ctx.enc_out
    cache = cross_prefill_cache(params, memory.astype(x.dtype), ctx.cfg)
    return y, cache


def layer_prefill(params, x, ctx: Ctx, spec: LayerSpec, max_len):
    ctx._spec = spec
    mix_pre = {"softmax": _softmax_prefill, "linear": _linear_prefill,
               "mamba2": _mamba2_prefill, "hymba": _hymba_prefill,
               "cross": _cross_prefill}[spec.mixer]
    h = rmsnorm(params["ln1"], x, ctx.cfg.norm_eps)
    y, mc = mix_pre(params["mixer"], h, ctx, spec, max_len)
    x = x + y
    if "mlp" in params:
        h = rmsnorm(params["ln2"], x, ctx.cfg.norm_eps)
        if spec.mlp == "moe":
            y, _ = moe_apply(params["mlp"], h, ctx)
        else:
            y = mlp_apply(params["mlp"], h, ctx.plan,
                          act=getattr(ctx.cfg, "mlp_act", "swiglu"))
        x = x + y
    x = ctx.plan.act(x, "batch", "residual_seq", None)
    return x, {"mixer": mc}


def layer_decode(params, x, cache, ctx: Ctx, spec: LayerSpec):
    ctx._spec = spec
    mix_dec = {"softmax": softmax_decode, "linear": linear_decode,
               "mamba2": mamba2_decode, "hymba": hymba_decode,
               "cross": cross_decode}[spec.mixer]
    h = rmsnorm(params["ln1"], x, ctx.cfg.norm_eps)
    if spec.mixer == "softmax":
        y, mc = mix_dec(params["mixer"], h, cache["mixer"], ctx,
                        window=spec.sliding_window)
    else:
        y, mc = mix_dec(params["mixer"], h, cache["mixer"], ctx)
    x = x + y
    if "mlp" in params:
        h = rmsnorm(params["ln2"], x, ctx.cfg.norm_eps)
        if spec.mlp == "moe":
            y, _ = moe_apply(params["mlp"], h, ctx)
        else:
            y = mlp_apply(params["mlp"], h, ctx.plan,
                          act=getattr(ctx.cfg, "mlp_act", "swiglu"))
        x = x + y
    return x, {"mixer": mc}
