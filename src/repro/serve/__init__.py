from repro.serve.engine import ServeEngine  # noqa: F401
from repro.serve.scheduler import (ContinuousScheduler,  # noqa: F401
                                   PrefillBatch, QueueFullError, Request)
