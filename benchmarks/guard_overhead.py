"""Guard-overhead A/B: the manual (2, 4) DP×SP train step with the
in-graph numerical health guard ON vs OFF.

The guard's design claim (docs/resilience.md) is that it is free: the
health scalar rides the existing packed gradient all-reduce, so the
collective count is UNCHANGED (asserted by ``assert_axis_budget`` in
tests/distributed_checks.py), and gradient non-finiteness is detected on
the already-computed post-reduce gnorm — no extra pass over the raveled
gradients. This bench pins the compute side of that claim two ways:

* **deterministic** — XLA ``cost_analysis`` flops and bytes-accessed of
  the two compiled steps. These are exactly reproducible, and the
  committed baseline's ``gate_ceilings`` pin the guard's overhead on
  both at 2% (``scripts/bench_gate.py`` fails any PR that grows the
  guarded program past that). The measured overhead is ~0.001% — a NaN
  check that costs a full isfinite sweep over the gradient vector shows
  up here as ~5% bytes and trips the gate.
* **indicative** — paired wall-clock medians (plain and guard sampled
  back-to-back so host-load drift lands on both sides of each pair).
  On this 1-core CPU container the run-to-run wall noise is far above
  the 2% bound, so ``guard_overhead_pct`` is reported but only the
  per-variant ``median_us`` rows gate (baseline-relative, at CI's wide
  ``--wall-tol``); the hard 2% ceiling rides on the deterministic
  compiled-cost metrics above.
"""

from __future__ import annotations

from benchmarks.common import emit, run_subprocess_bench, write_bench_json

BENCH_NAME = "guard"

_CODE = r"""
import json, time
import jax
from repro.configs import get_smoke
from repro.configs.base import RunConfig
from repro.data.pipeline import SyntheticLM
from repro.launch.mesh import make_training_mesh
from repro.sharding.rules import make_plan
from repro.train.step import init_state, make_train_step
from benchmarks.common import percentile

cfg = get_smoke("linear-llama3-1b")
data = SyntheticLM(cfg.vocab_size, 64, 8, seed=3)
mesh = make_training_mesh(2, 4)
batch = data.microbatched(0, 1)

def build(guard):
    run = RunConfig(num_microbatches=1, remat="none", total_steps=200,
                    warmup_steps=2, scan_unroll=True, guard=guard)
    plan = make_plan(mesh, "train", global_batch=8,
                     n_kv_heads=cfg.n_kv_heads, n_heads=cfg.n_heads,
                     comm=run.comm_spec(), zero1=run.zero1)
    state = init_state(jax.random.PRNGKey(0), cfg, run, plan)
    compiled = jax.jit(make_train_step(cfg, run, plan),
                       donate_argnums=(0,)).lower(state, batch).compile()
    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    cost = {"flops": float(ca.get("flops", 0.0)),
            "cost_bytes": float(ca.get("bytes accessed", 0.0))}
    for _ in range(3):   # warmup (compile already done)
        state, m = compiled(state, batch)
    jax.block_until_ready(m)
    return [compiled, state, cost]

PAIRS, CALLS = 30, 2
variants = {"plain": build(False), "guard": build(True)}
times = {k: [] for k in variants}

def sample(v):
    step, state = v[0], v[1]
    t0 = time.perf_counter()
    for _ in range(CALLS):
        state, m = step(state, batch)
    jax.block_until_ready(m)
    v[1] = state
    return (time.perf_counter() - t0) / CALLS * 1e6

# Paired A/B: plain and guard are sampled back-to-back so host-load
# drift (this is a 1-core container time-slicing 8 virtual devices)
# lands on both sides of each pair; the wall statistic is the median of
# per-pair ratios, which a slow patch of wall-clock shifts far less
# than a difference of independent medians.
ratios = []
for _ in range(PAIRS):
    p = sample(variants["plain"])
    g = sample(variants["guard"])
    times["plain"].append(p)
    times["guard"].append(g)
    ratios.append(g / p - 1.0)

cost = {k: v[2] for k, v in variants.items()}
def pct(key):
    return (cost["guard"][key] / cost["plain"][key] - 1.0) * 100.0

payload = {
    "mesh": "2x4",
    "rows": [
        {"name": f"train_step_2x4_{k}", "median_us": percentile(ts, 50),
         "p90_us": percentile(ts, 90), "iters": len(ts) * CALLS,
         **cost[k]}
        for k, ts in times.items()],
    "guard_overhead_pct": percentile(ratios, 50) * 100.0,
    "guard_flops_overhead_pct": pct("flops"),
    "guard_cost_bytes_overhead_pct": pct("cost_bytes"),
    "gate_ceilings": {"guard_flops_overhead_pct": 2.0,
                      "guard_cost_bytes_overhead_pct": 2.0},
}
print(json.dumps(payload))
"""


def main():
    payload = run_subprocess_bench(_CODE, devices=8)
    med = {r["name"]: r["median_us"] for r in payload["rows"]}
    emit([(name, us, "") for name, us in med.items()])
    emit([("guard_overhead_wall", 0.0,
           f"{payload['guard_overhead_pct']:+.2f}% (indicative)"),
          ("guard_overhead_flops", 0.0,
           f"{payload['guard_flops_overhead_pct']:+.4f}%"),
          ("guard_overhead_bytes", 0.0,
           f"{payload['guard_cost_bytes_overhead_pct']:+.4f}%")])
    return payload


if __name__ == "__main__":
    write_bench_json(BENCH_NAME, main())
