"""Finding records shared by both analysis layers.

A :class:`Finding` is one violation — from the AST lint (``JL*``/``PAL*``
codes, anchored to a source line) or from the compiled-program sanitizer
(``SAN*`` codes, anchored to a lowered/compiled program). Both layers emit
the same machine-readable shape so the CI ``analysis`` job can upload one
JSON artifact and ``scripts/report.py`` can render either kind.
"""

from __future__ import annotations

import json
from dataclasses import asdict, dataclass, field
from typing import Dict, List


@dataclass
class Finding:
    """One violation.

    ``path`` is the offending file (AST rules) or a program label like
    ``train_step[dp=2,sp=4]`` (sanitizer). ``line`` is 1-based; 0 means
    "whole program". ``source`` carries the offending source line or HLO
    snippet for the report.
    """

    code: str
    path: str
    line: int
    message: str
    col: int = 0
    source: str = ""

    def to_dict(self) -> dict:
        return asdict(self)

    def __str__(self) -> str:
        loc = f"{self.path}:{self.line}" if self.line else self.path
        return f"{loc}: {self.code} {self.message}"


@dataclass
class AnalysisResult:
    """Everything one analysis run produced: surviving findings, what was
    suppressed (and by which mechanism), and what was checked — the JSON
    document the CI job archives."""

    findings: List[Finding] = field(default_factory=list)
    suppressed: List[Finding] = field(default_factory=list)
    checked: Dict[str, int] = field(default_factory=dict)

    @property
    def ok(self) -> bool:
        return not self.findings

    def counts(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for f in self.findings:
            out[f.code] = out.get(f.code, 0) + 1
        return out

    def extend(self, other: "AnalysisResult") -> None:
        self.findings.extend(other.findings)
        self.suppressed.extend(other.suppressed)
        for k, v in other.checked.items():
            self.checked[k] = self.checked.get(k, 0) + v

    def to_dict(self) -> dict:
        return {"ok": self.ok,
                "counts": self.counts(),
                "checked": dict(self.checked),
                "findings": [f.to_dict() for f in self.findings],
                "suppressed": [f.to_dict() for f in self.suppressed]}

    def to_json(self, **kw) -> str:
        return json.dumps(self.to_dict(), indent=2, **kw)
