"""Observability layer (docs/observability.md): sinks, histogram math,
phase timers, flight-recorder drift rules, and the instrumented train
loop + report renderer end to end."""

import json
import os
import subprocess
import sys

import numpy as np

from repro.comm.primitives import CommRecord, tape_summary
from repro.obs import (FlightRecorder, Histogram, InMemorySink, JsonlSink,
                       Metrics, NullSink, PhaseTimer, as_sink, read_jsonl,
                       render_step, scoped_timer)

ROOT = os.path.join(os.path.dirname(__file__), "..")
sys.path.insert(0, ROOT)

from benchmarks.common import percentile as bench_percentile  # noqa: E402


# ---------------------------------------------------------------------------
# Histogram / percentile math.
# ---------------------------------------------------------------------------

def test_histogram_exact_quantiles_match_bench_percentile():
    """While under cap, Histogram.percentile is the SAME nearest-rank
    number benchmarks.common.percentile produces — bench JSON and
    telemetry quantiles must agree by construction."""
    rng = np.random.default_rng(0)
    for n in (1, 2, 3, 7, 10, 101):
        xs = list(rng.normal(size=n))
        h = Histogram()
        h.extend(xs)
        assert h.exact
        for p in (0, 25, 50, 90, 99, 100):
            assert h.percentile(p) == bench_percentile(xs, p), (n, p)
        assert h.min == min(xs) and h.max == max(xs)
        assert abs(h.mean - np.mean(xs)) < 1e-12


def test_histogram_small_input_quantiles_exact():
    h = Histogram()
    h.extend([3.0, 1.0, 2.0])
    assert h.percentile(0) == 1.0
    assert h.percentile(50) == 2.0
    assert h.percentile(100) == 3.0
    s = h.summary()
    assert s["count"] == 3 and s["mean"] == 2.0
    assert s["min"] == 1.0 and s["max"] == 3.0 and s["p50"] == 2.0


def test_histogram_empty():
    h = Histogram()
    assert h.percentile(50) is None
    assert h.mean is None
    s = h.summary()
    assert s["count"] == 0 and s["p50"] is None and s["min"] is None


def test_histogram_reservoir_bounded_but_exact_moments():
    h = Histogram(cap=64)
    xs = [float(i) for i in range(10_000)]
    h.extend(xs)
    assert not h.exact
    assert len(h._xs) == 64, "reservoir must stay bounded at cap"
    # count/total/min/max stay exact past the cap
    assert h.count == 10_000
    assert h.total == sum(xs)
    assert h.min == 0.0 and h.max == 9999.0
    # the sampled median is a coarse but sane estimate of the true one
    assert 1000.0 < h.percentile(50) < 9000.0


def test_histogram_reservoir_deterministic():
    a, b = Histogram(cap=32), Histogram(cap=32)
    for i in range(1000):
        a.add(float(i))
        b.add(float(i))
    assert a._xs == b._xs, "LCG reservoir must be run-to-run deterministic"


def test_histogram_merge_per_shard_exact_when_union_fits():
    """Per-shard sinks merge into one histogram: when the union of
    retained samples fits under cap the merged quantiles are exactly the
    pooled-data quantiles."""
    shard_a = [1.0, 5.0, 9.0, 13.0]
    shard_b = [2.0, 4.0, 8.0]
    ha, hb = Histogram(), Histogram()
    ha.extend(shard_a)
    hb.extend(shard_b)
    merged = ha.merge(hb)
    pool = shard_a + shard_b
    assert merged.count == len(pool)
    assert merged.total == sum(pool)
    assert merged.min == min(pool) and merged.max == max(pool)
    for p in (0, 50, 90, 100):
        assert merged.percentile(p) == bench_percentile(pool, p)


def test_histogram_merge_over_cap_stays_bounded():
    ha, hb = Histogram(cap=16), Histogram(cap=16)
    ha.extend(float(i) for i in range(16))
    hb.extend(float(i) for i in range(100, 116))
    merged = ha.merge(hb)
    assert len(merged._xs) <= merged.cap
    assert merged.count == 32
    assert merged.min == 0.0 and merged.max == 115.0


def test_metrics_registry_and_merge():
    m = Metrics()
    m.inc("requests")
    m.inc("requests", 2)
    m.gauge("queue", 3)
    m.gauge("queue", 1)         # latest wins; peak kept separately
    m.observe("lat_s", 0.1)
    m.observe("lat_s", 0.3)
    snap = m.snapshot()
    assert snap["requests"] == 3
    assert snap["queue"] == 1 and snap["queue_peak"] == 3
    assert snap["lat_s_count"] == 2 and snap["lat_s_p50"] == 0.1
    other = Metrics()
    other.inc("requests", 10)
    other.gauge("queue", 7)
    other.observe("lat_s", 0.2)
    merged = m.merge(other).snapshot()
    assert merged["requests"] == 13
    assert merged["queue_peak"] == 7
    assert merged["lat_s_count"] == 3


# ---------------------------------------------------------------------------
# Sinks.
# ---------------------------------------------------------------------------

def test_as_sink_resolution():
    assert isinstance(as_sink(None), NullSink)
    s = InMemorySink()
    assert as_sink(s) is s
    as_sink(None).emit({"kind": "step"})     # NullSink drops silently


def test_jsonl_sink_roundtrip(tmp_path):
    path = str(tmp_path / "m.jsonl")
    with JsonlSink(path) as sink:
        sink.emit({"kind": "step", "step": 0, "loss": 1.5})
        sink.emit({"kind": "step", "step": 1,
                   "loss": np.float32(1.25)})   # numpy scalar → coerced
    recs = read_jsonl(path)
    assert [r["step"] for r in recs] == [0, 1]
    assert recs[1]["loss"] == 1.25
    # lines are sorted-key json — what the CI smoke greps for
    with open(path) as f:
        assert '"kind": "step"' in f.readline()


def test_read_jsonl_tolerates_truncated_tail(tmp_path):
    path = str(tmp_path / "m.jsonl")
    with open(path, "w") as f:
        f.write(json.dumps({"kind": "step", "step": 0}) + "\n")
        f.write("\n")                                  # blank line
        f.write('{"kind": "step", "step"')             # crash mid-write
    recs = read_jsonl(path)
    assert len(recs) == 1 and recs[0]["step"] == 0


# ---------------------------------------------------------------------------
# Phase timing.
# ---------------------------------------------------------------------------

def test_scoped_timer_accumulates():
    out = {}
    clock = iter([0.0, 1.0, 5.0, 7.5]).__next__
    with scoped_timer("step", out, clock=clock):
        pass
    with scoped_timer("step", out, clock=clock):
        pass
    assert out["step"] == 1.0 + 2.5


def test_scoped_timer_fences_device_output():
    import jax.numpy as jnp
    out = {}
    with scoped_timer("step", out) as f:
        y = f.set(jnp.arange(1024) * 2)
    assert out["step"] > 0
    assert int(y[1]) == 2


def test_phase_timer_flush_and_summaries():
    t = PhaseTimer()
    for _ in range(3):
        with t.phase("data"):
            pass
        with t.phase("step"):
            pass
        walls = t.flush()
        assert set(walls) == {"data_s", "step_s"}
        assert t.current == {}, "flush must reset the per-step walls"
    summ = t.summaries()
    assert summ["step_s"]["count"] == 3
    assert summ["data_s"]["count"] == 3


# ---------------------------------------------------------------------------
# Flight recorder: tape vs HLO drift rules, step records, warmup.
# ---------------------------------------------------------------------------

def _tape():
    return [CommRecord("all-gather", 1000, 875, 1, 8, tag="lasp2.states"),
            CommRecord("all-gather", 1000, 875, 1, 8, tag="lasp2.states"),
            CommRecord("all-reduce", 4000, 7000, 1, 8, tag="grads")]


def test_tape_summary_empty():
    s = tape_summary([])
    assert s["total_bytes"] == 0 and s["total_steps"] == 0


def test_flight_recorder_no_drift_when_hlo_covers_tape():
    sink = InMemorySink()
    fr = FlightRecorder(sink)
    # autodiff adds collectives the tape never sees (e.g. the
    # reduce-scatter transpose of a forward gather): NOT drift
    snap = fr.on_compile(
        records=_tape(),
        hlo_counts={"all-gather": 3, "all-reduce": 1, "reduce-scatter": 1},
        hlo_bytes_by_op={"all-gather": 2000.0, "all-reduce": 7000.0,
                         "reduce-scatter": 500.0})
    assert snap.drift == []
    assert snap.expected_bytes_per_step == tape_summary(_tape())["total_bytes"]
    assert snap.tape_counts == {"all-gather": 2, "all-reduce": 1}
    (rec,) = sink.by_kind("compile")
    assert rec["tape/all-gather_count"] == 2
    assert rec["hlo/all-gather_count"] == 3
    assert rec["drift"] == []


def test_flight_recorder_flags_injected_drift():
    sink = InMemorySink()
    fr = FlightRecorder(sink)
    # inject a collective the compiled HLO does not carry
    records = _tape() + [CommRecord("all-to-all", 10, 70, 1, 8)]
    snap = fr.on_compile(
        records=records,
        hlo_counts={"all-gather": 3, "all-reduce": 1},
        hlo_bytes_by_op={"all-gather": 2000.0, "all-reduce": 7000.0})
    assert any("all-to-all" in d for d in snap.drift), snap.drift
    assert fr.drift_events == snap.drift
    (rec,) = sink.by_kind("compile")
    assert rec["drift"], "compile record must carry the drift flags"


def test_flight_recorder_flags_missing_instances():
    fr = FlightRecorder(InMemorySink())
    snap = fr.on_compile(records=_tape(),
                         hlo_counts={"all-gather": 1, "all-reduce": 1})
    assert any("tape promises 2" in d for d in snap.drift), snap.drift


def test_flight_recorder_step_records_and_warmup():
    sink = InMemorySink()
    fr = FlightRecorder(sink, model_flops_per_step=1e9, n_devices=2,
                        peak_flops=1e12, wall_warmup=1)
    fr.on_compile(records=_tape(), hlo_counts={"all-gather": 2,
                                               "all-reduce": 1})
    # first step is the compile spike: never flagged, never in the window
    rec0 = fr.on_step(0, 30.0, tokens=1000)
    assert rec0["straggler"] is False
    assert fr.expected_wall_s() is None, \
        "warmup wall must not enter the rolling window"
    for i in range(1, 13):
        fr.on_step(i, 0.1, tokens=1000)
    assert abs(fr.expected_wall_s() - 0.1) < 1e-9
    rec = fr.on_step(13, 1.0, tokens=1000)
    assert rec["straggler"] is True, \
        "post-warmup 10x spike must trip the rolling-median rule"
    # derived throughput fields on a normal step
    steps = sink.by_kind("step")
    r = steps[5]
    assert r["tokens_per_s"] == 1000 / 0.1
    assert abs(r["mfu"] - (1e9 / 0.1) / (2 * 1e12)) < 1e-12
    assert r["expected_collective_bytes"] == \
        tape_summary(_tape())["total_bytes"]
    assert r["comm_bytes_per_token"] == r["expected_collective_bytes"] / 1000
    summ = fr.summary(final_step=13)
    assert summ["steps_recorded"] == 14
    assert summ["wall_s_count"] == 13      # warmup step excluded


def test_flight_recorder_external_straggler_verdict_wins():
    fr = FlightRecorder(InMemorySink())
    for i in range(12):
        fr.on_step(i, 0.1)
    rec = fr.on_step(12, 0.1, straggler=True)   # external watchdog verdict
    assert rec["straggler"] is True


def test_render_step_one_liner():
    line = render_step({"kind": "step", "step": 7, "loss": 2.5,
                        "wall_s": 0.25, "tokens_per_s": 4096.0,
                        "mfu": 0.41})
    assert "step     7" in line and "loss 2.5000" in line
    assert "250ms" in line and "4096 tok/s" in line and "41.00%" in line


# ---------------------------------------------------------------------------
# Instrumented train loop + report renderer, end to end.
# ---------------------------------------------------------------------------

def test_train_sink_records_and_aot_parity(tmp_path):
    """train(sink=...) emits compile/step/summary records with phase
    walls + throughput, and the AOT-compiled instrumented path produces
    the SAME losses as the uninstrumented jit path."""
    from repro.configs import get_smoke
    from repro.configs.base import RunConfig
    from repro.data.pipeline import SyntheticLM

    from repro.train.loop import train

    cfg = get_smoke("linear-llama3-1b")
    run = RunConfig(num_microbatches=1, total_steps=5, warmup_steps=2,
                    learning_rate=1e-3, remat="none")
    data = SyntheticLM(cfg.vocab_size, 64, 4, seed=0)
    sink = InMemorySink()
    _, hist = train(cfg, run, data, log_every=10 ** 9,
                    log_fn=lambda *_: None, sink=sink)
    _, hist_ref = train(cfg, run, data, log_every=10 ** 9,
                        log_fn=lambda *_: None)
    np.testing.assert_array_equal([h["loss"] for h in hist],
                                  [h["loss"] for h in hist_ref])

    (comp,) = sink.by_kind("compile")
    assert comp["drift"] == [], \
        "single-device program must not flag drift (empty tape)"
    steps = sink.by_kind("step")
    assert len(steps) == 5
    for r in steps:
        assert {"step_s", "data_s", "ckpt_s", "wall_s", "loss",
                "tokens_per_s", "mfu", "straggler",
                "expected_collective_bytes"} <= set(r)
        assert r["tokens"] == 4 * 64
    assert steps[0]["straggler"] is False, "compile step never flagged"
    (summ,) = sink.by_kind("summary")
    assert summ["steps_recorded"] == 5 and summ["final_step"] == 5
    assert summ["phase_step_s_count"] == 5
    events = sink.by_kind("event")
    assert any(e["event"] == "compile" for e in events)


def test_report_renders_jsonl(tmp_path):
    """scripts/report.py turns a sink file into markdown (the CI smoke
    in .github/workflows/ci.yml runs the same pipeline on a real run)."""
    path = str(tmp_path / "metrics.jsonl")
    with JsonlSink(path) as sink:
        fr = FlightRecorder(sink, model_flops_per_step=1e9)
        fr.on_compile(records=_tape(),
                      hlo_counts={"all-gather": 2, "all-reduce": 1},
                      hlo_bytes_by_op={"all-gather": 1750.0,
                                       "all-reduce": 7000.0})
        for i in range(12):
            fr.on_step(i, 0.1 if i else 2.0, tokens=256,
                       phases={"data_s": 0.01, "step_s": 0.09})
        fr.event("resume", step=3)
        fr.summary(final_step=12)
        sink.emit({"kind": "request", "uid": 0, "prompt_len": 16,
                   "new_tokens": 8, "finish_reason": "length",
                   "wall_s": 0.5, "ttft_s": 0.2})
    out = str(tmp_path / "report.md")
    script = os.path.join(ROOT, "scripts", "report.py")
    proc = subprocess.run([sys.executable, script, path, "-o", out],
                          capture_output=True, text=True, timeout=120)
    assert proc.returncode == 0, proc.stderr
    text = open(out).read()
    assert "expected (tape) bytes/step" in text
    assert "all-gather" in text and "no drift" in text
    assert "tokens_per_s" in text and "ttft_s" in text


def test_report_exits_nonzero_on_empty(tmp_path):
    path = str(tmp_path / "empty.jsonl")
    open(path, "w").close()
    script = os.path.join(ROOT, "scripts", "report.py")
    proc = subprocess.run([sys.executable, script, path],
                          capture_output=True, text=True, timeout=60)
    assert proc.returncode == 1
