"""phi3.5-moe-42b-a6.6b — 16 experts top-2
[hf:microsoft/Phi-3.5-MoE-instruct; hf]."""
from repro.configs.base import LayerSpec, MoEConfig, ModelConfig

CONFIG = ModelConfig(
    name="phi3.5-moe-42b-a6.6b", family="moe",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=6400, vocab_size=32064,
    rope_theta=10000.0, norm_eps=1e-5,
    pattern=(LayerSpec(mixer="softmax", mlp="moe"),),
    moe=MoEConfig(num_experts=16, top_k=2, capacity_factor=1.25),
    source="[hf:microsoft/Phi-3.5-MoE-instruct; hf]",
)

SMOKE = ModelConfig(
    name="phi3.5-moe-42b-a6.6b-smoke", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=96,
    vocab_size=512,
    pattern=(LayerSpec(mixer="softmax", mlp="moe"),),
    moe=MoEConfig(num_experts=4, top_k=2, capacity_factor=2.0),
)
