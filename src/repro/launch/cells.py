"""Cell builder: (architecture × input shape × mesh) → jit-able step fn +
abstract inputs + shardings. Shared by the dry-run, the roofline pass and
the scalability benchmark.

A *cell* resolves to one of three step functions:
  train   → ``train_step(state, batch)``  (fwd+bwd+optimizer, grad accum)
  prefill → ``prefill(params, tokens, ...)``
  decode  → ``decode_step(params, token, cache, ...)``

``long_500k`` on a non-sub-quadratic arch automatically switches to the
paper's linearized 1/4-hybrid variant (windowed softmax layers) — the
substitution is recorded in the cell metadata (DESIGN.md §5).
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import get_config
from repro.configs.base import ModelConfig, RunConfig, SHAPES, ShapeConfig
from repro.launch.mesh import MODEL_AXIS, POD_AXIS
from repro.models import model as M
from repro.sharding.rules import (Parallelism, fit_spec, make_plan,
                                  param_specs)
from repro.train.step import init_state, make_train_step

MICROBATCH_TOKEN_TARGET = 4096   # per-device per-microbatch tokens


def choose_microbatches(shape: ShapeConfig, dp_size: int,
                        target: int = MICROBATCH_TOKEN_TARGET) -> int:
    tokens_per_dev = shape.global_batch * shape.seq_len // max(dp_size, 1)
    a = max(1, tokens_per_dev // target)
    a = min(a, shape.global_batch // max(dp_size, 1) or 1)
    while shape.global_batch % a:
        a -= 1
    return max(a, 1)


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(int(x) for x in shape), dtype)


def _named(mesh, spec):
    return NamedSharding(mesh, spec)


def aux_input_specs(cfg: ModelConfig, batch_rows: int, lead=()):
    """Stub-frontend inputs (ShapeDtypeStructs): whisper frames / vlm patches."""
    out = {}
    if cfg.encoder is not None:
        out["frames"] = _sds(lead + (batch_rows, cfg.encoder.n_frames,
                                     cfg.d_model), jnp.bfloat16)
    if cfg.n_image_tokens:
        out["img"] = _sds(lead + (batch_rows, cfg.n_image_tokens,
                                  cfg.d_model), jnp.bfloat16)
    return out


def _batch_sharding_tree(batch_tree, plan: Parallelism, *, lead_micro: bool):
    """Shardings for a batch dict. Dims: ([A], B, S or extra...)."""
    mesh = plan.mesh
    b_ax = plan.rules.get("batch")
    s_ax = plan.rules.get("seq")

    def spec_for(path, leaf):
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        dims = [None] * len(leaf.shape)
        i = 1 if lead_micro else 0
        dims[i] = b_ax
        if name in ("tokens", "labels", "resets") and len(leaf.shape) > i + 1:
            dims[i + 1] = s_ax
        return fit_spec(mesh, leaf.shape, P(*dims))

    return jax.tree_util.tree_map_with_path(
        lambda p, l: _named(mesh, spec_for(p, l)), batch_tree)


def cache_specs(cache_tree, plan: Parallelism):
    """PartitionSpecs for a decode cache (leading dim = layer groups)."""
    mesh = plan.mesh

    def spec_for(path, leaf):
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        if name == "pos":
            return P()
        b_ax = plan.rules.get("batch")
        if name in ("k", "v"):
            return fit_spec(mesh, leaf.shape,
                            P(None, b_ax, plan.rules.get("kv_heads"),
                              plan.rules.get("cache_seq"), None))
        if name == "m":
            return fit_spec(mesh, leaf.shape,
                            P(None, b_ax, plan.rules.get("heads"),
                              None, None))
        if name.startswith("conv_"):
            return fit_spec(mesh, leaf.shape,
                            P(None, b_ax, None, plan.tp_axis))
        return fit_spec(mesh, leaf.shape, P(None, b_ax))

    return jax.tree_util.tree_map_with_path(spec_for, cache_tree)


@dataclass
class Cell:
    arch: str
    shape: ShapeConfig
    cfg: ModelConfig
    plan: Parallelism
    run: RunConfig
    fn: Any                  # jit-able callable
    abstract_args: tuple     # ShapeDtypeStructs matching fn
    in_shardings: tuple
    donate: tuple
    note: str = ""

    def lower(self):
        jitted = jax.jit(self.fn, in_shardings=self.in_shardings,
                         donate_argnums=self.donate)
        return jitted.lower(*self.abstract_args)


def resolve_config(arch: str, shape_name: str) -> tuple[ModelConfig, str]:
    cfg = get_config(arch)
    note = "native"
    if shape_name == "long_500k" and not cfg.subquadratic:
        # paper's recipe: linearize (1/4 hybrid, windowed softmax) — pure
        # full attention cannot run 500k (DESIGN.md §5).
        cfg = cfg.linearize(hybrid_every=4)
        note = "linearized-1/4-hybrid (pure softmax infeasible at 500k)"
    return cfg, note


def build_cell(arch: str, shape_name: str, mesh: Optional[Mesh], *,
               run: Optional[RunConfig] = None,
               cfg_override: Optional[ModelConfig] = None,
               backend: Optional[str] = None) -> Cell:
    shape = SHAPES[shape_name]
    if cfg_override is not None:
        cfg, note = cfg_override, "override"
    else:
        cfg, note = resolve_config(arch, shape_name)
    run = run or RunConfig()
    plan = make_plan(mesh, shape.kind, global_batch=shape.global_batch,
                     n_kv_heads=cfg.n_kv_heads, n_heads=cfg.n_heads,
                     params_bytes=cfg.param_count() * 2, backend=backend,
                     comm=run.comm_spec())
    plan.banded_windows = run.banded_windows

    if shape.kind == "train":
        dp = 1
        if mesh is not None:
            dp = int(np.prod([mesh.shape[a] for a in plan.dp_axes
                              if a in mesh.axis_names]))
            if plan.sp is not None and not plan.manual_axes:
                # 1-D SP-mode training: batch on pod only. (The manual 2D
                # DP×SP plan keeps its "data"-axis dp.)
                dp = mesh.shape.get(POD_AXIS, 1)
        a = choose_microbatches(shape, dp, target=run.microbatch_tokens)
        run = dataclasses.replace(run, num_microbatches=a)
        bm = shape.global_batch // a
        state_shapes = jax.eval_shape(
            lambda: init_state(jax.random.PRNGKey(0), cfg, run, plan))
        batch = {"tokens": _sds((a, bm, shape.seq_len), jnp.int32),
                 "labels": _sds((a, bm, shape.seq_len), jnp.int32),
                 "resets": _sds((a, bm, shape.seq_len), jnp.bool_)}
        batch.update(aux_input_specs(cfg, bm, lead=(a,)))
        fn = make_train_step(cfg, run, plan)
        if mesh is None:
            return Cell(arch, shape, cfg, plan, run, fn,
                        (state_shapes, batch), None, (0,), note)
        sspec = _state_shardings(state_shapes, plan)
        bspec = _batch_sharding_tree(batch, plan, lead_micro=True)
        return Cell(arch, shape, cfg, plan, run, fn,
                    (state_shapes, batch), (sspec, bspec), (0,), note)

    params_shapes = jax.eval_shape(
        lambda: M.init_params(jax.random.PRNGKey(0), cfg))
    if run.infer_bf16:
        # §Perf: inference holds bf16 weights (no fp32 masters to gather)
        params_shapes = jax.tree.map(
            lambda l: _sds(l.shape, jnp.bfloat16)
            if (l.dtype == jnp.float32 and len(l.shape) >= 2) else l,
            params_shapes)
    if mesh is not None and run.infer_bf16 and shape.kind == "prefill":
        # §Perf: drop FSDP for PREFILL when the TP-sharded weights fit —
        # kills the per-layer weight all-gather (measured -96 GB/step on
        # moonshot×prefill_32k). Decode keeps FSDP: its per-step gather is
        # tiny and resident weights would blow the HBM budget (measured
        # +14 GiB peak on phi3.5 decode).
        total_b = sum(int(np.prod(l.shape)) * l.dtype.itemsize
                      for l in jax.tree.leaves(params_shapes))
        tp_size = mesh.shape.get(MODEL_AXIS, 1)
        if total_b / tp_size <= run.infer_fsdp_budget_gb * 2 ** 30:
            plan.fsdp_axis = None
    pspec = None
    if mesh is not None:
        pspec = jax.tree.map(lambda s: _named(mesh, s),
                             param_specs(params_shapes, plan),
                             is_leaf=lambda x: isinstance(x, P))

    if shape.kind == "prefill":
        b = shape.global_batch
        tokens = _sds((b, shape.seq_len), jnp.int32)
        aux = aux_input_specs(cfg, b)

        def fn(params, tokens, aux_in):
            logits, cache = M.prefill(
                params, tokens, cfg, plan, max_len=shape.seq_len,
                img_emb=aux_in.get("img"),
                enc_frames=aux_in.get("frames"),
                unroll=run.scan_unroll)
            return logits, cache

        if mesh is None:
            return Cell(arch, shape, cfg, plan, run, fn,
                        (params_shapes, tokens, aux), None, (), note)
        tspec = _named(mesh, fit_spec(mesh, tokens.shape,
                                      P(plan.rules.get("batch"),
                                        plan.rules.get("seq"))))
        aspec = jax.tree.map(
            lambda l: _named(mesh, fit_spec(
                mesh, l.shape, P(plan.rules.get("batch"), None, None))),
            aux)
        return Cell(arch, shape, cfg, plan, run, fn,
                    (params_shapes, tokens, aux),
                    (pspec, tspec, aspec), (), note)

    # decode
    b = shape.global_batch
    token = _sds((b,), jnp.int32)
    cache_shapes = jax.eval_shape(
        lambda: M.init_cache(cfg, b, shape.seq_len))
    aux = {}
    if cfg.encoder is not None:
        aux["enc_out"] = _sds((b, cfg.encoder.n_frames, cfg.d_model),
                              jnp.bfloat16)
    if cfg.n_image_tokens:
        aux["img"] = _sds((b, cfg.n_image_tokens, cfg.d_model),
                          jnp.bfloat16)

    def fn(params, token, cache, aux_in):
        return M.decode_step(params, token, cache, cfg, plan,
                             img_emb=aux_in.get("img"),
                             enc_out=aux_in.get("enc_out"),
                             unroll=run.scan_unroll)

    if mesh is None:
        return Cell(arch, shape, cfg, plan, run, fn,
                    (params_shapes, token, cache_shapes, aux), None, (2,),
                    note)
    tokspec = _named(mesh, fit_spec(mesh, token.shape,
                                    P(plan.rules.get("batch"))))
    cspec = jax.tree.map(lambda s: _named(mesh, s),
                         cache_specs(cache_shapes, plan),
                         is_leaf=lambda x: isinstance(x, P))
    aspec = jax.tree.map(
        lambda l: _named(mesh, fit_spec(
            mesh, l.shape, P(plan.rules.get("batch"), None, None))), aux)
    return Cell(arch, shape, cfg, plan, run, fn,
                (params_shapes, token, cache_shapes, aux),
                (pspec, tokspec, cspec, aspec), (2,), note)


def _state_shardings(state_shapes, plan: Parallelism):
    mesh = plan.mesh
    pspec = jax.tree.map(lambda s: _named(mesh, s),
                         param_specs(state_shapes["params"], plan),
                         is_leaf=lambda x: isinstance(x, P))
    from repro.optim import adamw
    if isinstance(state_shapes["opt"], adamw.Zero1AdamState):
        # ZeRO-1 flat moments: sharded over the data axis; params of the
        # manual 2D plan are replicated (param_specs above yields P()).
        zspec = _named(mesh, P(plan.zero1_axis))
        return {"params": pspec,
                "opt": adamw.Zero1AdamState(m=zspec, v=zspec,
                                            count=_named(mesh, P())),
                "step": _named(mesh, P())}
    out = {"params": pspec,
           "opt": type(state_shapes["opt"])(
               m=jax.tree.map(lambda s: _named(mesh, s),
                              param_specs(state_shapes["opt"].m, plan),
                              is_leaf=lambda x: isinstance(x, P)),
               v=jax.tree.map(lambda s: _named(mesh, s),
                              param_specs(state_shapes["opt"].v, plan),
                              is_leaf=lambda x: isinstance(x, P)),
               count=_named(mesh, P())),
           "step": _named(mesh, P())}
    if "err" in state_shapes:
        out["err"] = jax.tree.map(lambda s: _named(mesh, s),
                                  param_specs(state_shapes["err"], plan),
                                  is_leaf=lambda x: isinstance(x, P))
    return out


def reduced_depth_config(cfg: ModelConfig, n_units: int) -> ModelConfig:
    """Same widths, ``n_units`` pattern repetitions — used by the roofline
    cost extrapolation (cost is exactly linear in group count)."""
    return dataclasses.replace(
        cfg, n_layers=len(cfg.pattern) * n_units)
