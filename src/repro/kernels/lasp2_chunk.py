"""Pallas TPU kernel: intra-chunk decayed causal linear attention.

This is the compute hot-spot of LASP-2 (paper Alg. 2 lines 5–8): each
device's local sequence chunk is processed block-by-block, carrying the
``dk × dv`` memory state in VMEM scratch across the (sequential) block grid
dimension. The cross-device part (the AllGather of chunk states) lives in
``repro.core.lasp2``; this kernel is the per-device "intra" workhorse it
overlaps with.

TPU adaptation of the paper's Triton kernel:

* blocks are ``(BLOCK, dk/dv)`` tiles, MXU-aligned (128 lanes); the three
  matmuls per block (``QK^T``, ``scores·V``, ``K^T V``) hit the MXU with
  fp32 accumulation via ``preferred_element_type``;
* the memory state is fp32 in VMEM *scratch* that persists across the
  sequential grid axis — the HBM↔VMEM traffic per block is just the
  q/k/v/o tiles (the GPU version instead re-materializes through SMEM);
* decay math is log-space fp32; all reweighting factors are <= 1
  (see ``repro.core.linear_attention``).

Layout: inputs are flattened to ``(BH, S, d)``; grid = ``(BH, S//BLOCK)``
with ``dimension_semantics=("parallel", "arbitrary")`` so distinct
batch·head programs parallelize across cores while blocks run in order.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import compat as _compat

DEFAULT_BLOCK = 128


def _kernel(q_ref, k_ref, v_ref, la_ref, o_ref, state_ref, ld_ref,
            state_scratch, ld_scratch, *, nblocks: int):
    blk = pl.program_id(1)

    @pl.when(blk == 0)
    def _init():
        state_scratch[...] = jnp.zeros_like(state_scratch)
        ld_scratch[...] = jnp.zeros_like(ld_scratch)

    q = q_ref[0].astype(jnp.float32)          # (C, dk)
    k = k_ref[0].astype(jnp.float32)          # (C, dk)
    v = v_ref[0].astype(jnp.float32)          # (C, dv)
    la = la_ref[0].astype(jnp.float32)        # (C,)

    cb = jnp.cumsum(la)                       # inclusive cumulative log decay
    a_blk = cb[-1]
    c = q.shape[0]
    # D_ij = exp(cb_i - cb_j) for i >= j else 0 — all factors <= 1.
    diff = cb[:, None] - cb[None, :]
    row = jax.lax.broadcasted_iota(jnp.int32, (c, c), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (c, c), 1)
    dmat = jnp.where(row >= col, jnp.exp(jnp.minimum(diff, 0.0)), 0.0)

    scores = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * dmat            # (C, C)
    o_intra = jax.lax.dot_general(
        scores, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)                    # (C, dv)
    # inter (within-device, previous blocks): (q ⊙ b) @ S_carry
    state = state_scratch[...]
    o_inter = jax.lax.dot_general(
        q * jnp.exp(cb)[:, None], state, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    o_ref[0] = (o_intra + o_inter).astype(o_ref.dtype)

    # state update: S <- exp(A) S + (k ⊙ exp(A - cb))^T v
    kw = k * jnp.exp(a_blk - cb)[:, None]
    s_new = jnp.exp(a_blk) * state + jax.lax.dot_general(
        kw, v, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    state_scratch[...] = s_new
    ld_scratch[0, 0] = ld_scratch[0, 0] + a_blk

    @pl.when(blk == nblocks - 1)
    def _finalize():
        state_ref[0] = s_new
        ld_ref[0, 0] = ld_scratch[0, 0]


@functools.partial(jax.jit, static_argnames=("block_size", "interpret"))
def lasp2_chunk_fwd(q, k, v, log_a, *, block_size: int = DEFAULT_BLOCK,
                    interpret: bool = False):
    """Chunked decayed causal linear attention (forward), Pallas TPU.

    q, k: (BH, S, dk); v: (BH, S, dv); log_a: (BH, S).
    Returns (o (BH, S, dv), state (BH, dk, dv) fp32, log_decay (BH,) fp32).
    """
    bh, s, dk = q.shape
    dv = v.shape[-1]
    if s % block_size:
        raise ValueError(f"S={s} must be divisible by block={block_size}")
    nb = s // block_size

    grid = (bh, nb)
    kernel = functools.partial(_kernel, nblocks=nb)
    o, state, ld = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_size, dk), lambda b, t: (b, t, 0)),
            pl.BlockSpec((1, block_size, dk), lambda b, t: (b, t, 0)),
            pl.BlockSpec((1, block_size, dv), lambda b, t: (b, t, 0)),
            pl.BlockSpec((1, block_size), lambda b, t: (b, t)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_size, dv), lambda b, t: (b, t, 0)),
            pl.BlockSpec((1, dk, dv), lambda b, t: (b, 0, 0)),
            pl.BlockSpec((1, 1), lambda b, t: (b, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, s, dv), q.dtype),
            jax.ShapeDtypeStruct((bh, dk, dv), jnp.float32),
            jax.ShapeDtypeStruct((bh, 1), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((dk, dv), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
        ],
        compiler_params=_compat.tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
        name="lasp2_chunk_fwd",
    )(q, k, v, log_a)
    return o, state, ld[:, 0]
