"""SP baselines the paper compares against (paper §4.2, Appendix A.2/A.3).

* :func:`lasp1` — LASP-1 (paper Algorithms 5/6): ring-style P2P transfer of
  the memory state, ``W-1`` sequential ``ppermute`` steps in the forward.
* :func:`ring_attention` — Ring Attention (Liu et al. 2023): K/V blocks
  rotate around the ring with online-softmax accumulation.
* :func:`megatron_sp_attention` — Megatron-SP-style: all-gather the *full
  hidden activations* along the sequence axis before attention (traffic
  scales with sequence length — the point of comparison in paper §3.4).

These exist for benchmarks (`benchmarks/fig3_speed.py`) and parity tests;
production code uses ``repro.core.lasp2``.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.compat import shard_map as _shard_map

from repro.comm import primitives as comm_primitives
from repro.core.lasp2 import SPConfig
from repro.core.lasp2h import NEG_INF, _softmax_attend, causal_mask
from repro.core.linear_attention import (chunk_scan, chunk_summaries,
                                         pick_block)


def lasp1(q, k, v, log_a=None, *, sp: Optional[SPConfig] = None,
          block_size: int = 128):
    """LASP-1 (paper Alg. 6, decay-generalized): ring P2P state transfer.

    Each rank waits for M_{t-1} from rank t-1, computes its inter output
    and updated state, and forwards it — W-1 *sequential* communication
    steps. The ring is the comm subsystem's unrolled prefix-scan exchange
    (``repro.comm.primitives.pipelined_prefix_exchange`` with one slice):
    at step s the packet arriving at rank t originated at rank t-1-s with
    every intermediate chunk's decay already folded in by the forwarding
    ranks. The W-1 sequential hops — 2(W-1) per fwd+bwd iteration, each
    hop transposing to a hop — are the point: they are what LASP-2's
    single AllGather removes, and the HLO budget tests count them
    literally (``repro.comm.budget.ring_baseline_budget``).
    """
    if log_a is None:
        log_a = jnp.zeros(q.shape[:-1], dtype=jnp.float32)
    if sp is None or sp.degree == 1:
        return chunk_scan(q, k, v, log_a,
                          block_size=pick_block(q.shape[-2], block_size)).o

    axis = sp.sp_axis
    w = sp.degree

    def local_fn(q_, k_, v_, la_):
        bs = pick_block(q_.shape[-2], block_size)
        t = jax.lax.axis_index(axis)
        m_loc, a_loc = chunk_summaries(k_, v_, la_, block_size=bs)
        out = chunk_scan(q_, k_, v_, la_, block_size=bs)  # intra part
        b = jnp.exp(jnp.cumsum(la_.astype(jnp.float32), axis=-1))
        m_prev = comm_primitives.pipelined_prefix_exchange(
            m_loc, a_loc, axis, axis_size=w, t=t, n_slices=1, tag="lasp1")
        o_inter = jnp.einsum("...sk,...kv->...sv",
                             q_.astype(jnp.float32) * b[..., None], m_prev)
        return (out.o.astype(jnp.float32) + o_inter).astype(q_.dtype)

    spec = P(None, None, axis, None)
    aspec = P(None, None, axis)
    return _shard_map(local_fn, mesh=sp.mesh,
                         in_specs=(spec, spec, spec, aspec), out_specs=spec,
                         axis_names={axis}, check_vma=False)(q, k, v, log_a)


def ring_attention(q, k, v, *, sp: Optional[SPConfig] = None,
                   causal: bool = True, scale: Optional[float] = None):
    """Ring Attention: rotate K/V chunks with online-softmax accumulation."""
    if scale is None:
        scale = q.shape[-1] ** -0.5
    if sp is None or sp.degree == 1:
        mask = causal_mask(q.shape[-2], k.shape[-2], 0)[None, None] if causal \
            else None
        return _softmax_attend(q, k, v, scale=scale, mask=mask)

    axis = sp.sp_axis
    w = sp.degree
    # send chunk to the next rank; after step s we hold chunk (t - s) mod W

    def local_fn(q_, k_, v_):
        b, hq, c, dh = q_.shape
        hkv = k_.shape[1]
        rep = hq // hkv
        t = jax.lax.axis_index(axis)
        qf = q_.astype(jnp.float32)

        def attend_block(kc, vc, src):
            kf = jnp.repeat(kc, rep, axis=1).astype(jnp.float32)
            vf = jnp.repeat(vc, rep, axis=1).astype(jnp.float32)
            s = jnp.einsum("bhsd,bhtd->bhst", qf, kf) * scale
            if causal:
                qpos = t * c + jnp.arange(c)[:, None]
                kpos = src * c + jnp.arange(c)[None, :]
                s = jnp.where((qpos >= kpos)[None, None], s, NEG_INF)
            return s, vf

        def body(step, carry):
            o, m, l, kc, vc = carry
            src = (t - step) % w
            s, vf = attend_block(kc, vc, src)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l = l * corr + jnp.sum(p, axis=-1)
            o = o * corr[..., None] + jnp.einsum("bhst,bhtd->bhsd", p, vf)
            # K/V rotate W times inside the fori_loop; the body is traced
            # once, so the tape is told about all W trips up front.
            kc = comm_primitives.ring_sendrecv(
                kc, axis, axis_size=w, loop_trips=w, tag="ring_attn.k")
            vc = comm_primitives.ring_sendrecv(
                vc, axis, axis_size=w, loop_trips=w, tag="ring_attn.v")
            return (o, m_new, l, kc, vc)

        o0 = jnp.zeros((b, hq, c, dh), jnp.float32)
        m0 = jnp.full((b, hq, c), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, hq, c), jnp.float32)
        o, m, l, _, _ = jax.lax.fori_loop(0, w, body, (o0, m0, l0, k_, v_))
        o = o / jnp.maximum(l, 1e-30)[..., None]
        return o.astype(q_.dtype)

    spec = P(None, None, axis, None)
    return _shard_map(local_fn, mesh=sp.mesh,
                         in_specs=(spec, spec, spec), out_specs=spec,
                         axis_names={axis}, check_vma=False)(q, k, v)


def megatron_sp_attention(q, k, v, *, sp: Optional[SPConfig] = None,
                          causal: bool = True, scale: Optional[float] = None):
    """Megatron-SP-style: all-gather *everything* along the sequence axis.

    Traffic per layer is O(S·d) (vs LASP-2's O(d²)) — the unfavourable
    scaling the paper quantifies in §3.4. Only used for comparisons.
    """
    if scale is None:
        scale = q.shape[-1] ** -0.5
    if sp is None or sp.degree == 1:
        mask = causal_mask(q.shape[-2], k.shape[-2], 0)[None, None] if causal \
            else None
        return _softmax_attend(q, k, v, scale=scale, mask=mask)

    axis = sp.sp_axis
    w = sp.degree

    def local_fn(q_, k_, v_):
        c = q_.shape[-2]
        t = jax.lax.axis_index(axis)
        # Three full-activation gathers — traffic O(S·d), the unfavourable
        # scaling; routed through the subsystem so the tape records it.
        qg = comm_primitives.allgather_states(
            q_, axis, axis_size=w, gather_axis=2, tiled=True,
            tag="megatron.q")
        kg = comm_primitives.allgather_states(
            k_, axis, axis_size=w, gather_axis=2, tiled=True,
            tag="megatron.k")
        vg = comm_primitives.allgather_states(
            v_, axis, axis_size=w, gather_axis=2, tiled=True,
            tag="megatron.v")
        s_tot = qg.shape[2]
        mask = causal_mask(s_tot, s_tot, 0)[None, None] if causal else None
        o = _softmax_attend(qg, kg, vg, scale=scale, mask=mask)
        return jax.lax.dynamic_slice_in_dim(o, t * c, c, axis=2)

    spec = P(None, None, axis, None)
    return _shard_map(local_fn, mesh=sp.mesh,
                         in_specs=(spec, spec, spec), out_specs=spec,
                         axis_names={axis}, check_vma=False)(q, k, v)
