"""Comm/compute overlap scheduling for SP exchanges.

XLA's latency-hiding scheduler overlaps a collective with any compute
that is *dataflow-independent* of it (on TPU the collective becomes an
``all-gather-start`` / ``all-gather-done`` pair with the independent
compute scheduled between them). The scheduler here therefore controls
dependency structure, not threads:

``mode="overlap"`` (default) — double-buffered: the cheap chunk-summary
  pass fills buffer A (the exchange payload), the exchange is issued,
  and the heavy intra-chunk kernel fills buffer B while the states are
  in flight; the inter-chunk combine consumes both. This is paper
  Alg. 2's line ordering (summaries → AllGather → intra-chunk) realized
  as a dependency graph — the paper's comm/compute overlap claim.

``mode="none"`` — an ``optimization_barrier`` makes the exchange operand
  depend on the intra-chunk output, forcing the collective to start only
  after compute finishes. This is the A/B baseline
  ``benchmarks/comm_strategies.py`` measures overlap against.

``optimization_barrier`` has no differentiation rule on older jax
(0.4.x), so it is wrapped in a ``custom_vjp`` that passes cotangents
straight through — the serialization applies to the forward schedule,
which is what the A/B compares.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax

MODES = ("overlap", "none")


@jax.custom_vjp
def _serialize(payload, anchor):
    """Make ``payload`` data-depend on ``anchor`` (identity values)."""
    payload, anchor = jax.lax.optimization_barrier((payload, anchor))
    return payload, anchor


def _serialize_fwd(payload, anchor):
    return _serialize(payload, anchor), None


def _serialize_bwd(_, cot):
    return cot


_serialize.defvjp(_serialize_fwd, _serialize_bwd)


@dataclass(frozen=True)
class DoubleBufferedScheduler:
    """Orders one SP exchange against the intra-chunk compute."""

    mode: str = "overlap"

    def __post_init__(self):
        if self.mode not in MODES:
            raise ValueError(
                f"unknown overlap mode {self.mode!r}; expected one of "
                f"{MODES}")

    def run(self, payload, exchange, compute):
        """Returns ``(exchange_result, compute_result)``.

        ``exchange``: payload -> exchanged value (must contain the
        collective). ``compute``: () -> pytree, independent of the
        exchange (the intra-chunk kernel).
        """
        if self.mode == "none":
            out = compute()
            payload, out = _serialize(payload, out)
            return exchange(payload), out
        exchanged = exchange(payload)   # issued first → in flight …
        out = compute()                 # … while the intra kernel runs
        return exchanged, out
