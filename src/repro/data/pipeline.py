"""Deterministic synthetic LM data pipeline with document packing.

Determinism contract (fault tolerance): the batch for global step ``s`` is
a pure function of ``(seed, s)`` — any restarted/elastic worker regenerates
identical data, so checkpoint-resume is bitwise reproducible and straggler
re-execution is safe.

Packing (paper §A.4.2): multiple documents are packed into each row;
``resets`` marks document starts. Linear-attention layers consume resets
as decay zeroing (``RESET_LOG_A``), realizing the paper's "treat the whole
batch as one long sequence" trick without padding; equivalence to separate
documents is property-tested.
"""

from __future__ import annotations

import dataclasses
import numpy as np


@dataclasses.dataclass
class SyntheticLM:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    pack_documents: bool = True
    mean_doc_len: int = 512

    def batch(self, step: int) -> dict:
        """Batch for one global step: tokens/labels (B, S) int32,
        resets (B, S) bool. Labels are next-token; last position = -1."""
        rng = np.random.default_rng([self.seed, step])
        b, s = self.global_batch, self.seq_len
        # power-law token distribution (natural-language-ish unigram skew:
        # entropy well below ln(V), so CE visibly falls during training)
        u = rng.random((b, s + 1))
        tokens = np.minimum((self.vocab_size * u ** 4).astype(np.int32),
                            self.vocab_size - 1)
        # Inject learnable structure: second half of each doc repeats its
        # first half (associative recall flavour) so loss can decrease.
        resets = np.zeros((b, s + 1), bool)
        resets[:, 0] = True
        if self.pack_documents:
            n_docs = max(1, s // self.mean_doc_len)
            for i in range(b):
                cuts = np.sort(rng.choice(
                    np.arange(1, s), size=n_docs - 1, replace=False)) \
                    if n_docs > 1 else np.array([], np.int64)
                resets[i, cuts] = True
        # repetition structure within rows
        rep = s // 4
        tokens[:, 2 * rep:3 * rep] = tokens[:, :rep]
        labels = tokens[:, 1:].copy()
        labels[:, -1] = -1
        return {"tokens": tokens[:, :-1], "labels": labels,
                "resets": resets[:, :-1]}

    def microbatched(self, step: int, num_microbatches: int) -> dict:
        """(A, B/A, S)-shaped batch for gradient accumulation."""
        batch = self.batch(step)
        a = num_microbatches
        b = self.global_batch
        if b % a:
            raise ValueError(f"global_batch {b} % microbatches {a} != 0")
        return {k: v.reshape(a, b // a, *v.shape[1:])
                for k, v in batch.items()}


def doc_segments(resets: np.ndarray) -> np.ndarray:
    """Segment ids (B, S) from reset flags — for softmax-attention packing."""
    return np.cumsum(resets, axis=1).astype(np.int32)
