"""LASP-2H: the standard-attention half of hybrid-model sequence parallelism.

Paper §3.5 + Algorithm 7: for softmax-attention layers, LASP-2H uses
AllGather-based context parallelism (the Llama-3 recipe) instead of ring
P2P — K_t and V_t chunks are gathered across the SP group, then each device
computes attention for its local Q_t chunk. With GQA the gathered K/V are
much smaller than Q, so the all-gather is cheap relative to the attention
FLOPs (paper's argument).

This module also provides the *decode-time* counterpart we need at scale
(beyond-paper, flash-decoding style): when the KV cache's sequence dim is
sharded over a mesh axis, each shard computes a partial online-softmax
attention and the partials are merged with a tiny gather of per-shard
``(m, l, o)`` statistics.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.compat import shard_map as _shard_map

from repro.comm import primitives as comm_primitives
from repro.core.lasp2 import SPConfig
from repro.kernels.flash_attention import mask_value

# Masked-logit fill for fp32 score tensors, finfo-derived so a future
# reduced-precision score path cannot overflow the way a -1e30 literal
# does in fp16 (see repro.kernels.flash_attention.mask_value).
NEG_INF = mask_value(jnp.float32)


def _softmax_attend(q, k, v, *, bias=None, scale, mask=None):
    """Plain fp32-softmax attention on local tensors.

    q: (B, Hq, Sq, dh); k,v: (B, Hkv, Sk, dh). GQA via head repeat.
    mask: broadcastable to (B, 1|Hq, Sq, Sk), True = attend.
    """
    b, hq, sq, dh = q.shape
    hkv = k.shape[1]
    rep = hq // hkv
    if rep > 1:
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    scores = jnp.einsum("bhsd,bhtd->bhst", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if bias is not None:
        scores = scores + bias
    if mask is not None:
        scores = jnp.where(mask, scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    o = jnp.einsum("bhst,bhtd->bhsd", p, v.astype(jnp.float32))
    return o.astype(q.dtype)


def causal_mask(sq, sk, q_offset, *, sliding_window: Optional[int] = None,
                segment_q=None, segment_k=None):
    """(sq, sk) boolean mask. Query global position = q_offset + row index."""
    qpos = q_offset + jnp.arange(sq)[:, None]
    kpos = jnp.arange(sk)[None, :]
    m = qpos >= kpos
    if sliding_window is not None:
        m &= (qpos - kpos) < sliding_window
    mask = m  # (sq, sk)
    if segment_q is not None:
        seg = segment_q[:, None] == segment_k[None, :]
        mask = mask & seg
    return mask


def allgather_context_attention(q, k, v, *, sp: Optional[SPConfig] = None,
                                causal: bool = True,
                                sliding_window: Optional[int] = None,
                                scale: Optional[float] = None,
                                kernel_backend: Optional[str] = None):
    """Paper Algorithm 7: AllGather-based context parallelism.

    q: (B, Hq, S, dh), k/v: (B, Hkv, S, dh) — S is the global sequence and
    may be sharded over ``sp.sp_axis``. One forward all-gather each for K and
    V (sizes C×d per chunk — small under GQA); backward (via autodiff) emits
    the mirrored reduce-scatter on dK/dV, matching Megatron's AG/RS pairing
    shown in paper Fig. 2. With ``sp.comm_dtype="bf16"`` the gathered
    payload travels in bf16 and the local attention math stays fp32.

    ``kernel_backend`` (``None`` → ``sp.kernel_backend``, then the
    platform default) applies to degree-1 AND the sharded local
    attention — both dispatch through
    ``repro.kernels.ops.flash_attention_op``, whose Pallas kernels accept
    the rank offset ``t·C`` as a traced ``q_offset``. Hybrid (LASP-2H)
    training is therefore Pallas end-to-end on the Pallas backends.
    """
    if scale is None:
        scale = q.shape[-1] ** -0.5
    if kernel_backend is None and sp is not None:
        kernel_backend = sp.kernel_backend

    from repro.kernels import ops as _ops

    if sp is None or sp.degree == 1:
        return _ops.flash_attention_op(
            q, k, v, causal=causal, sliding_window=sliding_window,
            scale=scale, backend=kernel_backend)

    axis = sp.exchange_axis
    w = sp.degree
    narrow = _narrow_fn(sp.comm_dtype)

    def local_fn(q_, k_, v_):
        # q_: (B, Hq, C, dh); k_/v_: (B, Hkv, C, dh) local chunks.
        c = q_.shape[-2]
        t = comm_primitives.multi_axis_index(axis)
        # Alg. 7 line 5: gather K/V chunks; tiled=True concatenates along a
        # new leading dim which we fold into the sequence dim (line 6).
        # comm_dtype on the wire; attention math is fp32 locally either way.
        kg = comm_primitives.upcast_gathered(
            comm_primitives.allgather_states(
                narrow(k_), axis, axis_size=w, gather_axis=2,
                tiled=True, tag="lasp2h.k"), k_.dtype)     # (B,Hkv,S,dh)
        vg = comm_primitives.upcast_gathered(
            comm_primitives.allgather_states(
                narrow(v_), axis, axis_size=w, gather_axis=2,
                tiled=True, tag="lasp2h.v"), v_.dtype)
        # Local attention for this rank's Q chunk (Alg. 7 line 7): the
        # flash kernel masks with the traced rank offset t·C.
        return _ops.flash_attention_op(
            q_, kg, vg, causal=causal, sliding_window=sliding_window,
            scale=scale, q_offset=t * c, backend=kernel_backend)

    if sp.manual:
        # Already inside the train step's fully-manual shard_map:
        # q/k/v are this rank's sequence chunks (see SPConfig.manual).
        return local_fn(q, k, v)

    spec = P(None, None, axis, None)
    return _shard_map(local_fn, mesh=sp.mesh,
                         in_specs=(spec, spec, spec), out_specs=spec,
                         axis_names=set(sp.exchange_axes),
                         check_vma=False)(q, k, v)


# ---------------------------------------------------------------------------
# Ulysses head-parallel context attention (DeepSpeed-Ulysses / USP).
# ---------------------------------------------------------------------------

def _narrow_fn(comm_dtype):
    wire = comm_primitives.wire_dtype(comm_dtype)

    def narrow(x):
        # comm_dtype only ever NARROWS the wire payload: bf16 activations
        # under the default comm_dtype="fp32" keep their native-dtype
        # exchange (widening them would double the bytes this knob exists
        # to halve).
        if jnp.dtype(wire).itemsize < x.dtype.itemsize:
            return x.astype(wire)
        return x

    return narrow


def check_ulysses_heads(hq: int, hkv: int, degree: int,
                        axis: str = "?") -> None:
    """Fail loudly when head counts don't split over the head-parallel
    axis — the GQA-aware partitioning constraint of the ulysses path."""
    if hq % degree or hkv % degree:
        raise ValueError(
            f"ulysses head-parallelism needs n_heads and n_kv_heads "
            f"divisible by the head-parallel axis size: n_heads={hq}, "
            f"n_kv_heads={hkv}, axis {axis!r} size {degree}. Pick a tp "
            f"degree dividing both (GQA: kv heads are the binding "
            f"constraint) or use comm_strategy='allgather'.")


def pack_ulysses(q, k, v, degree: int):
    """Pack q/k/v into ONE tensor whose head dim splits contiguously into
    per-destination blocks for a tiled All-to-All.

    Block ``i`` (destination rank ``i`` on the head-parallel axis) is
    ``q_heads_i ‖ k_heads_i ‖ v_heads_i`` — ``(Hq + 2·Hkv)/g`` heads. A
    naive ``q ‖ k ‖ v`` concat would NOT work: ``all_to_all``'s
    contiguous equal split would hand rank 0 only query heads.

    q: (B, Hq, C, dh); k/v: (B, Hkv, C, dh) → (B, Hq+2·Hkv, C, dh).
    """
    b, hq, c, dh = q.shape
    hkv = k.shape[1]
    g = degree
    check_ulysses_heads(hq, hkv, g)
    qr = q.reshape(b, g, hq // g, c, dh)
    kr = k.astype(q.dtype).reshape(b, g, hkv // g, c, dh)
    vr = v.astype(q.dtype).reshape(b, g, hkv // g, c, dh)
    packed = jnp.concatenate([qr, kr, vr], axis=2)
    return packed.reshape(b, hq + 2 * hkv, c, dh)


def unpack_ulysses(block, hq: int, hkv: int, degree: int):
    """Split one received head block back into (q, k, v) head subsets.

    block: (B, (Hq+2·Hkv)/g, S, dh) — this rank's head block with the
    full (or All-to-All-widened) token range riding along. Inverse of
    the per-destination layout of :func:`pack_ulysses`.
    """
    nq, nkv = hq // degree, hkv // degree
    return (block[:, :nq], block[:, nq:nq + nkv],
            block[:, nq + nkv:nq + 2 * nkv])


def ulysses_context_attention(q, k, v, *, sp: Optional[SPConfig] = None,
                              causal: bool = True,
                              sliding_window: Optional[int] = None,
                              scale: Optional[float] = None,
                              kernel_backend: Optional[str] = None):
    """DeepSpeed-Ulysses head-parallel context attention for LASP-2H
    softmax layers (``comm_strategy="ulysses"``).

    Instead of gathering K/V (per-link volume constant in the axis
    size), TWO All-to-Alls repartition between layouts: packed q‖k‖v
    goes sequence-sharded → head-sharded (each rank gets a head subset
    over the full token range), flash attention runs per head subset,
    and the output All-to-Alls back to sequence-sharded. Per-link volume
    shrinks with the axis size; backward is the mirrored All-to-All pair
    (``custom_vjp`` on the primitive).

    On a 2D DP×SP mesh the ulysses axis is ``sp.sp_axis`` and each head
    subset sees the whole sequence (``q_offset=0``). On a 3D mesh
    (``sp.tp_axis`` set — the USP composition) the All-to-All runs over
    the head-parallel ``tp_axis`` alone: received token chunks
    ``sp_idx·tp + 0..tp-1`` are contiguous, K/V then AllGather over the
    residual ``sp_axis`` (heads ÷ tp cancels tokens × tp — same bytes as
    a width-``sp`` 2D K/V gather), and flash runs with
    ``q_offset = sp_idx · S/sp``.
    """
    if scale is None:
        scale = q.shape[-1] ** -0.5
    if kernel_backend is None and sp is not None:
        kernel_backend = sp.kernel_backend

    from repro.kernels import ops as _ops

    if sp is None or sp.degree == 1:
        return _ops.flash_attention_op(
            q, k, v, causal=causal, sliding_window=sliding_window,
            scale=scale, backend=kernel_backend)

    # The ulysses (head-parallel) axis: MODEL on 3D meshes, else the SP
    # axis itself (classic DeepSpeed-Ulysses).
    ax_u = sp.tp_axis if sp.tp_axis is not None else sp.sp_axis
    g = sp.mesh.shape[ax_u]
    sp_res = sp.mesh.shape[sp.sp_axis] if sp.tp_axis is not None else 1
    hq, hkv = q.shape[1], k.shape[1]
    check_ulysses_heads(hq, hkv, g, ax_u)
    narrow = _narrow_fn(sp.comm_dtype)

    def local_fn(q_, k_, v_):
        c = q_.shape[-2]
        # (1) seq→head repartition: ONE tiled All-to-All of the packed
        # per-destination blocks. Rank-order concat along the token dim
        # yields contiguous tokens (3D: this sp row's S/sp span).
        blk = comm_primitives.alltoall(
            narrow(pack_ulysses(q_, k_, v_, g)), ax_u, axis_size=g,
            split_axis=1, concat_axis=2, tag="ulysses.in")
        blk = comm_primitives.upcast_gathered(blk, q_.dtype)
        ql, kl, vl = unpack_ulysses(blk, hq, hkv, g)
        if sp_res > 1:
            # (1b) USP: widen K/V over the residual sequence axis.
            kl = comm_primitives.upcast_gathered(
                comm_primitives.allgather_states(
                    narrow(kl), sp.sp_axis, axis_size=sp_res,
                    gather_axis=2, tiled=True, tag="ulysses.k"), q_.dtype)
            vl = comm_primitives.upcast_gathered(
                comm_primitives.allgather_states(
                    narrow(vl), sp.sp_axis, axis_size=sp_res,
                    gather_axis=2, tiled=True, tag="ulysses.v"), q_.dtype)
            q_offset = jax.lax.axis_index(sp.sp_axis) * (c * g)
        else:
            q_offset = 0   # every head subset sees the whole sequence
        # (2) full-sequence flash attention on this rank's head subset.
        o = _ops.flash_attention_op(
            ql, kl, vl, causal=causal, sliding_window=sliding_window,
            scale=scale, q_offset=q_offset, backend=kernel_backend)
        # (3) head→seq repartition back: the mirrored All-to-All. Rank-
        # order concat along the head dim restores the original order.
        return comm_primitives.alltoall(
            o, ax_u, axis_size=g, split_axis=2, concat_axis=1,
            tag="ulysses.out")

    if sp.manual:
        return local_fn(q, k, v)

    spec = P(None, None, sp.exchange_axis, None)
    return _shard_map(local_fn, mesh=sp.mesh,
                      in_specs=(spec, spec, spec), out_specs=spec,
                      axis_names=set(sp.exchange_axes),
                      check_vma=False)(q, k, v)


# ---------------------------------------------------------------------------
# Banded sliding-window attention (beyond-paper perf: §Perf hillclimb #3).
# ---------------------------------------------------------------------------

def banded_attention(q, k, v, window: int, *, scale=None, q_offset=0,
                     has_prefix: bool = False):
    """Causal sliding-window attention computing only the diagonal band.

    Instead of materializing (S, S) scores and masking (the naive path —
    O(S²) memory/FLOPs regardless of window), queries are blocked by
    ``window`` and each block attends only its own + previous K block:
    O(S·2w) scores. q: (B,Hq,Sq,dh).

    ``has_prefix``: K/V carry one extra leading window block (the halo
    from the previous SP rank); otherwise a synthetic zero block is
    prepended and masked out. ``q_offset`` may be a traced scalar (the SP
    rank offset). Requires Sq % window == 0.
    """
    if scale is None:
        scale = q.shape[-1] ** -0.5
    b, hq, sq, dh = q.shape
    hkv = k.shape[1]
    w = window
    assert sq % w == 0, (sq, w)
    rep = hq // hkv
    kf = jnp.repeat(k, rep, axis=1) if rep > 1 else k
    vf = jnp.repeat(v, rep, axis=1) if rep > 1 else v
    if not has_prefix:   # synthetic previous block so every q block has 2
        zpad = jnp.zeros((b, hq, w, dh), kf.dtype)
        kf = jnp.concatenate([zpad, kf], axis=2)
        vf = jnp.concatenate([zpad, vf], axis=2)
    nb = sq // w
    qb = q.reshape(b, hq, nb, w, dh)
    kb = kf.reshape(b, hq, nb + 1, w, dh)
    vb = vf.reshape(b, hq, nb + 1, w, dh)
    kcat = jnp.concatenate([kb[:, :, :-1], kb[:, :, 1:]], axis=3)
    vcat = jnp.concatenate([vb[:, :, :-1], vb[:, :, 1:]], axis=3)
    s = jnp.einsum("bhnqd,bhnkd->bhnqk", qb.astype(jnp.float32),
                   kcat.astype(jnp.float32)) * scale      # (B,H,nb,w,2w)
    qpos = (q_offset + jnp.arange(nb)[:, None, None] * w
            + jnp.arange(w)[None, :, None])               # (nb,w,1)
    # K always starts one window block before q (real halo or zero pad)
    kpos = (q_offset - w + jnp.arange(nb)[:, None, None] * w
            + jnp.arange(2 * w)[None, None, :])           # (nb,1,2w)
    # positions before the real K start (synthetic zero pad, or the
    # non-existent halo on rank 0) are invalid, not just "score 0"
    min_kpos = q_offset if not has_prefix else 0
    mask = (qpos >= kpos) & ((qpos - kpos) < w) & (kpos >= min_kpos)
    s = jnp.where(mask[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhnqk,bhnkd->bhnqd", p, vcat.astype(jnp.float32))
    return o.reshape(b, hq, sq, dh).astype(q.dtype)


def banded_attention_chunked(q, k, v, window: int, n_chunks: int, *,
                             scale=None):
    """Banded sliding-window attention, SP-communication-optimal global
    form (§Perf hillclimb #3, iteration 3).

    The sequence is viewed as ``n_chunks`` shard-aligned chunks (set
    ``n_chunks = SP degree``); each chunk's halo (the previous chunk's
    last ``window`` tokens) is obtained with ONE small shifted-slice on
    the chunk axis — the only cross-shard communication, O(w·d) per chunk.
    The sub-diagonal block pairing *inside* each chunk uses shifted slices
    on an UNSHARDED block axis (free). This avoids both (a) GSPMD
    permuting the full K/V for a global block shift (measured 160 GB/step
    on hymba×prefill) and (b) partial-manual ``ppermute``, which XLA-CPU
    cannot partition.

    Requires S % n_chunks == 0 and (S / n_chunks) % window == 0.
    """
    if scale is None:
        scale = q.shape[-1] ** -0.5
    b, hq, sq, dh = q.shape
    hkv = k.shape[1]
    w, nc = window, n_chunks
    c = sq // nc
    assert sq % nc == 0 and c % w == 0, (sq, nc, w)
    nb = c // w
    rep = hq // hkv

    kc = k.reshape(b, hkv, nc, c, dh)
    vc = v.reshape(b, hkv, nc, c, dh)
    # halo: previous chunk's last window — the ONLY cross-chunk traffic
    halo_k = jnp.concatenate(
        [jnp.zeros((b, hkv, 1, w, dh), k.dtype), kc[:, :, :-1, -w:]], axis=2)
    halo_v = jnp.concatenate(
        [jnp.zeros((b, hkv, 1, w, dh), v.dtype), vc[:, :, :-1, -w:]], axis=2)
    k_ext = jnp.concatenate([halo_k, kc], axis=3)   # (B,Hkv,nc,c+w,dh)
    v_ext = jnp.concatenate([halo_v, vc], axis=3)
    if rep > 1:
        k_ext = jnp.repeat(k_ext, rep, axis=1)
        v_ext = jnp.repeat(v_ext, rep, axis=1)

    q5 = q.reshape(b, hq, nc, nb, w, dh)
    k5 = k_ext.reshape(b, hq, nc, nb + 1, w, dh)
    v5 = v_ext.reshape(b, hq, nc, nb + 1, w, dh)
    kcat = jnp.concatenate([k5[:, :, :, :-1], k5[:, :, :, 1:]], axis=4)
    vcat = jnp.concatenate([v5[:, :, :, :-1], v5[:, :, :, 1:]], axis=4)
    s = jnp.einsum("bhcnqd,bhcnkd->bhcnqk", q5.astype(jnp.float32),
                   kcat.astype(jnp.float32)) * scale  # (B,H,nc,nb,w,2w)
    qpos = (jnp.arange(nc)[:, None, None, None] * c
            + jnp.arange(nb)[None, :, None, None] * w
            + jnp.arange(w)[None, None, :, None])     # (nc,nb,w,1)
    kpos = (jnp.arange(nc)[:, None, None, None] * c - w
            + jnp.arange(nb)[None, :, None, None] * w
            + jnp.arange(2 * w)[None, None, None, :])  # (nc,nb,1,2w)
    mask = (qpos >= kpos) & ((qpos - kpos) < w) & (kpos >= 0)
    s = jnp.where(mask[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhcnqk,bhcnkd->bhcnqd", p, vcat.astype(jnp.float32))
    return o.reshape(b, hq, sq, dh).astype(q.dtype)


def windowed_context_attention(q, k, v, window: int, *,
                               sp: Optional[SPConfig] = None, scale=None,
                               halo_mode: Optional[str] = None):
    """Sliding-window attention under sequence parallelism via a halo
    exchange of the previous rank's last ``window`` K/V tokens — replaces
    the full AllGather-CP for windowed layers (traffic O(w·d) instead of
    O(S·d), and banded local compute).

    halo_mode:
      "ppermute" — one collective_permute (optimal; the TPU path).
      "gather"   — all_gather of the halos + dynamic index (W× the halo
        traffic — still ≪ full CP). Default off-TPU: XLA-CPU cannot
        partition ppermute under partial-manual shard_map (PartitionId
        error), so the dry-run measures this variant; EXPERIMENTS §Perf
        reports the TPU ppermute figure analytically alongside.
    """
    if scale is None:
        scale = q.shape[-1] ** -0.5
    if sp is None or sp.degree == 1:
        return banded_attention(q, k, v, window, scale=scale)
    if halo_mode is None:
        halo_mode = "ppermute" if jax.default_backend() == "tpu" \
            else "gather"

    axis = sp.sp_axis
    w_ranks = sp.degree

    def local_fn(q_, k_, v_):
        c = q_.shape[2]
        t = jax.lax.axis_index(axis)
        # rank 0's halo refers to positions < 0 under the band mask
        # (min_kpos), so whatever arrives there never attends.
        if halo_mode == "ppermute":
            halo_k = comm_primitives.ring_sendrecv(
                k_[:, :, -window:], axis, axis_size=w_ranks, tag="halo.k")
            halo_v = comm_primitives.ring_sendrecv(
                v_[:, :, -window:], axis, axis_size=w_ranks, tag="halo.v")
        else:
            hk = comm_primitives.allgather_states(
                k_[:, :, -window:], axis, axis_size=w_ranks,
                tag="halo.k")                                  # (W,...)
            hv = comm_primitives.allgather_states(
                v_[:, :, -window:], axis, axis_size=w_ranks, tag="halo.v")
            prev = jnp.maximum(t - 1, 0)
            halo_k = jax.lax.dynamic_index_in_dim(hk, prev, 0,
                                                  keepdims=False)
            halo_v = jax.lax.dynamic_index_in_dim(hv, prev, 0,
                                                  keepdims=False)
        kx = jnp.concatenate([halo_k, k_], axis=2)
        vx = jnp.concatenate([halo_v, v_], axis=2)
        return banded_attention(q_, kx, vx, window, scale=scale,
                                q_offset=t * c, has_prefix=True)

    spec = P(None, None, axis, None)
    return _shard_map(local_fn, mesh=sp.mesh,
                         in_specs=(spec, spec, spec), out_specs=spec,
                         axis_names={axis}, check_vma=False)(q, k, v)


# ---------------------------------------------------------------------------
# Sharded decode attention (flash-decoding style; beyond-paper).
# ---------------------------------------------------------------------------

def sharded_decode_attention(q, k_cache, v_cache, cache_len, *,
                             sp: Optional[SPConfig] = None,
                             scale: Optional[float] = None,
                             sliding_window=None):
    """One-token attention against a long KV cache whose seq dim is sharded.

    q: (B, Hq, 1, dh); k_cache/v_cache: (B, Hkv, S, dh) with S sharded over
    ``sp.sp_axis`` (typically the "model" axis when kv_heads < TP degree).
    cache_len: scalar — number of valid cache positions (<= S).

    Each shard computes a partial online-softmax over its cache slice, then
    the per-shard (max, sum, weighted-value) triplets are merged — a gather
    of O(B·Hq·dh) bytes, independent of S.
    """
    if scale is None:
        scale = q.shape[-1] ** -0.5

    def partial_attend(q_, k_, v_, valid):
        # returns (o_unnorm (B,Hq,dh) f32, m (B,Hq), l (B,Hq))
        b, hq, _, dh = q_.shape
        hkv = k_.shape[1]
        rep = hq // hkv
        kf = jnp.repeat(k_, rep, axis=1).astype(jnp.float32)
        vf = jnp.repeat(v_, rep, axis=1).astype(jnp.float32)
        s = jnp.einsum("bhd,bhtd->bht", q_[:, :, 0].astype(jnp.float32),
                       kf) * scale
        s = jnp.where(valid[:, None, :], s, NEG_INF)
        m = jnp.max(s, axis=-1)
        p = jnp.exp(s - m[..., None])
        # guard: fully-masked shard -> zero weight, m = NEG_INF
        p = jnp.where(valid[:, None, :], p, 0.0)
        l = jnp.sum(p, axis=-1)
        o = jnp.einsum("bht,bhtd->bhd", p, vf)
        return o, m, l

    if sp is None or sp.degree == 1:
        s_tot = k_cache.shape[2]
        kpos = jnp.arange(s_tot)[None, :]
        valid = kpos < cache_len
        if sliding_window is not None:
            valid &= (cache_len - 1 - kpos) < sliding_window
        valid = jnp.broadcast_to(valid, (q.shape[0], s_tot))
        o, m, l = partial_attend(q, k_cache, v_cache, valid)
        o = o / jnp.maximum(l, 1e-30)[..., None]
        return o[:, :, None, :].astype(q.dtype)

    axis = sp.sp_axis
    w = sp.degree

    def local_fn(q_, k_, v_, cache_len_):
        c = k_.shape[2]
        t = jax.lax.axis_index(axis)
        pos = t * c + jnp.arange(c)
        valid = pos[None, :] < cache_len_
        if sliding_window is not None:
            valid &= (cache_len_ - 1 - pos[None, :]) < sliding_window
        valid = jnp.broadcast_to(valid, (q_.shape[0], c))
        o, m, l = partial_attend(q_, k_, v_, valid)
        # Merge partials: gather (o, m, l) across shards — O(B*Hq*dh)·W bytes.
        og = comm_primitives.allgather_states(
            o, axis, axis_size=w, tag="decode.o")   # (W, B, Hq, dh)
        mg = comm_primitives.allgather_states(
            m, axis, axis_size=w, tag="decode.m")   # (W, B, Hq)
        lg = comm_primitives.allgather_states(
            l, axis, axis_size=w, tag="decode.l")
        m_glob = jnp.max(mg, axis=0)
        corr = jnp.exp(mg - m_glob[None])
        l_glob = jnp.sum(lg * corr, axis=0)
        o_glob = jnp.sum(og * corr[..., None], axis=0)
        o_final = o_glob / jnp.maximum(l_glob, 1e-30)[..., None]
        return o_final[:, :, None, :].astype(q_.dtype)

    qspec = P(None, None, None, None)           # q replicated over sp axis
    kvspec = P(None, None, axis, None)          # cache seq sharded
    cache_len = jnp.asarray(cache_len)
    return _shard_map(
        local_fn, mesh=sp.mesh, in_specs=(qspec, kvspec, kvspec, P()),
        out_specs=qspec, axis_names={axis}, check_vma=False)(
            q, k_cache, v_cache, cache_len)


def ring_decode_attention(q, k_cache, v_cache, key_pos, q_pos, *,
                          sliding_window=None, scale: Optional[float] = None,
                          sp: Optional[SPConfig] = None):
    """One-token attention against a ring-buffer KV cache.

    The serving cache for softmax layers stores only the last ``R`` tokens
    (``R`` = sliding window for windowed layers): slot ``i`` of the ring
    holds the key/value written at absolute position ``key_pos[b, i]``
    (``-1`` = never written). Because softmax attention is permutation
    invariant given correct masking, slots are attended in storage order —
    no unrotation — with validity derived from the stored positions:

        valid = key_pos >= 0  &  key_pos <= q_pos
                [&  q_pos - key_pos < sliding_window]

    q: (B, Hq, 1, dh); k_cache/v_cache: (B, Hkv, R, dh);
    key_pos: (B, R) int32 absolute positions; q_pos: (B,) int32 per-row
    query positions (continuous batching — rows decode at different
    offsets). ``sliding_window`` may be a traced scalar (hymba's dynamic
    global/local switch). With ``sp``, ring slots are sharded over
    ``sp.sp_axis`` and per-shard online-softmax partials are merged as in
    :func:`sharded_decode_attention`.
    """
    if scale is None:
        scale = q.shape[-1] ** -0.5

    def partial_attend(q_, k_, v_, valid):
        b, hq, _, dh = q_.shape
        rep = hq // k_.shape[1]
        kf = jnp.repeat(k_, rep, axis=1).astype(jnp.float32)
        vf = jnp.repeat(v_, rep, axis=1).astype(jnp.float32)
        s = jnp.einsum("bhd,bhtd->bht", q_[:, :, 0].astype(jnp.float32),
                       kf) * scale
        s = jnp.where(valid[:, None, :], s, NEG_INF)
        m = jnp.max(s, axis=-1)
        p = jnp.where(valid[:, None, :], jnp.exp(s - m[..., None]), 0.0)
        l = jnp.sum(p, axis=-1)
        o = jnp.einsum("bht,bhtd->bhd", p, vf)
        return o, m, l

    def slot_valid(kp, qp):
        valid = (kp >= 0) & (kp <= qp[:, None])
        if sliding_window is not None:
            valid &= (qp[:, None] - kp) < sliding_window
        return valid

    if sp is None or sp.degree == 1:
        o, m, l = partial_attend(q, k_cache, v_cache,
                                 slot_valid(key_pos, q_pos))
        o = o / jnp.maximum(l, 1e-30)[..., None]
        return o[:, :, None, :].astype(q.dtype)

    axis = sp.sp_axis
    w = sp.degree

    def local_fn(q_, k_, v_, kp_, qp_):
        o, m, l = partial_attend(q_, k_, v_, slot_valid(kp_, qp_))
        og = comm_primitives.allgather_states(
            o, axis, axis_size=w, tag="ring_decode.o")
        mg = comm_primitives.allgather_states(
            m, axis, axis_size=w, tag="ring_decode.m")
        lg = comm_primitives.allgather_states(
            l, axis, axis_size=w, tag="ring_decode.l")
        m_glob = jnp.max(mg, axis=0)
        corr = jnp.exp(mg - m_glob[None])
        l_glob = jnp.sum(lg * corr, axis=0)
        o_glob = jnp.sum(og * corr[..., None], axis=0)
        o_final = o_glob / jnp.maximum(l_glob, 1e-30)[..., None]
        return o_final[:, :, None, :].astype(q_.dtype)

    qspec = P(None, None, None, None)
    kvspec = P(None, None, axis, None)
    return _shard_map(
        local_fn, mesh=sp.mesh,
        in_specs=(qspec, kvspec, kvspec, P(None, axis), P()),
        out_specs=qspec, axis_names={axis}, check_vma=False)(
            q, k_cache, v_cache, key_pos, q_pos)
