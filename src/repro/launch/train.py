"""Training launcher.

Single-host (this container): runs the fault-tolerant loop on the local
device(s). On a real multi-host TPU/TRN cluster the same entry point is
launched per host with ``jax.distributed.initialize()`` (coordinator from
env) and the production mesh; data sharding per host falls out of the
deterministic pipeline (batch(step) is a pure function).

  PYTHONPATH=src python -m repro.launch.train --arch linear-llama3-1b \
      --steps 300 --batch 8 --seq 512 --smoke --ckpt-dir /tmp/ckpt
"""

from __future__ import annotations

import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="linear-llama3-1b")
    ap.add_argument("--variant", default=None,
                    help="config-module variant (e.g. HYBRID, DENSE)")
    ap.add_argument("--linearize", type=int, default=None,
                    help="paper recipe: 0=pure linear, k=1/k hybrid")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced smoke config")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=512)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--guard", action="store_true",
                    help="in-graph numerical health guard "
                         "(docs/resilience.md): finite check piggybacked "
                         "on the packed grad all-reduce (zero extra "
                         "collectives), skip-step on non-finite updates, "
                         "rolling-median grad-norm spike clipping, abort "
                         "after --guard-max-skips consecutive skips")
    ap.add_argument("--guard-max-skips", type=int, default=8,
                    help="consecutive skipped steps before the loop "
                         "aborts with GuardAbort")
    ap.add_argument("--ckpt-verify", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="verify per-array SHA-256 checksums on restore; "
                         "a corrupt latest checkpoint falls back to the "
                         "newest valid one (--no-ckpt-verify to disable)")
    ap.add_argument("--remat", default="none", choices=["none", "full"])
    ap.add_argument("--multi-device", action="store_true",
                    help="use all local devices as a (data,) mesh")
    ap.add_argument("--dp-degree", type=int, default=0,
                    help="data-parallel degree of the 2D (data, sequence) "
                         "training mesh; with --sp-degree, dp×sp must "
                         "equal the device count (docs/parallelism.md)")
    ap.add_argument("--sp-degree", type=int, default=0,
                    help="sequence-parallel degree of the 2D training "
                         "mesh (LASP-2 SP over the 'sequence' axis)")
    ap.add_argument("--tp-degree", type=int, default=0,
                    help="head-parallel degree of the 3D DP×SP×TP "
                         "training mesh ('model' axis — the ulysses "
                         "All-to-All head repartition for hybrid "
                         "layers; docs/parallelism.md §3D)")
    ap.add_argument("--no-zero1", action="store_true",
                    help="replicate optimizer state instead of ZeRO-1 "
                         "sharding it over the data axis")
    ap.add_argument("--grad-compression", action="store_true")
    ap.add_argument("--comm-strategy", default="allgather",
                    choices=["allgather", "ring", "pipelined", "ulysses"],
                    help="SP state-exchange strategy (repro/comm)")
    ap.add_argument("--comm-overlap", default="overlap",
                    choices=["overlap", "none"],
                    help="comm/compute overlap mode (A/B benchmarking)")
    ap.add_argument("--comm-dtype", default="fp32",
                    choices=["fp32", "bf16"],
                    help="wire dtype of the SP state/KV exchanges (bf16 "
                         "halves per-layer collective bytes; combines "
                         "stay fp32 — docs/communication.md)")
    ap.add_argument("--metrics-out", default=None,
                    help="write run telemetry (per-step phase walls, "
                         "tokens/s, MFU, expected-vs-compiled collective "
                         "bytes) as JSONL here; render with "
                         "scripts/report.py (docs/observability.md)")
    ap.add_argument("--kernel-backend", default=None,
                    choices=["xla", "pallas", "interpret"],
                    help="intra-chunk/attention kernel path "
                         "(repro/kernels/ops.py; default: pallas on TPU, "
                         "xla elsewhere)")
    args = ap.parse_args()

    import jax

    from repro.configs import get_config, get_smoke, get_variant
    from repro.configs.base import RunConfig
    from repro.data.pipeline import SyntheticLM
    from repro.sharding.rules import make_plan
    from repro.train.loop import train

    if args.smoke:
        cfg = get_smoke(args.arch)
    elif args.variant:
        cfg = get_variant(args.arch, args.variant)
    else:
        cfg = get_config(args.arch, linearize=args.linearize)

    run = RunConfig(num_microbatches=args.microbatches,
                    learning_rate=args.lr, total_steps=args.steps,
                    warmup_steps=max(args.steps // 20, 5),
                    remat=args.remat, seed=args.seed,
                    grad_compression=args.grad_compression,
                    comm_strategy=args.comm_strategy,
                    comm_overlap=args.comm_overlap,
                    comm_dtype=args.comm_dtype,
                    kernel_backend=args.kernel_backend,
                    zero1=not args.no_zero1,
                    dp_degree=args.dp_degree, sp_degree=args.sp_degree,
                    tp_degree=args.tp_degree,
                    guard=args.guard,
                    guard_max_consecutive_skips=args.guard_max_skips,
                    ckpt_verify=args.ckpt_verify)
    data = SyntheticLM(cfg.vocab_size, args.seq, args.batch,
                       seed=args.seed)
    plan = None
    if run.dp_degree or run.sp_degree or run.tp_degree:
        # DP×SP(×TP) training mesh (the paper's deployment shape plus
        # the optional ulysses head-parallel axis): batch over "data" ×
        # sequence over "sequence" (× "model"), ZeRO-1 optimizer state.
        from repro.launch.mesh import make_training_mesh
        # whichever degree is unset is inferred from the device count
        n_dev = len(jax.devices())
        tp = max(run.tp_degree, 1)
        dp = run.dp_degree or max(n_dev // (max(run.sp_degree, 1) * tp), 1)
        sp = run.sp_degree or max(n_dev // (dp * tp), 1)
        mesh = make_training_mesh(dp, sp, tp)
        mb = args.batch // args.microbatches
        if mb % dp or args.seq % max(sp * tp, 1):
            raise SystemExit(
                f"--batch/microbatches ({mb}) must divide by dp ({dp}) "
                f"and --seq ({args.seq}) by sp×tp ({sp}×{tp})")
        plan = make_plan(mesh, "train", global_batch=args.batch,
                         n_kv_heads=cfg.n_kv_heads, n_heads=cfg.n_heads,
                         backend=run.kernel_backend,
                         comm=run.comm_spec(), zero1=run.zero1)
    elif args.multi_device and len(jax.devices()) > 1:
        from repro.launch.mesh import DATA_AXIS, auto_axis_types
        mesh = jax.make_mesh((len(jax.devices()),), (DATA_AXIS,),
                             **auto_axis_types(1))
        plan = make_plan(mesh, "train", global_batch=args.batch,
                         n_kv_heads=cfg.n_kv_heads,
                         backend=run.kernel_backend,
                         comm=run.comm_spec())
    sink = None
    if args.metrics_out:
        from repro.obs import JsonlSink
        sink = JsonlSink(args.metrics_out)
    try:
        state, history = train(cfg, run, data, plan=plan,
                               ckpt_dir=args.ckpt_dir,
                               ckpt_every=args.ckpt_every, sink=sink)
    finally:
        if sink is not None:
            sink.close()
            print(f"[train] telemetry -> {args.metrics_out}")
    first = sum(h["loss"] for h in history[:10]) / max(len(history[:10]), 1)
    last = sum(h["loss"] for h in history[-10:]) / max(len(history[-10:]), 1)
    print(f"[train] {cfg.name}: loss {first:.4f} -> {last:.4f} over "
          f"{len(history)} steps "
          f"({'improved' if last < first else 'NOT improved'})")


if __name__ == "__main__":
    main()
