"""Version compatibility shims for jax APIs the repo relies on.

The codebase targets the modern ``jax.shard_map`` partial-manual API
(``axis_names`` = the manual axes, ``check_vma``). On older jax (< 0.5,
e.g. the 0.4.x pinned in some CPU containers) the same functionality lives
in ``jax.experimental.shard_map.shard_map`` with the inverse convention
(``auto`` = the NON-manual axes, ``check_rep``). This module exposes a
single :func:`shard_map` with the modern signature that dispatches to
whichever implementation exists.
"""

from __future__ import annotations

import jax


def shard_map(f, *, mesh, in_specs, out_specs, axis_names=None,
              check_vma=False):
    """Partial-manual shard_map with the modern keyword signature.

    ``axis_names``: set of mesh axes made manual inside ``f`` (all axes
    when None) — other axes stay auto-sharded by GSPMD.
    """
    if hasattr(jax, "shard_map"):
        kw = {}
        if axis_names is not None:
            kw["axis_names"] = set(axis_names)
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma, **kw)
    # Old jax: the partial-auto mode (``auto=``) lowers axis_index to a
    # PartitionId instruction XLA cannot SPMD-partition, so we run the body
    # fully manual instead. Axes absent from in_specs/out_specs are then
    # replicated inside the region rather than auto-sharded by GSPMD —
    # numerically identical, only the TP sharding of the body is lost.
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=check_vma)


def is_tracer(x) -> bool:
    """True iff ``x`` is a jax tracer (an abstract value inside a trace).

    ``jax.core.Tracer`` is deprecated-path API on newer jax (the class
    moved to ``jax.extend.core``); resolve whichever location exists so
    backend-dispatch checks (e.g. "is this sliding window dynamic?") keep
    working across versions without deprecation warnings.
    """
    tracer_cls = None
    try:
        from jax.extend import core as _jex_core
        tracer_cls = getattr(_jex_core, "Tracer", None)
    except ImportError:
        pass
    if tracer_cls is None:
        tracer_cls = jax.core.Tracer
    return isinstance(x, tracer_cls)


def tpu_compiler_params(**kw):
    """``pltpu.CompilerParams`` across the 0.4→0.5 rename
    (older jax exposes it as ``TPUCompilerParams``)."""
    from jax.experimental.pallas import tpu as pltpu
    cls = getattr(pltpu, "CompilerParams", None) \
        or getattr(pltpu, "TPUCompilerParams")
    return cls(**kw)


def cost_analysis(compiled) -> dict:
    """``Compiled.cost_analysis()`` as a flat dict on every jax version
    (older releases return a one-element list of per-program dicts)."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca or {}
