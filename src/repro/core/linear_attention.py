"""Core linear-attention math: oracles and chunked (block-scan) forms.

Everything here is *local* (single device) math. The SP layers in
``repro.core.lasp2`` compose these primitives with collectives.

Conventions
-----------
* Shapes: ``q, k: (..., S, dk)``, ``v: (..., S, dv)``; leading dims are
  batch/heads and are vmapped/broadcast.
* ``log_a: (..., S)`` is the per-token log-decay (``log a_s``, ``a_s in (0, 1]``,
  so ``log_a <= 0``). ``log_a = 0`` everywhere recovers basic linear attention
  (paper Eq. 3/4). A value of ``-inf`` (we use a large negative number) resets
  the state — used for document packing (paper §A.4.2).
* The recurrence (decay-generalized paper Eq. 4):

      M_s = a_s * M_{s-1} + k_s^T v_s,        o_s = q_s M_s

* All state/decay math is fp32; inputs may be bf16.

Numerical stability: within a block of length C we form cumulative log decays
``cb_i = sum_{j<=i} log_a_j`` (inclusive). All reweighting factors used are
``exp(cb_i - cb_j)`` with ``i >= j`` or ``exp(sum - cb_i)``, which are <= 1
because ``log_a <= 0`` — no overflow, fp32 throughout.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

# Stand-in for log(0) used by document-boundary state resets. Must be large
# enough that exp(RESET_LOG_A) underflows any realistic state magnitude
# (exp(-60) ~ 1e-26) but small enough that fp32 cumulative sums containing a
# handful of resets keep full relative precision (eps(60R) << 1 for R resets
# per block). -1e9 would be wrong: it wipes out all neighbouring log-decay
# information through catastrophic cancellation in the cumsum.
RESET_LOG_A = -60.0

# Block sizes the MXU tiles without padding waste, largest first.
MXU_ALIGNED_BLOCKS = (256, 128, 64, 32)


def pick_block(s: int, preferred: int) -> int:
    """Chunk block size for a local sequence of length ``s``.

    Returns ``preferred`` (capped at ``s``) when it divides ``s``;
    otherwise the largest MXU-aligned divisor (128/64/32 — e.g. S=192,
    preferred=128 → 64: three full tiles instead of two ragged 96-blocks);
    only when no aligned divisor exists, the largest divisor <= preferred.
    Shared by ``core/lasp2.py`` and ``kernels/ops.py`` — keep the policy in
    one place so the XLA scan and the Pallas kernel block identically.
    """
    bs = min(preferred, s)
    if bs < 1:
        return 1
    if s % bs == 0:
        return bs
    for cand in MXU_ALIGNED_BLOCKS:
        if cand <= bs and s % cand == 0:
            return cand
    while s % bs:
        bs -= 1
    return max(bs, 1)


class ChunkOutputs(NamedTuple):
    """Outputs of a chunked linear-attention pass over a local sequence."""

    o: jax.Array          # (..., S, dv) attention output
    state: jax.Array      # (..., dk, dv) final memory state (fp32)
    log_decay: jax.Array  # (...,) total log decay across the sequence (fp32)


# ---------------------------------------------------------------------------
# Oracles (sequential scan) — ground truth for tests.
# ---------------------------------------------------------------------------

def sequential_oracle(q, k, v, log_a=None, initial_state=None, causal=True):
    """Token-by-token recurrence; ground truth. O(S) scan, fp32.

    With ``causal=False`` computes the bidirectional (no-mask) form:
    ``o_s = q_s M_{1:S}`` (paper Alg. 1 semantics).
    """
    *lead, S, dk = q.shape
    dv = v.shape[-1]
    if log_a is None:
        log_a = jnp.zeros((*lead, S), dtype=jnp.float32)
    qf = q.astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    laf = log_a.astype(jnp.float32)
    s0 = (jnp.zeros((*lead, dk, dv), jnp.float32)
          if initial_state is None else initial_state.astype(jnp.float32))

    def step(m, inp):
        qs, ks, vs, la = inp  # (..., dk), (..., dk), (..., dv), (...,)
        a = jnp.exp(la)[..., None, None]
        m = a * m + ks[..., :, None] * vs[..., None, :]
        o = jnp.einsum("...k,...kv->...v", qs, m)
        return m, o

    xs = (jnp.moveaxis(qf, -2, 0), jnp.moveaxis(kf, -2, 0),
          jnp.moveaxis(vf, -2, 0), jnp.moveaxis(laf, -1, 0))
    m_final, o = jax.lax.scan(step, s0, xs)
    o = jnp.moveaxis(o, 0, -2)
    if not causal:
        # Bidirectional: every position reads the full-sequence state.
        o = jnp.einsum("...sk,...kv->...sv", qf, m_final)
    total_log_a = jnp.sum(laf, axis=-1)
    return ChunkOutputs(o.astype(q.dtype), m_final, total_log_a)


def recurrent_step(q, k, v, log_a=None, *, state, log_decay=None):
    """One recurrent decode step (paper Eq. 4) — the constant-memory path.

    Single-token inputs ``q, k: (..., dk)``, ``v: (..., dv)``,
    ``log_a: (...,)`` against the carried fp32 ``state: (..., dk, dv)`` and
    cumulative ``log_decay: (...,)``:

        M' = a * M + k^T v,      o = q M',      L' = L + log a

    Returns ``(o (..., dv) fp32, state' fp32, log_decay' fp32)``. Exactly
    the per-token recurrence of :func:`sequential_oracle`, so decoding from
    a prefill state reproduces the full chunked forward. The serving decode
    cache stores only ``(state, log_decay)`` — O(1) in context length.
    """
    qf = q.astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    m = state.astype(jnp.float32)
    if log_decay is None:
        log_decay = jnp.zeros(m.shape[:-2], jnp.float32)
    if log_a is not None:
        laf = log_a.astype(jnp.float32)
        m = jnp.exp(laf)[..., None, None] * m
        log_decay = log_decay + laf
    m = m + kf[..., :, None] * vf[..., None, :]
    o = jnp.einsum("...k,...kv->...v", qf, m)
    return o, m, log_decay


# ---------------------------------------------------------------------------
# Block-local (intra-chunk) primitives.
# ---------------------------------------------------------------------------

def _block_terms(q, k, v, log_a):
    """Per-block quantities, fp32. Block length C is the last-but-one dim.

    Returns (in fp32):
      o_intra: (..., C, dv)  masked intra-block output (zero initial state)
      m_blk:   (..., dk, dv) end-of-block state contribution
                             ``sum_i exp(cb_C - cb_i) k_i^T v_i``
      b:       (..., C)      inclusive cumulative decay ``exp(cb_i)``
      a_blk:   (...,)        total block log decay ``cb_C``
    """
    qf, kf, vf = (x.astype(jnp.float32) for x in (q, k, v))
    laf = log_a.astype(jnp.float32)
    cb = jnp.cumsum(laf, axis=-1)                      # (..., C) inclusive
    a_blk = cb[..., -1]
    # D_ij = exp(cb_i - cb_j) for i >= j else 0  (i: query pos, j: key pos).
    # The exponent is neutralized on the masked region with ``where``, NOT
    # clamped with ``minimum``: on the kept region diff <= 0 already
    # (log_a <= 0), and at log_a == 0 a clamp sits exactly on the min tie,
    # where jax's tie-splitting gradient would silently halve d log_a —
    # the kernel-grad parity tests pin the exact derivative.
    diff = cb[..., :, None] - cb[..., None, :]
    mask = jnp.tril(jnp.ones(diff.shape[-2:], bool))
    decay_mat = jnp.where(mask, jnp.exp(jnp.where(mask, diff, 0.0)), 0.0)
    scores = jnp.einsum("...ik,...jk->...ij", qf, kf) * decay_mat
    o_intra = jnp.einsum("...ij,...jv->...iv", scores, vf)
    # State contribution decayed to block end: weight exp(cb_C - cb_i) <= 1.
    w = jnp.exp(a_blk[..., None] - cb)                 # (..., C)
    m_blk = jnp.einsum("...ck,...cv->...kv", kf * w[..., None], vf)
    return o_intra, m_blk, jnp.exp(cb), a_blk


def block_summary(k, v, log_a):
    """State contribution + total log decay of a block (no output).

    Cheaper than ``_block_terms`` — skips the intra-block score matrix.
    """
    kf, vf = k.astype(jnp.float32), v.astype(jnp.float32)
    cb = jnp.cumsum(log_a.astype(jnp.float32), axis=-1)
    a_blk = cb[..., -1]
    w = jnp.exp(a_blk[..., None] - cb)                 # <= 1
    m_blk = jnp.einsum("...ck,...cv->...kv", kf * w[..., None], vf)
    return m_blk, a_blk


def _split_blocks(x, nb, block_size, *, seq_axis_is_last=False):
    """(..., S, d) -> (nb, ..., C, d)  or  (..., S) -> (nb, ..., C)."""
    if seq_axis_is_last:
        x = x.reshape(*x.shape[:-1], nb, block_size)
        return jnp.moveaxis(x, -2, 0)
    x = x.reshape(*x.shape[:-2], nb, block_size, x.shape[-1])
    return jnp.moveaxis(x, -3, 0)


def chunk_scan(q, k, v, log_a=None, *, initial_state=None, block_size=128):
    """Chunked causal linear attention over a local sequence (XLA path).

    Splits S into blocks of ``block_size``; scans over blocks carrying the
    fp32 memory state. Equivalent to ``sequential_oracle`` but runs on MXU
    friendly matmuls. This is the lightning-attention-2-style local form the
    Pallas kernel (``repro.kernels.lasp2_chunk``) mirrors.
    """
    *lead, S, dk = q.shape
    dv = v.shape[-1]
    if log_a is None:
        log_a = jnp.zeros((*lead, S), dtype=jnp.float32)
    if S % block_size:
        raise ValueError(f"S={S} not divisible by block_size={block_size}")
    nb = S // block_size
    s0 = (jnp.zeros((*lead, dk, dv), jnp.float32)
          if initial_state is None else initial_state.astype(jnp.float32))

    def body(carry, xs):
        m, ld = carry  # running state (fp32), running log decay
        qb, kb, vb, lab = xs
        o_intra, m_blk, b, a_blk = _block_terms(qb, kb, vb, lab)
        o = o_intra + jnp.einsum(
            "...ck,...kv->...cv", qb.astype(jnp.float32) * b[..., None], m)
        m = jnp.exp(a_blk)[..., None, None] * m + m_blk
        return (m, ld + a_blk), o

    # (nb, ..., C, d)
    xs = (_split_blocks(q, nb, block_size),
          _split_blocks(k, nb, block_size),
          _split_blocks(v, nb, block_size),
          _split_blocks(log_a.astype(jnp.float32), nb, block_size,
                        seq_axis_is_last=True))
    (m, ld), o_blocks = jax.lax.scan(body, (s0, jnp.zeros(tuple(lead), jnp.float32)), xs)
    o = jnp.moveaxis(o_blocks, 0, -3)  # (..., nb, C, dv)
    o = o.reshape(*o.shape[:-3], S, dv)
    return ChunkOutputs(o.astype(q.dtype), m, ld)


def chunk_summaries(k, v, log_a=None, *, block_size=128):
    """(M_local, A_local) of a local sequence without computing outputs.

    Used by the LASP-2 forward to produce the tensors that get AllGathered
    *before/concurrently with* the intra-chunk output computation (paper
    Alg. 2 lines 6–7; the overlap opportunity).
    """
    *lead, S, dk = k.shape
    dv = v.shape[-1]
    if log_a is None:
        log_a = jnp.zeros((*lead, S), dtype=jnp.float32)
    if S % block_size:
        raise ValueError(f"S={S} not divisible by block_size={block_size}")
    nb = S // block_size

    def body(carry, xs):
        m, ld = carry
        kb, vb, lab = xs
        m_blk, a_blk = block_summary(kb, vb, lab)
        m = jnp.exp(a_blk)[..., None, None] * m + m_blk
        return (m, ld + a_blk), None

    xs = (_split_blocks(k, nb, block_size),
          _split_blocks(v, nb, block_size),
          _split_blocks(log_a.astype(jnp.float32), nb, block_size,
                        seq_axis_is_last=True))
    s0 = (jnp.zeros((*lead, dk, dv), jnp.float32),
          jnp.zeros(tuple(lead), jnp.float32))
    (m, ld), _ = jax.lax.scan(body, s0, xs)
    return m, ld


# ---------------------------------------------------------------------------
# Gathered-state combines (the local math around an SP exchange).
# ---------------------------------------------------------------------------

def prefix_state_combine(ms, cum, t):
    """Decayed prefix-combine of gathered chunk states (paper Alg. 2 line 9).

    ms:  (W, ..., dk, dv) gathered chunk states (fp32)
    cum: (W, ...) inclusive cumulative chunk log-decays along axis 0
    t:   my chunk index (traced scalar)

    Returns M_{1:t-1} decayed to the *start* of chunk t:
        sum_{j < t} exp(cum[t-1] - cum[j]) * ms[j]
    """
    w_idx = jnp.arange(ms.shape[0])
    cum_tm1 = jax.lax.dynamic_index_in_dim(
        cum, jnp.maximum(t - 1, 0), axis=0, keepdims=False)
    logw = cum_tm1[None] - cum                           # <= 0 for j <= t-1
    mask = (w_idx < t)
    m = jnp.broadcast_to(
        mask.reshape((ms.shape[0],) + (1,) * (cum.ndim - 1)), logw.shape)
    # where-masked exponent, not min-clamped — see _block_terms.
    w = jnp.where(m, jnp.exp(jnp.where(m, logw, 0.0)), 0.0)
    return jnp.einsum("w...,w...kv->...kv", w, ms)


def suffix_grad_combine(dms, cum, t):
    """Decayed suffix-combine of gathered state grads (paper Alg. 4 line 9).

    dM_t^loc = sum_{t' > t} exp(cum[t'-1] - cum[t]) * dms[t']
    """
    w_idx = jnp.arange(dms.shape[0])
    cum_t = jax.lax.dynamic_index_in_dim(cum, t, axis=0, keepdims=False)
    cum_prev = jnp.concatenate([jnp.zeros_like(cum[:1]), cum[:-1]], axis=0)
    logw = cum_prev - cum_t[None]                        # <= 0 for t' > t
    mask = (w_idx > t)
    m = jnp.broadcast_to(
        mask.reshape((dms.shape[0],) + (1,) * (cum.ndim - 1)), logw.shape)
    w = jnp.where(m, jnp.exp(jnp.where(m, logw, 0.0)), 0.0)
    return jnp.einsum("w...,w...kv->...kv", w, dms)


# ---------------------------------------------------------------------------
# Feature maps (paper §4: basic / Lightning / Retention / GLA / Based).
# ---------------------------------------------------------------------------

def feature_map(x, kind: str):
    """Kernel feature maps applied to q and k before the linear recurrence."""
    if kind in ("identity", "none"):
        return x
    if kind == "elu1":         # Katharopoulos et al. basic linear attention
        return jax.nn.elu(x) + 1.0
    if kind == "silu":         # Lightning attention
        return jax.nn.silu(x)
    if kind == "relu":
        return jax.nn.relu(x)
    if kind == "taylor":       # Based: 1 + x + x^2/sqrt(2) second-order terms
        d = x.shape[-1]
        x2 = jnp.einsum("...i,...j->...ij", x, x) / jnp.sqrt(2.0)
        x2 = x2.reshape(*x.shape[:-1], d * d)
        ones = jnp.ones((*x.shape[:-1], 1), x.dtype)
        return jnp.concatenate([ones, x, x2], axis=-1)
    raise ValueError(f"unknown feature map {kind!r}")


def decay_log_a(kind: str, *, heads: int, s: int, gate=None, dtype=jnp.float32):
    """Per-token log decays ``(heads, s)`` for the supported variants.

    kind:
      "none"      — basic linear attention (log a = 0)
      "retention" — RetNet fixed per-head decay 1 - 2^{-5-h}
      "lightning" — Lightning/TransNormer fixed per-head slope (ALiBi-like)
      "data"      — data-dependent (caller passes ``gate`` = log a directly,
                    e.g. from a learned projection; GLA-lite / Mamba-2 SSD)
    """
    if kind == "none":
        return jnp.zeros((heads, s), dtype)
    if kind == "retention":
        a = 1.0 - jnp.exp2(-5.0 - jnp.arange(heads, dtype=jnp.float32))
        return jnp.broadcast_to(jnp.log(a)[:, None], (heads, s)).astype(dtype)
    if kind == "lightning":
        slope = jnp.exp2(-8.0 * (jnp.arange(heads, dtype=jnp.float32) + 1) / heads)
        return jnp.broadcast_to(-slope[:, None], (heads, s)).astype(dtype)
    if kind == "data":
        assert gate is not None, "data-dependent decay needs a gate"
        return gate
    raise ValueError(f"unknown decay kind {kind!r}")
