"""Continuous-batching scheduler unit tests (pure slot bookkeeping)."""

import numpy as np
import pytest

from repro.serve.scheduler import ContinuousScheduler, bucket_length


def _sched(**kw):
    return ContinuousScheduler(max_batch=4, max_len=128, **kw)


def test_bucket_length_powers_of_two():
    assert bucket_length(1) == 16          # floor
    assert bucket_length(16) == 16
    assert bucket_length(17) == 32
    assert bucket_length(100) == 128


def test_admission_respects_free_slots():
    s = _sched()
    for i in range(6):
        s.submit(np.arange(8), 4)
    batches = s.admit()
    admitted = sum(len(b.requests) for b in batches)
    assert admitted == 4                   # grid is full
    assert len(s.waiting) == 2
    assert not s.free_slots()
    assert s.admit() == []                 # no free slots -> no admission


def test_admission_groups_by_length_and_buckets():
    s = _sched()
    s.submit(np.arange(8), 4)
    s.submit(np.arange(12), 4)
    s.submit(np.arange(8), 4)
    batches = s.admit()
    sizes = sorted(b.prompts.shape for b in batches)
    assert sizes == [(1, 12), (2, 8)]      # exact-length groups
    assert all(not b.padded for b in batches)

    s2 = _sched(bucket_lengths=True)
    s2.submit(np.arange(8), 4)
    s2.submit(np.arange(12), 4)
    (b,) = s2.admit()                      # both land in the 16-bucket
    assert b.prompts.shape == (2, 16)
    np.testing.assert_array_equal(b.pad_lens, [8, 4])
    # left-padded: real tokens right-aligned
    np.testing.assert_array_equal(b.prompts[0, 8:], np.arange(8))
    np.testing.assert_array_equal(b.prompts[0, :8], 0)


def test_eviction_frees_slots_for_waiting_requests():
    s = _sched()
    for i in range(5):
        s.submit(np.arange(4), max_new_tokens=2, eos_id=99)
    (b,) = s.admit()
    # slot 0 hits EOS on its first (prefill-sampled) token
    finished = s.record_prefill(b, np.array([99, 1, 1, 1]))
    assert [r.slot for r in finished] == [0]
    assert finished[0].finish_reason == "eos"
    assert s.free_slots() == [0]
    (b2,) = s.admit()                      # waiting request takes slot 0
    assert list(b2.slots) == [0]
    # remaining three finish by length budget on the next decode step
    done = s.record_step(np.array([5, 5, 5, 5]))
    assert {r.finish_reason for r in done} == {"length"}
    assert len(s.free_slots()) == 3
    assert not s.waiting


def test_submit_rejects_overlong_requests():
    s = _sched()
    with pytest.raises(ValueError):
        s.submit(np.arange(120), max_new_tokens=16)


def test_submit_rejects_degenerate_requests():
    s = _sched()
    with pytest.raises(ValueError):
        s.submit(np.array([], np.int32), max_new_tokens=4)
    with pytest.raises(ValueError):
        s.submit(np.arange(8), max_new_tokens=0)


def test_fifo_admission_order():
    s = _sched()
    uids = [s.submit(np.arange(8), 4) for _ in range(6)]
    (b,) = s.admit()
    assert [r.uid for r in b.requests] == uids[:4]
    assert [r.uid for r in s.waiting] == uids[4:]
