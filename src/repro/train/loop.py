"""Fault-tolerant training loop.

* auto-resume from the latest checkpoint (determinism: batch(step) is a
  pure function, so resumed runs are bitwise-identical),
* periodic async checkpointing (atomic; crash-safe),
* step watchdog: wall-time per step is tracked, slow steps logged — the
  single-host analogue of straggler detection; on a real cluster the same
  hook triggers the coordinator's unhealthy-host path,
* non-finite gradient steps are skipped inside the jitted step,
* SIGTERM/KeyboardInterrupt → final checkpoint, clean exit (preemption).
"""

from __future__ import annotations

import signal
import time
from typing import Callable, Optional

import jax
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.configs.base import ModelConfig, RunConfig
from repro.data.pipeline import SyntheticLM
from repro.sharding.rules import Parallelism
from repro.train.step import init_state, make_train_step


class StepWatchdog:
    """Tracks step durations; flags stragglers (> factor × median)."""

    def __init__(self, factor: float = 3.0, window: int = 50):
        self.times, self.factor, self.window = [], factor, window
        self.slow_steps = 0

    def record(self, dt: float) -> bool:
        self.times.append(dt)
        self.times = self.times[-self.window:]
        med = float(np.median(self.times))
        slow = len(self.times) >= 10 and dt > self.factor * med
        self.slow_steps += int(slow)
        return slow


def train(cfg: ModelConfig, run: RunConfig, data: SyntheticLM, *,
          plan: Optional[Parallelism] = None, ckpt_dir: Optional[str] = None,
          ckpt_every: int = 50, log_every: int = 10,
          log_fn: Callable[[str], None] = print, max_steps=None):
    """Returns (final_state, history list of metric dicts)."""
    # single-device default still honours the kernel-backend knob
    plan = plan or Parallelism(backend=run.kernel_backend)
    key = jax.random.PRNGKey(run.seed)
    state = init_state(key, cfg, run, plan)
    start_step = 0

    mgr = CheckpointManager(ckpt_dir) if ckpt_dir else None
    if mgr is not None:
        latest = mgr.latest_step()
        if latest is not None:
            state = mgr.restore(latest, state)
            start_step = latest
            log_fn(f"[resume] restored step {latest} from {ckpt_dir}")

    step_fn = jax.jit(make_train_step(cfg, run, plan), donate_argnums=(0,))
    watchdog = StepWatchdog()
    history = []
    total = max_steps if max_steps is not None else run.total_steps

    stop = {"now": False}

    def _sig(_sig, _frm):
        stop["now"] = True

    old_handler = signal.signal(signal.SIGTERM, _sig)
    try:
        for step in range(start_step, total):
            batch = data.microbatched(step, run.num_microbatches)
            t0 = time.perf_counter()
            state, metrics = step_fn(state, batch)
            metrics = {k: float(v) for k, v in metrics.items()}
            dt = time.perf_counter() - t0
            metrics["step"], metrics["dt"] = step, dt
            history.append(metrics)
            if watchdog.record(dt):
                log_fn(f"[watchdog] step {step} straggled: {dt:.2f}s")
            if step % log_every == 0:
                log_fn(f"step {step:5d} loss {metrics['loss']:.4f} "
                       f"gnorm {metrics['grad_norm']:.2f} "
                       f"lr {metrics['lr']:.2e} {dt*1e3:.0f}ms")
            if mgr is not None and (step + 1) % ckpt_every == 0:
                mgr.save_async(step + 1, state)
            if stop["now"]:
                log_fn(f"[signal] interrupted at step {step}; saving")
                break
    except KeyboardInterrupt:
        log_fn("[interrupt] saving final checkpoint")
    finally:
        signal.signal(signal.SIGTERM, old_handler)
        if mgr is not None:
            mgr.wait()
            mgr.save(int(state["step"]), state)
    return state, history
