"""Shared benchmark utilities.

Wall-clock numbers on this CPU container are *indicative* (the TPU is the
target, not the runtime); every bench therefore also derives the analytic
quantity the paper's table is actually about (loss, comm steps, traffic,
memory). Multi-device timing benches run in subprocesses with 8 virtual
host devices so the main process keeps its single default device.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
import time

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def timeit(fn, *args, iters=5, warmup=2):
    for _ in range(warmup):
        out = fn(*args)
    _block(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    _block(out)
    return (time.perf_counter() - t0) / iters


def _block(out):
    import jax
    jax.tree.map(lambda x: x.block_until_ready()
                 if hasattr(x, "block_until_ready") else x, out)


def run_subprocess_bench(code: str, *, devices: int = 8,
                         timeout: int = 1200) -> dict:
    """Run `code` (which must print a JSON dict on its last line) in a
    subprocess with N virtual devices."""
    prelude = (
        "import os\n"
        f"os.environ['XLA_FLAGS'] = "
        f"'--xla_force_host_platform_device_count={devices}'\n"
        f"import sys; sys.path.insert(0, {SRC!r})\n")
    proc = subprocess.run([sys.executable, "-c", prelude + code],
                          capture_output=True, text=True, timeout=timeout)
    if proc.returncode != 0:
        raise RuntimeError(f"bench subprocess failed:\n{proc.stderr[-2000:]}")
    return json.loads(proc.stdout.strip().splitlines()[-1])


def emit(rows, header=None):
    """Print CSV rows: name,us_per_call,derived."""
    if header:
        print(header)
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
