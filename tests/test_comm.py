"""Comm subsystem (repro/comm): single-device unit tests + the 8-virtual-
device parity/budget battery (run in a subprocess so this pytest process
keeps its single default device)."""

import os
import subprocess
import sys

import pytest


def test_comm_battery():
    script = os.path.join(os.path.dirname(__file__), "comm_checks.py")
    proc = subprocess.run([sys.executable, script], capture_output=True,
                          text=True, timeout=1200)
    sys.stdout.write(proc.stdout)
    sys.stderr.write(proc.stderr[-3000:])
    assert proc.returncode == 0, "comm checks failed"
    assert "ALL" in proc.stdout and "PASSED" in proc.stdout


# --- pick_block (shared block policy) --------------------------------------

def test_pick_block_prefers_mxu_aligned_divisors():
    from repro.core.linear_attention import pick_block
    assert pick_block(512, 128) == 128        # preferred divides
    assert pick_block(64, 128) == 64          # short sequence: one block
    assert pick_block(192, 128) == 64         # NOT 96: aligned 64 wins
    assert pick_block(320, 128) == 64         # NOT 80
    assert pick_block(96, 128) == 96          # whole-sequence block is fine
    assert pick_block(3 * 32, 64) == 32       # aligned divisor < preferred
    assert pick_block(200, 128) == 100        # no aligned divisor: largest
    assert pick_block(97, 128) == 97          # prime < preferred: one block
    assert pick_block(97, 64) == 1            # prime > preferred: degenerate


def test_ops_pads_instead_of_degenerate_blocks():
    """kernels/ops shares pick_block but right-pads awkward lengths."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.core.linear_attention import sequential_oracle
    from repro.kernels.ops import linear_attention_op

    key = jax.random.PRNGKey(0)
    for s in (192, 200, 97):
        ks = jax.random.split(key, 3)
        q = jax.random.normal(ks[0], (1, 2, s, 16)) * 0.3
        k = jax.random.normal(ks[1], (1, 2, s, 16)) * 0.3
        v = jax.random.normal(ks[2], (1, 2, s, 16)) * 0.5
        o, st, _ = linear_attention_op(q, k, v, None, block_size=128,
                                       backend="xla")
        ref = sequential_oracle(q, k, v)
        np.testing.assert_allclose(np.asarray(o), np.asarray(ref.o),
                                   rtol=3e-4, atol=3e-4)
        np.testing.assert_allclose(np.asarray(st), np.asarray(ref.state),
                                   rtol=3e-4, atol=3e-4)
    del jnp


# --- budget bookkeeping (no devices needed) --------------------------------

def test_budget_tables():
    from repro.comm import lasp2_budget, ring_baseline_budget
    assert lasp2_budget("allgather", 8).counts == {"all-gather": 1}
    assert lasp2_budget("allgather", 8, with_grad=True).counts == \
        {"all-gather": 2}
    assert lasp2_budget("allgather", 8, with_grad=True,
                        backward="autodiff").counts == \
        {"all-gather": 1, "reduce-scatter": 1}
    assert lasp2_budget("ring", 8).counts == {"collective-permute": 7}
    assert lasp2_budget("ring", 8, with_grad=True).counts == \
        {"collective-permute": 14}
    assert lasp2_budget("pipelined", 8, n_slices=4).counts == \
        {"collective-permute": 28}
    assert ring_baseline_budget(64, with_grad=True).counts == \
        {"collective-permute": 126}      # the paper's 2(W-1) at W=64
    with pytest.raises(ValueError):
        lasp2_budget("smoke-signals", 8)


def test_check_budget_on_synthetic_hlo():
    from repro.comm import CollectiveBudget, check_budget

    hlo = """
HloModule m
ENTRY e {
  %x = f32[8,16]{1,0} parameter(0)
  %ag = f32[64,16]{1,0} all-gather(%x), replica_groups={{0,1,2,3,4,5,6,7}}, dimensions={0}
  %cp = f32[8,16]{1,0} collective-permute(%x), source_target_pairs={{0,1}}
  ROOT %r = f32[64,16]{1,0} add(%ag, %ag)
}
"""
    ok = CollectiveBudget({"all-gather": 1, "collective-permute": 1})
    assert check_budget(hlo, ok, 8) == []
    bad = CollectiveBudget({"all-gather": 2})
    violations = check_budget(hlo, bad, 8)
    assert len(violations) == 2          # wrong count + unexpected permute
    loose = CollectiveBudget({"all-gather": 1}, strict=False)
    assert check_budget(hlo, loose, 8) == []
    capped = CollectiveBudget({"all-gather": 1, "collective-permute": 1},
                              max_traffic={"all-gather": 10.0})
    assert any("exceeds budget" in v for v in check_budget(hlo, capped, 8))


def test_comm_record_cost_model():
    """Tape traffic uses the same ring model as hlo_analysis."""
    import jax.numpy as jnp
    from repro.comm.primitives import (CommRecord, auto_slices,
                                       tape_summary)
    del jnp
    r = CommRecord("all-gather", 1000, 7000, steps=1, group=8)
    assert tape_summary([r])["total_bytes"] == 7000
    rs = [CommRecord("collective-permute", 100, 100, steps=1, group=8)
          for _ in range(7)]
    s = tape_summary(rs)
    assert s["collective-permute_count"] == 7 and s["total_steps"] == 7
    assert auto_slices(64) == 4
    assert auto_slices(6) == 2
    assert auto_slices(7) == 1


def test_strategy_registry_and_overlap_modes():
    from repro.comm import get_strategy
    from repro.comm.overlap import DoubleBufferedScheduler

    assert get_strategy("allgather").supports_faithful
    assert not get_strategy("ring").supports_faithful
    assert get_strategy("pipelined").name == "pipelined"
    with pytest.raises(ValueError):
        get_strategy("carrier-pigeon")
    with pytest.raises(ValueError):
        DoubleBufferedScheduler("sometimes")
    # scheduler ordering is pure dataflow plumbing — check both modes
    # return (exchange, compute) results unchanged on plain arrays
    import jax.numpy as jnp
    import numpy as np
    payload = jnp.arange(4.0)
    for mode in ("overlap", "none"):
        sched = DoubleBufferedScheduler(mode)
        ex, out = sched.run(payload, lambda p: p * 2, lambda: payload + 1)
        np.testing.assert_array_equal(np.asarray(ex),
                                      np.asarray(payload * 2))
        np.testing.assert_array_equal(np.asarray(out),
                                      np.asarray(payload + 1))


# --- CommSpec (one validated comm contract) ---------------------------------

def test_comm_spec_validation():
    from repro.comm import CommSpec

    spec = CommSpec()
    assert (spec.strategy, spec.overlap, spec.dtype) == \
        ("allgather", "overlap", "fp32")
    assert CommSpec(dtype=None).dtype == "fp32"   # None = default wire
    assert CommSpec(strategy="ulysses").strategy == "ulysses"
    with pytest.raises(ValueError, match="smoke-signals"):
        CommSpec(strategy="smoke-signals")
    with pytest.raises(ValueError, match="overlap"):
        CommSpec(overlap="sometimes")
    with pytest.raises(ValueError, match="dtype"):
        CommSpec(dtype="fp7")


def test_comm_spec_deprecation_shim():
    """The legacy comm_strategy/overlap/comm_dtype kwargs keep working
    through resolve_comm_spec + SPConfig, warn ONCE per process, and
    mixing them with comm= raises."""
    import warnings

    from repro.comm import CommSpec, resolve_comm_spec
    from repro.comm.spec import _reset_deprecation_state

    _reset_deprecation_state()
    with pytest.warns(DeprecationWarning, match="comm_strategy"):
        spec = resolve_comm_spec(None, strategy="ring", dtype="bf16",
                                 where="test")
    assert (spec.strategy, spec.dtype) == ("ring", "bf16")
    # warn-once: the second legacy resolve is silent
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        spec2 = resolve_comm_spec(None, overlap="none", where="test")
    assert spec2.overlap == "none"
    # comm= plus legacy kwargs is ambiguous -> hard error
    with pytest.raises(ValueError, match="both"):
        resolve_comm_spec(CommSpec(), strategy="ring", where="test")
    # comm= alone passes through verbatim, no warning
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        assert resolve_comm_spec(CommSpec(strategy="ulysses"),
                                 where="test").strategy == "ulysses"
    _reset_deprecation_state()


def test_spconfig_legacy_kwargs_still_work():
    """Existing SPConfig(comm_strategy=..., comm_dtype=...) call sites
    keep their behavior: the fields land in the resolved CommSpec and
    the mirror attributes stay readable."""
    import jax

    from repro.comm.spec import _reset_deprecation_state
    from repro.core.lasp2 import SPConfig
    from repro.launch.mesh import SEQ_AXIS, make_sp_mesh

    _reset_deprecation_state()
    mesh = make_sp_mesh(1, devices=jax.devices()[:1])
    with pytest.warns(DeprecationWarning):
        sp = SPConfig(mesh=mesh, sp_axis=SEQ_AXIS, comm_strategy="ring",
                      comm_dtype="bf16")
    assert sp.comm.strategy == "ring" and sp.comm.dtype == "bf16"
    assert sp.comm_strategy == "ring" and sp.comm_dtype == "bf16"
    assert sp.overlap == "overlap"
    _reset_deprecation_state()


# --- strategy registry ------------------------------------------------------

def test_register_strategy_public_api():
    from repro.comm import (get_budget_fn, get_strategy, register_strategy,
                            registered_strategies)
    from repro.comm.strategy import _REGISTRY, AllGatherStrategy

    names = registered_strategies()
    assert {"allgather", "ring", "pipelined", "ulysses"} <= set(names)
    # unknown names list what IS registered
    with pytest.raises(ValueError) as ei:
        get_strategy("carrier-pigeon")
    assert "ulysses" in str(ei.value)
    with pytest.raises(TypeError):
        register_strategy("broken", "not-a-callable")
    # a third-party strategy registers through the same path ulysses uses
    class EchoStrategy(AllGatherStrategy):
        name = "echo"
    register_strategy("echo", EchoStrategy,
                      lambda world, **kw: None)
    try:
        assert get_strategy("echo").name == "echo"
        assert get_budget_fn("echo")(4) is None
    finally:
        _REGISTRY.pop("echo", None)


def test_ulysses_budget_fns():
    """ulysses context budget: 2 All-to-Alls forward (4 with grad), the
    per-link a2a bytes < the allgather K/V bytes whenever tp >= 2 on a
    3D mesh (the residual sp gathers included)."""
    from repro.comm.budget import (allgather_context_budget,
                                   hybrid_context_budget,
                                   ulysses_context_budget)

    # the hybrid-smoke shape (q:kv = 2:1). NOTE the advantage is
    # head-ratio-dependent: ulysses moves q+k+v through the a2a while
    # the baseline gathers only K/V, so extreme GQA (hq >> hkv) erodes
    # it (docs/communication.md, volume table).
    dims = dict(b=2, hq=4, hkv=2, c=128, dh=64)
    u = ulysses_context_budget(2, sp=2, with_grad=False, **dims)
    assert u.counts["all-to-all"] == 2
    assert u.counts["all-gather"] == 2       # residual sp K/V gathers
    ug = ulysses_context_budget(2, sp=2, with_grad=True, **dims)
    assert ug.counts == {"all-to-all": 4, "all-gather": 2,
                         "reduce-scatter": 2}
    # combined-degree allgather baseline on the same (2,2,2)-style mesh:
    a = allgather_context_budget(4, with_grad=False, **dims)
    assert a.counts == {"all-gather": 2}
    assert sum(u.max_traffic.values()) < sum(a.max_traffic.values())
    # and on (1,4,2): ulysses over tp=2, residual sp=4 vs allgather(8)
    u2 = ulysses_context_budget(2, sp=4, **dims)
    a2 = allgather_context_budget(8, **dims)
    assert sum(u2.max_traffic.values()) < sum(a2.max_traffic.values())
    # the registry dispatches hybrid_context_budget without if/elif
    via = hybrid_context_budget("ulysses", 2, sp=2, **dims)
    assert via.counts == u.counts and via.max_traffic == u.max_traffic


# --- ulysses head repartition (pure packing math, single device) ------------

def test_ulysses_pack_unpack_roundtrip():
    """The seq->head->seq repartition is an EXACT inverse across dtypes
    and GQA head counts. The tiled All-to-All (split head dim, concat
    seq dim) is simulated locally: device d receives the d-th head
    block of every source chunk, seq-concatenated in rank order."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core.lasp2h import pack_ulysses, unpack_ulysses

    key = jax.random.PRNGKey(7)
    B, S, dh = 2, 64, 8

    def a2a(blocks, g, split, cat):   # what jax.lax.all_to_all does
        # result[d] = concat over sources s of the d-th `split`-axis
        # piece of blocks[s], along `cat` — tiled semantics
        return [np.concatenate(
            [np.array_split(np.asarray(blocks[s]), g, axis=split)[d]
             for s in range(g)], axis=cat) for d in range(g)]

    for dtype in (jnp.float32, jnp.bfloat16, jnp.float16):
        for hq, hkv, g in ((8, 8, 4), (8, 4, 2), (4, 4, 1), (4, 2, 2),
                           (16, 4, 4)):
            ks = jax.random.split(key, 3)
            q = jax.random.normal(ks[0], (B, hq, S, dh), dtype)
            k = jax.random.normal(ks[1], (B, hkv, S, dh), dtype)
            v = jax.random.normal(ks[2], (B, hkv, S, dh), dtype)
            C = S // g
            packed = [pack_ulysses(q[:, :, s * C:(s + 1) * C],
                                   k[:, :, s * C:(s + 1) * C],
                                   v[:, :, s * C:(s + 1) * C], g)
                      for s in range(g)]
            assert packed[0].dtype == dtype
            assert packed[0].shape == (B, hq + 2 * hkv, C, dh)
            nq, nkv = hq // g, hkv // g
            outs = []
            for d, blk in enumerate(a2a(packed, g, 1, 2)):
                ql, kl, vl = unpack_ulysses(blk, hq, hkv, g)
                # head-sharded, full-sequence — the flash-attention view
                np.testing.assert_array_equal(
                    ql, np.asarray(q[:, d * nq:(d + 1) * nq]))
                np.testing.assert_array_equal(
                    kl, np.asarray(k[:, d * nkv:(d + 1) * nkv]))
                np.testing.assert_array_equal(
                    vl, np.asarray(v[:, d * nkv:(d + 1) * nkv]))
                outs.append(ql)
            # the return leg (split seq / concat heads — the mirrored
            # a2a) lands every rank back on its own seq chunk with ALL
            # query heads: the exact inverse, bit-for-bit
            for r, ret in enumerate(a2a(outs, g, 2, 1)):
                np.testing.assert_array_equal(
                    ret, np.asarray(q[:, :, r * C:(r + 1) * C]))


def test_ulysses_head_divisibility_error():
    from repro.core.lasp2h import check_ulysses_heads
    from repro.launch.mesh import MODEL_AXIS

    check_ulysses_heads(8, 2, 2, MODEL_AXIS)       # divides: no error
    with pytest.raises(ValueError, match="n_kv_heads=2"):
        check_ulysses_heads(8, 2, 4, MODEL_AXIS)
    with pytest.raises(ValueError, match=MODEL_AXIS):
        check_ulysses_heads(6, 6, 4, MODEL_AXIS)
