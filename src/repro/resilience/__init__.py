"""Fault-tolerance subsystem (docs/resilience.md).

* :mod:`repro.resilience.guard` — the in-graph numerical health guard
  fused into the train step (finite check piggybacked on the packed
  gradient all-reduce, rolling-median spike clipping, skip-step
  counters, consecutive-skip abort).
* :mod:`repro.resilience.chaos` — deterministic fault injectors for the
  drill harness and tests (checkpoint corruption, flaky/killed saves,
  SIGTERM mid-run, straggler steps).
* ``python -m repro.resilience.drill`` — runs the real train loop on
  the (2, 4) mesh under a fault schedule and asserts recovery plus loss
  parity with the fault-free run.
"""

from repro.resilience.guard import (GUARD_METRICS, GuardAbort,  # noqa: F401
                                    guard_init, guard_verdict,
                                    rolling_median)
