"""Comm-subsystem battery (repro/comm), run on 8 virtual host devices.

Invoked by tests/test_comm.py in a subprocess (so the main pytest process
keeps its single default device). Two families:

* parity — every strategy × overlap mode matches the single-device
  sequential oracle (forward and gradients);
* budget — compiled HLO carries EXACTLY the collectives each strategy is
  allowed: 1 forward all-gather per LASP-2 layer (packed M‖A), a
  reduce-scatter in the autodiff backward, 2(W-1) collective-permutes
  for the ring baseline fwd+bwd, W-1 for LASP-1's forward.
"""

import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import sys  # noqa: E402

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax                     # noqa: E402
import jax.numpy as jnp        # noqa: E402
import numpy as np             # noqa: E402

from repro.comm import (assert_budget, lasp2_budget,  # noqa: E402
                        ring_baseline_budget, tape, tape_summary)
from repro.comm.budget import compiled_hlo, gather_result_bytes  # noqa: E402
from repro.comm.primitives import auto_slices                    # noqa: E402
from repro.core import linear_attention as la                    # noqa: E402
from repro.core.baselines import lasp1                           # noqa: E402
from repro.core.lasp2 import SPConfig, lasp2                     # noqa: E402
from repro.launch.mesh import SEQ_AXIS, make_sp_mesh             # noqa: E402

PASSED = []
W = 8


def check(name):
    def deco(fn):
        fn()
        PASSED.append(name)
        print(f"  ✓ {name}", flush=True)
    return deco


mesh = make_sp_mesh(W)
sp = SPConfig(mesh=mesh, sp_axis=SEQ_AXIS)
B, H, S, dk, dv = 2, 4, 512, 32, 64
ks = jax.random.split(jax.random.PRNGKey(7), 4)
q = jax.random.normal(ks[0], (B, H, S, dk)) * 0.3
k = jax.random.normal(ks[1], (B, H, S, dk)) * 0.3
v = jax.random.normal(ks[2], (B, H, S, dv)) * 0.5
log_a = -jnp.abs(jax.random.normal(ks[3], (B, H, S))) * 0.03
ref = la.sequential_oracle(q, k, v, log_a)
N_SLICES = auto_slices(dv)


def run_lasp2(strategy, overlap, backward="autodiff"):
    return jax.jit(lambda a, b, c, d: lasp2(
        a, b, c, d, sp=sp, comm_strategy=strategy, overlap=overlap,
        backward=backward))


def loss_fn(strategy, overlap="overlap", backward="autodiff"):
    return lambda a, b, c, d: jnp.sum(jnp.sin(lasp2(
        a, b, c, d, sp=sp, comm_strategy=strategy, overlap=overlap,
        backward=backward)))


# --- parity ----------------------------------------------------------------

@check("every strategy × overlap mode == sequential oracle (forward)")
def _():
    for strategy in ("allgather", "ring", "pipelined"):
        for overlap in ("overlap", "none"):
            o = run_lasp2(strategy, overlap)(q, k, v, log_a)
            np.testing.assert_allclose(np.asarray(o), np.asarray(ref.o),
                                       rtol=3e-4, atol=3e-4,
                                       err_msg=f"{strategy}/{overlap}")


@check("every strategy's gradients == oracle gradients")
def _():
    go = jax.jit(jax.grad(lambda a, b, c, d: jnp.sum(jnp.sin(
        la.sequential_oracle(a, b, c, d).o)),
        argnums=(0, 1, 2, 3)))(q, k, v, log_a)
    cases = [("allgather", "faithful"), ("allgather", "autodiff"),
             ("ring", "autodiff"), ("pipelined", "autodiff")]
    for strategy, backward in cases:
        g = jax.jit(jax.grad(loss_fn(strategy, backward=backward),
                             argnums=(0, 1, 2, 3)))(q, k, v, log_a)
        # faithful treats decay as a constant (paper) — skip its d(log_a)
        pairs = zip(g[:3], go[:3]) if backward == "faithful" \
            else zip(g, go)
        for got, want in pairs:
            np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3,
                                       err_msg=f"{strategy}/{backward}")


@check("overlap='none' is numerically identical to overlap='overlap'")
def _():
    a = run_lasp2("allgather", "overlap")(q, k, v, log_a)
    b = run_lasp2("allgather", "none")(q, k, v, log_a)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# --- HLO budgets -----------------------------------------------------------

@check("LASP-2 fwd: exactly 1 all-gather, of W·B·H·(dk·dv+1) fp32")
def _():
    for overlap in ("overlap", "none"):
        txt = compiled_hlo(lambda a, b, c, d: lasp2(
            a, b, c, d, sp=sp, overlap=overlap), q, k, v, log_a)
        assert_budget(txt, lasp2_budget("allgather", W), W)
        assert gather_result_bytes(txt, W) == W * B * H * (dk * dv + 1) * 4


@check("LASP-2 fwd+bwd faithful: exactly 2 all-gathers (Alg. 2 + Alg. 4)")
def _():
    txt = compiled_hlo(jax.grad(loss_fn("allgather", backward="faithful"),
                                argnums=(0, 1, 2)), q, k, v, log_a)
    assert_budget(txt, lasp2_budget("allgather", W, with_grad=True,
                                    backward="faithful"), W)


@check("LASP-2 fwd+bwd autodiff: 1 all-gather + 1 reduce-scatter")
def _():
    txt = compiled_hlo(jax.grad(loss_fn("allgather", backward="autodiff"),
                                argnums=(0, 1, 2, 3)), q, k, v, log_a)
    assert_budget(txt, lasp2_budget("allgather", W, with_grad=True,
                                    backward="autodiff"), W)


@check("ring strategy: W-1 permutes fwd, 2(W-1) fwd+bwd; no gathers")
def _():
    txt = compiled_hlo(lambda a, b, c, d: lasp2(
        a, b, c, d, sp=sp, comm_strategy="ring"), q, k, v, log_a)
    assert_budget(txt, lasp2_budget("ring", W), W)
    txt = compiled_hlo(jax.grad(loss_fn("ring"), argnums=(0, 1, 2, 3)),
                       q, k, v, log_a)
    assert_budget(txt, lasp2_budget("ring", W, with_grad=True), W)


@check("pipelined strategy: k(W-1) permutes of 1/k-size slices")
def _():
    txt = compiled_hlo(lambda a, b, c, d: lasp2(
        a, b, c, d, sp=sp, comm_strategy="pipelined"), q, k, v, log_a)
    assert_budget(txt, lasp2_budget("pipelined", W, n_slices=N_SLICES), W)


@check("LASP-1 baseline: W-1 permutes fwd, 2(W-1) per iteration")
def _():
    txt = compiled_hlo(lambda a, b, c, d: lasp1(a, b, c, d, sp=sp),
                       q, k, v, log_a)
    assert_budget(txt, ring_baseline_budget(W), W)
    txt = compiled_hlo(jax.grad(
        lambda a, b, c, d: jnp.sum(jnp.sin(lasp1(a, b, c, d, sp=sp))),
        argnums=(0, 1, 2, 3)), q, k, v, log_a)
    assert_budget(txt, ring_baseline_budget(W, with_grad=True), W)


@check("invalid strategy names / causal-only strategies raise")
def _():
    for bad in ({"comm_strategy": "smoke-signals"},
                {"comm_strategy": "ring", "causal": False},
                {"comm_strategy": "pipelined", "causal": False}):
        try:
            lasp2(q, k, v, log_a, sp=sp, **bad)
        except ValueError:
            continue
        raise AssertionError(f"lasp2(**{bad}) should have raised")


@check("reduce_scatter_grads == gather+sum+slice; 1 reduce-scatter in HLO")
def _():
    from jax.sharding import PartitionSpec as P
    from repro.core.compat import shard_map as _shard_map
    from repro.comm.primitives import reduce_scatter_grads

    x = jax.random.normal(ks[0], (B, H, S, dk))

    def mapped(x_):
        # hand-written mirror of the autodiff backward: every rank holds a
        # full dM-like tensor; reduce-scatter sums them and returns the
        # local sequence shard.
        return reduce_scatter_grads(x_, SEQ_AXIS, axis_size=W,
                                    scatter_axis=2, tag="check.rs")

    f = jax.jit(_shard_map(mapped, mesh=mesh, in_specs=(P(),),
                           out_specs=P(None, None, SEQ_AXIS, None),
                           axis_names={SEQ_AXIS}, check_vma=False))
    with tape() as recs:
        txt = f.lower(x).compile().as_text()
    got = f(x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(x) * W,
                               rtol=1e-5, atol=1e-5)
    from repro.comm import CollectiveBudget
    assert_budget(txt, CollectiveBudget({"reduce-scatter": 1}), W)
    s = tape_summary(recs)
    assert s["reduce-scatter_count"] == 1
    # per-device ring traffic: (g-1)/g × the full payload
    assert s["total_bytes"] == (W - 1) * (B * H * S * dk * 4) // W


# --- comm_dtype (bf16 wire) ------------------------------------------------

@check("comm_dtype=bf16: counts unchanged, bytes halved, budget-asserted")
def _():
    from repro.comm.budget import packed_state_bytes
    sp_bf = SPConfig(mesh=mesh, sp_axis=SEQ_AXIS, comm_dtype="bf16")
    sb16 = packed_state_bytes(B, H, dk, dv, "bf16")
    assert sb16 * 2 == packed_state_bytes(B, H, dk, dv, "fp32")

    # forward: still EXACTLY 1 all-gather; tape bytes = (W-1) × bf16 payload
    # (the byte ceiling is checked against the trace-time tape: XLA-CPU's
    # float-normalization upcasts bf16 collectives in compiled HLO — on
    # TPU the HLO itself carries bf16 and the two views agree)
    with tape() as recs:
        txt = compiled_hlo(lambda a, b, c, d: lasp2(a, b, c, d, sp=sp_bf),
                           q, k, v, log_a)
    assert_budget(txt, lasp2_budget("allgather", W, state_bytes=sb16), W,
                  records=recs)
    s = tape_summary(recs)
    assert s["all-gather_count"] == 1 and s["total_steps"] == 1
    assert s["total_bytes"] == (W - 1) * sb16

    # an fp32-sized gather must FAIL the bf16 ceiling (halving asserted,
    # not assumed)
    with tape() as recs32:
        compiled_hlo(lambda a, b, c, d: lasp2(a, b, c, d, sp=sp), q, k, v,
                     log_a)
    try:
        assert_budget(txt, lasp2_budget("allgather", W, state_bytes=sb16),
                      W, records=recs32)
    except AssertionError:
        pass
    else:
        raise AssertionError("fp32-sized tape passed the bf16 byte budget")

    # autodiff backward: 1 gather + 1 reduce-scatter, both counts pinned
    txt = compiled_hlo(jax.grad(
        lambda a, b, c, d: jnp.sum(jnp.sin(lasp2(
            a, b, c, d, sp=sp_bf, backward="autodiff"))),
        argnums=(0, 1, 2, 3)), q, k, v, log_a)
    assert_budget(txt, lasp2_budget("allgather", W, with_grad=True,
                                    backward="autodiff"), W)

    # parity within bf16 payload tolerance, both backwards
    for backward in ("faithful", "autodiff"):
        o = jax.jit(lambda a, b, c, d, bw=backward: lasp2(
            a, b, c, d, sp=sp_bf, backward=bw))(q, k, v, log_a)
        np.testing.assert_allclose(np.asarray(o), np.asarray(ref.o),
                                   rtol=3e-2, atol=3e-2,
                                   err_msg=f"bf16/{backward}")

    # ring/pipelined wires also halve (per-hop casts; fp32 accumulate)
    for strategy in ("ring", "pipelined"):
        with tape() as recs:
            compiled_hlo(lambda a, b, c, d, s_=strategy: lasp2(
                a, b, c, d, sp=sp_bf, comm_strategy=s_,
                backward="autodiff"), q, k, v, log_a)
        sm = tape_summary(recs)
        assert sm["total_bytes"] == (W - 1) * B * H * dk * dv * 2, strategy


@check("invalid comm_dtype raises on every entry point")
def _():
    for fn in (lambda: lasp2(q, k, v, log_a, sp=sp, comm_dtype="fp64"),
               lambda: SPConfig(mesh=mesh, sp_axis=SEQ_AXIS,
                                comm_dtype="int8") and lasp2(
                   q, k, v, log_a,
                   sp=SPConfig(mesh=mesh, sp_axis=SEQ_AXIS,
                               comm_dtype="int8"))):
        try:
            fn()
        except ValueError:
            continue
        raise AssertionError("bad comm_dtype should have raised")


# --- CommRecord tape vs HLO cross-validation -------------------------------

@check("CommRecord tape agrees with the HLO on count/steps/bytes")
def _():
    state_bytes = B * H * (dk * dv + 1) * 4
    with tape() as recs:
        jax.jit(lambda a, b, c, d: lasp2(a, b, c, d, sp=sp)).lower(
            q, k, v, log_a)
    s = tape_summary(recs)
    assert s["all-gather_count"] == 1 and s["total_steps"] == 1
    assert s["total_bytes"] == (W - 1) * state_bytes

    m_bytes = B * H * dk * dv * 4
    with tape() as recs:
        jax.jit(lambda a, b, c, d: lasp2(
            a, b, c, d, sp=sp, comm_strategy="ring")).lower(q, k, v, log_a)
    s = tape_summary(recs)
    assert s["collective-permute_count"] == W - 1
    assert s["total_steps"] == W - 1
    assert s["total_bytes"] == (W - 1) * m_bytes

    with tape() as recs:
        jax.jit(lambda a, b, c, d: lasp2(
            a, b, c, d, sp=sp, comm_strategy="pipelined")).lower(
                q, k, v, log_a)
    s = tape_summary(recs)
    # sliced ring: k× the permute count, same total volume as the ring
    assert s["collective-permute_count"] == N_SLICES * (W - 1)
    assert s["total_bytes"] == (W - 1) * m_bytes


# --- ulysses (head-parallel All-to-All) -------------------------------------

@check("ulysses CP == full attention (+grads) on a 2-wide SEQ axis")
def _():
    """Classic (1D) ulysses: heads repartition over the sequence axis
    itself — full-sequence flash per head subset, two All-to-Alls on
    the wire, output and grads matching the unsharded oracle."""
    from repro.core.lasp2h import (allgather_context_attention,
                                   ulysses_context_attention)

    mesh2 = make_sp_mesh(2)
    spu = SPConfig(mesh=mesh2, sp_axis=SEQ_AXIS)
    Hq, Hkv, dh = 8, 2, 32
    qs = jax.random.normal(ks[0], (B, Hq, S, dh)) * 0.5
    ks_ = jax.random.normal(ks[1], (B, Hkv, S, dh)) * 0.5
    vs = jax.random.normal(ks[2], (B, Hkv, S, dh)) * 0.5
    ref = allgather_context_attention(qs, ks_, vs, sp=None)
    o = jax.jit(lambda a, b, c: ulysses_context_attention(
        a, b, c, sp=spu))(qs, ks_, vs)
    np.testing.assert_allclose(o, ref, rtol=2e-4, atol=2e-4)
    g1 = jax.jit(jax.grad(lambda a, b, c: jnp.sum(jnp.sin(
        ulysses_context_attention(a, b, c, sp=spu))),
        argnums=(0, 1, 2)))(qs, ks_, vs)
    g0 = jax.jit(jax.grad(lambda a, b, c: jnp.sum(jnp.sin(
        allgather_context_attention(a, b, c, sp=None))),
        argnums=(0, 1, 2)))(qs, ks_, vs)
    for a_, b_ in zip(g1, g0):
        np.testing.assert_allclose(a_, b_, rtol=1e-3, atol=1e-3)


@check("ulysses budget: 2 fwd / 4 fwd+bwd All-to-Alls, tape == ceiling")
def _():
    from repro.comm.budget import hybrid_context_budget
    from repro.core.lasp2h import ulysses_context_attention

    mesh2 = make_sp_mesh(2)
    spu = SPConfig(mesh=mesh2, sp_axis=SEQ_AXIS)
    Hq, Hkv, dh = 8, 2, 32
    qs = jax.random.normal(ks[0], (B, Hq, S, dh)) * 0.5
    ks_ = jax.random.normal(ks[1], (B, Hkv, S, dh)) * 0.5
    vs = jax.random.normal(ks[2], (B, Hkv, S, dh)) * 0.5

    import re
    with tape() as recs:
        txt = compiled_hlo(lambda a, b, c: ulysses_context_attention(
            a, b, c, sp=spu), qs, ks_, vs)
    assert len(re.findall(r"all-to-all\(", txt)) == 2
    assert not re.search(r"all-gather\(|collective-permute\(", txt)
    budget = hybrid_context_budget("ulysses", 2, sp=1, b=B, hq=Hq,
                                   hkv=Hkv, c=S // 2, dh=dh)
    assert budget.counts == {"all-to-all": 2}
    s = tape_summary(recs)
    assert s["all-to-all_count"] == 2
    assert s["total_bytes"] == budget.max_traffic["all-to-all"]
    # fwd+bwd: the custom_vjp mirrors each All-to-All — 4 total, and
    # the with_grad ceiling is byte-exact (the in-leg cotangent arrives
    # in the wire dtype)
    with tape() as recs:
        txt = compiled_hlo(jax.grad(lambda a, b, c: jnp.sum(jnp.sin(
            ulysses_context_attention(a, b, c, sp=spu))),
            argnums=(0, 1, 2)), qs, ks_, vs)
    assert len(re.findall(r"all-to-all\(", txt)) == 4
    gbudget = hybrid_context_budget("ulysses", 2, sp=1, b=B, hq=Hq,
                                    hkv=Hkv, c=S // 2, dh=dh,
                                    with_grad=True)
    s = tape_summary(recs)
    assert s["all-to-all_count"] == 4
    assert s["total_bytes"] == gbudget.max_traffic["all-to-all"]


@check("lasp2(comm=CommSpec) threads the spec; ulysses aliases allgather")
def _():
    import re

    from repro.comm import CommSpec

    o = jax.jit(lambda a, b, c, d: lasp2(
        a, b, c, d, sp=sp, comm=CommSpec(strategy="ulysses")))(
            q, k, v, log_a)
    np.testing.assert_allclose(o, ref.o, rtol=3e-4, atol=3e-4)
    # linear layers have no softmax heads to repartition: the ulysses
    # state exchange IS LASP-2's packed allgather, budget unchanged
    txt = compiled_hlo(lambda a, b, c, d: lasp2(
        a, b, c, d, sp=sp, comm=CommSpec(strategy="ulysses")),
        q, k, v, log_a)
    assert len(re.findall(r"all-gather\(", txt)) == 1
    assert not re.search(r"all-to-all\(", txt)
    # a bf16 wire through the spec narrows the gather, same as the
    # legacy comm_dtype kwarg
    with tape() as recs:
        jax.jit(lambda a, b, c, d: lasp2(
            a, b, c, d, sp=sp, comm=CommSpec(dtype="bf16"))).lower(
                q, k, v, log_a)
    from repro.comm.budget import packed_state_bytes
    assert tape_summary(recs)["total_bytes"] == \
        (W - 1) * packed_state_bytes(B, H, dk, dv, "bf16")


if __name__ == "__main__":
    print(f"ALL {len(PASSED)} COMM CHECKS PASSED")
