"""Paper Table 6 / Fig. 4: scalability — memory per device and linear
sequence scaling with device count.

Three parts:
(a) compiled evidence: per-device memory from the dry-run artifacts
    (results/dryrun/*.json) for each arch × shape on the 256-chip pod;
(b) LASP-2 scaling law reproduced structurally: compile the paper's pure-
    SP workload (Linear-Llama3-1B, batch 1) at W ∈ {2,4,8} devices with
    S ∝ W and verify per-device memory stays ~constant (the paper's
    Fig. 4 "same memory, 16× devices → 16× sequence" result);
(c) Table-6-style MESH-SHAPE sweep: the 2D DP×SP train step (ZeRO-1,
    docs/parallelism.md) compiled at every (dp, sp) split of 8 devices —
    per-device memory, per-axis collective instruction counts, and the
    exact ``train_step_axis_budget`` verified for each shape.
"""

from __future__ import annotations

import glob
import json
import os

from benchmarks.common import emit, run_subprocess_bench

_CODE = r"""
import json
import jax, jax.numpy as jnp
from repro.launch.mesh import SEQ_AXIS, make_sp_mesh
from repro.core.lasp2 import lasp2, SPConfig
from jax.sharding import PartitionSpec as P, NamedSharding

res = {}
for w, s in ((2, 16384), (4, 32768), (8, 65536)):
    mesh = make_sp_mesh(w)
    sp = SPConfig(mesh=mesh, sp_axis=SEQ_AXIS)
    B, H, d = 1, 16, 128
    sh = NamedSharding(mesh, P(None, None, SEQ_AXIS, None))
    args = [jax.ShapeDtypeStruct((B, H, s, d), jnp.bfloat16)] * 3

    def f(q, k, v):
        return lasp2(q, k, v, sp=sp)

    compiled = jax.jit(f, in_shardings=(sh, sh, sh)).lower(*args).compile()
    ma = compiled.memory_analysis()
    per_dev = (ma.argument_size_in_bytes + ma.output_size_in_bytes
               + ma.temp_size_in_bytes - ma.alias_size_in_bytes)
    res[f"W{w}_S{s}"] = per_dev / 1e6
print(json.dumps(res))
"""


_MESH_CODE = r"""
import json
import jax
import numpy as np

from repro.comm.budget import assert_axis_budget, train_step_axis_budget
from repro.configs import get_smoke
from repro.configs.base import RunConfig
from repro.data.pipeline import SyntheticLM
from repro.launch.hlo_analysis import collective_axis_counts
from repro.launch.mesh import make_training_mesh
from repro.sharding.rules import make_plan
from repro.train.step import init_state, make_train_step

cfg = get_smoke("linear-llama3-1b")
data = SyntheticLM(cfg.vocab_size, 64, 8, seed=3)
run = RunConfig(num_microbatches=1, remat="none", total_steps=10,
                warmup_steps=2, scan_unroll=True)
res = {}
for dp, sp in ((1, 8), (2, 4), (4, 2), (8, 1)):
    mesh = make_training_mesh(dp, sp)
    plan = make_plan(mesh, "train", global_batch=8,
                     n_kv_heads=cfg.n_kv_heads)
    state = init_state(jax.random.PRNGKey(0), cfg, run, plan)
    compiled = jax.jit(make_train_step(cfg, run, plan)).lower(
        state, data.microbatched(0, 1)).compile()
    txt = compiled.as_text()
    budget = train_step_axis_budget(
        mesh, n_sp_layers=cfg.n_layers, microbatches=1,
        backward="autodiff", zero1=plan.zero1_axis is not None)
    assert_axis_budget(txt, mesh, budget)
    ma = compiled.memory_analysis()
    per_dev = (ma.argument_size_in_bytes + ma.output_size_in_bytes
               + ma.temp_size_in_bytes - ma.alias_size_in_bytes)
    counts = {f"{op}@{'+'.join(axes) or 'none'}": n
              for (op, axes), n in sorted(
                  collective_axis_counts(txt, mesh).items())}
    res[f"dp{dp}_sp{sp}"] = {"per_dev_MB": per_dev / 1e6,
                             "collectives_by_axis": counts,
                             "budget_verified": True}
print(json.dumps(res))
"""


def main():
    rows = []
    payload = {}
    # (a) dry-run memory table
    for path in sorted(glob.glob("results/dryrun/*16x16.json")):
        with open(path) as f:
            rec = json.load(f)
        if rec.get("status") != "ok" or "2x16x16" in os.path.basename(path):
            continue
        mem = rec.get("memory", {})
        peak = mem.get("peak_bytes", 0) / 2 ** 30
        rows.append((f"table6/mem/{rec['arch']}@{rec['shape']}", 0.0,
                     f"peak_GiB_per_dev={peak:.2f}"))
    # (b) constant-memory sequence scaling
    res = run_subprocess_bench(_CODE, devices=8, timeout=900)
    payload["seq_scaling"] = res
    vals = sorted(res.items())
    base = vals[0][1]
    for k, mb in vals:
        rows.append((f"table6/scaling/{k}", 0.0,
                     f"per_dev_MB={mb:.1f};rel={mb / base:.3f}"))
    # (c) DP×SP mesh-shape sweep (budget-asserted in the subprocess)
    res = run_subprocess_bench(_MESH_CODE, devices=8, timeout=1800)
    payload["mesh_sweep"] = res
    for k, rec in sorted(res.items()):
        colls = ";".join(f"{op}={n}"
                         for op, n in rec["collectives_by_axis"].items())
        rows.append((f"table6/mesh/{k}", 0.0,
                     f"per_dev_MB={rec['per_dev_MB']:.1f};{colls}"))
    emit(rows)
    payload["rows"] = [{"name": n, "us_per_call": us, "derived": d}
                      for n, us, d in rows]
    return payload


if __name__ == "__main__":
    main()
