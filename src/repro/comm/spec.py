"""One validated communication spec.

Before this module the three comm knobs — strategy, overlap mode, wire
dtype — were threaded as three loose keyword arguments through every
layer of the stack (``RunConfig`` → ``make_plan`` → ``SPConfig`` →
strategy call sites), each hop re-declaring the same trio with the same
defaults. :class:`CommSpec` collapses them into a single frozen,
self-validating object that is constructed once and passed whole.

Legacy call sites keep working: :func:`resolve_comm_spec` accepts the
old ``comm_strategy=`` / ``overlap=`` / ``comm_dtype=`` keywords, folds
them into a spec, and emits a :class:`DeprecationWarning` ONCE per
process (the first legacy use wins; subsequent ones are silent so a big
old codebase doesn't drown in warnings).
"""

from __future__ import annotations

import warnings
from dataclasses import dataclass, replace
from typing import Optional


@dataclass(frozen=True)
class CommSpec:
    """The full communication configuration as one value.

    ``strategy``: inter-chunk / context exchange strategy — any name in
    :func:`repro.comm.strategy.registered_strategies`.
    ``overlap``: comm/compute overlap mode (``"overlap"`` | ``"none"``).
    ``dtype``: wire dtype knob (``"fp32"`` | ``"bf16"``); ``None`` is
    normalized to ``"fp32"``.
    """

    strategy: str = "allgather"
    overlap: str = "overlap"
    dtype: Optional[str] = "fp32"

    def __post_init__(self):
        # Local imports: strategy.py is the registry owner and must be
        # importable without this module (it is not), and primitives
        # owns the dtype registry.
        from repro.comm.overlap import MODES
        from repro.comm.primitives import _COMM_DTYPES
        from repro.comm.strategy import registered_strategies

        names = registered_strategies()
        if self.strategy not in names:
            raise ValueError(
                f"unknown comm strategy {self.strategy!r}; expected one "
                f"of {names}")
        if self.overlap not in MODES:
            raise ValueError(
                f"unknown overlap mode {self.overlap!r}; expected one of "
                f"{MODES}")
        if self.dtype is None:
            object.__setattr__(self, "dtype", "fp32")
        elif self.dtype not in _COMM_DTYPES:
            raise ValueError(
                f"unknown comm_dtype {self.dtype!r}; expected one of "
                f"{tuple(_COMM_DTYPES)}")


_warned = False


def _reset_deprecation_state():
    """Re-arm the warn-once latch (tests only)."""
    global _warned
    _warned = False


def _warn_once(where: str):
    global _warned
    if _warned:
        return
    _warned = True
    warnings.warn(
        f"passing comm_strategy= / overlap= / comm_dtype= keywords"
        f"{' to ' + where if where else ''} is deprecated; pass one "
        f"comm=CommSpec(strategy=..., overlap=..., dtype=...) instead "
        f"(this warning fires once per process)",
        DeprecationWarning, stacklevel=4)


def resolve_comm_spec(comm: Optional[CommSpec] = None, *,
                      strategy: Optional[str] = None,
                      overlap: Optional[str] = None,
                      dtype: Optional[str] = None,
                      base: Optional[CommSpec] = None,
                      where: str = "") -> CommSpec:
    """Fold a new-style ``comm=CommSpec`` and/or legacy loose keywords
    into one validated :class:`CommSpec`.

    * only ``comm`` (or nothing): return it (or ``base``/defaults) — no
      warning.
    * legacy keywords: deprecation-warn once, then apply them as
      overrides on top of ``base`` (or the defaults).
    * both ``comm`` and legacy keywords: ambiguous — raise.
    """
    legacy = {k: v for k, v in
              (("strategy", strategy), ("overlap", overlap),
               ("dtype", dtype)) if v is not None}
    if comm is not None:
        if legacy:
            raise ValueError(
                f"pass either comm=CommSpec(...) or the deprecated loose "
                f"keywords, not both (got comm= and {tuple(legacy)})"
                + (f" in {where}" if where else ""))
        return comm
    spec = base if base is not None else CommSpec()
    if not legacy:
        return spec
    _warn_once(where)
    return replace(spec, **legacy)
