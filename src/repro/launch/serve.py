"""Serving launcher: load/initialize a model and serve batched requests
through the continuous-batching engine.

  PYTHONPATH=src python -m repro.launch.serve --arch hymba-1.5b --smoke \
      --requests 8 --prompt-len 64 --new-tokens 32
"""

from __future__ import annotations

import argparse
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="linear-llama3-1b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--linearize", type=int, default=None)
    ap.add_argument("--requests", type=int, default=8,
                    help="number of requests to submit")
    ap.add_argument("--max-batch", type=int, default=4,
                    help="decode slots (continuous-batching grid)")
    ap.add_argument("--prompt-len", type=int, default=64,
                    help="max prompt length (ragged, varied per request)")
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--max-queue", type=int, default=0,
                    help="bound the admission queue; submissions beyond "
                         "this many waiting requests are rejected with "
                         "backpressure (0 = unbounded; "
                         "docs/resilience.md)")
    ap.add_argument("--deadline-s", type=float, default=0.0,
                    help="per-request deadline: unfinished requests are "
                         "evicted (finish_reason=deadline, partial "
                         "tokens kept) this many seconds after submit "
                         "(0 = none)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--metrics-out", default=None,
                    help="write serve telemetry (per-request records + "
                         "summary with TTFT / decode-latency percentiles) "
                         "as JSONL here (docs/observability.md)")
    args = ap.parse_args()

    import jax
    import numpy as np

    from repro.checkpoint.manager import CheckpointManager
    from repro.configs import get_config, get_smoke
    from repro.models import model as M
    from repro.serve.engine import ServeEngine

    cfg = get_smoke(args.arch) if args.smoke \
        else get_config(args.arch, linearize=args.linearize)
    key = jax.random.PRNGKey(0)
    params = M.init_params(key, cfg)
    if args.ckpt_dir:
        mgr = CheckpointManager(args.ckpt_dir)
        step = mgr.latest_step()
        if step is not None:
            state = mgr.restore(step, {"params": params})
            params = state["params"]
            print(f"[serve] restored params from step {step}")

    sink = None
    if args.metrics_out:
        from repro.obs import JsonlSink
        sink = JsonlSink(args.metrics_out)

    max_len = args.prompt_len + args.new_tokens
    engine = ServeEngine(cfg, params, max_len=max_len,
                         max_batch=args.max_batch, sink=sink,
                         max_queue=args.max_queue or None)

    if cfg.encoder is not None or cfg.n_image_tokens:
        # encoder / image-conditioned models run the static-batch path
        kw = {}
        if cfg.encoder is not None:
            kw["enc_frames"] = jax.random.normal(
                key, (args.max_batch, cfg.encoder.n_frames,
                      cfg.d_model)) * 0.1
        if cfg.n_image_tokens:
            kw["img_emb"] = jax.random.normal(
                key, (args.max_batch, cfg.n_image_tokens, cfg.d_model)) * 0.1
        prompts = jax.random.randint(
            key, (args.max_batch, args.prompt_len), 0, cfg.vocab_size)
        t0 = time.perf_counter()
        out = engine.generate(prompts, args.new_tokens,
                              temperature=args.temperature, **kw)
        dt = time.perf_counter() - t0
        total_new = out.shape[0] * args.new_tokens
        print(f"[serve] {cfg.name}: static batch {out.shape} in {dt:.2f}s "
              f"({total_new / dt:.1f} tok/s incl. prefill+compile)")
        return

    # continuous batching: ragged prompts, more requests than slots
    rng = np.random.default_rng(0)
    lens = rng.integers(max(args.prompt_len // 2, 1), args.prompt_len + 1,
                        size=args.requests)
    from repro.serve.scheduler import QueueFullError
    uids = []
    rejected = 0
    for i, ln in enumerate(lens):
        prompt = rng.integers(0, cfg.vocab_size, size=int(ln))
        try:
            uids.append(engine.submit(
                prompt, args.new_tokens, temperature=args.temperature,
                seed=0, stream=i,
                deadline_s=args.deadline_s or None))
        except QueueFullError:
            rejected += 1
    if rejected:
        print(f"[serve] queue full: rejected {rejected}/{args.requests} "
              f"requests (--max-queue {args.max_queue})")
    t0 = time.perf_counter()
    results = engine.run()
    dt = time.perf_counter() - t0
    total_new = sum(len(v) for v in results.values())
    stats = engine.cache_stats()
    print(f"[serve] {cfg.name}: {len(results)} requests "
          f"(prompts {lens.min()}..{lens.max()}) on {args.max_batch} slots "
          f"in {dt:.2f}s ({total_new / dt:.1f} tok/s incl. prefill+compile)")
    print(f"[serve] cache bytes: linear_state={stats['linear_state']} "
          f"kv_ring={stats['kv_ring']} conv={stats['conv']} "
          f"total={stats['total']}")
    s = engine.stats()
    if "ttft_s_p50" in s:
        print(f"[serve] ttft p50 {s['ttft_s_p50']*1e3:.1f}ms "
              f"p99 {s['ttft_s_p99']*1e3:.1f}ms; decode p50 "
              f"{s.get('decode_step_s_p50', 0)*1e3:.1f}ms p99 "
              f"{s.get('decode_step_s_p99', 0)*1e3:.1f}ms; "
              f"queue_depth peak {s.get('queue_depth_peak', 0):.0f}; "
              f"{s.get('decode_tokens_per_s', 0):.1f} decode tok/s")
    if sink is not None:
        engine.emit_summary(requests=len(results))
        sink.close()
        print(f"[serve] telemetry -> {args.metrics_out}")
    if uids and uids[0] in results:
        print("[serve] first result:", results[uids[0]][:16], "...")


if __name__ == "__main__":
    main()
