# launch: mesh/dryrun/train/serve/roofline entry points (import lazily
# — dryrun must set XLA_FLAGS before jax init).
