"""End-to-end driver: train a ~100M-param Linear-Llama3 for a few hundred
steps with checkpointing + auto-resume — the paper's §4 setup at
laptop scale (pure linear attention; pass --hybrid for the 1/4 hybrid).

  PYTHONPATH=src python examples/train_linear_llama3.py \
      [--steps 300] [--hybrid] [--resume-demo]

``--resume-demo`` kills training halfway and restarts it, demonstrating
bitwise-deterministic checkpoint resume (fault tolerance).
"""

import argparse
import dataclasses
import sys

sys.path.insert(0, "src")

from repro.configs.base import (LayerSpec, LinearAttnConfig, ModelConfig,
                                RunConfig)
from repro.data.pipeline import SyntheticLM
from repro.train.loop import train


def model_100m(hybrid: bool) -> ModelConfig:
    """~100M params: 12 layers, d=512, 8 heads — Linear-Llama3 recipe."""
    pattern = (LayerSpec(mixer="linear", mlp="dense"),)
    cfg = ModelConfig(
        name="linear-llama3-100m", family="dense",
        n_layers=12, d_model=512, n_heads=8, n_kv_heads=8,
        d_ff=1408, vocab_size=32000,
        pattern=pattern,
        linear_attn=LinearAttnConfig(feature_map="identity", decay="none",
                                     backward="faithful"))
    if hybrid:
        cfg = dataclasses.replace(
            cfg.linearize(hybrid_every=4), name="linear-llama3-100m-h4")
        # (linearize on an already-linear pattern keeps it linear; build
        # the hybrid from the softmax base instead)
        base = dataclasses.replace(cfg, pattern=(LayerSpec(),),
                                   name="llama3-100m")
        cfg = base.linearize(hybrid_every=4)
    return cfg


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--hybrid", action="store_true")
    ap.add_argument("--resume-demo", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/linear_llama3_ckpt")
    args = ap.parse_args()

    cfg = model_100m(args.hybrid)
    print(f"model: {cfg.name}, {cfg.param_count()/1e6:.0f}M params")
    run = RunConfig(num_microbatches=2, total_steps=args.steps,
                    warmup_steps=20, learning_rate=6e-4, remat="full")
    data = SyntheticLM(cfg.vocab_size, seq_len=512, global_batch=8, seed=0)

    if args.resume_demo:
        half = args.steps // 2
        print(f"--- phase 1: train to step {half}, then 'crash' ---")
        train(cfg, run, data, ckpt_dir=args.ckpt_dir, ckpt_every=25,
              max_steps=half)
        print("--- phase 2: restart; auto-resume from latest ckpt ---")

    state, history = train(cfg, run, data, ckpt_dir=args.ckpt_dir,
                           ckpt_every=50)
    first = sum(h["loss"] for h in history[:5]) / max(len(history[:5]), 1)
    last = sum(h["loss"] for h in history[-5:]) / max(len(history[-5:]), 1)
    print(f"\n{cfg.name}: loss {first:.3f} -> {last:.3f} over "
          f"{len(history)} steps (final step {int(state['step'])})")


if __name__ == "__main__":
    main()
