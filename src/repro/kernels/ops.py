"""Backend dispatch for the perf-critical ops.

Model code calls these wrappers; on TPU the Pallas kernels run, elsewhere
(this CPU container, the dry-run) the mathematically-identical XLA path
from ``repro.core`` runs. ``backend="interpret"`` forces Pallas interpret
mode (used by tests). The dispatch is deliberately value-free: same
signatures, same semantics, sub-1e-3 numerical agreement enforced by
``tests/test_kernels.py``.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.linear_attention import (chunk_scan, pick_block,
                                         recurrent_step)
from repro.core.lasp2h import _softmax_attend, causal_mask
from repro.kernels import flash_attention as _flash
from repro.kernels import lasp2_chunk as _chunk
from repro.kernels import lasp2_decode as _decode


def default_backend() -> str:
    return "pallas" if jax.default_backend() == "tpu" else "xla"


def linear_attention_op(q, k, v, log_a=None, *, block_size: int = 128,
                        backend: Optional[str] = None):
    """Local chunked decayed causal linear attention.

    q, k: (B, H, S, dk); v: (B, H, S, dv); log_a: (B, H, S) or None.
    Returns (o, state (B,H,dk,dv) fp32, log_decay (B,H) fp32).
    """
    backend = backend or default_backend()
    b, h, s, dk = q.shape
    dv = v.shape[-1]
    if log_a is None:
        log_a = jnp.zeros((b, h, s), jnp.float32)
    # Block policy is shared with core/lasp2.py (``pick_block``): the
    # preferred block when it divides S, else the largest MXU-aligned
    # divisor. Serving prefill additionally sees arbitrary prompt lengths
    # where no usable divisor exists (e.g. prime S) — rather than
    # degenerating toward 1-token blocks, right-pad to the next block
    # multiple: zero k/v rows add nothing to the state and log_a = 0
    # leaves the decay product alone, so outputs (sliced back to S),
    # final state, and log decay are exact.
    bs = pick_block(s, block_size)
    if bs != s and bs % 32:
        bs = min(block_size, s)
    if s % bs:
        pad = bs - s % bs
        zkv = ((0, 0),) * (q.ndim - 2) + ((0, pad), (0, 0))
        q, k, v = (jnp.pad(x, zkv) for x in (q, k, v))
        log_a = jnp.pad(log_a, ((0, 0),) * (log_a.ndim - 1) + ((0, pad),))
        o, st, ld = linear_attention_op(q, k, v, log_a,
                                        block_size=block_size,
                                        backend=backend)
        return o[..., :s, :], st, ld
    if backend in ("pallas", "interpret"):
        qf = q.reshape(b * h, s, dk)
        kf = k.reshape(b * h, s, dk)
        vf = v.reshape(b * h, s, dv)
        laf = log_a.reshape(b * h, s)
        o, st, ld = _chunk.lasp2_chunk_fwd(
            qf, kf, vf, laf, block_size=bs,
            interpret=(backend == "interpret"))
        return (o.reshape(b, h, s, dv), st.reshape(b, h, dk, dv),
                ld.reshape(b, h))
    out = chunk_scan(q, k, v, log_a, block_size=bs)
    return out.o, out.state, out.log_decay


def linear_decode_op(q, k, v, log_a, state, log_decay, *,
                     backend: Optional[str] = None):
    """Single-token recurrent linear-attention decode (``mode="decode"``).

    q, k: (B, H, dk); v: (B, H, dv); log_a: (B, H) or None;
    state: (B, H, dk, dv) fp32; log_decay: (B, H) fp32.
    Returns (o (B, H, dv) fp32, state', log_decay') — the constant-memory
    decode path: no prefix re-scan, state updated in place.
    """
    backend = backend or default_backend()
    b, h, dk = q.shape
    dv = v.shape[-1]
    if log_a is None:
        log_a = jnp.zeros((b, h), jnp.float32)
    if backend in ("pallas", "interpret"):
        o, st, ld = _decode.lasp2_decode_step(
            q.reshape(b * h, dk), k.reshape(b * h, dk),
            v.reshape(b * h, dv), log_a.reshape(b * h),
            state.reshape(b * h, dk, dv), log_decay.reshape(b * h),
            interpret=(backend == "interpret"))
        return (o.reshape(b, h, dv), st.reshape(b, h, dk, dv),
                ld.reshape(b, h))
    return recurrent_step(q, k, v, log_a, state=state, log_decay=log_decay)


def flash_attention_op(q, k, v, *, causal: bool = True, sliding_window=None,
                       scale=None, backend: Optional[str] = None,
                       block_q: int = 128, block_k: int = 128):
    """GQA softmax attention. q: (B,Hq,S,dh); k/v: (B,Hkv,Sk,dh)."""
    backend = backend or default_backend()
    if isinstance(sliding_window, jax.core.Tracer):
        backend = "xla"   # dynamic window (hymba stacked layers) → XLA path
    if backend in ("pallas", "interpret"):
        sq, sk = q.shape[2], k.shape[2]
        if sq % min(block_q, sq) == 0 and sk % min(block_k, sk) == 0:
            return _flash.flash_attention(
                q, k, v, causal=causal, sliding_window=sliding_window,
                scale=scale, block_q=block_q, block_k=block_k,
                interpret=(backend == "interpret"))
        # fall through for awkward shapes
    if scale is None:
        scale = q.shape[-1] ** -0.5
    mask = None
    if causal or sliding_window is not None:
        mask = causal_mask(q.shape[2], k.shape[2],
                           q_offset=k.shape[2] - q.shape[2],
                           sliding_window=sliding_window)[None, None]
    return _softmax_attend(q, k, v, scale=scale, mask=mask)
