"""Kernel-backend A/B: pallas(interpret) vs XLA wall time on both hot
paths.

Measured: median/p90 per call of ``ops.linear_attention_op`` — the
LASP-2 intra-chunk hot path — AND ``ops.flash_attention_op`` — the
LASP-2H hybrid softmax hot path — on each differentiable backend,
forward and forward+backward (for the linear op ``jax.grad`` pulls on
o, state and log_decay, i.e. what the faithful SP backward pulls on;
for flash on o). On this CPU container the interpret numbers are
*indicative only* (Pallas interpret mode is a jax-level emulator; the
TPU "pallas" backend is the target) — the bench exists so CI tracks
that both custom_vjp paths stay wired and their relative cost
trajectory across PRs. Derived: fwd/bwd FLOP counts. Emits
``BENCH_kernels.json``.
"""

from __future__ import annotations

from benchmarks.common import emit, run_subprocess_bench

BENCH_NAME = "kernels"

_CODE = r"""
import json, time
import jax, jax.numpy as jnp
from repro.kernels import ops
from benchmarks.common import percentile

BH, S, D, BS = 4, 2048, 64, 128
key = jax.random.PRNGKey(0)
ks = jax.random.split(key, 4)
q = jax.random.normal(ks[0], (1, BH, S, D)) * 0.3
k = jax.random.normal(ks[1], (1, BH, S, D)) * 0.3
v = jax.random.normal(ks[2], (1, BH, S, D)) * 0.5
la = -jnp.abs(jax.random.normal(ks[3], (1, BH, S))) * 0.03

def make_fwd(backend):
    return jax.jit(lambda a, b, c, d: ops.linear_attention_op(
        a, b, c, d, block_size=BS, backend=backend)[0])

def make_grad(backend):
    def loss(a, b, c, d):
        o, st, ld = ops.linear_attention_op(a, b, c, d, block_size=BS,
                                            backend=backend)
        return jnp.sum(o) + jnp.sum(st) + jnp.sum(ld)
    return jax.jit(jax.grad(loss, argnums=(0, 1, 2, 3)))

# chunked-algorithm FLOPs (per _block_terms: QK^T, scores·V, K^T V + the
# inter-chunk (q·b)@M term), fwd; bwd re-runs ~2x that in the two passes.
flops_fwd = 2 * S * (2 * BS * D + 2 * D * D) * BH

def timeit(fn, *args):
    out = fn(*args)
    jax.block_until_ready(out)
    times = []
    for _ in range(5):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append((time.perf_counter() - t0) * 1e6)
    return times

res = {}
for backend in ("xla", "interpret"):
    for tag, fn in (("fwd", make_fwd(backend)), ("grad", make_grad(backend))):
        times = timeit(fn, q, k, v, la)
        res[f"{backend}_{tag}"] = {
            "median_us": percentile(times, 50),
            "p90_us": percentile(times, 90),
            "flops_analytic": flops_fwd * (3 if tag == "grad" else 1),
        }

# LASP-2H flash hot path: GQA softmax attention (causal), fwd + grad
# through the flash custom_vjp (interpret) vs XLA masked-softmax autodiff.
FB, FHQ, FHKV, FS, FD = 1, 8, 2, 1024, 64
fks = jax.random.split(jax.random.PRNGKey(1), 3)
fq = jax.random.normal(fks[0], (FB, FHQ, FS, FD)) * 0.4
fk = jax.random.normal(fks[1], (FB, FHKV, FS, FD)) * 0.4
fv = jax.random.normal(fks[2], (FB, FHKV, FS, FD)) * 0.5
# causal flash FLOPs: ~1/2 the dense 2·2·S²·D per head pair; bwd ~2.5x
flash_flops_fwd = 2 * 2 * FS * FS * FD * FHQ * FB // 2

def make_flash_fwd(backend):
    return jax.jit(lambda a, b, c: ops.flash_attention_op(
        a, b, c, causal=True, backend=backend))

def make_flash_grad(backend):
    def loss(a, b, c):
        return jnp.sum(ops.flash_attention_op(a, b, c, causal=True,
                                              backend=backend))
    return jax.jit(jax.grad(loss, argnums=(0, 1, 2)))

for backend in ("xla", "interpret"):
    for tag, fn in (("fwd", make_flash_fwd(backend)),
                    ("grad", make_flash_grad(backend))):
        times = timeit(fn, fq, fk, fv)
        res[f"flash_{backend}_{tag}"] = {
            "median_us": percentile(times, 50),
            "p90_us": percentile(times, 90),
            "flops_analytic":
                flash_flops_fwd * (5 if tag == "grad" else 2) // 2,
        }
print(json.dumps(res))
"""


def main():
    res = run_subprocess_bench(_CODE, devices=1)
    rows = []
    for name, r in sorted(res.items()):
        rows.append((f"kernels/{name}", r["median_us"],
                     f"p90={r['p90_us']:.0f}us "
                     f"flops={r['flops_analytic']}"))
    emit(rows, header=None)
    xla = res["xla_grad"]["median_us"]
    interp = res["interpret_grad"]["median_us"]
    return {
        "rows": [{"name": n, "us_per_call": us, "derived": d}
                 for n, us, d in rows],
        "shape": {"bh": 4, "s": 2048, "d": 64, "block": 128},
        "flash_shape": {"b": 1, "hq": 8, "hkv": 2, "s": 1024, "dh": 64},
        "interpret_over_xla_grad": interp / max(xla, 1e-9),
        "flash_interpret_over_xla_grad":
            res["flash_interpret_grad"]["median_us"]
            / max(res["flash_xla_grad"]["median_us"], 1e-9),
        "note": ("interpret backend is a CPU emulator of the Pallas "
                 "kernel — TPU 'pallas' is the production path; tracked "
                 "for wiring + trajectory, not absolute speed"),
    }


if __name__ == "__main__":
    main()
