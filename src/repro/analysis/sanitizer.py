"""Layer 2: compiled-program sanitizer (SAN2xx invariants).

Where the AST lint reads source, this layer reads the *programs*: it
lowers and compiles the small-config train steps ((1,8) and (2,4)
DP×SP splits of the 8 virtual devices, plus the (2,2,2) DP×SP×TP
ulysses hybrid step) plus the serve decode step, and statically
asserts the program-level invariants the HLO collective budgets
(``repro.comm.budget``) don't cover:

* SAN201 — zero host transfers (no infeed/outfeed/host custom-calls);
* SAN202 — zero f64 (or c128) ops;
* SAN203 — ``comm_dtype=bf16`` exchanges actually carry bf16 on the
  wire, read from the LOWERED StableHLO (XLA:CPU float normalization
  upcasts bf16 collectives to f32 in compiled HLO, so the compiled text
  cannot prove this);
* SAN204 — donated buffers truly aliased (non-empty input_output_alias
  table: the train state under ``donate_argnums=(0,)``, the decode
  cache under ``donate_argnums=(2,)``);
* SAN205 — deterministic lowering: two independent lowerings produce
  the identical collective fingerprint (op, dtype, shape, groups).

``sanitize_text`` is the pure-text core (unit-testable against crafted
HLO); the ``sanitize_*`` drivers build the real programs. The train
drivers need the 8-virtual-device CPU topology — the CLI
(``python -m repro.analysis``) sets ``XLA_FLAGS`` before importing jax.
"""

from __future__ import annotations

import re
from typing import Callable, List, Optional

from repro.analysis.findings import AnalysisResult, Finding

_HOST_OP_RE = re.compile(r"\b(?:infeed|outfeed)(?:-done|-start)?\(")
_F64_RE = re.compile(r"\b(f64|c128)\[")

_WIRE_DTYPE = {"bf16": "bf16", "fp32": "f32"}


# ---------------------------------------------------------------------------
# Pure-text checks (unit-testable on crafted HLO/StableHLO).
# ---------------------------------------------------------------------------

def sanitize_text(label: str, *, compiled_text: Optional[str] = None,
                  lowered_text: Optional[str] = None, mesh=None,
                  comm_dtype: Optional[str] = None,
                  expect_donation: bool = False) -> List[Finding]:
    findings: List[Finding] = []
    if compiled_text is not None:
        findings += _check_host_transfers(label, compiled_text)
        findings += _check_f64(label, compiled_text)
        if expect_donation:
            findings += _check_donation(label, compiled_text)
    if lowered_text is not None and mesh is not None and comm_dtype:
        findings += _check_wire_dtype(label, lowered_text, mesh, comm_dtype)
    return findings


def _check_host_transfers(label: str, compiled_text: str) -> List[Finding]:
    out = []
    for i, line in enumerate(compiled_text.splitlines(), start=1):
        s = line.strip()
        what = None
        if _HOST_OP_RE.search(s):
            what = "infeed/outfeed"
        elif "is_host_transfer=true" in s:
            what = "host-transfer send/recv"
        elif "custom-call" in s and "host" in s.lower():
            what = "host custom-call"
        if what:
            out.append(Finding(
                code="SAN201", path=label, line=i,
                message=f"{what} in compiled program — a device<->host "
                        f"round trip inside the step",
                source=s[:160]))
    return out


def _check_f64(label: str, compiled_text: str) -> List[Finding]:
    out = []
    for i, line in enumerate(compiled_text.splitlines(), start=1):
        m = _F64_RE.search(line)
        if m and "metadata" not in line[:m.start()]:
            out.append(Finding(
                code="SAN202", path=label, line=i,
                message=f"{m.group(1)} buffer in compiled program — "
                        f"accidental double-precision promotion",
                source=line.strip()[:160]))
            if len(out) >= 5:       # one is a failure; don't spam
                break
    return out


def _check_donation(label: str, compiled_text: str) -> List[Finding]:
    from repro.launch.hlo_analysis import alias_entries
    n = alias_entries(compiled_text)
    if n == 0:
        return [Finding(
            code="SAN204", path=label, line=0,
            message="input_output_alias table is empty — the donated "
                    "buffers (donate_argnums) silently degraded to "
                    "copies; peak memory doubles for the donated state")]
    return []


def _check_wire_dtype(label: str, lowered_text: str, mesh,
                      comm_dtype: str) -> List[Finding]:
    from repro.launch import hlo_analysis as H
    from repro.launch.mesh import SEQ_AXIS

    want = _WIRE_DTYPE[comm_dtype]
    out: List[Finding] = []
    n_seq_exchanges = 0
    for c in H.parse_stablehlo_collectives(lowered_text):
        if c.op not in ("all-gather", "reduce-scatter") or c.groups is None:
            continue        # model-axis all-to-alls are the ulysses head
            # repartition (a legitimate mixed-dtype wire: packed q‖k‖v in
            # the narrow dtype, attention output in compute dtype) — not
            # part of the sequence-wire contract
        axes = H.group_axes([list(g) for g in c.groups], mesh)
        if SEQ_AXIS not in axes:
            continue        # ZeRO-1 (data, model) gather / grad reduce:
            # fp32 by design, not part of the comm_dtype contract
        n_seq_exchanges += 1
        if c.dtype != want:
            out.append(Finding(
                code="SAN203", path=label, line=0,
                message=f"comm_dtype={comm_dtype}: {c.op} over the "
                        f"sequence axis carries {c.dtype} (shape "
                        f"{c.shape}) — expected {want} on the wire",
                source=f"{c.op} {c.dtype}{list(c.shape)} "
                       f"groups={c.groups}"))
    if mesh.shape.get(SEQ_AXIS, 1) > 1 and n_seq_exchanges == 0:
        out.append(Finding(
            code="SAN203", path=label, line=0,
            message="no sequence-axis state exchange found in the "
                    "lowered program — the wire-dtype check would be "
                    "vacuous (did the LASP-2 path compile in?)"))
    return out


def check_determinism(label: str,
                      lower_once: Callable[[], str]) -> List[Finding]:
    """SAN205: two independent lowerings -> identical collective
    fingerprints."""
    from repro.launch.hlo_analysis import collective_fingerprint
    fp1 = collective_fingerprint(lower_once())
    fp2 = collective_fingerprint(lower_once())
    if fp1 == fp2:
        return []
    diff = next((i for i, (a, b) in enumerate(zip(fp1, fp2)) if a != b),
                min(len(fp1), len(fp2)))
    return [Finding(
        code="SAN205", path=label, line=0,
        message=f"collective fingerprint drifts between two independent "
                f"lowerings (first divergence at collective #{diff}: "
                f"{fp1[diff] if diff < len(fp1) else '<missing>'} vs "
                f"{fp2[diff] if diff < len(fp2) else '<missing>'}) — "
                f"nondeterministic trace-time state")]


# ---------------------------------------------------------------------------
# Program builders (real lowerings of the repo's hot-path steps).
# ---------------------------------------------------------------------------

def _smoke_cfg():
    from repro.configs import get_smoke
    return get_smoke("linear-llama3-1b")


def _hybrid_smoke_cfg():
    """Tiny linear+softmax hybrid — the program that actually carries
    the ulysses model-axis All-to-Alls on a 3D mesh."""
    from repro.configs.base import (LayerSpec, LinearAttnConfig,
                                    ModelConfig)
    return ModelConfig(
        name="hybrid-smoke", family="hybrid", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, d_ff=160, vocab_size=512,
        pattern=(LayerSpec(mixer="linear"), LayerSpec(mixer="softmax")),
        linear_attn=LinearAttnConfig(feature_map="identity", decay="none"))


def _require_devices(n: int):
    import jax
    have = len(jax.devices())
    if have < n:
        raise RuntimeError(
            f"sanitizer needs {n} devices, jax sees {have} — run via "
            f"`python -m repro.analysis` (it sets XLA_FLAGS="
            f"--xla_force_host_platform_device_count=8 before importing "
            f"jax), or export it yourself")


def lower_train_step(dp: int, sp: int, tp: int = 1, *,
                     comm_strategy: str = "allgather",
                     comm_dtype: str = "bf16",
                     zero1: bool = True, batch: int = 8, seq: int = 64,
                     cfg=None):
    """Lower (not compile) one DP×SP(×TP) smoke train step; returns
    ``(lowered, mesh)``. Fresh closures per call, so calling twice gives
    the two independent lowerings SAN205 needs. ``tp > 1`` builds the
    3D mesh (pass ``comm_strategy="ulysses"`` + the hybrid smoke config
    to put model-axis All-to-Alls in the program)."""
    import jax
    import jax.numpy as jnp

    from repro.comm.spec import CommSpec
    from repro.configs.base import RunConfig
    from repro.launch.mesh import make_training_mesh
    from repro.sharding.rules import make_plan
    from repro.train.step import init_state, make_train_step

    _require_devices(dp * sp * tp)
    cfg = cfg if cfg is not None else _smoke_cfg()
    mesh = make_training_mesh(dp, sp, tp)
    plan = make_plan(mesh, "train", global_batch=batch,
                     n_kv_heads=cfg.n_kv_heads, n_heads=cfg.n_heads,
                     comm=CommSpec(strategy=comm_strategy,
                                   dtype=comm_dtype),
                     zero1=zero1)
    run = RunConfig(comm_strategy=comm_strategy, comm_dtype=comm_dtype,
                    zero1=zero1, dp_degree=dp, sp_degree=sp,
                    tp_degree=tp)
    state = jax.eval_shape(
        lambda: init_state(jax.random.PRNGKey(0), cfg, run, plan))
    sds = jax.ShapeDtypeStruct
    batch_sds = {"tokens": sds((1, batch, seq), jnp.int32),
                 "labels": sds((1, batch, seq), jnp.int32),
                 "resets": sds((1, batch, seq), jnp.bool_)}
    step = make_train_step(cfg, run, plan)
    lowered = jax.jit(step, donate_argnums=(0,)).lower(state, batch_sds)
    return lowered, mesh


def lower_decode_step(*, batch: int = 2, max_len: int = 64):
    """Lower the serve decode step (single device, donated cache) —
    the same jit the engine builds (``serve/engine.py``)."""
    import jax
    import jax.numpy as jnp

    from repro.models import model as M
    from repro.sharding.rules import local_plan

    cfg = _smoke_cfg()
    plan = local_plan()
    params = jax.eval_shape(
        lambda: M.init_params(jax.random.PRNGKey(0), cfg))
    cache = jax.eval_shape(lambda: M.init_cache(cfg, batch, max_len))

    def _decode(p, tok, c):
        return M.decode_step(p, tok, c, cfg, plan)

    tok = jax.ShapeDtypeStruct((batch,), jnp.int32)
    return jax.jit(_decode, donate_argnums=(2,)).lower(params, tok, cache)


def sanitize_train_step(dp: int, sp: int, tp: int = 1, *,
                        comm_strategy: str = "allgather",
                        comm_dtype: str = "bf16",
                        zero1: bool = True, cfg=None,
                        determinism: bool = True) -> List[Finding]:
    label = (f"train_step[dp={dp},sp={sp},tp={tp},"
             f"comm={comm_strategy},comm_dtype={comm_dtype}]")
    lowered, mesh = lower_train_step(dp, sp, tp,
                                     comm_strategy=comm_strategy,
                                     comm_dtype=comm_dtype,
                                     zero1=zero1, cfg=cfg)
    compiled_text = lowered.compile().as_text()
    findings = sanitize_text(
        label, compiled_text=compiled_text, lowered_text=lowered.as_text(),
        mesh=mesh, comm_dtype=comm_dtype, expect_donation=True)
    if determinism:
        findings += check_determinism(
            label, lambda: lower_train_step(
                dp, sp, tp, comm_strategy=comm_strategy,
                comm_dtype=comm_dtype, zero1=zero1,
                cfg=cfg)[0].as_text())
    return findings


def sanitize_decode_step() -> List[Finding]:
    lowered = lower_decode_step()
    return sanitize_text("decode_step[serve]",
                         compiled_text=lowered.compile().as_text(),
                         expect_donation=True)


def run_sanitizer() -> AnalysisResult:
    """The CI battery: (1,8) + (2,4) train steps (bf16 wire), the
    (2,2,2) ulysses hybrid train step, and the serve decode step."""
    result = AnalysisResult()
    result.findings += sanitize_train_step(1, 8)
    result.findings += sanitize_train_step(2, 4)
    result.findings += sanitize_train_step(
        2, 2, 2, comm_strategy="ulysses", cfg=_hybrid_smoke_cfg())
    result.findings += sanitize_decode_step()
    result.checked["programs"] = 4
    return result
