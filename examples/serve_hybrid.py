"""Serve a hybrid (linear + softmax attention) model with continuous
batching.

Shows the paper's constant-memory-inference property end to end: the
linear layers' decode cache is a fixed (B, H, dk, dv) fp32 state (+ a
cumulative log decay) regardless of how long the generation runs, and the
(1-in-4) softmax layers keep a ring-buffer KV cache bounded by their
sliding window — so the whole decode cache is O(1) in context length.
Requests with different prompt lengths are admitted into and evicted from
the decode batch mid-flight.

  PYTHONPATH=src python examples/serve_hybrid.py
"""

import sys

sys.path.insert(0, "src")

import dataclasses

import jax
import numpy as np

from repro.configs import get_smoke
from repro.configs.base import LayerSpec
from repro.models import model as M
from repro.serve.engine import ServeEngine


def main():
    base = get_smoke("linear-llama3-1b")
    dense = dataclasses.replace(base, pattern=(LayerSpec(),), n_layers=4,
                                name="smoke-dense")
    cfg = dense.linearize(hybrid_every=4)   # 3 linear + 1 windowed softmax
    print("serving", cfg.name, "| pattern:",
          [s.mixer for s in cfg.pattern])

    key = jax.random.PRNGKey(0)
    params = M.init_params(key, cfg)
    engine = ServeEngine(cfg, params, max_len=256, max_batch=4)

    # 8 ragged requests over 4 decode slots — continuous batching.
    rng = np.random.default_rng(0)
    uids = []
    for i in range(8):
        prompt = rng.integers(0, cfg.vocab_size, size=int(rng.integers(8, 65)))
        uids.append(engine.submit(prompt, 24, temperature=0.8,
                                  seed=1, stream=i))
    results = engine.run()
    print("generated:", {u: len(results[u]) for u in uids})
    stats = engine.cache_stats()
    print(f"decode-cache bytes: linear_state={stats['linear_state']} "
          f"kv_ring={stats['kv_ring']} (ring = sliding window, "
          f"not context length)")

    # constant-memory property: linear state size is independent of length
    cache256 = M.init_cache(cfg, batch=4, max_len=256)
    cache4k = M.init_cache(cfg, batch=4, max_len=4096)
    lin256 = cache256["layers"][0]["mixer"]["m"]
    lin4k = cache4k["layers"][0]["mixer"]["m"]
    kv256 = cache256["layers"][3]["mixer"]["k"]
    kv4k = cache4k["layers"][3]["mixer"]["k"]
    print(f"linear-attn state:  max_len=256 -> {lin256.shape}, "
          f"max_len=4096 -> {lin4k.shape}  (CONSTANT — paper's claim)")
    print(f"softmax KV ring:    max_len=256 -> {kv256.shape}, "
          f"max_len=4096 -> {kv4k.shape}  (bounded by the 2048 window)")
    assert lin256.shape == lin4k.shape
    assert kv4k.shape[-2] == 2048, "ring capped at the sliding window"
    print("OK")


if __name__ == "__main__":
    main()
