"""Pallas TPU kernel: blockwise online-softmax (flash) GQA attention.

Used by the standard-attention layers of hybrid models (LASP-2H's local
compute after the K/V AllGather — paper Alg. 7 line 7) and by prefill.

Grid = ``(B, Hq, nq, nkv)``; the kv axis is the innermost sequential axis;
``(m, l, acc)`` live in VMEM scratch and are reset when ``ik == 0``. Causal
blocks strictly above the diagonal are skipped with ``pl.when`` (their HBM
tiles are still fetched by the pipeline — acceptable; the hillclimb notes
discuss trimming the grid). GQA is expressed in the K/V index maps
(``hq // rep``), so KV tiles are fetched once per q-head group member
without materializing repeated heads in HBM.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import compat as _compat

NEG_INF = -1e30
DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, causal: bool, sliding_window, q_offset: int,
            nkv: int, block_q: int, block_k: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # Query row i of this block sits at *global* position
    # q_offset + q_start + i (q_offset = sk - sq for prefill-with-cache /
    # ring-decode shapes; 0 when sq == sk). Key positions are global
    # already. Masking with local q indices here was the sq != sk bug.
    q_start = iq * block_q
    k_start = ik * block_k

    # Causality at block granularity: skip blocks entirely above the diagonal
    # (and, with a sliding window, blocks entirely below it) — both
    # predicates in global coordinates.
    needed = True
    if causal:
        needed = jnp.asarray(k_start <= q_offset + q_start + block_q - 1)
    if sliding_window is not None:
        lo_ok = (q_offset + q_start - (k_start + block_k - 1)) \
            < sliding_window
        needed = jnp.logical_and(needed, lo_ok)

    @pl.when(needed)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)       # (bq, dh)
        k = k_ref[0, 0].astype(jnp.float32)       # (bk, dh)
        v = v_ref[0, 0].astype(jnp.float32)       # (bk, dh)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale       # (bq, bk)
        qpos = q_offset + q_start + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 0)
        kpos = k_start + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 1)
        mask = jnp.ones((block_q, block_k), bool)
        if causal:
            mask &= qpos >= kpos
        if sliding_window is not None:
            mask &= (qpos - kpos) < sliding_window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[:, 0]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(mask, p, 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_scr[:, 0] = l_scr[:, 0] * corr + jnp.sum(p, axis=-1)
        acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[:, 0] = m_new

    @pl.when(ik == nkv - 1)
    def _finalize():
        l = jnp.maximum(l_scr[:, 0], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "sliding_window", "scale", "q_offset", "block_q", "block_k",
    "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, sliding_window=None,
                    scale=None, q_offset: Optional[int] = None,
                    block_q: int = DEFAULT_BLOCK_Q,
                    block_k: int = DEFAULT_BLOCK_K, interpret: bool = False):
    """GQA flash attention (forward). q: (B,Hq,S,dh), k/v: (B,Hkv,Sk,dh).

    ``q_offset``: global position of query row 0 (keys are global already).
    Defaults to ``sk - sq`` — the prefill-with-cache convention shared
    with the XLA mask fallback in ``repro.kernels.ops``.
    """
    b, hq, sq, dh = q.shape
    hkv, sk = k.shape[1], k.shape[2]
    rep = hq // hkv
    if scale is None:
        scale = dh ** -0.5
    if q_offset is None:
        q_offset = sk - sq
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    if sq % block_q or sk % block_k:
        raise ValueError(f"sq={sq}, sk={sk} not divisible by blocks "
                         f"({block_q}, {block_k})")
    nq, nkv = sq // block_q, sk // block_k

    kernel = functools.partial(
        _kernel, scale=scale, causal=causal, sliding_window=sliding_window,
        q_offset=q_offset, nkv=nkv, block_q=block_q, block_k=block_k)
    return pl.pallas_call(
        kernel,
        grid=(b, hq, nq, nkv),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, dh),
                         lambda b_, h, iq, ik: (b_, h, iq, 0)),
            pl.BlockSpec((1, 1, block_k, dh),
                         lambda b_, h, iq, ik, rep_=rep: (b_, h // rep_, ik, 0)),
            pl.BlockSpec((1, 1, block_k, dh),
                         lambda b_, h, iq, ik, rep_=rep: (b_, h // rep_, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, dh),
                               lambda b_, h, iq, ik: (b_, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, sq, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, dh), jnp.float32),
        ],
        compiler_params=_compat.tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
        name="flash_attention_fwd",
    )(q, k, v)
