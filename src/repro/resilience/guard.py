"""In-graph numerical health guard for the train step.

Everything here is pure ``jnp`` on values the step already computes —
the guard adds ZERO collectives and no extra pass over the gradients.
On the manual DP×SP(×TP) step the per-rank loss-health indicator rides
as one extra fp32 scalar inside the packed gradient all-reduce
(``train.grads``); gradient non-finiteness needs no local sweep because
NaN/Inf are absorbing under summation, so the post-reduction global
norm/loss checks see any rank's bad contribution. Every rank reaches
the same verdict from the same reduction that was already on the wire
(verified against the per-axis HLO budgets in the distributed battery,
and pinned to <2% compiled flops/bytes overhead by BENCH_guard).

Semantics per step, given the post-reduction global grad norm:

* **skip** — any rank saw a non-finite gradient/loss, or the reduced
  norm/loss is non-finite: gradients are zeroed, parameters AND
  optimizer state (including Adam's ``count``) are left untouched, and
  ``skipped_steps`` / ``consecutive_skips`` increment. The LR schedule
  keys off ``state["step"]`` which still advances, so a skipped step is
  exactly a no-op update — the property the chaos drill pins.
* **spike clip** — once ``GUARD_WARMUP`` finite norms are recorded, a
  finite norm above ``spike_factor ×`` the rolling median is clipped to
  ``min(grad_clip, spike_factor × median)``. The window records the
  post-clip norm, so one spike cannot drag the median, while genuine
  scale shifts still adapt within a window.
* **abort** — the loop (host side) raises :class:`GuardAbort` when
  ``consecutive_skips`` reaches ``run.guard_max_consecutive_skips``:
  params are clean (skips never applied updates), so the newest
  checkpoint is safe to resume from after the cause is fixed.
"""

from __future__ import annotations

import jax.numpy as jnp

# Finite steps recorded before the spike detector arms. Below this the
# guard only clips to ``grad_clip`` (the unguarded behaviour).
GUARD_WARMUP = 8

# Metric keys every guarded step emits (the loop and report tables key
# off these; all fp32 scalars so ``float(v)`` works host-side).
GUARD_METRICS = ("skipped_steps", "consecutive_skips", "guard_spike",
                 "guard_median")


class GuardAbort(RuntimeError):
    """Raised by the train loop when ``consecutive_skips`` crosses the
    configured threshold — the run cannot make progress and needs a
    human (or a restart from the last checkpoint with a fix)."""


def guard_init(window: int):
    """Guard state carried inside the train state (checkpointed like
    any other leaf; replicated on every rank — it is a pure function of
    all-reduced quantities)."""
    return {
        "norm_window": jnp.zeros((window,), jnp.float32),
        "window_count": jnp.zeros((), jnp.int32),
        "skipped_steps": jnp.zeros((), jnp.int32),
        "consecutive_skips": jnp.zeros((), jnp.int32),
        "spike_steps": jnp.zeros((), jnp.int32),
    }


def rolling_median(window, count):
    """Median of the ``min(count, len(window))`` recorded norms; 0 when
    empty. Unfilled slots are masked to +inf before the sort so they
    never contribute."""
    w = window.shape[0]
    n = jnp.minimum(count, w)
    vals = jnp.sort(jnp.where(jnp.arange(w) < n, window, jnp.inf))
    med = vals[jnp.maximum((n - 1) // 2, 0)]
    return jnp.where(n > 0, med, 0.0)


def guard_verdict(guard, gnorm, nonfinite, *, grad_clip: float,
                  spike_factor: float, warmup: int = GUARD_WARMUP):
    """The per-step guard decision.

    Args:
      guard: state from :func:`guard_init`.
      gnorm: global (post-reduction) gradient norm, fp32 scalar.
      nonfinite: bool scalar — True if ANY rank contributed a
        non-finite gradient/loss (or the reduced norm itself is bad).
      grad_clip / spike_factor: from RunConfig.

    Returns ``(scale, ok, new_guard, info)``: multiply the flat
    gradient by ``scale`` (0 on skip), gate state updates on ``ok``,
    merge ``info`` into the step metrics.
    """
    count = guard["window_count"]
    med = rolling_median(guard["norm_window"], count)
    armed = count >= warmup
    ok = jnp.logical_not(nonfinite)
    spike = armed & ok & (gnorm > spike_factor * med)
    limit = jnp.where(spike, jnp.minimum(grad_clip, spike_factor * med),
                      grad_clip)
    scale = jnp.where(
        ok, jnp.minimum(1.0, limit / jnp.maximum(gnorm, 1e-9)), 0.0)

    w = guard["norm_window"].shape[0]
    recorded = jnp.minimum(gnorm, limit)      # post-clip: spikes can't drag it
    new_window = jnp.where(
        ok, guard["norm_window"].at[count % w].set(recorded),
        guard["norm_window"])
    oki = ok.astype(jnp.int32)
    new_guard = {
        "norm_window": new_window,
        "window_count": count + oki,
        "skipped_steps": guard["skipped_steps"] + (1 - oki),
        "consecutive_skips": jnp.where(
            ok, 0, guard["consecutive_skips"] + 1),
        "spike_steps": guard["spike_steps"] + spike.astype(jnp.int32),
    }
    info = {
        "skipped_steps": new_guard["skipped_steps"].astype(jnp.float32),
        "consecutive_skips":
            new_guard["consecutive_skips"].astype(jnp.float32),
        "guard_spike": spike.astype(jnp.float32),
        "guard_median": jnp.where(armed, med, 0.0),
    }
    return scale, ok, new_guard, info


# -- deterministic fault injection (compiled into the step; drill/tests) ----

def chaos_hit(step, steps) -> jnp.ndarray:
    """True iff the (traced) step counter is in the static tuple."""
    hit = jnp.zeros((), bool)
    for s in steps:
        hit = hit | (step == s)
    return hit


def chaos_poison_nan(flat, step, nan_steps):
    """Poison the flat local gradient with NaN at the scheduled steps —
    exercises the guard's detection path end-to-end (the NaN survives
    the packed reduction and trips the post-reduce norm check)."""
    if not nan_steps:
        return flat
    return jnp.where(chaos_hit(step, nan_steps),
                     jnp.full_like(flat, jnp.nan), flat)
