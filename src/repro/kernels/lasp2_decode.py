"""Pallas TPU kernel: single-token recurrent linear-attention decode.

The serving hot loop (paper's constant-memory inference): every step
multiplies the fp32 ``dk × dv`` memory state by the token's decay, adds the
rank-1 update ``k^T v``, and reads it out with ``q`` — no re-scan of the
prefix, no KV cache. One program per batch·head keeps the whole state
resident in VMEM for the three small matmuls; HBM traffic is exactly the
state in + state out + the q/k/v vectors, which is what makes batched
decode memory-bound on the state and O(1) in context length.

Mirrors ``repro.core.linear_attention.recurrent_step`` (the XLA path ops.py
falls back to off-TPU); agreement is enforced by ``tests/test_kernels.py``
in interpret mode.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import compat as _compat


def _kernel(q_ref, k_ref, v_ref, la_ref, m_ref, ld_ref,
            o_ref, m_out_ref, ld_out_ref):
    q = q_ref[0].astype(jnp.float32)          # (1, dk)
    k = k_ref[0].astype(jnp.float32)          # (1, dk)
    v = v_ref[0].astype(jnp.float32)          # (1, dv)
    la = la_ref[0, 0]                         # scalar log decay
    m = m_ref[0]                              # (dk, dv) fp32

    a = jnp.exp(la)
    # M' = a·M + k^T v  (rank-1 outer product on the MXU)
    kv = jax.lax.dot_general(k, v, (((0,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    m_new = a * m + kv
    # o = q M'
    o = jax.lax.dot_general(q, m_new, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    o_ref[0] = o.astype(o_ref.dtype)
    m_out_ref[0] = m_new
    ld_out_ref[0, 0] = ld_ref[0, 0] + la


@functools.partial(jax.jit, static_argnames=("interpret",))
def lasp2_decode_step(q, k, v, log_a, state, log_decay, *,
                      interpret: bool = False):
    """Batched single-token recurrent decode, Pallas TPU.

    q, k: (BH, dk); v: (BH, dv); log_a: (BH,); state: (BH, dk, dv) fp32;
    log_decay: (BH,) fp32.
    Returns (o (BH, dv) fp32, state' (BH, dk, dv) fp32, log_decay' (BH,)).
    """
    bh, dk = q.shape
    dv = v.shape[-1]
    la2 = log_a.astype(jnp.float32).reshape(bh, 1)
    ld2 = log_decay.astype(jnp.float32).reshape(bh, 1)
    o, m_new, ld_new = pl.pallas_call(
        _kernel,
        grid=(bh,),
        in_specs=[
            pl.BlockSpec((1, 1, dk), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, 1, dk), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, 1, dv), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, 1), lambda b: (b, 0)),
            pl.BlockSpec((1, dk, dv), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, 1), lambda b: (b, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, dv), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, dk, dv), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, 1), lambda b: (b, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, 1, dv), jnp.float32),
            jax.ShapeDtypeStruct((bh, dk, dv), jnp.float32),
            jax.ShapeDtypeStruct((bh, 1), jnp.float32),
        ],
        compiler_params=_compat.tpu_compiler_params(
            dimension_semantics=("parallel",)),
        interpret=interpret,
        name="lasp2_decode_step",
    )(q[:, None, :], k[:, None, :], v[:, None, :], la2,
      state.astype(jnp.float32), ld2)
    return o[:, 0, :], m_new, ld_new[:, 0]
