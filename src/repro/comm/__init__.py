"""Pluggable SP communication subsystem.

Every cross-device exchange the sequence-parallel layers perform goes
through this package:

* :mod:`repro.comm.primitives` — named collectives (``allgather_states``,
  ``ring_sendrecv``, ``reduce_scatter_grads``, the ZeCO-style
  ``pipelined_prefix_exchange``), each recording a :class:`CommRecord`
  of bytes/steps onto an ambient trace-time tape.
* :mod:`repro.comm.strategy` — the pluggable exchange strategies for the
  LASP-2 inter-chunk state ("allgather" | "ring" | "pipelined").
* :mod:`repro.comm.overlap` — the double-buffered comm/compute overlap
  scheduler (``overlap`` vs ``none`` for A/B benchmarking).
* :mod:`repro.comm.budget` — HLO-verified collective budgets: assert the
  exact collective count/volume a strategy is allowed to put on the wire.

See docs/communication.md for the strategy matrix and overlap timeline.
"""

from repro.comm.primitives import (CommRecord, allgather_states,  # noqa: F401
                                   alltoall, auto_slices,
                                   pipelined_prefix_exchange,
                                   reduce_scatter_grads, ring_sendrecv,
                                   tape, tape_summary, wire_dtype)
from repro.comm.overlap import DoubleBufferedScheduler   # noqa: F401
from repro.comm.strategy import (PrefixExchange, get_budget_fn,  # noqa: F401
                                 get_context_budget_fn, get_strategy,
                                 pack_state, register_strategy,
                                 registered_strategies, unpack_state)
from repro.comm.spec import CommSpec, resolve_comm_spec   # noqa: F401
from repro.comm.budget import (CollectiveBudget, assert_budget,  # noqa: F401
                               check_budget, comm_itemsize,
                               hybrid_context_budget, lasp2_budget,
                               packed_state_bytes, ring_baseline_budget)

# Snapshot of the registry at import; prefer registered_strategies()
# which reflects later register_strategy() calls.
STRATEGY_NAMES = registered_strategies()
OVERLAP_MODES = ("overlap", "none")
COMM_DTYPES = ("fp32", "bf16")
