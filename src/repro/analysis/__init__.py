"""Static-analysis subsystem (docs/static_analysis.md).

Two layers over the SPMD hot path:

* **jaxlint** (``repro.analysis.lint`` + ``rules``): AST rules JL101 —
  JL106 over the Python sources (axis-name constants, host syncs,
  tracer isinstance, nondeterminism, Pallas debris / unmasked dynamic
  loads) plus the PAL301 BlockSpec grid-bounds checker
  (``pallas_check``).
* **sanitizer** (``repro.analysis.sanitizer``): compiles the small-
  config train/decode steps and asserts program-level invariants
  SAN201 — SAN205 (no host transfers, no f64, bf16 actually on the
  wire, donation aliased, deterministic lowering).

CLI: ``python -m repro.analysis`` (``--explain CODE``, ``--json OUT``).
This module stays import-light; jax loads only when a check needs it.
"""

from repro.analysis.decorators import host_sync_allowed
from repro.analysis.findings import AnalysisResult, Finding

__all__ = ["AnalysisResult", "Finding", "host_sync_allowed"]
