"""Named SP collectives with trace-time communication accounting.

Each primitive performs exactly one logical exchange via the matching
``jax.lax`` collective and appends a :class:`CommRecord` to the ambient
tape (:func:`tape`): the op name, the per-device wire traffic under the
standard ring cost model (the same model ``repro.launch.hlo_analysis``
applies to compiled HLO), and the number of *sequential* exchange steps
the call represents. Records are computed from static shapes at trace
time, so wrapping ``jax.jit(fn).lower(...)`` in a tape captures a
program's full communication budget without running it:

    with comm.tape() as records:
        jax.jit(step).lower(batch)
    bytes_on_wire = sum(r.traffic_bytes for r in records)

The tape is advisory (benchmarks, reports); the *enforced* budget checks
parse compiled HLO instead (:mod:`repro.comm.budget`), so the two views
cross-validate each other.
"""

from __future__ import annotations

import threading
from contextlib import contextmanager
from dataclasses import dataclass
from functools import partial
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp

_TAPE = threading.local()

# Wire-dtype registry for the ``comm_dtype`` knob (docs/communication.md):
# exchanges cast their payload to this dtype before the collective and
# accumulate/combine in fp32 locally. "bf16" halves every state/KV
# exchange's bytes (ZeCO's observation: comm *volume*, not just count,
# limits SP scalability) at ~3 decimal digits of payload precision.
_COMM_DTYPES = {
    "fp32": jnp.float32, "float32": jnp.float32,
    "bf16": jnp.bfloat16, "bfloat16": jnp.bfloat16,
}


def wire_dtype(comm_dtype: Optional[str]):
    """Resolve a ``comm_dtype`` knob value ("fp32" | "bf16") to a dtype."""
    if comm_dtype is None:
        return jnp.float32
    try:
        return _COMM_DTYPES[comm_dtype]
    except KeyError:
        raise ValueError(
            f"unknown comm_dtype {comm_dtype!r}; expected one of "
            f"{tuple(_COMM_DTYPES)}") from None


@jax.custom_vjp
def _pin(x):
    """Identity that XLA passes cannot look through (values unchanged)."""
    return jax.lax.optimization_barrier(x)


def _pin_fwd(x):
    return _pin(x), None


def _pin_bwd(_, ct):
    return (ct,)


_pin.defvjp(_pin_fwd, _pin_bwd)


def upcast_gathered(x, dtype=jnp.float32):
    """Upcast a gathered wire-dtype payload to the local accumulate dtype
    *behind an optimization barrier*.

    Without the barrier XLA's convert-mover commutes the upcast across
    the adjacent collective ("convert processes 1/W the data before the
    gather") — undoing the comm_dtype bf16 halving by putting the fp32
    payload back on the wire (observed on XLA-CPU, whose cost model does
    not price collective bytes). A no-op when no cast happened.
    """
    if x.dtype == jnp.dtype(dtype):
        return x
    return _pin(x).astype(dtype)


@dataclass(frozen=True)
class CommRecord:
    """One collective issued by an SP layer (static, trace-time)."""

    op: str              # all-gather | collective-permute | reduce-scatter
    payload_bytes: int   # bytes entering the collective, per device
    traffic_bytes: int   # per-device wire traffic (ring cost model)
    steps: int           # sequential exchange steps this call represents
    group: int           # devices participating
    tag: str = ""        # call-site label, e.g. "lasp2.states"


@contextmanager
def tape():
    """Collect CommRecords from every primitive traced inside the block."""
    prev = getattr(_TAPE, "records", None)
    _TAPE.records = []
    try:
        yield _TAPE.records
    finally:
        _TAPE.records = prev


def _record(rec: CommRecord) -> None:
    records = getattr(_TAPE, "records", None)
    if records is not None:
        records.append(rec)


def tape_summary(records: List[CommRecord]) -> Dict[str, float]:
    """Totals per op + overall, mirroring hlo_analysis.collective_summary."""
    out: Dict[str, float] = {}
    for r in records:
        out[r.op] = out.get(r.op, 0) + r.traffic_bytes
        out[f"{r.op}_count"] = out.get(f"{r.op}_count", 0) + 1
        out[f"{r.op}_steps"] = out.get(f"{r.op}_steps", 0) + r.steps
    out["total_bytes"] = sum(r.traffic_bytes for r in records)
    out["total_steps"] = sum(r.steps for r in records)
    return out


def _nbytes(x) -> int:
    return int(x.size) * x.dtype.itemsize


# ---------------------------------------------------------------------------
# The collectives.
# ---------------------------------------------------------------------------

def allgather_states(x, axis: str, *, axis_size: int, gather_axis: int = 0,
                     tiled: bool = False, tag: str = ""):
    """AllGather along mesh axis ``axis`` — THE LASP-2 exchange.

    Traffic per device (ring model): ``(g-1) × payload`` — the result is
    ``g × payload`` of which ``(g-1)/g`` crosses the wire. One collective
    call = one sequential step regardless of group size: the whole point
    of LASP-2 vs the ring (paper §3.4).
    """
    pb = _nbytes(x)
    _record(CommRecord("all-gather", pb, (axis_size - 1) * pb, steps=1,
                       group=axis_size, tag=tag))
    return jax.lax.all_gather(x, axis, axis=gather_axis, tiled=tiled)


def ring_sendrecv(x, axis: str, *, axis_size: int, shift: int = 1,
                  loop_trips: int = 1, tag: str = ""):
    """One ring hop: every rank sends ``x`` to ``(rank + shift) % W``.

    Implemented with ``ppermute``; per-device traffic = payload, one
    sequential step. ``loop_trips``: when called once inside a
    ``fori_loop`` body that executes W times, pass ``loop_trips=W`` so the
    tape stays honest (HLO also shows while bodies once — the budget
    checker has the same caveat).
    """
    perm = [(i, (i + shift) % axis_size) for i in range(axis_size)]
    pb = _nbytes(x)
    _record(CommRecord("collective-permute", pb, pb * loop_trips,
                       steps=loop_trips, group=axis_size, tag=tag))
    return jax.lax.ppermute(x, axis, perm)


def reduce_scatter_grads(x, axis: str, *, axis_size: int,
                         scatter_axis: int = 0, tiled: bool = True,
                         tag: str = ""):
    """Reduce-scatter along ``axis`` — the AD transpose of the state
    AllGather (what ``backward="autodiff"`` puts on the wire; emitted
    explicitly here for callers that hand-write the mirrored backward).

    Traffic per device: ``(g-1)/g × payload`` (result is payload / g).
    """
    pb = _nbytes(x)
    _record(CommRecord("reduce-scatter", pb,
                       (axis_size - 1) * pb // axis_size, steps=1,
                       group=axis_size, tag=tag))
    return jax.lax.psum_scatter(x, axis, scatter_dimension=scatter_axis,
                                tiled=tiled)


def psum_packed(x, axes, *, group_size: int, tag: str = ""):
    """All-reduce ``x`` over ``axes`` (a mesh axis name or tuple of them)
    in ONE collective — the 2D train step's single gradient reduction
    (all microbatch-accumulated gradients plus the loss/token counters are
    raveled into one fp32 vector first; see ``repro.train.step``).

    Traffic per device (ring model): ``2(g-1)/g × payload``.
    """
    pb = _nbytes(x)
    _record(CommRecord("all-reduce", pb,
                       2 * (group_size - 1) * pb // max(group_size, 1),
                       steps=1, group=group_size, tag=tag))
    return jax.lax.psum(x, axes)


def multi_axis_index(axis):
    """``jax.lax.axis_index`` that also accepts a TUPLE of axis names.

    Returns the mixed-radix rank index with the FIRST axis most
    significant — the same ordering ``jax.lax.all_gather`` uses when
    concatenating over a tuple of axes, so the value is directly usable
    as the gathered-chunk index ``t`` of this rank's shard.
    """
    if isinstance(axis, (tuple, list)):
        t = jax.lax.axis_index(axis[0])
        for a in axis[1:]:
            t = t * jax.lax.psum(1, a) + jax.lax.axis_index(a)
        return t
    return jax.lax.axis_index(axis)


def _alltoall_impl(x, axis, axis_size, split_axis, concat_axis, tag):
    pb = _nbytes(x)
    _record(CommRecord("all-to-all", pb,
                       (axis_size - 1) * pb // max(axis_size, 1), steps=1,
                       group=axis_size, tag=tag))
    return jax.lax.all_to_all(x, axis, split_axis, concat_axis, tiled=True)


@partial(jax.custom_vjp, nondiff_argnums=(1, 2, 3, 4, 5))
def _alltoall(x, axis, axis_size, split_axis, concat_axis, tag):
    return _alltoall_impl(x, axis, axis_size, split_axis, concat_axis, tag)


def _alltoall_fwd(x, axis, axis_size, split_axis, concat_axis, tag):
    return _alltoall_impl(x, axis, axis_size, split_axis, concat_axis,
                          tag), None


def _alltoall_bwd(axis, axis_size, split_axis, concat_axis, tag, _, ct):
    # The AD transpose of an all-to-all is the all-to-all with the split/
    # concat dims swapped — the mirrored pair, recorded on the tape like
    # any forward exchange.
    return (_alltoall_impl(ct, axis, axis_size, concat_axis, split_axis,
                           f"{tag}.bwd" if tag else "alltoall.bwd"),)


_alltoall.defvjp(_alltoall_fwd, _alltoall_bwd)


def alltoall(x, axis: str, *, axis_size: int, split_axis: int,
             concat_axis: int, tag: str = ""):
    """Tiled All-to-All over mesh axis ``axis`` — the Ulysses repartition.

    Splits ``split_axis`` into ``axis_size`` chunks (chunk j to rank j),
    concatenating the received chunks along ``concat_axis`` in rank
    order: ``dim[split] /= g``, ``dim[concat] *= g``. Traffic per device
    (ring model): ``(g-1)/g × payload`` — each rank keeps its own chunk.
    One collective call = one sequential step.

    Differentiable via ``custom_vjp``: the backward is the mirrored
    all-to-all (split/concat swapped), so autodiff through a
    seq→head→seq repartition pair costs exactly two more all-to-alls
    and the trace-time tape stays honest in both directions.
    """
    return _alltoall(x, axis, axis_size, split_axis, concat_axis, tag)


# ---------------------------------------------------------------------------
# Ring / pipelined prefix-scan exchanges (LASP-1 pattern, ZeCO refinement).
# ---------------------------------------------------------------------------

def auto_slices(dv: int, preferred: int = 4) -> int:
    """Slice count for the pipelined exchange: largest power of two
    <= ``preferred`` dividing the state's value dimension."""
    n = preferred
    while n > 1 and dv % n:
        n //= 2
    return max(n, 1)


def _prefix_chain(m_slice, chunk_decay, axis: str, axis_size: int, t,
                  tag: str, wire=jnp.float32):
    """Unrolled W-1 step ring prefix-accumulation of one state slice.

    At step s, rank t receives the packet that originated at rank
    ``t-1-s``; every forwarding rank has already folded its own chunk
    decay in, so the arriving packet equals
    ``exp(cum[t-1] - cum[src]) * M_src`` — accumulate iff ``src >= 0``.
    The loop is unrolled (W is a static mesh degree), which (a) lets the
    HLO budget checker count the 2(W-1) fwd+bwd permutes literally and
    (b) exposes every hop to XLA's latency-hiding scheduler.

    ``wire``: each hop's payload dtype; accumulation stays fp32. Note a
    bf16 wire re-rounds the packet at every hop (W-1 compounding casts) —
    looser than the single cast of the allgather strategy.
    """
    m_prev = jnp.zeros_like(m_slice)
    packet = m_slice
    for s in range(axis_size - 1):
        packet = upcast_gathered(
            ring_sendrecv(packet.astype(wire), axis, axis_size=axis_size,
                          tag=tag), jnp.float32)
        m_prev = jnp.where(t - 1 - s >= 0, m_prev + packet, m_prev)
        packet = packet * chunk_decay
    return m_prev


def pipelined_prefix_exchange(m_loc, log_decay, axis: str, *, axis_size: int,
                              t, n_slices: Optional[int] = None,
                              comm_dtype: Optional[str] = None,
                              tag: str = "pipelined"):
    """ZeCO-style pipelined ring prefix-scan of the chunk states.

    ``m_loc``: (..., dk, dv) fp32 local chunk state; ``log_decay``: (...,)
    fp32 total chunk log-decay; returns the decayed prefix state
    ``M_{1:t-1}`` (what :func:`prefix_state_combine` computes from a full
    gather). The prefix combine is elementwise-linear in the state, so the
    state splits along ``dv`` into ``n_slices`` *independent* ring chains:
    slice i+1's permute is dataflow-independent of slice i's accumulate,
    letting the scheduler pipeline communication of one slice behind
    computation on another (ZeCO's all-scan idea at chunk granularity —
    same total volume as the plain ring, W-1 → interleaved latency).

    With ``n_slices=1`` this *is* the LASP-1 ring exchange.
    """
    dv = m_loc.shape[-1]
    if n_slices is None:
        n_slices = auto_slices(dv)
    wire = wire_dtype(comm_dtype)
    chunk_decay = jnp.exp(log_decay)[..., None, None]
    if n_slices == 1:
        return _prefix_chain(m_loc, chunk_decay, axis, axis_size, t, tag,
                             wire=wire)
    slices = jnp.split(m_loc, n_slices, axis=-1)
    outs = [_prefix_chain(s_, chunk_decay, axis, axis_size, t,
                          f"{tag}[{i}]", wire=wire)
            for i, s_ in enumerate(slices)]
    return jnp.concatenate(outs, axis=-1)
