"""Per-kernel Pallas sweeps (interpret mode) vs the ref.py oracles."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops
from repro.kernels.flash_attention import flash_attention
from repro.kernels.lasp2_chunk import lasp2_chunk_fwd
from repro.kernels.ref import flash_attention_ref, linear_attention_ref

TOL = {jnp.float32: 3e-4, jnp.bfloat16: 4e-2}


@pytest.mark.parametrize("s,dk,dv", [(256, 64, 64), (512, 128, 128),
                                     (256, 32, 64), (128, 128, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("decay", [False, True])
def test_lasp2_chunk_kernel_sweep(rng, s, dk, dv, dtype, decay):
    bh = 3
    ks = jax.random.split(rng, 4)
    q = (jax.random.normal(ks[0], (bh, s, dk)) * 0.3).astype(dtype)
    k = (jax.random.normal(ks[1], (bh, s, dk)) * 0.3).astype(dtype)
    v = (jax.random.normal(ks[2], (bh, s, dv)) * 0.5).astype(dtype)
    la = (-jnp.abs(jax.random.normal(ks[3], (bh, s))) * 0.03) if decay \
        else jnp.zeros((bh, s))
    o, st, ld = lasp2_chunk_fwd(q, k, v, la, block_size=128, interpret=True)
    oref, stref = linear_attention_ref(q, k, v, la)
    t = TOL[dtype]
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(oref, np.float32), rtol=t, atol=t)
    np.testing.assert_allclose(st, stref, rtol=t, atol=t)
    np.testing.assert_allclose(ld, jnp.sum(la, -1), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("sq,sk,hq,hkv,dh", [
    (256, 256, 4, 2, 64), (128, 128, 8, 1, 64), (256, 256, 4, 4, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal,window", [(True, None), (False, None),
                                           (True, 64)])
def test_flash_kernel_sweep(rng, sq, sk, hq, hkv, dh, dtype, causal,
                            window):
    b = 2
    ks = jax.random.split(rng, 3)
    q = (jax.random.normal(ks[0], (b, hq, sq, dh)) * 0.4).astype(dtype)
    k = (jax.random.normal(ks[1], (b, hkv, sk, dh)) * 0.4).astype(dtype)
    v = (jax.random.normal(ks[2], (b, hkv, sk, dh)) * 0.5).astype(dtype)
    o = flash_attention(q, k, v, causal=causal, sliding_window=window,
                        block_q=64, block_k=64, interpret=True)
    oref = flash_attention_ref(q, k, v, causal=causal,
                               sliding_window=window)
    t = TOL[dtype]
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(oref, np.float32), rtol=t, atol=t)


def test_ops_dispatch_linear(rng):
    ks = jax.random.split(rng, 3)
    q = jax.random.normal(ks[0], (2, 4, 256, 32)) * 0.3
    k = jax.random.normal(ks[1], (2, 4, 256, 32)) * 0.3
    v = jax.random.normal(ks[2], (2, 4, 256, 32)) * 0.5
    o_xla, st_xla, _ = ops.linear_attention_op(q, k, v, backend="xla")
    o_int, st_int, _ = ops.linear_attention_op(q, k, v, backend="interpret")
    np.testing.assert_allclose(o_xla, o_int, rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(st_xla, st_int, rtol=3e-4, atol=3e-4)


def test_ops_dispatch_flash(rng):
    ks = jax.random.split(rng, 3)
    q = jax.random.normal(ks[0], (2, 4, 256, 64)) * 0.4
    k = jax.random.normal(ks[1], (2, 2, 256, 64)) * 0.4
    v = jax.random.normal(ks[2], (2, 2, 256, 64)) * 0.5
    o_xla = ops.flash_attention_op(q, k, v, backend="xla")
    o_int = ops.flash_attention_op(q, k, v, backend="interpret")
    np.testing.assert_allclose(o_xla, o_int, rtol=3e-4, atol=3e-4)


def test_kernel_vmem_footprint_static():
    """BlockSpec tiles must fit VMEM (16 MB/core budget, fp32 scratch)."""
    bq, bk, dh, dkv = 128, 128, 128, 128
    flash_tiles = (bq * dh + 2 * bk * dh + bq * dh) * 4 + bq * dh * 4
    chunk_tiles = (2 * 128 * dkv + 2 * 128 * dkv) * 4 + dkv * dkv * 4
    assert flash_tiles < 16 * 2 ** 20
    assert chunk_tiles < 16 * 2 ** 20
