"""Hypothesis property tests on the system's invariants (DESIGN.md §6)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import linear_attention as la
from repro.core.lasp2h import causal_mask

jax.config.update("jax_platform_name", "cpu")

SETTINGS = dict(max_examples=20, deadline=None)


def _qkv(seed, b, h, s, dk, dv):
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 4)
    return (jax.random.normal(ks[0], (b, h, s, dk)) * 0.3,
            jax.random.normal(ks[1], (b, h, s, dk)) * 0.3,
            jax.random.normal(ks[2], (b, h, s, dv)) * 0.5,
            -jnp.abs(jax.random.normal(ks[3], (b, h, s))) * 0.05)


@settings(**SETTINGS)
@given(seed=st.integers(0, 2**16), block=st.sampled_from([16, 32, 64]),
       s_mult=st.integers(1, 4))
def test_chunk_invariance(seed, block, s_mult):
    """Output must not depend on the chunking (the core LASP-2 soundness
    property: any chunk split — hence any device count — is equivalent)."""
    s = 64 * s_mult
    q, k, v, log_a = _qkv(seed, 1, 2, s, 16, 24)
    ref = la.sequential_oracle(q, k, v, log_a)
    out = la.chunk_scan(q, k, v, log_a, block_size=block)
    np.testing.assert_allclose(out.o, ref.o, rtol=5e-4, atol=5e-4)


@settings(**SETTINGS)
@given(seed=st.integers(0, 2**16), pert=st.integers(1, 62))
def test_causality(seed, pert):
    """Perturbing token j never changes outputs at positions < j."""
    q, k, v, log_a = _qkv(seed, 1, 2, 64, 16, 24)
    out1 = la.chunk_scan(q, k, v, log_a, block_size=16).o
    k2 = k.at[..., pert, :].add(1.0)
    v2 = v.at[..., pert, :].add(-1.0)
    out2 = la.chunk_scan(q, k2, v2, log_a, block_size=16).o
    np.testing.assert_allclose(out1[..., :pert, :], out2[..., :pert, :],
                               rtol=1e-5, atol=1e-5)
    assert not np.allclose(out1[..., pert:, :], out2[..., pert:, :])


@settings(**SETTINGS)
@given(seed=st.integers(0, 2**16), cut8=st.integers(2, 14))
def test_decay_semigroup(seed, cut8):
    cut = cut8 * 8   # chunk_summaries needs block-divisible lengths
    """M(0→S) == A(cut→S)·M(0→cut) + M(cut→S)."""
    _, k, v, log_a = _qkv(seed, 1, 2, 128, 16, 24)
    m_full, ld_full = la.chunk_summaries(k, v, log_a, block_size=16)
    m1, ld1 = la.chunk_summaries(k[..., :cut, :], v[..., :cut, :],
                                 log_a[..., :cut], block_size=8)
    m2, ld2 = la.chunk_summaries(k[..., cut:, :], v[..., cut:, :],
                                 log_a[..., cut:], block_size=8)
    combined = jnp.exp(ld2)[..., None, None] * m1 + m2
    np.testing.assert_allclose(combined, m_full, rtol=5e-4, atol=5e-4)
    np.testing.assert_allclose(ld1 + ld2, ld_full, rtol=1e-5, atol=1e-5)


@settings(**SETTINGS)
@given(seed=st.integers(0, 2**16), ndocs=st.integers(2, 4))
def test_packing_equivalence(seed, ndocs):
    """Packed docs with resets == each doc processed separately."""
    s = 96
    q, k, v, _ = _qkv(seed, 1, 1, s, 8, 8)
    rng = np.random.default_rng(seed)
    cuts = np.sort(rng.choice(np.arange(8, s - 8), ndocs - 1,
                              replace=False))
    bounds = [0, *cuts.tolist(), s]
    log_a = jnp.zeros((1, 1, s))
    for c in cuts:
        log_a = log_a.at[..., int(c)].set(la.RESET_LOG_A)
    packed = la.chunk_scan(q, k, v, log_a, block_size=16).o
    for lo, hi in zip(bounds[:-1], bounds[1:]):
        sep = la.sequential_oracle(q[..., lo:hi, :], k[..., lo:hi, :],
                                   v[..., lo:hi, :], None).o
        np.testing.assert_allclose(packed[..., lo:hi, :], sep,
                                   rtol=5e-4, atol=5e-4)


@settings(**SETTINGS)
@given(seed=st.integers(0, 2**16), rep=st.sampled_from([1, 2, 4]))
def test_gqa_repeat_equivalence(seed, rep):
    """GQA == MHA with repeated KV heads (flash ref property)."""
    from repro.kernels.ref import flash_attention_ref
    b, hkv, s, dh = 1, 2, 64, 16
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, hkv * rep, s, dh)) * 0.4
    k = jax.random.normal(ks[1], (b, hkv, s, dh)) * 0.4
    v = jax.random.normal(ks[2], (b, hkv, s, dh)) * 0.5
    o1 = flash_attention_ref(q, k, v)
    o2 = flash_attention_ref(q, jnp.repeat(k, rep, 1),
                             jnp.repeat(v, rep, 1))
    np.testing.assert_allclose(o1, o2, rtol=1e-5, atol=1e-5)


@settings(**SETTINGS)
@given(sq=st.integers(1, 32), off=st.integers(0, 32),
       win=st.sampled_from([None, 4, 16]))
def test_causal_mask_properties(sq, off, win):
    sk = sq + off
    m = np.asarray(causal_mask(sq, sk, off, sliding_window=win))
    for i in range(sq):
        for j in range(sk):
            expect = (off + i) >= j
            if win is not None:
                expect = expect and ((off + i) - j) < win
            assert m[i, j] == expect
