"""Pluggable inter-chunk state-exchange strategies for LASP-2 layers.

A strategy answers one question: given each rank's local chunk state
``M_t`` (+ total chunk log-decay ``A_t``), how does rank t obtain the
decayed prefix state ``M_{1:t-1}``?

=============  ===========================  =======  =====================
strategy       forward collectives          steps    backward (autodiff)
=============  ===========================  =======  =====================
"allgather"    1 all-gather (packed M‖A)    1        1 reduce-scatter
"ring"         W-1 collective-permutes      W-1      W-1 permutes
"pipelined"    k(W-1) permutes (1/k size)   W-1*     W-1* (k chains)
=============  ===========================  =======  =====================

(*) pipelined chains are dataflow-independent, so the W-1 hops of one
slice hide behind the accumulates of another — same volume as "ring",
pipelined latency (ZeCO-style; see EXPERIMENTS.md).

"allgather" is the paper's LASP-2 and the only strategy compatible with
the paper-faithful Algorithm 3/4 ``custom_vjp`` (its backward AllGathers
the state grads and needs the gathered cumulative decays as residuals);
"ring" reproduces LASP-1's sequential-dependency pattern *inside* the
LASP-2 layer for apples-to-apples strategy benchmarking.
"""

from __future__ import annotations

from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.comm import primitives
from repro.comm.overlap import DoubleBufferedScheduler
from repro.core.linear_attention import prefix_state_combine


class PrefixExchange(NamedTuple):
    """Result of one inter-chunk prefix exchange.

    ``cum``/``states`` (the gathered (W, ...) cumulative log-decays and
    chunk states) are only available under the "allgather" strategy —
    ring-family exchanges never materialize them (that is the point).
    """

    m_prev: jax.Array              # (..., dk, dv) decayed prefix state
    intra: object                  # whatever the overlapped compute returned
    cum: Optional[jax.Array]       # (W, ...) or None
    states: Optional[jax.Array]    # (W, ..., dk, dv) or None


def pack_state(m_loc, a_loc):
    """Pack (M_t, A_t) into ONE tensor so the exchange is a single
    collective: (..., dk, dv) ‖ (...,) -> (..., dk*dv + 1) fp32."""
    lead = m_loc.shape[:-2]
    return jnp.concatenate(
        [m_loc.reshape(*lead, -1), a_loc[..., None]], axis=-1)


def unpack_state(packed, dk: int, dv: int):
    """Inverse of :func:`pack_state` (gathered: leading W axis rides
    along). Returns (ms (..., dk, dv), las (...,))."""
    ms = packed[..., :-1].reshape(*packed.shape[:-1], dk, dv)
    return ms, packed[..., -1]


class CommStrategy:
    name: str = "?"
    supports_faithful = False

    def __init__(self, comm_dtype: Optional[str] = None):
        # Wire dtype of the exchange payload (docs/communication.md):
        # fp32 states are cast to this dtype for the collective and the
        # prefix combine happens in fp32 locally — "bf16" halves the
        # per-layer exchange bytes.
        self.comm_dtype = comm_dtype
        self.wire = primitives.wire_dtype(comm_dtype)

    def prefix(self, m_loc, a_loc, axis: str, axis_size: int, t,
               scheduler: DoubleBufferedScheduler,
               compute: Callable[[], object]) -> PrefixExchange:
        raise NotImplementedError


class AllGatherStrategy(CommStrategy):
    """LASP-2 proper: one AllGather of sequence-length-independent state."""

    name = "allgather"
    supports_faithful = True

    def prefix(self, m_loc, a_loc, axis, axis_size, t, scheduler, compute):
        dk, dv = m_loc.shape[-2:]
        packed = pack_state(m_loc, a_loc).astype(self.wire)
        gathered, intra = scheduler.run(
            packed,
            lambda p: primitives.allgather_states(
                p, axis, axis_size=axis_size, tag="lasp2.states"),
            compute)
        ms, las = unpack_state(
            primitives.upcast_gathered(gathered, jnp.float32), dk, dv)
        cum = jnp.cumsum(las, axis=0)
        return PrefixExchange(prefix_state_combine(ms, cum, t), intra,
                              cum, ms)


class RingStrategy(CommStrategy):
    """LASP-1's pattern: W-1 sequential P2P hops of the full state."""

    name = "ring"

    def prefix(self, m_loc, a_loc, axis, axis_size, t, scheduler, compute):
        m_prev, intra = scheduler.run(
            m_loc,
            lambda m: primitives.pipelined_prefix_exchange(
                m, a_loc, axis, axis_size=axis_size, t=t, n_slices=1,
                comm_dtype=self.comm_dtype, tag="lasp2.ring"),
            compute)
        return PrefixExchange(m_prev, intra, None, None)


class PipelinedStrategy(CommStrategy):
    """ZeCO-style pipelined prefix-scan: the ring, sliced along dv into
    independent chains so hops of one slice hide behind accumulates of
    another."""

    name = "pipelined"

    def __init__(self, n_slices: Optional[int] = None,
                 comm_dtype: Optional[str] = None):
        super().__init__(comm_dtype)
        self.n_slices = n_slices

    def prefix(self, m_loc, a_loc, axis, axis_size, t, scheduler, compute):
        m_prev, intra = scheduler.run(
            m_loc,
            lambda m: primitives.pipelined_prefix_exchange(
                m, a_loc, axis, axis_size=axis_size, t=t,
                n_slices=self.n_slices, comm_dtype=self.comm_dtype,
                tag="lasp2.pipelined"),
            compute)
        return PrefixExchange(m_prev, intra, None, None)


class UlyssesStrategy(AllGatherStrategy):
    """DeepSpeed-Ulysses head-parallel strategy.

    The ulysses mechanism lives on the LASP-2H *softmax* context path
    (``repro.core.lasp2h.ulysses_context_attention``): two All-to-Alls
    repartition q/k/v from sequence-sharded to head-sharded layout and
    back around a full-sequence flash attention. The *linear* layers
    have no per-token context to repartition — their inter-chunk state
    exchange under ulysses is exactly LASP-2's single state AllGather,
    hence the subclass.
    """

    name = "ulysses"


# ---------------------------------------------------------------------------
# Strategy registry: the single dispatch point for strategy names.
# ---------------------------------------------------------------------------

class _StrategyEntry(NamedTuple):
    exchange_fn: Callable[..., CommStrategy]
    budget_fn: Optional[Callable]
    context_budget_fn: Optional[Callable]


_REGISTRY: "dict[str, _StrategyEntry]" = {}


def register_strategy(name: str, exchange_fn: Callable[..., CommStrategy],
                      budget_fn: Optional[Callable] = None, *,
                      context_budget_fn: Optional[Callable] = None) -> None:
    """Register a comm strategy under ``name``.

    ``exchange_fn(comm_dtype=...)`` builds the :class:`CommStrategy`
    (any callable with that signature — the built-ins pass their class).
    ``budget_fn(world, *, with_grad, backward, n_slices, state_bytes)``
    states the strategy's linear-layer :class:`CollectiveBudget` (what
    ``lasp2_budget`` dispatches to). ``context_budget_fn`` states the
    LASP-2H softmax context budget (``hybrid_context_budget``); ``None``
    means "uses the default K/V AllGather context path".

    Re-registering a name replaces the entry (tests swap in fakes).
    """
    if not callable(exchange_fn):
        raise TypeError(f"exchange_fn for {name!r} must be callable, "
                        f"got {type(exchange_fn).__name__}")
    _REGISTRY[name] = _StrategyEntry(exchange_fn, budget_fn,
                                     context_budget_fn)


def registered_strategies() -> tuple:
    """Registered strategy names, in registration order."""
    return tuple(_REGISTRY)


def _entry(name: str) -> _StrategyEntry:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown comm strategy {name!r}; expected one of "
            f"{registered_strategies()}") from None


def get_strategy(name: str,
                 comm_dtype: Optional[str] = None) -> CommStrategy:
    return _entry(name).exchange_fn(comm_dtype=comm_dtype)


def get_budget_fn(name: str) -> Callable:
    fn = _entry(name).budget_fn
    if fn is None:
        raise ValueError(f"strategy {name!r} registered without a "
                         f"budget_fn")
    return fn


def get_context_budget_fn(name: str) -> Callable:
    entry = _entry(name)
    if entry.context_budget_fn is not None:
        return entry.context_budget_fn
    from repro.comm.budget import allgather_context_budget
    return allgather_context_budget


def _register_builtins():
    # One-way import: budget.py never imports this module at load time
    # (lasp2_budget resolves the registry lazily inside the call).
    from repro.comm import budget as _b
    register_strategy("allgather", AllGatherStrategy,
                      _b.allgather_state_budget)
    register_strategy("ring", RingStrategy, _b.ring_state_budget)
    register_strategy("pipelined", PipelinedStrategy, _b.ring_state_budget)
    # ulysses goes through the same public API as any out-of-tree
    # strategy: allgather linear-state exchange, a2a context budget.
    register_strategy("ulysses", UlyssesStrategy,
                      _b.allgather_state_budget,
                      context_budget_fn=_b.ulysses_context_budget)


_register_builtins()
