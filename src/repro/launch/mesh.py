"""Production meshes.

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run sets ``XLA_FLAGS`` for 512 host devices before any jax
initialization; tests and benches see the default single device).
"""

from __future__ import annotations

import jax


def auto_axis_types(n: int):
    """``axis_types`` kwargs compatible with both old and new jax.

    ``jax.sharding.AxisType`` only exists from jax 0.5; older versions
    treat every axis as Auto already, so the kwarg is simply omitted.
    """
    if hasattr(jax.sharding, "AxisType"):
        return {"axis_types": (jax.sharding.AxisType.Auto,) * n}
    return {}


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 16×16 = 256 chips, ("data", "model").
    Multi-pod: 2×16×16 = 512 chips, ("pod", "data", "model")."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **auto_axis_types(len(axes)))


def make_test_mesh(shape=(4, 2), axes=("data", "model")):
    """Small mesh for in-repo distributed tests (8 host devices)."""
    return jax.make_mesh(shape, axes, **auto_axis_types(len(axes)))
