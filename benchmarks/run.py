"""Benchmark driver — one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--only fig3,table2,...]

Prints ``name,us_per_call,derived`` CSV rows per bench and writes a
machine-readable ``BENCH_<name>.json`` at the repo root for every bench
whose ``main()`` returns a payload (all of them) — median/p90 wall
times where the bench measures them (``fig3_speed``,
``comm_strategies``) plus the derived analytic quantities. CI uploads
the ``BENCH_*.json`` files as artifacts so the perf trajectory is
tracked across PRs (see docs/communication.md for the comm schema).
"""

from __future__ import annotations

import argparse
import sys
import time
import traceback

from benchmarks.common import write_bench_json

BENCHES = ["fig3_speed", "comm_strategies", "kernels", "guard_overhead",
           "serve_throughput",
           "table2_convergence", "table3_bidirectional",
           "table4_hybrid_ratio", "table5_gather_splits",
           "table6_scalability"]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated bench prefixes")
    args = ap.parse_args()
    only = args.only.split(",") if args.only else None

    print("name,us_per_call,derived")
    failures = []
    for name in BENCHES:
        if only and not any(name.startswith(o) for o in only):
            continue
        t0 = time.time()
        try:
            mod = __import__(f"benchmarks.{name}", fromlist=["main"])
            payload = mod.main()
            if payload is not None:
                write_bench_json(getattr(mod, "BENCH_NAME", name), payload)
            print(f"# {name}: done in {time.time()-t0:.1f}s",
                  file=sys.stderr)
        except Exception as e:  # noqa: BLE001
            failures.append(name)
            print(f"{name}/FAILED,0,{type(e).__name__}:{e}")
            traceback.print_exc()
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
