"""Serving launcher: load/initialize a model and serve batched requests.

  PYTHONPATH=src python -m repro.launch.serve --arch hymba-1.5b --smoke \
      --batch 4 --prompt-len 64 --new-tokens 32
"""

from __future__ import annotations

import argparse
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="linear-llama3-1b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--linearize", type=int, default=None)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp

    from repro.checkpoint.manager import CheckpointManager
    from repro.configs import get_config, get_smoke
    from repro.models import model as M
    from repro.serve.engine import ServeEngine

    cfg = get_smoke(args.arch) if args.smoke \
        else get_config(args.arch, linearize=args.linearize)
    key = jax.random.PRNGKey(0)
    params = M.init_params(key, cfg)
    if args.ckpt_dir:
        mgr = CheckpointManager(args.ckpt_dir)
        step = mgr.latest_step()
        if step is not None:
            state = mgr.restore(step, {"params": params})
            params = state["params"]
            print(f"[serve] restored params from step {step}")

    kw = {}
    if cfg.encoder is not None:
        kw["enc_frames"] = jax.random.normal(
            key, (args.batch, cfg.encoder.n_frames, cfg.d_model)) * 0.1
    if cfg.n_image_tokens:
        kw["img_emb"] = jax.random.normal(
            key, (args.batch, cfg.n_image_tokens, cfg.d_model)) * 0.1

    engine = ServeEngine(cfg, params,
                         max_len=args.prompt_len + args.new_tokens)
    prompts = jax.random.randint(
        key, (args.batch, args.prompt_len), 0, cfg.vocab_size)
    t0 = time.perf_counter()
    out = engine.generate(prompts, args.new_tokens,
                          temperature=args.temperature, **kw)
    dt = time.perf_counter() - t0
    total_new = args.batch * args.new_tokens
    print(f"[serve] {cfg.name}: generated {out.shape} in {dt:.2f}s "
          f"({total_new / dt:.1f} tok/s incl. prefill+compile)")
    print("[serve] first row:", out[0][:16], "...")


if __name__ == "__main__":
    main()
