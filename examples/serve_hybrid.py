"""Serve a hybrid (linear + softmax attention) model with batched requests.

Shows the paper's constant-memory-inference property: the linear layers'
decode cache is a fixed (B, H, dk, dv) state regardless of how long the
generation runs, while the (1-in-4) softmax layers keep a windowed KV
cache.

  PYTHONPATH=src python examples/serve_hybrid.py
"""

import sys

sys.path.insert(0, "src")

import jax
import numpy as np

from repro.configs import get_smoke
from repro.models import model as M
from repro.serve.engine import ServeEngine


def main():
    cfg = get_smoke("linear-llama3-1b")
    base = cfg
    import dataclasses
    from repro.configs.base import LayerSpec
    dense = dataclasses.replace(base, pattern=(LayerSpec(),), n_layers=4,
                                name="smoke-dense")
    cfg = dense.linearize(hybrid_every=4)   # 3 linear + 1 windowed softmax
    print("serving", cfg.name, "| pattern:",
          [s.mixer for s in cfg.pattern])

    key = jax.random.PRNGKey(0)
    params = M.init_params(key, cfg)
    engine = ServeEngine(cfg, params, max_len=256)

    prompts = jax.random.randint(key, (4, 64), 0, cfg.vocab_size)
    out = engine.generate(prompts, 48, temperature=0.8, seed=1)
    print("generated:", out.shape)

    # constant-memory property: linear state size is independent of length
    cache16 = M.init_cache(cfg, batch=4, max_len=16)
    cache4k = M.init_cache(cfg, batch=4, max_len=4096)
    lin16 = cache16["layers"][0]["mixer"]["m"]
    lin4k = cache4k["layers"][0]["mixer"]["m"]
    kv16 = cache16["layers"][3]["mixer"]["k"]
    kv4k = cache4k["layers"][3]["mixer"]["k"]
    print(f"linear-attn state:  max_len=16 -> {lin16.shape}, "
          f"max_len=4096 -> {lin4k.shape}  (CONSTANT — paper's claim)")
    print(f"softmax KV cache:   max_len=16 -> {kv16.shape}, "
          f"max_len=4096 -> {kv4k.shape}  (grows with length)")
    assert lin16.shape == lin4k.shape
    assert kv16.shape != kv4k.shape
    print("OK")


if __name__ == "__main__":
    main()
