"""Checkpointing: atomic, async, verified, keep-k, mesh-independent.

Layout: ``<dir>/step_<n>/`` containing ``manifest.json`` (tree paths,
shapes, dtypes, per-array SHA-256 checksums) and ``arrays.npz``. Arrays
are saved as host numpy in a fully-replicated layout, so a checkpoint
written on one mesh can be restored onto any other mesh/device count —
the loader re-shards with whatever shardings the new run provides
(tested in tests/test_data_checkpoint.py).

Hardening (docs/resilience.md):

* writes are atomic: tmp dir + fsync(arrays, manifest, tmp dir) +
  ``os.replace`` + fsync(parent) — a crash at ANY point leaves either
  the old checkpoint or the new one, never a torn directory;
* transient ``OSError`` during a write is retried with backoff
  (``retries``/``backoff_s``) before surfacing;
* ``save_async`` captures exceptions from the writer thread and
  re-raises them on ``wait()`` or the next ``save_async`` — they are
  never silently dropped;
* ``restore`` verifies the per-array checksums (``verify=True``) and
  raises :class:`CheckpointCorruptError` with the offending arrays, and
  a clear error (not a raw ``np.load`` traceback) on missing/truncated
  files; :meth:`restore_latest_valid` walks checkpoints newest-first
  and returns the first one that restores cleanly;
* leaves are addressed by tree path (``manifest["paths"]``), so a
  SUBTREE restore — e.g. ``{"params": ...}`` for serving — picks the
  right arrays regardless of flatten order (index-based pre-v2
  manifests restore with the old positional rule).
"""

from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
import time
import zipfile
from typing import Any, Optional

import jax
import numpy as np

MANIFEST_VERSION = 2


class CheckpointError(RuntimeError):
    """A checkpoint could not be written or restored."""


class CheckpointCorruptError(CheckpointError):
    """A checkpoint directory exists but its contents are unreadable or
    fail checksum verification (truncated write, bit rot, tampering)."""


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = [jax.tree_util.keystr(p) for p, _ in flat]
    leaves = [l for _, l in flat]
    return paths, leaves, treedef


def _sha256(arr: np.ndarray) -> str:
    return hashlib.sha256(np.ascontiguousarray(arr).tobytes()).hexdigest()


def _fsync_dir(path: str) -> None:
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, *,
                 verify: bool = True, retries: int = 3,
                 backoff_s: float = 0.05):
        self.dir = directory
        self.keep = keep
        self.verify = verify
        self.retries = retries
        self.backoff_s = backoff_s
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None
        # seam for fault injection (repro.resilience.chaos / tests):
        # instance-assignable array writer
        self._savez = np.savez

    # -- write --------------------------------------------------------------

    def save(self, step: int, tree: Any):
        paths, leaves, _ = _flatten_with_paths(tree)
        host = [np.asarray(jax.device_get(l)) for l in leaves]
        self._write_with_retry(step, host, paths)

    def save_async(self, step: int, tree: Any):
        """Device→host copy happens synchronously (cheap, avoids racing the
        next update-in-place); disk serialization runs on a thread. An
        exception from the PREVIOUS async write re-raises here (or on
        ``wait()``) — async failures are never dropped."""
        paths, leaves, _ = _flatten_with_paths(tree)
        host = [np.asarray(jax.device_get(l)) for l in leaves]
        self.wait()
        self._thread = threading.Thread(
            target=self._write_safe, args=(step, host, paths), daemon=True)
        self._thread.start()

    def wait(self):
        """Join the in-flight async write; re-raise its exception if it
        failed (the error is cleared, so a later save can proceed)."""
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _write_safe(self, step: int, host_leaves, paths):
        try:
            self._write_with_retry(step, host_leaves, paths)
        except BaseException as e:    # surfaced from wait()/next save_async
            self._error = e

    def _write_with_retry(self, step: int, host_leaves, paths):
        for attempt in range(self.retries + 1):
            try:
                return self._write(step, host_leaves, paths)
            except OSError:
                if attempt >= self.retries:
                    raise
                time.sleep(self.backoff_s * (2 ** attempt))

    def _write(self, step: int, host_leaves, paths):
        final = os.path.join(self.dir, f"step_{step:08d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        with open(os.path.join(tmp, "arrays.npz"), "wb") as f:
            self._savez(f, **{f"a{i}": l for i, l in
                              enumerate(host_leaves)})
            f.flush()
            os.fsync(f.fileno())
        manifest = {
            "format_version": MANIFEST_VERSION,
            "step": step,
            "n_leaves": len(host_leaves),
            "paths": list(paths),
            "shapes": [list(l.shape) for l in host_leaves],
            "dtypes": [str(l.dtype) for l in host_leaves],
            "checksums": [_sha256(l) for l in host_leaves],
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        # fsync the tmp dir (entries durable) BEFORE the rename, and the
        # parent after — the replace is then crash-atomic on disk, not
        # just in the page cache.
        _fsync_dir(tmp)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)
        _fsync_dir(self.dir)
        self._gc()

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    # -- read ---------------------------------------------------------------

    def all_steps(self):
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                try:
                    out.append(int(name[5:]))
                except ValueError:
                    pass
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def _read_manifest(self, path: str) -> dict:
        mpath = os.path.join(path, "manifest.json")
        if not os.path.exists(mpath):
            raise CheckpointCorruptError(
                f"{path}: manifest.json is missing — the checkpoint write "
                "was interrupted or the directory was damaged; restore an "
                "older step (CheckpointManager.restore_latest_valid) or "
                "delete this directory")
        try:
            with open(mpath) as f:
                return json.load(f)
        except (json.JSONDecodeError, UnicodeDecodeError, OSError) as e:
            raise CheckpointCorruptError(
                f"{path}: manifest.json is unreadable ({e}) — truncated "
                "write or corruption; restore an older step or delete "
                "this directory") from e

    def _load_arrays(self, path: str, n: int) -> list:
        apath = os.path.join(path, "arrays.npz")
        if not os.path.exists(apath):
            raise CheckpointCorruptError(
                f"{path}: arrays.npz is missing — the checkpoint write was "
                "interrupted; restore an older step or delete this "
                "directory")
        try:
            with np.load(apath) as data:
                return [np.asarray(data[f"a{i}"]) for i in range(n)]
        except (zipfile.BadZipFile, KeyError, ValueError, EOFError,
                OSError) as e:
            raise CheckpointCorruptError(
                f"{path}: arrays.npz is unreadable ({type(e).__name__}: "
                f"{e}) — truncated or corrupted archive; restore an older "
                "step (CheckpointManager.restore_latest_valid) or delete "
                "this directory") from e

    def restore(self, step: int, target_tree: Any, shardings: Any = None,
                *, verify: Optional[bool] = None):
        """Restore into the structure of ``target_tree`` (a subtree of the
        saved state is fine — leaves are matched by tree path).
        ``shardings`` is an optional matching tree of
        jax.sharding.Sharding — this is where elastic resharding happens
        (host numpy → any mesh). ``verify`` overrides the manager-level
        checksum-verification default."""
        verify = self.verify if verify is None else verify
        path = os.path.join(self.dir, f"step_{step:08d}")
        if not os.path.isdir(path):
            raise CheckpointError(
                f"no checkpoint for step {step} under {self.dir} "
                f"(available steps: {self.all_steps() or 'none'})")
        manifest = self._read_manifest(path)
        n_saved = int(manifest["n_leaves"])
        arrays = self._load_arrays(path, n_saved)

        paths, leaves, treedef = _flatten_with_paths(target_tree)
        saved_paths = manifest.get("paths")
        if saved_paths is not None:
            index = {p: i for i, p in enumerate(saved_paths)}
            missing = [p for p in paths if p not in index]
            if missing:
                raise CheckpointError(
                    f"{path}: target leaves {missing} not in the "
                    f"checkpoint (it holds {len(saved_paths)} leaves, "
                    f"e.g. {saved_paths[:4]}) — the target tree structure "
                    "does not match what was saved")
            order = [index[p] for p in paths]
        else:
            # pre-v2 manifest: positional, requires identical structure
            if n_saved != len(leaves):
                raise CheckpointError(
                    f"{path}: checkpoint holds {n_saved} leaves but the "
                    f"target tree has {len(leaves)} — structure mismatch "
                    "(pre-v2 checkpoints can only restore the exact tree "
                    "they saved)")
            order = list(range(len(leaves)))

        if verify:
            sums = manifest.get("checksums")
            if sums is not None:
                bad = [paths[j] for j, i in enumerate(order)
                       if _sha256(arrays[i]) != sums[i]]
                if bad:
                    raise CheckpointCorruptError(
                        f"{path}: SHA-256 checksum mismatch for "
                        f"{len(bad)} array(s): {bad[:4]}"
                        f"{'…' if len(bad) > 4 else ''} — on-disk "
                        "corruption; restore an older step "
                        "(CheckpointManager.restore_latest_valid)")

        loaded = [arrays[i] for i in order]
        for p, got, want in zip(paths, loaded, leaves):
            if tuple(got.shape) != tuple(want.shape):
                raise ValueError(
                    f"checkpoint shape {got.shape} != target {want.shape} "
                    f"at {p}")
        if shardings is not None:
            flat_sh, _ = _flatten(shardings)
            loaded = [jax.device_put(np.asarray(l, w.dtype), s)
                      for l, w, s in zip(loaded, leaves, flat_sh)]
        else:
            loaded = [jax.device_put(np.asarray(l, w.dtype))
                      for l, w in zip(loaded, leaves)]
        return jax.tree_util.tree_unflatten(treedef, loaded)

    def restore_latest_valid(self, target_tree: Any, shardings: Any = None):
        """Walk checkpoints newest-first and restore the first VALID one
        (checksums verified). Returns ``(step, tree, rejected)`` where
        ``rejected`` is ``[(step, reason), ...]`` for every newer
        checkpoint that failed. Raises :class:`CheckpointError` when no
        checkpoint restores cleanly."""
        steps = self.all_steps()
        rejected = []
        for step in reversed(steps):
            try:
                tree = self.restore(step, target_tree, shardings,
                                    verify=True)
                return step, tree, rejected
            except (CheckpointError, ValueError) as e:
                rejected.append((step, f"{type(e).__name__}: {e}"))
        raise CheckpointError(
            f"no valid checkpoint under {self.dir} "
            f"(tried {list(reversed(steps)) or 'none'}; "
            f"rejections: {[r[0] for r in rejected]})")
