"""Long-context sequence parallelism demo: shard a 64K-token sequence over
8 virtual devices with LASP-2, verify exactness vs the local computation,
and show the communication difference vs LASP-1 / Ring Attention straight
from the compiled HLO (the paper's §3.4 comparison, reproduced
structurally).

This example re-execs itself with 8 virtual CPU devices.

  PYTHONPATH=src python examples/long_context_sp.py
"""

import os
import sys

if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

sys.path.insert(0, "src")

import re

import jax
import jax.numpy as jnp

from repro.core.baselines import lasp1, megatron_sp_attention
from repro.core.lasp2 import SPConfig, lasp2


def collective_report(txt):
    ops = {}
    for op in ("all-gather", "all-reduce", "reduce-scatter",
               "collective-permute", "all-to-all"):
        n = len(re.findall(rf"{op}\(", txt))
        if n:
            ops[op] = n
    has_loop = bool(re.search(r"\bwhile\b", txt))
    return ops, has_loop


def main():
    from repro.launch.mesh import SEQ_AXIS, make_sp_mesh
    mesh = make_sp_mesh(8)
    sp = SPConfig(mesh=mesh, sp_axis=SEQ_AXIS)
    B, H, S, d = 1, 8, 65536, 64
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, H, S, d), jnp.bfloat16) * 0.3
    k = jax.random.normal(ks[1], (B, H, S, d), jnp.bfloat16) * 0.3
    v = jax.random.normal(ks[2], (B, H, S, d), jnp.bfloat16) * 0.5

    print(f"sequence: {S} tokens over {sp.degree} devices "
          f"({S // sp.degree} per device)\n")

    o_sp = jax.jit(lambda a, b, c: lasp2(a, b, c, sp=sp))(q, k, v)
    o_loc = jax.jit(lambda a, b, c: lasp2(a, b, c, sp=None))(q, k, v)
    diff = jnp.abs(o_sp.astype(jnp.float32) - o_loc.astype(jnp.float32))
    rel = float(jnp.max(diff) / jnp.max(jnp.abs(o_loc.astype(jnp.float32))))
    print(f"LASP-2 sharded == local: max rel Δ = {rel:.2e} "
          f"(bf16 I/O, fp32 state)\n")

    from repro.comm import assert_budget, lasp2_budget, ring_baseline_budget

    for name, fn, budget in [
        ("LASP-2 (AllGather of M_t)",
         lambda a, b, c: lasp2(a, b, c, sp=sp),
         lasp2_budget("allgather", sp.degree)),
        ("LASP-1 (ring P2P)",
         lambda a, b, c: lasp1(a, b, c, sp=sp),
         ring_baseline_budget(sp.degree)),
        ("Megatron-SP (AllGather activations)",
         lambda a, b, c: megatron_sp_attention(a, b, c, sp=sp),
         None),
    ]:
        txt = jax.jit(fn).lower(q, k, v).compile().as_text()
        ops, loop = collective_report(txt)
        if budget is not None:   # HLO-verified (repro/comm/budget.py)
            assert_budget(txt, budget, sp.degree)
        print(f"{name:40s} collectives={ops} sequential-loop={loop} "
              f"budget={'verified' if budget else 'n/a'}")

    print("\nLASP-2's gather moves H·dk·dv state bytes — independent of the"
          "\n65536-token sequence; Megatron-SP's gather scales with S.")


if __name__ == "__main__":
    main()
