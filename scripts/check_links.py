#!/usr/bin/env python
"""Cross-reference checker for the documentation suite.

Verifies that (a) every relative markdown link / image in README.md,
docs/**.md, and the other top-level *.md files points at a file that
exists, (b) every `path/to/file.py`-style inline-code reference to a
repo file resolves, and (c) every ``python -m dotted.module`` invocation
quoted in the docs resolves to a module file (so quickstart commands
like ``python -m benchmarks.comm_strategies`` can't silently rot).
External (http/…) links are not fetched.

  python scripts/check_links.py        # exit 1 + report on broken refs
"""

from __future__ import annotations

import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)#\s]+)(#[^)\s]*)?\)")
CODEPATH_RE = re.compile(
    r"`([A-Za-z0-9_.-]+(?:/[A-Za-z0-9_.*-]+)+\.(?:py|md|toml|yml|json))`")
MODULE_RE = re.compile(r"python\s+-m\s+([A-Za-z_][A-Za-z0-9_.]*)")


SKIP = {"ISSUE.md"}          # transient per-PR task file, not docs

# Inline-code refs may be written relative to any of these roots
# (prose shorthand like `core/lasp2.py` means src/repro/core/lasp2.py;
# `.github` so workflow files can be referenced as `workflows/ci.yml`).
CODE_ROOTS = ("", "src", "src/repro", ".github")

# ``python -m`` module roots (mirrors how PYTHONPATH=src is used).
MODULE_ROOTS = ("", "src")


def module_resolves(dotted: str) -> bool:
    top = dotted.split(".")[0]
    if not any((ROOT / r / top).is_dir() or (ROOT / r / f"{top}.py").exists()
               for r in MODULE_ROOTS):
        return True      # external tool (pytest, pip, …) — not ours to check
    rel = dotted.replace(".", "/")
    return any((ROOT / r / rel).with_suffix(".py").exists()
               or (ROOT / r / rel / "__main__.py").exists()
               or (ROOT / r / rel / "__init__.py").exists()
               for r in MODULE_ROOTS)


def md_files():
    for p in ROOT.glob("*.md"):
        if p.name not in SKIP:
            yield p
    for p in (ROOT / "docs").rglob("*.md"):
        if "__pycache__" not in p.parts:
            yield p


def check_file(md: Path):
    errors = []
    text = md.read_text()
    for rx, kind in ((LINK_RE, "link"), (CODEPATH_RE, "code ref")):
        for m in rx.finditer(text):
            target = m.group(1)
            if target.startswith(("http://", "https://", "mailto:")):
                continue
            if "*" in target:            # glob-style mention, not a path
                continue
            line = text[:m.start()].count("\n") + 1
            if kind == "link":
                ok = (md.parent / target).resolve().exists()
            else:
                ok = any((ROOT / r / target).exists() for r in CODE_ROOTS)
            if not ok:
                errors.append(f"{md.relative_to(ROOT)}:{line}: "
                              f"broken {kind} -> {target}")
    for m in MODULE_RE.finditer(text):
        dotted = m.group(1)
        if not module_resolves(dotted):
            line = text[:m.start()].count("\n") + 1
            errors.append(f"{md.relative_to(ROOT)}:{line}: "
                          f"broken module ref -> python -m {dotted}")
    return errors


def main() -> int:
    errors = []
    n = 0
    for md in sorted(set(md_files())):
        n += 1
        errors += check_file(md)
    if errors:
        print(f"{len(errors)} broken cross-reference(s) in {n} files:")
        print("\n".join(errors))
        return 1
    print(f"OK: all cross-references resolve ({n} markdown files).")
    return 0


if __name__ == "__main__":
    sys.exit(main())
