"""Pattern-based model builder: init / forward / prefill / decode.

The layer stack is ``cfg.pattern`` repeated ``cfg.n_groups`` times; params
for each pattern position are stacked over groups and the stack is applied
with ``lax.scan`` — keeping HLO size (and compile time) independent of
depth, which is what makes 80–100-layer dry-runs tractable.

Whisper-style encoder stacks and VLM image embeddings enter through
``aux_inputs`` (stub frontends per the assignment: ``input_specs`` provides
precomputed frame/patch embeddings).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import LayerSpec, ModelConfig
from repro.models import blocks
from repro.models.blocks import Ctx
from repro.models.layers import (embed_init, embed_lookup, logits_out,
                                 rmsnorm, rmsnorm_init, sinusoidal_positions)
from repro.sharding.rules import Parallelism, local_plan


def hymba_global_flags(cfg: ModelConfig):
    """Hymba keeps full attention in the first / middle / last layers.

    Used only for single-position dynamic hymba patterns; multi-position
    patterns mark globals statically via ``LayerSpec.is_global`` (which
    enables the banded sliding-window fast path — §Perf)."""
    if not (len(cfg.pattern) == 1 and cfg.pattern[0].mixer == "hymba"):
        return None
    n = cfg.n_layers
    flags = jnp.zeros((cfg.n_groups, len(cfg.pattern)), bool)
    for layer in (0, n // 2, n - 1):
        g, p = divmod(layer, len(cfg.pattern))
        flags = flags.at[g, p].set(True)
    return flags


def _stack_init(key, cfg: ModelConfig, specs, n_groups: int):
    """Stacked layer params: one pytree per pattern position, leading dim G."""
    out = []
    for i, spec in enumerate(specs):
        keys = jax.random.split(jax.random.fold_in(key, i), n_groups)
        out.append(jax.vmap(lambda k: blocks.layer_init(k, cfg, spec))(keys))
    return out


def init_params(key, cfg: ModelConfig):
    k_embed, k_blocks, k_enc = jax.random.split(key, 3)
    params = {
        "embed": embed_init(k_embed, cfg.padded_vocab, cfg.d_model,
                            tie=cfg.tie_embeddings),
        "groups": _stack_init(k_blocks, cfg, cfg.pattern, cfg.n_groups),
        "final_norm": rmsnorm_init(cfg.d_model),
    }
    if cfg.encoder is not None:
        enc_spec = (LayerSpec(mixer="softmax", mlp="dense"),)
        params["encoder"] = {
            "groups": _stack_init(k_enc, cfg, enc_spec,
                                  cfg.encoder.n_layers),
            "final_norm": rmsnorm_init(cfg.d_model),
        }
    return params


def _apply_stack(groups, x, ctx: Ctx, specs, flags=None, remat="full",
                 unroll=False):
    """Scan the stacked layers. Returns (x, summed aux losses)."""

    def apply_one(i, spec, p, x_):
        return blocks.layer_apply(p, x_, ctx, spec)

    if remat == "full" and len(specs) > 1:
        # nested per-layer remat: without it the whole multi-position body
        # recomputes as ONE block and its transient live-set scales with
        # the pattern length (measured 20 GiB vs 6 GiB on hymba×train_4k)
        apply_one = jax.checkpoint(
            apply_one, policy=jax.checkpoint_policies.nothing_saveable,
            static_argnums=(0, 1))

    def body(carry, xs):
        x_ = carry
        layer_params = xs[:-1] if flags is not None else xs
        f = xs[-1] if flags is not None else None
        aux = 0.0
        for i, spec in enumerate(specs):
            if f is not None:
                ctx.is_global = f[i]
            x_, a = apply_one(i, spec, layer_params[i], x_)
            aux = aux + a
        return x_, aux

    if remat == "full":
        body = jax.checkpoint(body,
                              policy=jax.checkpoint_policies.nothing_saveable)
    elif remat == "dots":
        # §Perf: keep matmul outputs — avoids recomputing attention scores
        # and projections in the backward pass at the cost of activation
        # memory (measured per-cell; see EXPERIMENTS.md §Perf).
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.checkpoint_dots)
    xs = tuple(groups) + ((flags,) if flags is not None else ())
    x, auxs = jax.lax.scan(body, x, xs, unroll=True if unroll else 1)
    return x, jnp.sum(auxs)


def forward(params, tokens, cfg: ModelConfig, plan: Optional[Parallelism]
            = None, *, img_emb=None, enc_frames=None, causal=True,
            remat="full", resets=None, unroll=False):
    """Full-sequence forward → (logits, aux). tokens: (B, S) int32."""
    plan = plan or local_plan()
    dtype = jnp.dtype(cfg.dtype)
    b, s = tokens.shape
    x = embed_lookup(params["embed"], tokens, dtype)
    x = plan.act(x, "batch", "residual_seq", None)
    positions = jnp.arange(s)
    if plan.sp is not None and plan.sp.manual and plan.sp.degree > 1:
        # Inside the train step's fully-manual shard_map ``s`` is the
        # per-rank sequence chunk; RoPE needs absolute positions. On a 3D
        # mesh the chunk index spans the combined (sequence, model) axes.
        positions = plan.sp.chunk_index() * s + positions

    enc_out = None
    if cfg.encoder is not None:
        if enc_frames is None:
            raise ValueError("whisper-style model needs enc_frames")
        enc_out = encode(params, enc_frames, cfg, plan, remat=remat,
                         unroll=unroll)

    flags = hymba_global_flags(cfg) \
        if any(sp.mixer == "hymba" for sp in cfg.pattern) else None
    ctx = Ctx(cfg=cfg, plan=plan, positions=positions, img_emb=img_emb,
              enc_out=enc_out, causal=causal, resets=resets)
    x, aux = _apply_stack(params["groups"], x, ctx, cfg.pattern,
                          flags=flags, remat=remat, unroll=unroll)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = logits_out(params["embed"], x, plan, cfg.vocab_size)
    return logits, aux


def encode(params, frames, cfg: ModelConfig, plan, *, remat="none",
           unroll=False):
    """Whisper-style bidirectional encoder over (stub) frame embeddings."""
    dtype = jnp.dtype(cfg.dtype)
    x = frames.astype(dtype)
    x = x + sinusoidal_positions(x.shape[1], cfg.d_model).astype(dtype)[None]
    enc_spec = (LayerSpec(mixer="softmax", mlp="dense"),)
    ctx = Ctx(cfg=cfg, plan=plan, positions=None, causal=False)
    x, _ = _apply_stack(params["encoder"]["groups"], x, ctx, enc_spec,
                        remat=remat, unroll=unroll)
    return rmsnorm(params["encoder"]["final_norm"], x, cfg.norm_eps)


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------

def lm_loss_sum(logits, labels):
    """Unnormalized masked CE: ``(ce_sum, n_valid, lse)`` over positions
    with label >= 0. Shared by :func:`lm_loss` (local normalization) and
    the manual 2D DP×SP step (``repro.train.step``), which sums across
    shards BEFORE normalizing — keeping the two loss paths one math."""
    lf = logits.astype(jnp.float32)
    mask = labels >= 0
    lab = jnp.maximum(labels, 0)
    lse = jax.nn.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, lab[..., None], axis=-1)[..., 0]
    ce_sum = jnp.sum((lse - gold) * mask)
    return ce_sum, jnp.sum(mask), lse * mask


def lm_loss(logits, labels, *, z_coef=0.0):
    """Mean CE over positions with label >= 0 (+ optional z-loss)."""
    ce_sum, n_valid, lse_masked = lm_loss_sum(logits, labels)
    n = jnp.maximum(n_valid, 1)
    loss = ce_sum / n
    if z_coef:
        loss = loss + z_coef * jnp.sum(lse_masked ** 2) / n
    return loss


# ---------------------------------------------------------------------------
# Decode (single token, cached)
# ---------------------------------------------------------------------------

def pad_safe(cfg: ModelConfig) -> bool:
    """True if left-padded (length-bucketed) prefill is exact for this
    config: every mixer is recurrent (state reset erases filler) and MLPs
    are position-wise (no cross-token routing). With qkv biases, filler
    columns turn nonzero after the first linear layer, so a downstream
    mamba causal-conv could leak them into the first real tokens — exclude
    that combination."""
    mixers = {sp.mixer for sp in cfg.pattern}
    if not all(sp.mixer in ("linear", "mamba2") and sp.mlp != "moe"
               for sp in cfg.pattern):
        return False
    return not (cfg.qkv_bias and "mamba2" in mixers)

def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    """Decode cache: per linear/SSM layer a constant-size fp32 state (+
    cumulative log decay), per softmax layer a ring-buffer KV cache (ring =
    sliding window for the windowed layers of LASP-2H hybrids). ``pos`` is
    per-row — rows of a continuously-batched decode sit at different
    offsets."""
    caches = []
    for spec in cfg.pattern:
        c = blocks.layer_cache(cfg, spec, batch, max_len)
        caches.append(jax.tree.map(
            lambda x: jnp.zeros((cfg.n_groups,) + x.shape, x.dtype), c))
    return {"layers": caches, "pos": jnp.zeros((batch,), jnp.int32)}


def decode_step(params, token, cache, cfg: ModelConfig,
                plan: Optional[Parallelism] = None, *, img_emb=None,
                enc_out=None, unroll=False):
    """One decode step. token: (B,) int32 → (logits (B, V), new cache).

    ``cache["pos"]`` may be a scalar (legacy, all rows aligned) or a (B,)
    vector of per-row positions (continuous batching). No prefix re-scan:
    linear/SSM layers advance their recurrent state by one
    ``recurrent_step``, softmax layers write one ring slot."""
    plan = plan or local_plan()
    dtype = jnp.dtype(cfg.dtype)
    pos = cache["pos"]
    x = embed_lookup(params["embed"], token[:, None], dtype)
    x = plan.act(x, "batch", None, None)

    # RoPE positions: (1,) broadcast for scalar pos, else per-row (B, 1).
    positions = pos[None] if jnp.ndim(pos) == 0 else pos[:, None]
    flags = hymba_global_flags(cfg) \
        if any(sp.mixer == "hymba" for sp in cfg.pattern) else None
    ctx = Ctx(cfg=cfg, plan=plan, positions=positions,
              img_emb=img_emb, enc_out=enc_out, causal=True,
              decode_pos=pos)

    def body(carry, xs):
        x_ = carry
        n = len(cfg.pattern)
        layer_params = xs[:n]
        layer_caches = xs[n:2 * n]
        f = xs[-1] if flags is not None else None
        new_caches = []
        for i, spec in enumerate(cfg.pattern):
            if f is not None:
                ctx.is_global = f[i]
            x_, nc = blocks.layer_decode(layer_params[i], x_,
                                         layer_caches[i], ctx, spec)
            new_caches.append(nc)
        return x_, tuple(new_caches)

    xs = tuple(params["groups"]) + tuple(cache["layers"]) \
        + ((flags,) if flags is not None else ())
    x, new_layer_caches = jax.lax.scan(body, x, xs,
                                       unroll=True if unroll else 1)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = logits_out(params["embed"], x, plan, cfg.vocab_size)
    new_cache = {"layers": list(new_layer_caches), "pos": pos + 1}
    return logits[:, 0, :], new_cache


# ---------------------------------------------------------------------------
# Prefill (full prompt → cache)
# ---------------------------------------------------------------------------

def prefill(params, tokens, cfg: ModelConfig,
            plan: Optional[Parallelism] = None, *, max_len=None,
            img_emb=None, enc_frames=None, unroll=False, pad_lens=None):
    """Run the prompt, returning (logits of last position, decode cache).

    Implemented as forward + a per-layer cache-extraction pass; the mixers'
    prefill paths reuse the exact same chunked-scan kernels as forward
    (tested equal to running decode token-by-token), and the final
    per-layer recurrent states land directly in the cache.

    ``pad_lens`` (B,) enables length-bucketed batched prefill for pure
    linear/SSM stacks: row ``b`` is LEFT-padded with ``pad_lens[b]`` filler
    tokens, per-row positions start at ``-pad_lens[b]`` so real tokens sit
    at 0..L-1, and a state reset (``RESET_LOG_A``) at the first real token
    erases the filler's contribution to the recurrent state. Only valid
    when no layer does softmax attention over the text sequence (softmax
    layers would attend the filler).
    """
    plan = plan or local_plan()
    dtype = jnp.dtype(cfg.dtype)
    b, s = tokens.shape
    max_len = max_len or s
    x = embed_lookup(params["embed"], tokens, dtype)
    x = plan.act(x, "batch", "seq", None)
    resets = None
    if pad_lens is not None:
        if not pad_safe(cfg):
            raise ValueError(
                "pad_lens prefill requires a pure linear/SSM stack with "
                "dense MLPs (softmax layers would attend the filler; MoE "
                "routing lets filler tokens steal expert capacity)")
        cols = jnp.arange(s)[None, :]
        positions = cols - pad_lens[:, None]                     # (B, S)
        resets = cols == pad_lens[:, None]
        # Zero filler embeddings so the mamba causal-conv sees the same
        # zeros it would for an unpadded sequence start; linear-state
        # leakage is erased by the reset at the first real token.
        x = jnp.where((cols >= pad_lens[:, None])[..., None], x, 0)
    else:
        positions = jnp.arange(s)

    enc_out = None
    if cfg.encoder is not None:
        enc_out = encode(params, enc_frames, cfg, plan)

    flags = hymba_global_flags(cfg) \
        if any(sp.mixer == "hymba" for sp in cfg.pattern) else None
    ctx = Ctx(cfg=cfg, plan=plan, positions=positions, img_emb=img_emb,
              enc_out=enc_out, causal=True, resets=resets)

    def body(carry, xs):
        x_ = carry
        layer_params = xs[:-1] if flags is not None else xs
        f = xs[-1] if flags is not None else None
        caches = []
        for i, spec in enumerate(cfg.pattern):
            if f is not None:
                ctx.is_global = f[i]
            x_, c = blocks.layer_prefill(layer_params[i], x_, ctx, spec,
                                         max_len)
            caches.append(c)
        return x_, tuple(caches)

    xs = tuple(params["groups"]) + ((flags,) if flags is not None else ())
    x, layer_caches = jax.lax.scan(body, x, xs,
                                   unroll=True if unroll else 1)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = logits_out(params["embed"], x[:, -1:, :], plan, cfg.vocab_size)
    pos = jnp.full((b,), s, jnp.int32)
    if pad_lens is not None:
        pos = pos - pad_lens            # per-row true prompt lengths
    cache = {"layers": list(layer_caches), "pos": pos}
    return logits[:, 0, :], cache
