"""PAL301: grid-bounds checking of Pallas ``BlockSpec`` index maps.

Every ``pallas_call`` BlockSpec index map must send every grid point to a
block index inside the operand's block grid — ``0 <= idx[d] <
ceil(shape[d] / block[d])``. Out-of-range maps read a neighbor's blocks
(or clamp silently on TPU): the bug class the PR-3 backward-band fixes
removed by hand, now enforced.

Mechanism: :func:`checking` monkeypatches ``pl.pallas_call`` with a
wrapper that, instead of binding the Pallas primitive, (1) evaluates
every in/out BlockSpec's ``index_map`` at every grid point with concrete
Python ints — the repo's maps are pure index arithmetic (``jnp.clip`` on
concrete ints yields concrete arrays even under tracing), so bounds are
decidable without running the kernel — and (2) returns zeros of
``out_shape``. Drive the kernel entry points under ``jax.eval_shape``
(:func:`check_repo_kernels` covers the in-tree battery: chunk fwd/bwd,
flash fwd/bwd across causal/window/offset variants, decode); nothing is
compiled or executed.

Index maps that close over *traced* values (none in-tree today) are
skipped per grid point, not failed: the checker only asserts what is
statically decidable.
"""

from __future__ import annotations

import contextlib
import itertools
import math
from typing import List, Optional

from repro.analysis.findings import Finding

_MAX_GRID_POINTS = 8192


def _block_counts(shape, block_shape):
    return tuple(
        1 if bs is None else math.ceil(dim / bs)
        for dim, bs in zip(shape, block_shape))


def _check_spec(name, kind, i, spec, shape, grid, findings: List[Finding]):
    block_shape = getattr(spec, "block_shape", None)
    index_map = getattr(spec, "index_map", None)
    if spec is None or block_shape is None or index_map is None:
        return
    if len(block_shape) != len(shape):
        findings.append(Finding(
            code="PAL301", path=name, line=0,
            message=f"{kind}[{i}]: block_shape rank {len(block_shape)} != "
                    f"operand rank {len(shape)} (shape {tuple(shape)})"))
        return
    nblocks = _block_counts(shape, block_shape)
    points = itertools.product(*[range(g) for g in grid])
    for pt in itertools.islice(points, _MAX_GRID_POINTS):
        try:
            idx = index_map(*pt)
        except Exception as e:      # arity mismatch, bad arithmetic
            findings.append(Finding(
                code="PAL301", path=name, line=0,
                message=f"{kind}[{i}]: index_map raised at grid point "
                        f"{pt}: {type(e).__name__}: {e}"))
            return
        if not isinstance(idx, tuple):
            idx = (idx,)
        if len(idx) != len(block_shape):
            findings.append(Finding(
                code="PAL301", path=name, line=0,
                message=f"{kind}[{i}]: index_map returned {len(idx)} "
                        f"indices for rank-{len(block_shape)} blocks"))
            return
        for d, (v, nb) in enumerate(zip(idx, nblocks)):
            try:
                vi = int(v)
            except Exception:       # traced index — not decidable here
                continue
            if not 0 <= vi < nb:
                findings.append(Finding(
                    code="PAL301", path=name, line=0,
                    message=f"{kind}[{i}] dim {d}: index_map{pt} -> "
                            f"{vi}, outside [0, {nb}) "
                            f"(shape {tuple(shape)}, block "
                            f"{tuple(block_shape)})"))
                return              # one finding per spec is enough


def _as_list(x):
    if x is None:
        return []
    return list(x) if isinstance(x, (list, tuple)) else [x]


@contextlib.contextmanager
def checking(findings: List[Finding]):
    """Patch ``pl.pallas_call`` to bounds-check instead of binding."""
    import jax
    import jax.numpy as jnp
    from jax.experimental import pallas as pl

    real = pl.pallas_call

    def fake_pallas_call(kernel, *call_args, grid=None, in_specs=None,
                         out_specs=None, out_shape=None, **kw):
        name = kw.get("name") or getattr(kernel, "__name__", "<kernel>")
        gridt = (grid,) if isinstance(grid, int) else tuple(grid or ())

        def runner(*operands):
            for i, (spec, op) in enumerate(
                    zip(_as_list(in_specs), operands)):
                _check_spec(name, "in_specs", i, spec, op.shape, gridt,
                            findings)
            shapes = _as_list(out_shape)
            for i, (spec, sds) in enumerate(
                    zip(_as_list(out_specs), shapes)):
                _check_spec(name, "out_specs", i, spec, sds.shape, gridt,
                            findings)
            outs = [jnp.zeros(s.shape, s.dtype) for s in shapes]
            if out_shape is None or isinstance(out_shape, (list, tuple)):
                return outs
            return outs[0]

        return runner

    pl.pallas_call = fake_pallas_call
    try:
        yield
    finally:
        pl.pallas_call = real


def check_fn(fn, *args, name: Optional[str] = None) -> List[Finding]:
    """Bounds-check every pallas_call reached by ``jax.eval_shape(fn,
    *args)``. Clears jit caches first so already-traced entry points are
    re-traced through the patch."""
    import jax
    findings: List[Finding] = []
    jax.clear_caches()
    with checking(findings):
        try:
            jax.eval_shape(fn, *args)
        except Exception as e:
            findings.append(Finding(
                code="PAL301", path=name or getattr(fn, "__name__", "<fn>"),
                line=0,
                message=f"kernel tracing failed under the bounds "
                        f"checker: {type(e).__name__}: {e}"))
    jax.clear_caches()
    return findings


def check_repo_kernels():
    """The in-tree kernel battery: every Pallas kernel's fwd + bwd index
    maps, across the causal/sliding-window/offset variants. Returns
    ``(findings, n_entry_points)``."""
    import jax
    import jax.numpy as jnp

    from repro.kernels.flash_attention import flash_attention
    from repro.kernels.lasp2_chunk import lasp2_chunk
    from repro.kernels.lasp2_decode import lasp2_decode_step

    f32 = jnp.float32
    sds = jax.ShapeDtypeStruct
    findings: List[Finding] = []
    n_entries = 0

    # lasp2_chunk: fwd + the two bwd passes (value-and-grad traces both).
    q = sds((2, 64, 8), f32)
    v = sds((2, 64, 16), f32)
    la = sds((2, 64), f32)

    def chunk_loss(q_, k_, v_, la_):
        o, state, ld = lasp2_chunk(q_, k_, v_, la_, block_size=16)
        return jnp.sum(o) + jnp.sum(state) + jnp.sum(ld)

    findings += check_fn(jax.grad(chunk_loss, argnums=(0, 1, 2, 3)),
                         q, q, v, la, name="lasp2_chunk")
    n_entries += 1

    # flash attention: fwd + bwd over the mask-shape variants.
    qf = sds((1, 4, 64, 16), f32)
    kf = sds((1, 2, 128, 16), f32)   # GQA 2:1, sk != sq

    def flash_loss(**kwargs):
        def loss(q_, k_, v_):
            return jnp.sum(flash_attention(q_, k_, v_, block_q=32,
                                           block_k=32, **kwargs))
        return loss

    variants = {
        "flash[causal]": dict(causal=True),
        "flash[causal,q_offset=0]": dict(causal=True, q_offset=0),
        "flash[window]": dict(causal=True, sliding_window=48),
        "flash[kv_len]": dict(causal=True, kv_len=100),
    }
    for label, kwargs in variants.items():
        findings += check_fn(
            jax.grad(flash_loss(**kwargs), argnums=(0, 1, 2)),
            qf, kf, kf, name=label)
        n_entries += 1

    # traced q_offset (the LASP-2H SP rank offset): untrimmed band.
    def flash_traced_offset(q_, k_, v_, off):
        return jnp.sum(flash_attention(q_, k_, v_, causal=True,
                                       q_offset=off, block_q=32,
                                       block_k=32))

    findings += check_fn(
        jax.grad(flash_traced_offset, argnums=(0, 1, 2)),
        qf, kf, kf, sds((), jnp.int32), name="flash[traced offset]")
    n_entries += 1

    # decode step.
    findings += check_fn(
        lasp2_decode_step, sds((4, 8), f32), sds((4, 8), f32),
        sds((4, 16), f32), sds((4,), f32), sds((4, 8, 16), f32),
        sds((4,), f32), name="lasp2_decode_step")
    n_entries += 1
    return findings, n_entries
