from repro.data.pipeline import SyntheticLM, doc_segments  # noqa: F401
