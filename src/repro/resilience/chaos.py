"""Deterministic fault injectors for the chaos drill and tests.

Host-side counterparts to the in-graph injection knobs
(``RunConfig.chaos_nan_steps`` / ``chaos_skip_steps``): byte-level
checkpoint corruption, flaky/killed checkpoint writers (plugged into the
``CheckpointManager._savez`` seam), and data-pipeline wrappers that
deliver a SIGTERM or a straggler sleep at an exact step. Everything is
deterministic — a drill run is reproducible bit-for-bit.
"""

from __future__ import annotations

import os
import signal
import time
from typing import Optional

import numpy as np


class KillSave(RuntimeError):
    """Injected hard failure mid-save (simulated crash — NOT retried,
    unlike OSError)."""


# -- checkpoint byte corruption --------------------------------------------

def _step_dir(ckpt_dir: str, step: Optional[int]) -> str:
    if step is None:
        steps = sorted(int(n[5:]) for n in os.listdir(ckpt_dir)
                       if n.startswith("step_") and not n.endswith(".tmp"))
        if not steps:
            raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
        step = steps[-1]
    return os.path.join(ckpt_dir, f"step_{step:08d}")


def corrupt_checkpoint(ckpt_dir: str, step: Optional[int] = None, *,
                       n_bytes: int = 64, offset_frac: float = 0.5) -> str:
    """Flip ``n_bytes`` in the middle of a checkpoint's ``arrays.npz``
    (default: the latest step). Returns the corrupted file's path."""
    path = os.path.join(_step_dir(ckpt_dir, step), "arrays.npz")
    size = os.path.getsize(path)
    off = min(int(size * offset_frac), max(size - n_bytes, 0))
    with open(path, "r+b") as f:
        f.seek(off)
        chunk = f.read(n_bytes)
        f.seek(off)
        f.write(bytes(b ^ 0xFF for b in chunk))
    return path


def truncate_manifest(ckpt_dir: str, step: Optional[int] = None, *,
                      keep_frac: float = 0.5) -> str:
    """Truncate a checkpoint's ``manifest.json`` mid-document (a torn
    write). Returns the truncated file's path."""
    path = os.path.join(_step_dir(ckpt_dir, step), "manifest.json")
    size = os.path.getsize(path)
    with open(path, "r+b") as f:
        f.truncate(max(int(size * keep_frac), 1))
    return path


# -- checkpoint writer faults (CheckpointManager._savez seam) --------------

class FlakySavez:
    """``np.savez`` stand-in that raises OSError for the first ``fails``
    calls, then writes normally — exercises save retry-with-backoff."""

    def __init__(self, fails: int):
        self.fails = fails
        self.calls = 0

    def __call__(self, file, **arrays):
        self.calls += 1
        if self.calls <= self.fails:
            raise OSError(f"injected save IOError (call {self.calls})")
        return np.savez(file, **arrays)


class KillingSavez:
    """Writes a torn archive prefix then raises :class:`KillSave` —
    simulates the process dying mid-save. The atomic tmp-dir protocol
    must leave the previous checkpoint untouched."""

    def __call__(self, file, **arrays):
        file.write(b"PK\x03\x04 torn write, not a real archive")
        file.flush()
        raise KillSave("injected kill mid-save")


# -- data-pipeline wrappers (delivered at an exact step) -------------------

class _DataWrapper:
    """Delegates the SyntheticLM interface, intercepting per-step
    fetches."""

    def __init__(self, data):
        self._data = data

    def _on_fetch(self, step: int) -> None:   # pragma: no cover - override
        pass

    def batch(self, step: int):
        self._on_fetch(step)
        return self._data.batch(step)

    def microbatched(self, step: int, a: int):
        self._on_fetch(step)
        return self._data.microbatched(step, a)

    def __getattr__(self, name):
        return getattr(self._data, name)


class InterruptData(_DataWrapper):
    """Raises ``signum`` in the main thread when step ``at_step``'s batch
    is fetched — the train loop's handler finishes the step, saves a
    final checkpoint, and exits cleanly (the preemption path)."""

    def __init__(self, data, at_step: int,
                 signum: int = signal.SIGTERM):
        super().__init__(data)
        self.at_step = at_step
        self.signum = signum

    def _on_fetch(self, step: int) -> None:
        if step == self.at_step:
            signal.raise_signal(self.signum)


class StragglerData(_DataWrapper):
    """Sleeps ``sleep_s`` when step ``at_step``'s batch is fetched — an
    injected input-pipeline straggler, visible in the step record's
    ``data`` phase wall."""

    def __init__(self, data, at_step: int, sleep_s: float = 1.0):
        super().__init__(data)
        self.at_step = at_step
        self.sleep_s = sleep_s

    def _on_fetch(self, step: int) -> None:
        if step == self.at_step:
            time.sleep(self.sleep_s)
