"""Paper Fig. 3: SP-method speed comparison (LASP-2 vs LASP-1 vs Ring
Attention vs Megatron-SP).

Measured: wall-clock (median/p90 per call) of each SP method's attention
layer on 8 virtual devices, sequence lengths 8K→32K (CPU-indicative),
plus the bytes each method puts on the wire from the comm subsystem's
CommRecord tape. Derived: the paper §3.4 communication model at the
paper's scale (64 GPUs, 2048K tokens): communication steps per iteration
and traffic per device per layer. Emits ``BENCH_fig3_speed.json``.
"""

from __future__ import annotations

from benchmarks.common import emit, run_subprocess_bench, write_bench_json

BENCH_NAME = "fig3_speed"

_CODE = r"""
import json, time
import jax, jax.numpy as jnp
from repro.core.lasp2 import lasp2, SPConfig
from repro.core.baselines import lasp1, ring_attention, megatron_sp_attention
from repro.comm import tape, tape_summary

from repro.launch.mesh import SEQ_AXIS, make_sp_mesh
mesh = make_sp_mesh(8)
sp = SPConfig(mesh=mesh, sp_axis=SEQ_AXIS)
B, H, d = 1, 8, 64

from benchmarks.common import percentile

res = {}
for S in (8192, 16384, 32768):
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, H, S, d), jnp.bfloat16) * 0.3
    k = jax.random.normal(ks[1], (B, H, S, d), jnp.bfloat16) * 0.3
    v = jax.random.normal(ks[2], (B, H, S, d), jnp.bfloat16) * 0.5
    fns = {
        "lasp2": jax.jit(lambda a,b,c: lasp2(a,b,c, sp=sp)),
        "lasp1": jax.jit(lambda a,b,c: lasp1(a,b,c, sp=sp)),
    }
    if S <= 8192:  # quadratic baselines are compile/OOM-hostile on CPU
        fns["ring_attention"] = jax.jit(lambda a,b,c: ring_attention(a,b,c, sp=sp))
        fns["megatron_sp"] = jax.jit(lambda a,b,c: megatron_sp_attention(a,b,c, sp=sp))
    for name, f in fns.items():
        with tape() as recs:
            f.lower(q, k, v)
        f(q, k, v)[0].block_until_ready()
        times = []
        for _ in range(5):
            t0 = time.perf_counter()
            out = f(q, k, v)
            out.block_until_ready()
            times.append((time.perf_counter() - t0) * 1e6)
        res[f"{name}@{S}"] = {
            "median_us": percentile(times, 50),
            "p90_us": percentile(times, 90),
            "comm_bytes": tape_summary(recs).get("total_bytes", 0),
            "comm_steps": tape_summary(recs).get("total_steps", 0),
        }
print(json.dumps(res))
"""


def analytic_rows():
    """Paper §3.4 at the paper's scale: W=64, B=1, H=16(heads)·d=128/head
    (Linear-Llama3-1B per-head states), N=2048K, per layer."""
    w, bh, dk, dv = 64, 16, 128, 128
    n, dmodel = 2 ** 21, 2048
    state = bh * dk * dv * 2                      # bf16 bytes
    rows = []
    rows.append(("derived/lasp2_comm_steps_per_iter", 0, 2))
    rows.append(("derived/lasp1_comm_steps_per_iter", 0, 2 * (w - 1)))
    rows.append(("derived/lasp2_fwd_traffic_per_dev_MB", 0,
                 round((w - 1) / w * w * state / 1e6, 2)))
    rows.append(("derived/lasp1_fwd_traffic_per_dev_MB", 0,
                 round((w - 1) * state / 1e6, 2)))
    # Megatron-SP gathers activations: N/W tokens × d per gather, 2 gathers
    rows.append(("derived/megatron_sp_fwd_traffic_per_dev_MB", 0,
                 round(2 * (w - 1) / w * n * dmodel * 2 / 1e6, 2)))
    # Ring attention circulates K+V chunks: (W-1) steps × 2·C·d
    rows.append(("derived/ring_fwd_traffic_per_dev_MB", 0,
                 round((w - 1) * 2 * (n // w) * dmodel * 2 / 1e6, 2)))
    return rows


def main():
    rows = []
    res = run_subprocess_bench(_CODE, devices=8, timeout=2400)
    for k, stats in sorted(res.items()):
        us = stats["median_us"]
        rows.append((f"fig3/{k}", us,
                     "tokens/s="
                     + str(round(int(k.split("@")[1]) / (us / 1e6)))
                     + f";p90={stats['p90_us']:.0f}us"
                     + f";bytes={stats['comm_bytes']}"))
    rows += [(f"fig3/{n}", u, d) for n, u, d in analytic_rows()]
    emit(rows)
    # benchmarks.run writes BENCH_fig3_speed.json from this payload (the
    # __main__ path below covers standalone invocation)
    return {
        "measured": res,
        "rows": [{"name": n, "us_per_call": us, "derived": d}
                 for n, us, d in rows],
    }


if __name__ == "__main__":
    write_bench_json(BENCH_NAME, main())
