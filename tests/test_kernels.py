"""Per-kernel Pallas sweeps (interpret mode) vs the ref.py oracles."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops
from repro.kernels.flash_attention import flash_attention
from repro.kernels.lasp2_chunk import lasp2_chunk_fwd
from repro.kernels.ref import flash_attention_ref, linear_attention_ref

TOL = {jnp.float32: 3e-4, jnp.bfloat16: 4e-2}


@pytest.mark.parametrize("s,dk,dv", [(256, 64, 64), (512, 128, 128),
                                     (256, 32, 64), (128, 128, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("decay", [False, True])
def test_lasp2_chunk_kernel_sweep(rng, s, dk, dv, dtype, decay):
    bh = 3
    ks = jax.random.split(rng, 4)
    q = (jax.random.normal(ks[0], (bh, s, dk)) * 0.3).astype(dtype)
    k = (jax.random.normal(ks[1], (bh, s, dk)) * 0.3).astype(dtype)
    v = (jax.random.normal(ks[2], (bh, s, dv)) * 0.5).astype(dtype)
    la = (-jnp.abs(jax.random.normal(ks[3], (bh, s))) * 0.03) if decay \
        else jnp.zeros((bh, s))
    o, st, ld = lasp2_chunk_fwd(q, k, v, la, block_size=128, interpret=True)
    oref, stref = linear_attention_ref(q, k, v, la)
    t = TOL[dtype]
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(oref, np.float32), rtol=t, atol=t)
    np.testing.assert_allclose(st, stref, rtol=t, atol=t)
    np.testing.assert_allclose(ld, jnp.sum(la, -1), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("sq,sk,hq,hkv,dh", [
    (256, 256, 4, 2, 64), (128, 128, 8, 1, 64), (256, 256, 4, 4, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal,window", [(True, None), (False, None),
                                           (True, 64)])
def test_flash_kernel_sweep(rng, sq, sk, hq, hkv, dh, dtype, causal,
                            window):
    b = 2
    ks = jax.random.split(rng, 3)
    q = (jax.random.normal(ks[0], (b, hq, sq, dh)) * 0.4).astype(dtype)
    k = (jax.random.normal(ks[1], (b, hkv, sk, dh)) * 0.4).astype(dtype)
    v = (jax.random.normal(ks[2], (b, hkv, sk, dh)) * 0.5).astype(dtype)
    o = flash_attention(q, k, v, causal=causal, sliding_window=window,
                        block_q=64, block_k=64, interpret=True)
    oref = flash_attention_ref(q, k, v, causal=causal,
                               sliding_window=window)
    t = TOL[dtype]
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(oref, np.float32), rtol=t, atol=t)


@pytest.mark.parametrize("dk,dv", [(32, 32), (64, 128), (128, 64)])
@pytest.mark.parametrize("decay", [False, True])
def test_lasp2_decode_kernel_sweep(rng, dk, dv, decay):
    """Single-step recurrent decode kernel == oracle recurrence, and
    chaining steps from a chunked-prefill state continues the scan."""
    from repro.core import linear_attention as la
    from repro.kernels.lasp2_chunk import lasp2_chunk_fwd

    bh, s, split = 4, 32, 24
    ks = jax.random.split(rng, 4)
    q = jax.random.normal(ks[0], (bh, s, dk)) * 0.3
    k = jax.random.normal(ks[1], (bh, s, dk)) * 0.3
    v = jax.random.normal(ks[2], (bh, s, dv)) * 0.5
    la_ = (-jnp.abs(jax.random.normal(ks[3], (bh, s))) * 0.05) if decay \
        else jnp.zeros((bh, s))
    ref = la.sequential_oracle(q, k, v, la_)
    # prefill the first `split` tokens with the chunked kernel...
    _, st, ld = lasp2_chunk_fwd(q[:, :split], k[:, :split], v[:, :split],
                                la_[:, :split], block_size=8,
                                interpret=True)
    # ...then decode the rest one step at a time
    from repro.kernels.lasp2_decode import lasp2_decode_step
    outs = []
    for t in range(split, s):
        o, st, ld = lasp2_decode_step(q[:, t], k[:, t], v[:, t], la_[:, t],
                                      st, ld, interpret=True)
        outs.append(o)
    o_dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(o_dec, np.asarray(ref.o)[:, split:],
                               rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(st, ref.state, rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(ld, ref.log_decay, rtol=1e-5, atol=1e-5)


def test_linear_decode_op_dispatch(rng):
    ks = jax.random.split(rng, 4)
    b, h, dk, dv = 2, 4, 32, 64
    q = jax.random.normal(ks[0], (b, h, dk)) * 0.3
    k = jax.random.normal(ks[1], (b, h, dk)) * 0.3
    v = jax.random.normal(ks[2], (b, h, dv)) * 0.5
    la_ = -jnp.abs(jax.random.normal(ks[3], (b, h))) * 0.05
    st = jax.random.normal(ks[0], (b, h, dk, dv)).astype(jnp.float32)
    ld = jnp.zeros((b, h), jnp.float32)
    o1, s1, l1 = ops.linear_decode_op(q, k, v, la_, st, ld, backend="xla")
    o2, s2, l2 = ops.linear_decode_op(q, k, v, la_, st, ld,
                                      backend="interpret")
    np.testing.assert_allclose(o1, o2, rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(s1, s2, rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(l1, l2, rtol=1e-6, atol=1e-6)


def test_ops_dispatch_linear(rng):
    ks = jax.random.split(rng, 3)
    q = jax.random.normal(ks[0], (2, 4, 256, 32)) * 0.3
    k = jax.random.normal(ks[1], (2, 4, 256, 32)) * 0.3
    v = jax.random.normal(ks[2], (2, 4, 256, 32)) * 0.5
    o_xla, st_xla, _ = ops.linear_attention_op(q, k, v, backend="xla")
    o_int, st_int, _ = ops.linear_attention_op(q, k, v, backend="interpret")
    np.testing.assert_allclose(o_xla, o_int, rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(st_xla, st_int, rtol=3e-4, atol=3e-4)


@pytest.mark.parametrize("s", [17, 129, 251])
def test_ops_linear_awkward_lengths(rng, s):
    """Arbitrary (incl. prime) prompt lengths must keep full-size blocks
    via zero right-padding — output, state and log decay stay exact."""
    from repro.core import linear_attention as la
    ks = jax.random.split(rng, 4)
    q = jax.random.normal(ks[0], (1, 2, s, 16)) * 0.3
    k = jax.random.normal(ks[1], (1, 2, s, 16)) * 0.3
    v = jax.random.normal(ks[2], (1, 2, s, 24)) * 0.5
    la_ = -jnp.abs(jax.random.normal(ks[3], (1, 2, s))) * 0.05
    ref = la.sequential_oracle(q, k, v, la_)
    for backend in ("xla", "interpret"):
        o, st, ld = ops.linear_attention_op(q, k, v, la_, block_size=128,
                                            backend=backend)
        assert o.shape[-2] == s
        np.testing.assert_allclose(o, ref.o, rtol=5e-4, atol=5e-4)
        np.testing.assert_allclose(st, ref.state, rtol=5e-4, atol=5e-4)
        np.testing.assert_allclose(ld, ref.log_decay, rtol=1e-5, atol=1e-5)


def test_ops_dispatch_flash(rng):
    ks = jax.random.split(rng, 3)
    q = jax.random.normal(ks[0], (2, 4, 256, 64)) * 0.4
    k = jax.random.normal(ks[1], (2, 2, 256, 64)) * 0.4
    v = jax.random.normal(ks[2], (2, 2, 256, 64)) * 0.5
    o_xla = ops.flash_attention_op(q, k, v, backend="xla")
    o_int = ops.flash_attention_op(q, k, v, backend="interpret")
    np.testing.assert_allclose(o_xla, o_int, rtol=3e-4, atol=3e-4)


def test_kernel_vmem_footprint_static():
    """BlockSpec tiles must fit VMEM (16 MB/core budget, fp32 scratch)."""
    bq, bk, dh, dkv = 128, 128, 128, 128
    flash_tiles = (bq * dh + 2 * bk * dh + bq * dh) * 4 + bq * dh * 4
    chunk_tiles = (2 * 128 * dkv + 2 * 128 * dkv) * 4 + dkv * dkv * 4
    assert flash_tiles < 16 * 2 ** 20
    assert chunk_tiles < 16 * 2 ** 20
