"""Comm subsystem (repro/comm): single-device unit tests + the 8-virtual-
device parity/budget battery (run in a subprocess so this pytest process
keeps its single default device)."""

import os
import subprocess
import sys

import pytest


def test_comm_battery():
    script = os.path.join(os.path.dirname(__file__), "comm_checks.py")
    proc = subprocess.run([sys.executable, script], capture_output=True,
                          text=True, timeout=1200)
    sys.stdout.write(proc.stdout)
    sys.stderr.write(proc.stderr[-3000:])
    assert proc.returncode == 0, "comm checks failed"
    assert "ALL" in proc.stdout and "PASSED" in proc.stdout


# --- pick_block (shared block policy) --------------------------------------

def test_pick_block_prefers_mxu_aligned_divisors():
    from repro.core.linear_attention import pick_block
    assert pick_block(512, 128) == 128        # preferred divides
    assert pick_block(64, 128) == 64          # short sequence: one block
    assert pick_block(192, 128) == 64         # NOT 96: aligned 64 wins
    assert pick_block(320, 128) == 64         # NOT 80
    assert pick_block(96, 128) == 96          # whole-sequence block is fine
    assert pick_block(3 * 32, 64) == 32       # aligned divisor < preferred
    assert pick_block(200, 128) == 100        # no aligned divisor: largest
    assert pick_block(97, 128) == 97          # prime < preferred: one block
    assert pick_block(97, 64) == 1            # prime > preferred: degenerate


def test_ops_pads_instead_of_degenerate_blocks():
    """kernels/ops shares pick_block but right-pads awkward lengths."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.core.linear_attention import sequential_oracle
    from repro.kernels.ops import linear_attention_op

    key = jax.random.PRNGKey(0)
    for s in (192, 200, 97):
        ks = jax.random.split(key, 3)
        q = jax.random.normal(ks[0], (1, 2, s, 16)) * 0.3
        k = jax.random.normal(ks[1], (1, 2, s, 16)) * 0.3
        v = jax.random.normal(ks[2], (1, 2, s, 16)) * 0.5
        o, st, _ = linear_attention_op(q, k, v, None, block_size=128,
                                       backend="xla")
        ref = sequential_oracle(q, k, v)
        np.testing.assert_allclose(np.asarray(o), np.asarray(ref.o),
                                   rtol=3e-4, atol=3e-4)
        np.testing.assert_allclose(np.asarray(st), np.asarray(ref.state),
                                   rtol=3e-4, atol=3e-4)
    del jnp


# --- budget bookkeeping (no devices needed) --------------------------------

def test_budget_tables():
    from repro.comm import lasp2_budget, ring_baseline_budget
    assert lasp2_budget("allgather", 8).counts == {"all-gather": 1}
    assert lasp2_budget("allgather", 8, with_grad=True).counts == \
        {"all-gather": 2}
    assert lasp2_budget("allgather", 8, with_grad=True,
                        backward="autodiff").counts == \
        {"all-gather": 1, "reduce-scatter": 1}
    assert lasp2_budget("ring", 8).counts == {"collective-permute": 7}
    assert lasp2_budget("ring", 8, with_grad=True).counts == \
        {"collective-permute": 14}
    assert lasp2_budget("pipelined", 8, n_slices=4).counts == \
        {"collective-permute": 28}
    assert ring_baseline_budget(64, with_grad=True).counts == \
        {"collective-permute": 126}      # the paper's 2(W-1) at W=64
    with pytest.raises(ValueError):
        lasp2_budget("smoke-signals", 8)


def test_check_budget_on_synthetic_hlo():
    from repro.comm import CollectiveBudget, check_budget

    hlo = """
HloModule m
ENTRY e {
  %x = f32[8,16]{1,0} parameter(0)
  %ag = f32[64,16]{1,0} all-gather(%x), replica_groups={{0,1,2,3,4,5,6,7}}, dimensions={0}
  %cp = f32[8,16]{1,0} collective-permute(%x), source_target_pairs={{0,1}}
  ROOT %r = f32[64,16]{1,0} add(%ag, %ag)
}
"""
    ok = CollectiveBudget({"all-gather": 1, "collective-permute": 1})
    assert check_budget(hlo, ok, 8) == []
    bad = CollectiveBudget({"all-gather": 2})
    violations = check_budget(hlo, bad, 8)
    assert len(violations) == 2          # wrong count + unexpected permute
    loose = CollectiveBudget({"all-gather": 1}, strict=False)
    assert check_budget(hlo, loose, 8) == []
    capped = CollectiveBudget({"all-gather": 1, "collective-permute": 1},
                              max_traffic={"all-gather": 10.0})
    assert any("exceeds budget" in v for v in check_budget(hlo, capped, 8))


def test_comm_record_cost_model():
    """Tape traffic uses the same ring model as hlo_analysis."""
    import jax.numpy as jnp
    from repro.comm.primitives import (CommRecord, auto_slices,
                                       tape_summary)
    del jnp
    r = CommRecord("all-gather", 1000, 7000, steps=1, group=8)
    assert tape_summary([r])["total_bytes"] == 7000
    rs = [CommRecord("collective-permute", 100, 100, steps=1, group=8)
          for _ in range(7)]
    s = tape_summary(rs)
    assert s["collective-permute_count"] == 7 and s["total_steps"] == 7
    assert auto_slices(64) == 4
    assert auto_slices(6) == 2
    assert auto_slices(7) == 1


def test_strategy_registry_and_overlap_modes():
    from repro.comm import get_strategy
    from repro.comm.overlap import DoubleBufferedScheduler

    assert get_strategy("allgather").supports_faithful
    assert not get_strategy("ring").supports_faithful
    assert get_strategy("pipelined").name == "pipelined"
    with pytest.raises(ValueError):
        get_strategy("carrier-pigeon")
    with pytest.raises(ValueError):
        DoubleBufferedScheduler("sometimes")
    # scheduler ordering is pure dataflow plumbing — check both modes
    # return (exchange, compute) results unchanged on plain arrays
    import jax.numpy as jnp
    import numpy as np
    payload = jnp.arange(4.0)
    for mode in ("overlap", "none"):
        sched = DoubleBufferedScheduler(mode)
        ex, out = sched.run(payload, lambda p: p * 2, lambda: payload + 1)
        np.testing.assert_array_equal(np.asarray(ex),
                                      np.asarray(payload * 2))
        np.testing.assert_array_equal(np.asarray(out),
                                      np.asarray(payload + 1))
