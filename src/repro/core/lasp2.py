"""LASP-2: sequence parallelism for linear attention (paper Algorithms 1–4).

The public entry point is :func:`lasp2` — chunked (decay-generalized) linear
attention whose sequence dimension may be sharded over a mesh axis. When it
is, the *only* cross-device communication is

  * forward:  one ``all_gather`` of the per-chunk memory states
              ``M_t in R^{dk x dv}`` (+ per-chunk cumulative log-decays
              ``A_t``, a scalar per head — the decay generalization),
  * backward: one ``all_gather`` of the state gradients ``dM_t``
              (paper Algorithms 3/4),

both independent of sequence length — the paper's central claim.

Two backward modes:

* ``backward="faithful"``: ``custom_vjp`` implementing the paper's
  Algorithm 3/4 communication pattern literally (AllGather on ``dM_t``,
  local decayed suffix sums). Decay is treated as a constant (no gradient)
  — matching the paper, which assumes basic linear attention. Use for
  basic / Retention / Lightning (non-learned decay) variants.
* ``backward="autodiff"``: plain XLA autodiff of the forward. The AD of the
  forward ``all_gather`` is a ``reduce_scatter`` — mathematically identical,
  with (W-1)/W× *less* backward traffic than the paper's AllGather. Required
  for data-dependent decays (GLA-lite / Mamba-2 SSD) and recorded in
  EXPERIMENTS.md as a beyond-paper variant.

Sharding integration: we use partial-manual ``jax.shard_map`` —
``axis_names={sp_axis}`` makes only the sequence axis manual; batch/head
dimensions stay auto-sharded by GSPMD (tensor parallelism over ``"model"``,
batch over ``"pod"`` compose transparently).
"""

from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.compat import shard_map as _shard_map

from repro.core.linear_attention import chunk_scan, chunk_summaries


@dataclass(frozen=True)
class SPConfig:
    """How the sequence dimension is sharded for LASP-2 style layers."""

    mesh: Mesh
    sp_axis: str = "data"    # mesh axis the sequence dim is split over

    @property
    def degree(self) -> int:
        return self.mesh.shape[self.sp_axis]


def _pick_block(s: int, preferred: int) -> int:
    """Largest divisor of ``s`` that is <= preferred (MXU-aligned when possible)."""
    bs = min(preferred, s)
    while s % bs:
        bs -= 1
    return max(bs, 1)


# ---------------------------------------------------------------------------
# Cross-chunk (inter) combination — the math around the AllGather.
# ---------------------------------------------------------------------------

def _prefix_state(ms, cum, t):
    """Decayed prefix-combine of gathered chunk states (paper Alg. 2 line 9).

    ms:  (W, ..., dk, dv) gathered chunk states (fp32)
    cum: (W, ...) inclusive cumulative chunk log-decays along axis 0
    t:   my chunk index (traced scalar)

    Returns M_{1:t-1} decayed to the *start* of chunk t:
        sum_{j < t} exp(cum[t-1] - cum[j]) * ms[j]
    """
    w_idx = jnp.arange(ms.shape[0])
    cum_tm1 = jax.lax.dynamic_index_in_dim(
        cum, jnp.maximum(t - 1, 0), axis=0, keepdims=False)
    logw = cum_tm1[None] - cum                           # <= 0 for j <= t-1
    mask = (w_idx < t)
    shape = (ms.shape[0],) + (1,) * (cum.ndim - 1)
    w = jnp.where(mask.reshape(shape), jnp.exp(jnp.minimum(logw, 0.0)), 0.0)
    return jnp.einsum("w...,w...kv->...kv", w, ms)


def _suffix_grad_state(dms, cum, t):
    """Decayed suffix-combine of gathered state grads (paper Alg. 4 line 9).

    dM_t^loc = sum_{t' > t} exp(cum[t'-1] - cum[t]) * dms[t']
    """
    w_idx = jnp.arange(dms.shape[0])
    cum_t = jax.lax.dynamic_index_in_dim(cum, t, axis=0, keepdims=False)
    cum_prev = jnp.concatenate([jnp.zeros_like(cum[:1]), cum[:-1]], axis=0)
    logw = cum_prev - cum_t[None]                        # <= 0 for t' > t
    mask = (w_idx > t)
    shape = (dms.shape[0],) + (1,) * (cum.ndim - 1)
    w = jnp.where(mask.reshape(shape), jnp.exp(jnp.minimum(logw, 0.0)), 0.0)
    return jnp.einsum("w...,w...kv->...kv", w, dms)


def _cumulative_decay(log_a):
    """Inclusive in-chunk cumulative decay b_i = exp(sum_{j<=i} log_a_j)."""
    return jnp.exp(jnp.cumsum(log_a.astype(jnp.float32), axis=-1))


# ---------------------------------------------------------------------------
# Local (per-shard) forward bodies.
# ---------------------------------------------------------------------------

def _causal_fwd_local(q, k, v, log_a, sp_axis, block_size):
    """Runs on each device's sequence shard. Returns output + residual pack.

    Ordering mirrors paper Alg. 2: chunk summaries are produced first so the
    AllGather can overlap with the (heavy) intra-chunk computation — XLA's
    latency-hiding scheduler overlaps the independent ``all_gather`` with
    ``chunk_scan`` on TPU, which is the paper's comm/compute overlap.
    """
    bs = _pick_block(q.shape[-2], block_size)
    # (1) cheap summary pass: M_t, A_t — only K/V/decay.
    m_loc, a_loc = chunk_summaries(k, v, log_a, block_size=bs)
    # (2) single AllGather of (M_t, A_t) — THE communication of LASP-2.
    ms = jax.lax.all_gather(m_loc, sp_axis)              # (W, ..., dk, dv)
    las = jax.lax.all_gather(a_loc, sp_axis)             # (W, ...)
    # (3) intra-chunk output (independent of the gather → overlappable).
    out = chunk_scan(q, k, v, log_a, block_size=bs)
    # (4) local prefix combine + inter-chunk output.
    t = jax.lax.axis_index(sp_axis)
    cum = jnp.cumsum(las, axis=0)
    m_prev = _prefix_state(ms, cum, t)
    b = _cumulative_decay(log_a)
    o_inter = jnp.einsum(
        "...sk,...kv->...sv", q.astype(jnp.float32) * b[..., None], m_prev)
    o = out.o.astype(jnp.float32) + o_inter
    return o.astype(q.dtype), (m_prev, cum, t)


def _noncausal_fwd_local(q, k, v, sp_axis, block_size):
    """Paper Alg. 1: no mask — every position reads the full-sequence state."""
    del block_size
    kf, vf = k.astype(jnp.float32), v.astype(jnp.float32)
    m_loc = jnp.einsum("...sk,...sv->...kv", kf, vf)
    ms = jax.lax.all_gather(m_loc, sp_axis)
    m_tot = jnp.sum(ms, axis=0)
    o = jnp.einsum("...sk,...kv->...sv", q.astype(jnp.float32), m_tot)
    return o.astype(q.dtype), m_tot


# ---------------------------------------------------------------------------
# Paper-faithful custom_vjp (Algorithms 3/4).
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5))
def _lasp2_causal_faithful(q, k, v, log_a, sp_axis, block_size):
    o, _ = _causal_fwd_local(q, k, v, log_a, sp_axis, block_size)
    return o


def _faithful_fwd(q, k, v, log_a, sp_axis, block_size):
    o, (m_prev, cum, t) = _causal_fwd_local(q, k, v, log_a, sp_axis, block_size)
    return o, (q, k, v, log_a, m_prev, cum, t)


def _faithful_bwd(sp_axis, block_size, res, do):
    q, k, v, log_a, m_prev, cum, t = res
    bs = _pick_block(q.shape[-2], block_size)
    dof = do.astype(jnp.float32)
    b = _cumulative_decay(log_a)
    qb = q.astype(jnp.float32) * b[..., None]
    # Alg. 4 line 3: dM_t = (Q_t~)^T dO_t  (decay-weighted in our general form)
    dm_up = jnp.einsum("...sk,...sv->...kv", qb, dof)
    # Alg. 4 line 4: the single backward AllGather.
    dms = jax.lax.all_gather(dm_up, sp_axis)
    # Alg. 4 line 9: decayed suffix sum, local.
    dm_loc = _suffix_grad_state(dms, cum, t)

    # Intra-chunk + local state-contribution gradients (Alg. 4 lines 5–7,
    # 10–11). Computed by re-running the local chunk pass under VJP — the
    # recompute mirrors the paper's activation-checkpointing remark.
    def local_parts(q_, k_, v_):
        out = chunk_scan(q_, k_, v_, log_a, block_size=bs)
        return out.o, out.state

    _, pull = jax.vjp(local_parts, q, k, v)
    dq_i, dk_i, dv_i = pull((do, dm_loc))
    # Alg. 4 line 8: dQ_inter = dO_t M_{1:t-1}^T (decay-weighted).
    dq_inter = jnp.einsum("...sv,...kv->...sk", dof, m_prev) * b[..., None]
    dq = (dq_i.astype(jnp.float32) + dq_inter).astype(q.dtype)
    # Faithful path: decay is a non-learned constant → zero cotangent.
    return dq, dk_i, dv_i, jnp.zeros_like(log_a)


_lasp2_causal_faithful.defvjp(_faithful_fwd, _faithful_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def _lasp2_noncausal_faithful(q, k, v, sp_axis, block_size):
    o, _ = _noncausal_fwd_local(q, k, v, sp_axis, block_size)
    return o


def _nc_fwd(q, k, v, sp_axis, block_size):
    o, m_tot = _noncausal_fwd_local(q, k, v, sp_axis, block_size)
    return o, (q, k, v, m_tot)


def _nc_bwd(sp_axis, block_size, res, do):
    q, k, v, m_tot = res
    dof = do.astype(jnp.float32)
    # Alg. 3: dM_t = Q_t^T dO_t; AllGather; combine.
    dm_up = jnp.einsum("...sk,...sv->...kv", q.astype(jnp.float32), dof)
    dms = jax.lax.all_gather(dm_up, sp_axis)
    # NOTE: paper Alg. 3 line 5 writes Sum([dM]_{t+1}^T) — a suffix sum — but
    # in the unmasked form every chunk's state feeds every output, so the
    # correct cotangent sums over *all* chunks (verified against autodiff in
    # tests/test_distributed checks). We implement the correct full sum.
    dm_tot = jnp.sum(dms, axis=0)
    dq = jnp.einsum("...sv,...kv->...sk", dof, m_tot).astype(q.dtype)
    dk = jnp.einsum("...sv,...kv->...sk", v.astype(jnp.float32), dm_tot
                    ).astype(k.dtype)
    dv = jnp.einsum("...sk,...kv->...sv", k.astype(jnp.float32), dm_tot
                    ).astype(v.dtype)
    return dq, dk, dv


_lasp2_noncausal_faithful.defvjp(_nc_fwd, _nc_bwd)


# ---------------------------------------------------------------------------
# Autodiff-path forwards (plain functions; XLA derives the backward).
# ---------------------------------------------------------------------------

def _lasp2_causal_autodiff(q, k, v, log_a, sp_axis, block_size):
    o, _ = _causal_fwd_local(q, k, v, log_a, sp_axis, block_size)
    return o


def lasp2_with_state(q, k, v, log_a=None, *, sp: Optional[SPConfig] = None,
                     block_size: int = 128):
    """Causal LASP-2 forward that also returns the end-of-sequence memory
    state (used by prefill to seed the decode cache). No custom_vjp —
    prefill is inference-only."""
    if log_a is None:
        log_a = jnp.zeros(q.shape[:-1], dtype=jnp.float32)
    if sp is None or sp.degree == 1:
        out = chunk_scan(q, k, v, log_a,
                         block_size=_pick_block(q.shape[-2], block_size))
        return out.o, out.state

    axis = sp.sp_axis

    def local_fn(q_, k_, v_, la_):
        bs = _pick_block(q_.shape[-2], block_size)
        m_loc, a_loc = chunk_summaries(k_, v_, la_, block_size=bs)
        ms = jax.lax.all_gather(m_loc, axis)
        las = jax.lax.all_gather(a_loc, axis)
        out = chunk_scan(q_, k_, v_, la_, block_size=bs)
        t = jax.lax.axis_index(axis)
        cum = jnp.cumsum(las, axis=0)
        m_prev = _prefix_state(ms, cum, t)
        b = _cumulative_decay(la_)
        o = out.o.astype(jnp.float32) + jnp.einsum(
            "...sk,...kv->...sv", q_.astype(jnp.float32) * b[..., None],
            m_prev)
        # global end state: decayed combine of all chunks (same on all ranks)
        w_ = ms.shape[0]
        logw = cum[-1][None] - cum
        m_end = jnp.einsum("w...,w...kv->...kv",
                           jnp.exp(jnp.minimum(logw, 0.0)), ms)
        return o.astype(q_.dtype), m_end

    nd = q.ndim
    spec_qkv = P(*([None] * (nd - 2)), axis, None)
    spec_a = P(*([None] * (nd - 2)), axis)
    spec_state = P(*([None] * nd))
    return _shard_map(
        local_fn, mesh=sp.mesh,
        in_specs=(spec_qkv, spec_qkv, spec_qkv, spec_a),
        out_specs=(spec_qkv, spec_state), axis_names={axis},
        check_vma=False)(q, k, v, log_a)


# ---------------------------------------------------------------------------
# Public API.
# ---------------------------------------------------------------------------

def lasp2(q, k, v, log_a=None, *, sp: Optional[SPConfig] = None,
          causal: bool = True, block_size: int = 128,
          backward: str = "faithful"):
    """Chunked linear attention with LASP-2 sequence parallelism.

    Args:
      q, k: ``(..., S, dk)``; v: ``(..., S, dv)`` — global (logical) shapes.
      log_a: optional per-token log decays ``(..., S)`` (see
        ``repro.core.linear_attention``). ``None`` = basic linear attention.
      sp: sequence-parallel config; ``None`` or degree 1 → purely local
        chunked scan (no communication).
      causal: causal (paper Alg. 2) vs bidirectional (paper Alg. 1).
      backward: "faithful" (paper Alg. 3/4 custom_vjp) or "autodiff".
        Learned/data-dependent ``log_a`` requires "autodiff".
    """
    if log_a is None:
        log_a = jnp.zeros(q.shape[:-1], dtype=jnp.float32)
    if sp is None or sp.degree == 1:
        if causal:
            return chunk_scan(q, k, v, log_a,
                              block_size=_pick_block(q.shape[-2], block_size)).o
        m_tot, _ = chunk_summaries(
            k, v, None, block_size=_pick_block(q.shape[-2], block_size))
        # no-decay bidirectional total state
        return jnp.einsum("...sk,...kv->...sv", q.astype(jnp.float32),
                          m_tot).astype(q.dtype)

    axis = sp.sp_axis
    nd = q.ndim
    spec_qkv = P(*([None] * (nd - 2)), axis, None)
    spec_a = P(*([None] * (nd - 2)), axis)

    if causal:
        fn = (_lasp2_causal_faithful if backward == "faithful"
              else _lasp2_causal_autodiff)

        def mapped(q_, k_, v_, la_):
            return fn(q_, k_, v_, la_, axis, block_size)

        return _shard_map(
            mapped, mesh=sp.mesh,
            in_specs=(spec_qkv, spec_qkv, spec_qkv, spec_a),
            out_specs=spec_qkv, axis_names={axis},
            check_vma=False)(q, k, v, log_a)

    if backward == "faithful":
        def mapped_nc(q_, k_, v_):
            return _lasp2_noncausal_faithful(q_, k_, v_, axis, block_size)
    else:
        def mapped_nc(q_, k_, v_):
            o, _ = _noncausal_fwd_local(q_, k_, v_, axis, block_size)
            return o

    return _shard_map(
        mapped_nc, mesh=sp.mesh, in_specs=(spec_qkv, spec_qkv, spec_qkv),
        out_specs=spec_qkv, axis_names={axis},
        # check_vma=False: scan carries start as unvarying zeros; the
        # varying-manual-axes static check cannot see that they immediately
        # combine with varying data. Collective placement is verified by the
        # HLO-counting tests instead.
        check_vma=False)(q, k, v)
