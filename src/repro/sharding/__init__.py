from repro.sharding.rules import (Parallelism, fit_spec, make_plan,
                                  param_specs)

__all__ = ["Parallelism", "fit_spec", "make_plan", "param_specs"]
