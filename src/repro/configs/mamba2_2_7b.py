"""mamba2-2.7b — SSD (state-space duality) [arXiv:2405.21060; unverified].

d_inner = 2*2560 = 5120, 80 SSD heads of headdim 64, d_state 128, no MLP.
SSD == chunked decayed linear attention, so LASP-2 applies exactly
(DESIGN.md §5).
"""
from repro.configs.base import LayerSpec, MambaConfig, ModelConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b", family="ssm",
    n_layers=64, d_model=2560, n_heads=80, n_kv_heads=80,
    d_ff=0, vocab_size=50280, head_dim=64,
    norm_eps=1e-5,
    pattern=(LayerSpec(mixer="mamba2", mlp="none"),),
    mamba=MambaConfig(d_state=128, d_conv=4, expand=2, headdim=64,
                      ngroups=1),
    source="[arXiv:2405.21060; unverified]",
)

SMOKE = ModelConfig(
    name="mamba2-2.7b-smoke", family="ssm",
    n_layers=2, d_model=64, n_heads=8, n_kv_heads=8, d_ff=0,
    vocab_size=512, head_dim=16,
    pattern=(LayerSpec(mixer="mamba2", mlp="none"),),
    mamba=MambaConfig(d_state=16, d_conv=4, expand=2, headdim=16,
                      ngroups=1),
)
