"""Unified telemetry: sinks, histograms, and phase timers.

The observability layer turns every run into machine-readable telemetry
(docs/observability.md). Three pieces live here:

* **Sinks** — a :class:`MetricsSink` is anything with ``emit(record)``;
  records are flat JSON-able dicts tagged with a ``kind`` field
  (``step`` | ``compile`` | ``event`` | ``request`` | ``summary``).
  :class:`JsonlSink` appends one JSON object per line (the format
  ``scripts/report.py`` renders); :class:`InMemorySink` keeps records in
  a list (tests, benchmarks); :class:`NullSink` drops everything —
  instrumented code paths always emit unconditionally and rely on the
  null sink for the "off" case, so there are no ``if sink`` branches to
  rot.

* **Histograms / counters / gauges** — :class:`Histogram` is a
  streaming sample store: quantiles are EXACT (nearest-rank, the same
  rule as ``benchmarks.common.percentile``) while the sample count stays
  under ``cap``, then degrade to deterministic reservoir sampling while
  ``count``/``total``/``min``/``max`` stay exact. Histograms ``merge()``
  across per-shard sinks. :class:`Metrics` bundles named counters,
  gauges, and histograms into one registry with a flat ``snapshot()``.

* **Phase timers** — :func:`scoped_timer` wraps a block in
  ``jax.named_scope`` (so device profiles attribute ops to the phase)
  and measures HOST wall time with explicit ``block_until_ready``
  fencing: the block registers its output via ``fence.set(x)`` and the
  timer blocks on it before reading the clock, so async dispatch cannot
  leak one phase's device time into the next. Everything here is
  host-side — instrumentation adds **no collectives and no device ops**
  to the traced program.
"""

from __future__ import annotations

import json
import time
from contextlib import contextmanager
from typing import Any, Dict, List, Optional, Protocol, runtime_checkable

from repro.analysis.decorators import host_sync_allowed


# ---------------------------------------------------------------------------
# Sinks.
# ---------------------------------------------------------------------------

@runtime_checkable
class MetricsSink(Protocol):
    """Anything that accepts telemetry records (flat JSON-able dicts)."""

    def emit(self, record: Dict[str, Any]) -> None: ...

    def close(self) -> None: ...


class NullSink:
    """Drops every record — the ``sink=None`` resolution."""

    def emit(self, record: Dict[str, Any]) -> None:
        pass

    def close(self) -> None:
        pass


class InMemorySink:
    """Keeps records in a list (tests, benchmarks, report assembly)."""

    def __init__(self):
        self.records: List[Dict[str, Any]] = []

    def emit(self, record: Dict[str, Any]) -> None:
        self.records.append(dict(record))

    def close(self) -> None:
        pass

    def by_kind(self, kind: str) -> List[Dict[str, Any]]:
        return [r for r in self.records if r.get("kind") == kind]


class JsonlSink:
    """One JSON object per line, flushed per record (crash-safe tail).

    The on-disk format ``scripts/report.py`` renders and CI uploads as a
    run artifact. Values that are not JSON-native (jax/numpy scalars)
    are coerced via ``float()``."""

    def __init__(self, path: str):
        self.path = path
        self._f = open(path, "a")

    def emit(self, record: Dict[str, Any]) -> None:
        self._f.write(json.dumps(record, sort_keys=True, default=_coerce))
        self._f.write("\n")
        self._f.flush()

    def close(self) -> None:
        if not self._f.closed:
            self._f.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()


def _coerce(x):
    """JSON fallback for numpy/jax scalars (and anything float-able)."""
    try:
        return float(x)
    except (TypeError, ValueError):
        return str(x)


def as_sink(sink: Optional[MetricsSink]) -> MetricsSink:
    """``None`` → :class:`NullSink`; instrumented code calls this once
    so the hot path never branches on sink presence."""
    return sink if sink is not None else NullSink()


def read_jsonl(path: str) -> List[Dict[str, Any]]:
    """Load a :class:`JsonlSink` file back into records (report tooling).
    Blank lines are skipped; a truncated final line (crash mid-write)
    is dropped rather than raising."""
    out = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            try:
                out.append(json.loads(line))
            except json.JSONDecodeError:
                continue
    return out


# ---------------------------------------------------------------------------
# Histograms / counters / gauges.
# ---------------------------------------------------------------------------

class Histogram:
    """Streaming samples with nearest-rank quantiles.

    Exact while ``count <= cap`` (every sample kept); past that, samples
    degrade to a uniform reservoir (Vitter's Algorithm R with a
    deterministic LCG so runs are reproducible) while ``count``,
    ``total``, ``min`` and ``max`` stay exact. ``percentile`` uses the
    same nearest-rank rule as ``benchmarks.common.percentile`` so bench
    JSON and telemetry quantiles agree by construction.
    """

    def __init__(self, cap: int = 4096, _seed: int = 0x9E3779B9):
        self.cap = int(cap)
        self.count = 0
        self.total = 0.0
        self.min: Optional[float] = None
        self.max: Optional[float] = None
        self._xs: List[float] = []
        self._rng = _seed & 0xFFFFFFFF

    def _rand(self, n: int) -> int:
        # 32-bit LCG (Numerical Recipes constants): deterministic, cheap.
        self._rng = (1664525 * self._rng + 1013904223) & 0xFFFFFFFF
        return self._rng % n

    def add(self, x: float) -> None:
        x = float(x)
        self.count += 1
        self.total += x
        self.min = x if self.min is None else min(self.min, x)
        self.max = x if self.max is None else max(self.max, x)
        if len(self._xs) < self.cap:
            self._xs.append(x)
        else:
            # Algorithm R: keep each of the `count` samples with prob cap/count.
            j = self._rand(self.count)
            if j < self.cap:
                self._xs[j] = x

    def extend(self, xs) -> None:
        for x in xs:
            self.add(x)

    @property
    def exact(self) -> bool:
        """True while every sample is retained (quantiles are exact)."""
        return self.count == len(self._xs)

    def percentile(self, p: float) -> Optional[float]:
        """Nearest-rank percentile over the retained samples."""
        if not self._xs:
            return None
        xs = sorted(self._xs)
        idx = min(len(xs) - 1,
                  max(0, int(round(p / 100 * (len(xs) - 1)))))
        return xs[idx]

    @property
    def mean(self) -> Optional[float]:
        return self.total / self.count if self.count else None

    def merge(self, other: "Histogram") -> "Histogram":
        """Combine two histograms (e.g. per-shard sinks) into a new one.

        If the union of retained samples fits under ``cap`` the merged
        quantiles stay exact; otherwise the union is deterministically
        subsampled. Exact fields (count/total/min/max) always combine
        exactly."""
        out = Histogram(cap=max(self.cap, other.cap))
        pool = self._xs + other._xs
        if len(pool) > out.cap:
            # deterministic thinning: evenly strided over the sorted pool
            # keeps the empirical distribution's shape
            pool = sorted(pool)
            stride = len(pool) / out.cap
            pool = [pool[int(i * stride)] for i in range(out.cap)]
        out._xs = list(pool)
        out.count = self.count + other.count
        out.total = self.total + other.total
        mins = [m for m in (self.min, other.min) if m is not None]
        maxs = [m for m in (self.max, other.max) if m is not None]
        out.min = min(mins) if mins else None
        out.max = max(maxs) if maxs else None
        return out

    def summary(self) -> Dict[str, Optional[float]]:
        return {"count": self.count, "mean": self.mean,
                "min": self.min, "max": self.max,
                "p50": self.percentile(50), "p90": self.percentile(90),
                "p99": self.percentile(99)}


class Metrics:
    """Named counters (monotonic), gauges (latest value, plus peak), and
    histograms — one registry per instrumented component."""

    def __init__(self):
        self.counters: Dict[str, float] = {}
        self.gauges: Dict[str, float] = {}
        self._gauge_peaks: Dict[str, float] = {}
        self.histograms: Dict[str, Histogram] = {}

    def inc(self, name: str, n: float = 1) -> None:
        self.counters[name] = self.counters.get(name, 0) + n

    def gauge(self, name: str, value: float) -> None:
        value = float(value)
        self.gauges[name] = value
        self._gauge_peaks[name] = max(self._gauge_peaks.get(name, value),
                                      value)

    def observe(self, name: str, value: float) -> None:
        self.histogram(name).add(value)

    def histogram(self, name: str) -> Histogram:
        if name not in self.histograms:
            self.histograms[name] = Histogram()
        return self.histograms[name]

    def merge(self, other: "Metrics") -> "Metrics":
        """Combine two registries (per-shard aggregation): counters add,
        gauge peaks take the max (latest values keep ``self``'s),
        histograms merge sample pools."""
        out = Metrics()
        out.counters = dict(other.counters)
        for k, v in self.counters.items():
            out.counters[k] = out.counters.get(k, 0) + v
        out.gauges = {**other.gauges, **self.gauges}
        out._gauge_peaks = dict(other._gauge_peaks)
        for k, v in self._gauge_peaks.items():
            out._gauge_peaks[k] = max(out._gauge_peaks.get(k, v), v)
        for k in set(self.histograms) | set(other.histograms):
            a = self.histograms.get(k, Histogram())
            b = other.histograms.get(k, Histogram())
            out.histograms[k] = a.merge(b)
        return out

    def snapshot(self) -> Dict[str, Any]:
        """Flat dict view: counters, gauges (+ ``<name>_peak``), and
        per-histogram summaries — what sinks receive in summary records."""
        out: Dict[str, Any] = dict(self.counters)
        out.update(self.gauges)
        out.update({f"{k}_peak": v for k, v in self._gauge_peaks.items()})
        for name, h in self.histograms.items():
            for stat, v in h.summary().items():
                out[f"{name}_{stat}"] = v
        return out


# ---------------------------------------------------------------------------
# Phase timing.
# ---------------------------------------------------------------------------

@host_sync_allowed
def block_until_ready(x):
    """Block on every jax array in a pytree (no-op for host values)."""
    import jax
    jax.tree.map(lambda v: v.block_until_ready()
                 if hasattr(v, "block_until_ready") else v, x)
    return x


class Fence:
    """Mutable holder a timed block uses to register its device output;
    the surrounding :func:`scoped_timer` blocks on it before stopping
    the clock."""

    def __init__(self):
        self.value = None

    def set(self, x):
        self.value = x
        return x

    @host_sync_allowed
    def block(self):
        if self.value is not None:
            block_until_ready(self.value)


@contextmanager
def scoped_timer(name: str, out: Dict[str, float], *,
                 clock=time.perf_counter):
    """Time a named phase into ``out[name]`` (seconds, accumulating).

    The block runs inside ``jax.named_scope(name)`` so device traces
    attribute its ops to the phase; on exit the timer blocks on whatever
    the block registered via ``fence.set(...)`` — without the fence,
    jax's async dispatch would charge this phase's device time to
    whichever later phase first synchronizes.
    """
    import jax
    fence = Fence()
    with jax.named_scope(name):
        t0 = clock()
        try:
            yield fence
        finally:
            fence.block()
            out[name] = out.get(name, 0.0) + clock() - t0


class PhaseTimer:
    """Per-step phase walls + cumulative per-phase histograms.

    Usage::

        timer = PhaseTimer()
        with timer.phase("step") as f:
            state, metrics = step_fn(state, batch)
            f.set(metrics)                  # fence on the device output
        walls = timer.flush()               # {"step_s": 0.0123}
    """

    def __init__(self):
        self.current: Dict[str, float] = {}
        self.metrics = Metrics()

    def phase(self, name: str):
        return scoped_timer(name, self.current)

    def flush(self) -> Dict[str, float]:
        """Close out the current step: fold the per-phase walls into the
        cumulative histograms and return them as ``{"<name>_s": wall}``."""
        out = {f"{k}_s": v for k, v in self.current.items()}
        for k, v in self.current.items():
            self.metrics.observe(f"{k}_s", v)
        self.current = {}
        return out

    def summaries(self) -> Dict[str, Dict[str, Optional[float]]]:
        return {k: h.summary() for k, h in self.metrics.histograms.items()}


# ---------------------------------------------------------------------------
# Console rendering.
# ---------------------------------------------------------------------------

def render_step(rec: Dict[str, Any]) -> str:
    """Human-readable one-liner for a ``kind="step"`` record — the
    console view of what the sink received (replaces the train loop's
    old ad-hoc print)."""
    parts = [f"step {int(rec.get('step', 0)):5d}"]
    if "loss" in rec:
        parts.append(f"loss {rec['loss']:.4f}")
    if "grad_norm" in rec:
        parts.append(f"gnorm {rec['grad_norm']:.2f}")
    if "lr" in rec:
        parts.append(f"lr {rec['lr']:.2e}")
    if "wall_s" in rec:
        parts.append(f"{rec['wall_s'] * 1e3:.0f}ms")
    if rec.get("tokens_per_s"):
        parts.append(f"{rec['tokens_per_s']:.0f} tok/s")
    if rec.get("mfu") is not None:
        parts.append(f"mfu {rec['mfu']:.2%}")
    return " ".join(parts)
