"""Paper Table 4 (Appendix A.5.2): hybrid-ratio ablation.

0 (pure linear), 1/8, 1/4, 1/2 hybrid tiny Linear-Llama3 models trained
identically; report final losses. Expectation (paper): loss improves
monotonically-ish with hybrid ratio, most of the gain by 1/4.
"""

from __future__ import annotations

import time

from benchmarks.common import emit

STEPS = 120
SEQ = 256
BATCH = 8


def _cfg(hybrid_every):
    from repro.configs.base import LayerSpec, ModelConfig
    base = ModelConfig(
        name="llama3-tiny", family="dense", n_layers=8, d_model=128,
        n_heads=4, n_kv_heads=4, d_ff=352, vocab_size=2048,
        pattern=(LayerSpec(),))
    cfg = base.linearize(hybrid_every=hybrid_every)
    return cfg


def _train(cfg):
    from repro.configs.base import RunConfig
    from repro.data.pipeline import SyntheticLM
    from repro.train.loop import train
    run = RunConfig(num_microbatches=1, total_steps=STEPS,
                    warmup_steps=10, learning_rate=1e-3, remat="none")
    data = SyntheticLM(cfg.vocab_size, SEQ, BATCH, seed=0)
    t0 = time.perf_counter()
    _, hist = train(cfg, run, data, log_every=10 ** 9,
                    log_fn=lambda *_: None)
    dt = time.perf_counter() - t0
    return sum(h["loss"] for h in hist[-10:]) / 10, dt


def main():
    rows = []
    for label, he in (("0-pure-linear", 0), ("1of8", 8), ("1of4", 4),
                      ("1of2", 2)):
        loss, dt = _train(_cfg(he))
        rows.append((f"table4/hybrid-{label}", dt / STEPS * 1e6,
                     f"loss={loss:.3f}"))
    emit(rows)
    return rows


if __name__ == "__main__":
    main()
