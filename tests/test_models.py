"""Per-architecture smoke tests: reduced config, one forward + one train
step + prefill/decode parity on CPU; output shapes + finiteness.
(Requirement (f): every assigned arch has a runnable smoke test.)"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ALL_IDS, get_smoke
from repro.configs.base import RunConfig
from repro.data.pipeline import SyntheticLM
from repro.models import model as M
from repro.train.step import init_state, make_train_step
from repro.sharding.rules import local_plan


def _aux_inputs(cfg, batch, key):
    kw = {}
    if cfg.encoder is not None:
        kw["enc_frames"] = jax.random.normal(
            key, (batch, cfg.encoder.n_frames, cfg.d_model)) * 0.1
    if cfg.n_image_tokens:
        kw["img_emb"] = jax.random.normal(
            key, (batch, cfg.n_image_tokens, cfg.d_model)) * 0.1
    return kw


@pytest.mark.parametrize("arch", ALL_IDS)
def test_smoke_forward_and_shapes(arch, rng):
    cfg = get_smoke(arch)
    params = M.init_params(rng, cfg)
    b, s = 2, 32
    tokens = jax.random.randint(rng, (b, s), 0, cfg.vocab_size)
    kw = _aux_inputs(cfg, b, rng)
    logits, aux = jax.jit(
        lambda p, t: M.forward(p, t, cfg, remat="none", **kw))(
            params, tokens)
    assert logits.shape == (b, s, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits[..., :cfg.vocab_size],
                                  np.float32)).all(), f"{arch}: non-finite"
    loss = M.lm_loss(logits, jnp.roll(tokens, -1, 1))
    assert np.isfinite(float(loss))


@pytest.mark.parametrize("arch", ALL_IDS)
def test_smoke_train_step(arch, rng):
    cfg = get_smoke(arch)
    run = RunConfig(num_microbatches=2, remat="full", total_steps=10,
                    warmup_steps=2)
    data = SyntheticLM(cfg.vocab_size, 32, 4, seed=1)
    batch = data.microbatched(0, 2)
    if cfg.encoder is not None:
        batch["frames"] = np.random.default_rng(0).normal(
            size=(2, 2, cfg.encoder.n_frames, cfg.d_model)).astype(
                np.float32) * 0.1
    if cfg.n_image_tokens:
        batch["img"] = np.random.default_rng(0).normal(
            size=(2, 2, cfg.n_image_tokens, cfg.d_model)).astype(
                np.float32) * 0.1
    state = init_state(rng, cfg, run)
    step = jax.jit(make_train_step(cfg, run, local_plan()))
    state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"])), f"{arch}: loss NaN"
    assert float(metrics["skipped"]) == 0.0
    assert int(state["step"]) == 1
    # a second step must also be finite (optimizer state exercised)
    state, metrics = step(state, data.microbatched(1, 2) | {
        k: v for k, v in batch.items() if k in ("frames", "img")})
    assert np.isfinite(float(metrics["loss"]))


@pytest.mark.parametrize("arch", ["codeqwen1.5-7b", "mamba2-2.7b",
                                  "hymba-1.5b", "moonshot-v1-16b-a3b",
                                  "whisper-base", "llama-3.2-vision-90b",
                                  "linear-llama3-1b"])
def test_smoke_prefill_decode_parity(arch, rng):
    """prefill + decode == full forward, per family (serving correctness)."""
    cfg = get_smoke(arch)
    params = M.init_params(rng, cfg)
    b, s, sp_ = 2, 24, 16
    tokens = jax.random.randint(rng, (b, s), 0, cfg.vocab_size)
    kw = _aux_inputs(cfg, b, rng)
    full, _ = jax.jit(lambda p, t: M.forward(p, t, cfg, remat="none",
                                             **kw))(params, tokens)
    enc_out = None
    if cfg.encoder is not None:
        enc_out = M.encode(params, kw["enc_frames"], cfg, local_plan())
    lg, cache = jax.jit(lambda p, t: M.prefill(
        p, t, cfg, max_len=s, img_emb=kw.get("img_emb"),
        enc_frames=kw.get("enc_frames")))(params, tokens[:, :sp_])
    np.testing.assert_allclose(
        np.asarray(lg, np.float32), np.asarray(full[:, sp_ - 1], np.float32),
        rtol=3e-2, atol=3e-2)
    step = jax.jit(lambda p, t, c: M.decode_step(
        p, t, c, cfg, img_emb=kw.get("img_emb"), enc_out=enc_out))
    for i in range(sp_, s):
        lg, cache = step(params, tokens[:, i], cache)
        np.testing.assert_allclose(
            np.asarray(lg, np.float32), np.asarray(full[:, i], np.float32),
            rtol=3e-2, atol=3e-2, err_msg=f"{arch} pos {i}")


def test_linearize_variants():
    from repro.configs import get_config
    cfg = get_config("codeqwen1.5-7b", linearize=4)
    mixers = [s.mixer for s in cfg.pattern]
    assert mixers == ["linear", "linear", "linear", "softmax"]
    assert cfg.pattern[3].sliding_window == 2048
    assert cfg.subquadratic
    vlm = get_config("llama-3.2-vision-90b", linearize=4)
    assert [s.mixer for s in vlm.pattern] == \
        ["linear", "linear", "linear", "softmax", "cross"]
