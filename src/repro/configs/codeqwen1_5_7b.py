"""codeqwen1.5-7b — qwen1.5 arch [hf:Qwen/CodeQwen1.5-7B; hf]."""
from repro.configs.base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="codeqwen1.5-7b", family="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=32,
    d_ff=13440, vocab_size=92416,
    qkv_bias=True, rope_theta=1_000_000.0, norm_eps=1e-6,
    pattern=(LayerSpec(mixer="softmax", mlp="dense"),),
    source="[hf:Qwen/CodeQwen1.5-7B; hf]",
)

SMOKE = ModelConfig(
    name="codeqwen1.5-7b-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=160,
    vocab_size=512, qkv_bias=True, rope_theta=1_000_000.0, norm_eps=1e-6,
    pattern=(LayerSpec(mixer="softmax", mlp="dense"),),
)
