"""Backend dispatch for the perf-critical ops.

Model code calls these wrappers; on TPU the Pallas kernels run, elsewhere
(this CPU container, the dry-run) the mathematically-identical XLA path
from ``repro.core`` runs. ``backend="interpret"`` forces Pallas interpret
mode (used by tests). The dispatch is deliberately value-free: same
signatures, same semantics, sub-1e-3 numerical agreement enforced by
``tests/test_kernels.py``.

All three backends of :func:`linear_attention_op` are differentiable:
the XLA path via plain autodiff of ``chunk_scan``, the Pallas paths via
the two-pass backward kernels behind ``lasp2_chunk``'s ``custom_vjp``
(including the data-dependent ``log_a`` gradient and cotangents on the
end-of-chunk ``state`` — what the faithful LASP-2 backward pulls on).
"""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import compat as _compat
from repro.core.linear_attention import (chunk_scan, pick_block,
                                         recurrent_step)
from repro.kernels import flash_attention as _flash
from repro.kernels import lasp2_chunk as _chunk
from repro.kernels import lasp2_decode as _decode

BACKENDS = ("xla", "pallas", "interpret")


def default_backend() -> str:
    return "pallas" if jax.default_backend() == "tpu" else "xla"


def resolve_backend(backend: Optional[str]) -> str:
    """``None`` → platform default; otherwise validate the name."""
    if backend is None:
        return default_backend()
    if backend not in BACKENDS:
        raise ValueError(f"unknown kernel backend {backend!r}; expected "
                         f"one of {BACKENDS}")
    return backend


def linear_attention_op(q, k, v, log_a=None, *, block_size: int = 128,
                        backend: Optional[str] = None):
    """Local chunked decayed causal linear attention (differentiable).

    q, k: (..., S, dk); v: (..., S, dv); log_a: (..., S) or None.
    Returns (o, state (..., dk, dv) fp32, log_decay (...,) fp32).
    """
    backend = resolve_backend(backend)
    *lead, s, dk = q.shape
    dv = v.shape[-1]
    if log_a is None:
        log_a = jnp.zeros((*lead, s), jnp.float32)
    # Block policy is shared with core/lasp2.py (``pick_block``): the
    # preferred block when it divides S, else the largest MXU-aligned
    # divisor. Serving prefill additionally sees arbitrary prompt lengths
    # where no usable divisor exists (e.g. prime S) — rather than
    # degenerating toward 1-token blocks, right-pad to the next block
    # multiple: zero k/v rows add nothing to the state and log_a = 0
    # leaves the decay product alone, so outputs (sliced back to S),
    # final state, and log decay are exact.
    bs = pick_block(s, block_size)
    if bs != s and bs % 32:
        bs = min(block_size, s)
    if s % bs:
        pad = bs - s % bs
        zkv = ((0, 0),) * (q.ndim - 2) + ((0, pad), (0, 0))
        q, k, v = (jnp.pad(x, zkv) for x in (q, k, v))
        log_a = jnp.pad(log_a, ((0, 0),) * (log_a.ndim - 1) + ((0, pad),))
        o, st, ld = linear_attention_op(q, k, v, log_a,
                                        block_size=block_size,
                                        backend=backend)
        return o[..., :s, :], st, ld
    if backend in ("pallas", "interpret"):
        bh = math.prod(lead)
        o, st, ld = _chunk.lasp2_chunk(
            q.reshape(bh, s, dk), k.reshape(bh, s, dk),
            v.reshape(bh, s, dv), log_a.reshape(bh, s),
            bs, backend == "interpret")
        return (o.reshape(*lead, s, dv), st.reshape(*lead, dk, dv),
                ld.reshape(*lead))
    out = chunk_scan(q, k, v, log_a, block_size=bs)
    return out.o, out.state, out.log_decay


def linear_decode_op(q, k, v, log_a, state, log_decay, *,
                     backend: Optional[str] = None):
    """Single-token recurrent linear-attention decode (``mode="decode"``).

    q, k: (B, H, dk); v: (B, H, dv); log_a: (B, H) or None;
    state: (B, H, dk, dv) fp32; log_decay: (B, H) fp32.
    Returns (o (B, H, dv) fp32, state', log_decay') — the constant-memory
    decode path: no prefix re-scan, state updated in place.
    """
    backend = resolve_backend(backend)
    b, h, dk = q.shape
    dv = v.shape[-1]
    if log_a is None:
        log_a = jnp.zeros((b, h), jnp.float32)
    if backend in ("pallas", "interpret"):
        o, st, ld = _decode.lasp2_decode_step(
            q.reshape(b * h, dk), k.reshape(b * h, dk),
            v.reshape(b * h, dv), log_a.reshape(b * h),
            state.reshape(b * h, dk, dv), log_decay.reshape(b * h),
            interpret=(backend == "interpret"))
        return (o.reshape(b, h, dv), st.reshape(b, h, dk, dv),
                ld.reshape(b, h))
    return recurrent_step(q, k, v, log_a, state=state, log_decay=log_decay)


def flash_attention_op(q, k, v, *, causal: bool = True, sliding_window=None,
                       scale=None, backend: Optional[str] = None,
                       block_q: int = 128, block_k: int = 128,
                       q_offset=None):
    """GQA softmax attention (differentiable). q: (B,Hq,S,dh); k/v:
    (B,Hkv,Sk,dh).

    For ``sq != sk`` (prefill-with-cache / ring-decode shapes) queries sit
    at global positions ``(sk - sq) + i`` — the same ``q_offset``
    convention on the Pallas kernel and the XLA mask fallback. Callers
    with a different origin (the LASP-2H rank offset ``t·C``) pass
    ``q_offset`` explicitly; a traced scalar is accepted.

    Awkward (non-block-multiple) ``sq``/``sk`` are right-padded to block
    multiples — mask-safe: padded keys are masked out via the kernel's
    ``kv_len`` and padded query rows are sliced off (their cotangents are
    zeroed by the pad/slice transpose) — so the Pallas path runs on odd
    prompt lengths instead of silently dropping to XLA.
    """
    backend = resolve_backend(backend)
    if _compat.is_tracer(sliding_window):
        backend = "xla"   # dynamic window (hymba stacked layers) → XLA path
    sq, sk = q.shape[2], k.shape[2]
    if q_offset is None:
        q_offset = sk - sq
    if backend in ("pallas", "interpret"):
        bq, bk = min(block_q, sq), min(block_k, sk)
        pad_q, pad_k = -sq % bq, -sk % bk
        if pad_q:
            q = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
        if pad_k:
            k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
            v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        o = _flash.flash_attention(
            q, k, v, causal=causal, sliding_window=sliding_window,
            scale=scale, q_offset=q_offset, kv_len=sk, block_q=bq,
            block_k=bk, interpret=(backend == "interpret"))
        return o[..., :sq, :] if pad_q else o
    # Imported lazily: lasp2h imports core.lasp2 (SPConfig), which in turn
    # dispatches its intra-chunk compute through this module — a top-level
    # import here would close that cycle.
    from repro.core.lasp2h import _softmax_attend, causal_mask
    if scale is None:
        scale = q.shape[-1] ** -0.5
    mask = None
    if causal:
        mask = causal_mask(sq, sk, q_offset=q_offset,
                           sliding_window=sliding_window)[None, None]
    elif sliding_window is not None:
        # Non-causal + window: the kernel applies only the one-sided
        # window bound (no future cutoff) — mirror that here instead of
        # sneaking the causal mask in via causal_mask.
        qpos = q_offset + jnp.arange(sq)[:, None]
        kpos = jnp.arange(sk)[None, :]
        mask = ((qpos - kpos) < sliding_window)[None, None]
    return _softmax_attend(q, k, v, scale=scale, mask=mask)
