"""Pallas TPU kernels: intra-chunk decayed causal linear attention, fwd+bwd.

This is the compute hot-spot of LASP-2 (paper Alg. 2 lines 5–8): each
device's local sequence chunk is processed block-by-block, carrying the
``dk × dv`` memory state in VMEM scratch across the (sequential) block grid
dimension. The cross-device part (the AllGather of chunk states) lives in
``repro.core.lasp2``; this kernel is the per-device "intra" workhorse it
overlaps with.

TPU adaptation of the paper's Triton kernel:

* blocks are ``(BLOCK, dk/dv)`` tiles, MXU-aligned (128 lanes); the three
  matmuls per block (``QK^T``, ``scores·V``, ``K^T V``) hit the MXU with
  fp32 accumulation via ``preferred_element_type``;
* the memory state is fp32 in VMEM *scratch* that persists across the
  sequential grid axis — the HBM↔VMEM traffic per block is just the
  q/k/v/o tiles (the GPU version instead re-materializes through SMEM);
* decay math is log-space fp32; all reweighting factors are <= 1
  (see ``repro.core.linear_attention``).

The backward follows Lightning Attention-2's two-pass scheme, decay
generalized (paper Alg. 4's local lines):

* ``dq`` — a forward-order pass re-carrying the prefix state ``M`` in VMEM
  scratch (``dq_i = dO_i M_i^T``, split into the intra-block score matrix
  and the carried inter-block term);
* ``dk/dv/dlog_a`` — a reverse-order pass (reversed block index maps on
  the sequential grid axis) carrying the *suffix* state gradient
  ``N_j = Σ_{i≥j} e^{L_i−L_j} q_i^T dO_i + e^{L_S−L_j} dM``, seeded with
  the end-of-chunk state cotangent ``dM`` — the faithful SP backward
  (Alg. 4) pulls on both ``o`` *and* ``state``, so the kernel accepts
  both cotangents. The decay gradient uses the log-space identity
  ``∂L/∂log a_m = Σ_{i≥m} (dO_i·o_i − k_i·dk_i) + ⟨state, dM⟩ + dA``
  (suffix-accumulated in scratch; the constant term is added by the
  ``custom_vjp`` wrapper).

:func:`lasp2_chunk` wraps forward+backward in ``jax.custom_vjp`` — this
is what ``repro.kernels.ops.linear_attention_op`` dispatches to, making
the Pallas path trainable end-to-end.

Layout: inputs are flattened to ``(BH, S, d)``; grid = ``(BH, S//BLOCK)``
with ``dimension_semantics=("parallel", "arbitrary")`` so distinct
batch·head programs parallelize across cores while blocks run in order.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import compat as _compat

DEFAULT_BLOCK = 128


def _kernel(q_ref, k_ref, v_ref, la_ref, o_ref, state_ref, ld_ref,
            state_scratch, ld_scratch, *, nblocks: int):
    blk = pl.program_id(1)

    @pl.when(blk == 0)
    def _init():
        state_scratch[...] = jnp.zeros_like(state_scratch)
        ld_scratch[...] = jnp.zeros_like(ld_scratch)

    q = q_ref[0].astype(jnp.float32)          # (C, dk)
    k = k_ref[0].astype(jnp.float32)          # (C, dk)
    v = v_ref[0].astype(jnp.float32)          # (C, dv)
    la = la_ref[0].astype(jnp.float32)        # (C,)

    cb = jnp.cumsum(la)                       # inclusive cumulative log decay
    a_blk = cb[-1]
    c = q.shape[0]
    # D_ij = exp(cb_i - cb_j) for i >= j else 0 — all factors <= 1.
    diff = cb[:, None] - cb[None, :]
    row = jax.lax.broadcasted_iota(jnp.int32, (c, c), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (c, c), 1)
    dmat = jnp.where(row >= col, jnp.exp(jnp.minimum(diff, 0.0)), 0.0)

    scores = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * dmat            # (C, C)
    o_intra = jax.lax.dot_general(
        scores, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)                    # (C, dv)
    # inter (within-device, previous blocks): (q ⊙ b) @ S_carry
    state = state_scratch[...]
    o_inter = jax.lax.dot_general(
        q * jnp.exp(cb)[:, None], state, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    o_ref[0] = (o_intra + o_inter).astype(o_ref.dtype)

    # state update: S <- exp(A) S + (k ⊙ exp(A - cb))^T v
    kw = k * jnp.exp(a_blk - cb)[:, None]
    s_new = jnp.exp(a_blk) * state + jax.lax.dot_general(
        kw, v, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    state_scratch[...] = s_new
    ld_scratch[0, 0] = ld_scratch[0, 0] + a_blk

    @pl.when(blk == nblocks - 1)
    def _finalize():
        state_ref[0] = s_new
        ld_ref[0, 0] = ld_scratch[0, 0]


@functools.partial(jax.jit, static_argnames=("block_size", "interpret"))
def lasp2_chunk_fwd(q, k, v, log_a, *, block_size: int = DEFAULT_BLOCK,
                    interpret: bool = False):
    """Chunked decayed causal linear attention (forward), Pallas TPU.

    q, k: (BH, S, dk); v: (BH, S, dv); log_a: (BH, S).
    Returns (o (BH, S, dv), state (BH, dk, dv) fp32, log_decay (BH,) fp32).
    """
    bh, s, dk = q.shape
    dv = v.shape[-1]
    if s % block_size:
        raise ValueError(f"S={s} must be divisible by block={block_size}")
    nb = s // block_size

    grid = (bh, nb)
    kernel = functools.partial(_kernel, nblocks=nb)
    o, state, ld = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_size, dk), lambda b, t: (b, t, 0)),
            pl.BlockSpec((1, block_size, dk), lambda b, t: (b, t, 0)),
            pl.BlockSpec((1, block_size, dv), lambda b, t: (b, t, 0)),
            pl.BlockSpec((1, block_size), lambda b, t: (b, t)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_size, dv), lambda b, t: (b, t, 0)),
            pl.BlockSpec((1, dk, dv), lambda b, t: (b, 0, 0)),
            pl.BlockSpec((1, 1), lambda b, t: (b, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, s, dv), q.dtype),
            jax.ShapeDtypeStruct((bh, dk, dv), jnp.float32),
            jax.ShapeDtypeStruct((bh, 1), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((dk, dv), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
        ],
        compiler_params=_compat.tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
        name="lasp2_chunk_fwd",
    )(q, k, v, log_a)
    return o, state, ld[:, 0]


# ---------------------------------------------------------------------------
# Backward kernels.
# ---------------------------------------------------------------------------

def _decay_mat(cb):
    """D_ij = exp(cb_i - cb_j) for i >= j else 0 (all factors <= 1)."""
    c = cb.shape[0]
    diff = cb[:, None] - cb[None, :]
    row = jax.lax.broadcasted_iota(jnp.int32, (c, c), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (c, c), 1)
    return jnp.where(row >= col, jnp.exp(jnp.minimum(diff, 0.0)), 0.0)


def _bwd_dq_kernel(k_ref, v_ref, la_ref, do_ref, dq_ref, state_scratch):
    """Forward-order pass: dq_i = dO_i M_i^T, re-carrying the prefix state."""
    blk = pl.program_id(1)

    @pl.when(blk == 0)
    def _init():
        state_scratch[...] = jnp.zeros_like(state_scratch)

    k = k_ref[0].astype(jnp.float32)          # (C, dk)
    v = v_ref[0].astype(jnp.float32)          # (C, dv)
    la = la_ref[0].astype(jnp.float32)        # (C,)
    do = do_ref[0].astype(jnp.float32)        # (C, dv)

    cb = jnp.cumsum(la)
    a_blk = cb[-1]
    dmat = _decay_mat(cb)
    # intra: dq_i += sum_{j<=i} e^{cb_i-cb_j} (dO_i·v_j) k_j
    dsc = jax.lax.dot_general(
        do, v, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * dmat            # (C, C)
    dq_intra = jax.lax.dot_general(
        dsc, k, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)                    # (C, dk)
    # inter: dq_i += e^{cb_i} dO_i M_prev^T
    state = state_scratch[...]
    dq_inter = jax.lax.dot_general(
        do, state, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * jnp.exp(cb)[:, None]
    dq_ref[0] = (dq_intra + dq_inter).astype(dq_ref.dtype)

    # same carry update as the forward: M <- e^A M + (k ⊙ e^{A-cb})^T v
    kw = k * jnp.exp(a_blk - cb)[:, None]
    state_scratch[...] = jnp.exp(a_blk) * state + jax.lax.dot_general(
        kw, v, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)


def _bwd_dkv_kernel(q_ref, k_ref, v_ref, la_ref, do_ref, o_ref, dstate_ref,
                    dk_ref, dv_ref, dla_ref, dstate_scratch, r_scratch):
    """Reverse-order pass carrying the suffix dstate N (+ suffix decay-grad
    scalar). Block index maps are reversed, so program 0 sees the LAST
    sequence block and N is seeded with the state cotangent ``dM``."""
    blk = pl.program_id(1)

    @pl.when(blk == 0)
    def _init():
        dstate_scratch[...] = dstate_ref[0].astype(jnp.float32)
        r_scratch[0, 0] = jnp.float32(0.0)

    q = q_ref[0].astype(jnp.float32)          # (C, dk)
    k = k_ref[0].astype(jnp.float32)          # (C, dk)
    v = v_ref[0].astype(jnp.float32)          # (C, dv)
    la = la_ref[0].astype(jnp.float32)        # (C,)
    do = do_ref[0].astype(jnp.float32)        # (C, dv)
    o = o_ref[0].astype(jnp.float32)          # (C, dv)

    cb = jnp.cumsum(la)
    a_blk = cb[-1]
    dmat = _decay_mat(cb)
    w = jnp.exp(a_blk - cb)                    # e^{A - cb_j} <= 1
    n = dstate_scratch[...]                    # (dk, dv) suffix dstate

    # dk_j = sum_{i>=j} e^{cb_i-cb_j}(dO_i·v_j) q_i + w_j (N v_j)
    dsc = jax.lax.dot_general(
        do, v, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * dmat            # (C, C)
    dk = jax.lax.dot_general(
        dsc, q, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)                    # (C, dk)
    dk = dk + w[:, None] * jax.lax.dot_general(
        v, n, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)
    # dv_j = sum_{i>=j} e^{cb_i-cb_j}(q_i·k_j) dO_i + w_j (N^T k_j)
    sc = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * dmat             # (C, C)
    dv = jax.lax.dot_general(
        sc, do, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)                    # (C, dv)
    dv = dv + w[:, None] * jax.lax.dot_general(
        k, n, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    dk_ref[0] = dk.astype(dk_ref.dtype)
    dv_ref[0] = dv.astype(dv_ref.dtype)

    # decay grad: dlog_a_m = Σ_{i>=m} r_i (suffix over the whole sequence),
    # r_i = dO_i·o_i − k_i·dk_i; in-block inclusive suffix cumsum + the
    # carried sum over later blocks.
    r = jnp.sum(do * o, axis=-1) - jnp.sum(k * dk, axis=-1)   # (C,)
    suffix = jnp.sum(r) - jnp.cumsum(r) + r
    dla_ref[0] = suffix + r_scratch[0, 0]
    r_scratch[0, 0] = r_scratch[0, 0] + jnp.sum(r)

    # carry to the previous block: N' = e^A N + sum_i e^{cb_i} q_i^T dO_i
    qw = q * jnp.exp(cb)[:, None]
    dstate_scratch[...] = jnp.exp(a_blk) * n + jax.lax.dot_general(
        qw, do, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("block_size", "interpret"))
def lasp2_chunk_bwd(q, k, v, log_a, o, do, dstate, *,
                    block_size: int = DEFAULT_BLOCK, interpret: bool = False):
    """Backward of :func:`lasp2_chunk_fwd` wrt (q, k, v, log_a).

    ``o`` is the saved forward output; ``do``/``dstate`` are the cotangents
    of the output and the end-of-chunk state. Returns
    ``(dq, dk, dv, dla_partial)`` where ``dla_partial`` still needs the
    constant ``⟨state, dM⟩ + dA`` term (added by the custom_vjp wrapper,
    which owns the ``state``/``log_decay`` residuals).
    """
    bh, s, dk = q.shape
    dv = v.shape[-1]
    if s % block_size:
        raise ValueError(f"S={s} must be divisible by block={block_size}")
    nb = s // block_size

    fwd_order = lambda b, t: (b, t, 0)
    rev_order = lambda b, t: (b, nb - 1 - t, 0)

    dq = pl.pallas_call(
        _bwd_dq_kernel,
        grid=(bh, nb),
        in_specs=[
            pl.BlockSpec((1, block_size, dk), fwd_order),
            pl.BlockSpec((1, block_size, dv), fwd_order),
            pl.BlockSpec((1, block_size), lambda b, t: (b, t)),
            pl.BlockSpec((1, block_size, dv), fwd_order),
        ],
        out_specs=pl.BlockSpec((1, block_size, dk), fwd_order),
        out_shape=jax.ShapeDtypeStruct((bh, s, dk), q.dtype),
        scratch_shapes=[pltpu.VMEM((dk, dv), jnp.float32)],
        compiler_params=_compat.tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
        name="lasp2_chunk_bwd_dq",
    )(k, v, log_a, do)

    dk_out, dv_out, dla = pl.pallas_call(
        _bwd_dkv_kernel,
        grid=(bh, nb),
        in_specs=[
            pl.BlockSpec((1, block_size, dk), rev_order),
            pl.BlockSpec((1, block_size, dk), rev_order),
            pl.BlockSpec((1, block_size, dv), rev_order),
            pl.BlockSpec((1, block_size), lambda b, t: (b, nb - 1 - t)),
            pl.BlockSpec((1, block_size, dv), rev_order),
            pl.BlockSpec((1, block_size, dv), rev_order),
            pl.BlockSpec((1, dk, dv), lambda b, t: (b, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_size, dk), rev_order),
            pl.BlockSpec((1, block_size, dv), rev_order),
            pl.BlockSpec((1, block_size), lambda b, t: (b, nb - 1 - t)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, s, dk), k.dtype),
            jax.ShapeDtypeStruct((bh, s, dv), v.dtype),
            jax.ShapeDtypeStruct((bh, s), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((dk, dv), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
        ],
        compiler_params=_compat.tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
        name="lasp2_chunk_bwd_dkv",
    )(q, k, v, log_a, do, o, dstate)
    return dq, dk_out, dv_out, dla


# ---------------------------------------------------------------------------
# Differentiable entry point (custom_vjp over the two Pallas passes).
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5))
def lasp2_chunk(q, k, v, log_a, block_size=DEFAULT_BLOCK, interpret=False):
    """Trainable chunked decayed causal linear attention (Pallas).

    Same signature/returns as :func:`lasp2_chunk_fwd`, but differentiable:
    ``jax.grad`` dispatches to the two-pass backward kernels. All three
    outputs ``(o, state, log_decay)`` accept cotangents — the faithful SP
    backward (paper Alg. 4) pulls on both ``o`` and ``state``.
    """
    return lasp2_chunk_fwd(q, k, v, log_a, block_size=block_size,
                           interpret=interpret)


def _chunk_vjp_fwd(q, k, v, log_a, block_size, interpret):
    o, state, ld = lasp2_chunk_fwd(q, k, v, log_a, block_size=block_size,
                                   interpret=interpret)
    return (o, state, ld), (q, k, v, log_a, o, state)


def _chunk_vjp_bwd(block_size, interpret, res, cots):
    q, k, v, log_a, o, state = res
    do, dstate, dld = cots
    dq, dk, dv, dla = lasp2_chunk_bwd(
        q, k, v, log_a, o, do, dstate.astype(jnp.float32),
        block_size=block_size, interpret=interpret)
    # ∂L/∂log_a_m also carries the end-of-chunk terms ⟨state, dM⟩ + dA,
    # identical for every position m (they sit behind the full decay chain).
    const = (jnp.einsum("bkv,bkv->b", state, dstate.astype(jnp.float32))
             + dld.astype(jnp.float32))
    dla = (dla + const[:, None]).astype(log_a.dtype)
    return dq, dk, dv, dla


lasp2_chunk.defvjp(_chunk_vjp_fwd, _chunk_vjp_bwd)
