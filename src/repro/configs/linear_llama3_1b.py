"""Linear-Llama3-1B — the paper's own evaluation model (paper §4).

Llama3-style 1B: 16 layers, d_model=2048, 16 heads. The paper replaces
softmax attention with linear attention modules (basic / lightning /
retention / GLA / based / rebased); ``CONFIG`` is the pure-linear basic
variant, ``HYBRID`` the 1/4 hybrid, ``DENSE`` the softmax baseline.

Deviation noted in DESIGN.md: the paper keeps a per-head state of
d x d (full hidden); we use the standard per-head d_h x d_h state — the
sequence-length-independence of the AllGather is unchanged.
"""
import dataclasses

from repro.configs.base import LayerSpec, LinearAttnConfig, ModelConfig

DENSE = ModelConfig(
    name="llama3-1b", family="dense",
    n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=5504, vocab_size=128256,
    rope_theta=500000.0, norm_eps=1e-5,
    pattern=(LayerSpec(mixer="softmax", mlp="dense"),),
    source="[paper §4 Linear-Llama3; arXiv Llama-3 herd]",
)

CONFIG = dataclasses.replace(
    DENSE.linearize(), name="linear-llama3-1b",
    linear_attn=LinearAttnConfig(feature_map="identity", decay="none",
                                 backward="faithful"))

HYBRID = dataclasses.replace(
    DENSE.linearize(hybrid_every=4), name="linear-llama3-1b-hybrid4",
    linear_attn=LinearAttnConfig(feature_map="identity", decay="none",
                                 backward="faithful"))

SMOKE = ModelConfig(
    name="linear-llama3-1b-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=160,
    vocab_size=512,
    pattern=(LayerSpec(mixer="linear", mlp="dense"),),
    linear_attn=LinearAttnConfig(feature_map="identity", decay="none"),
)
