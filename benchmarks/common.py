"""Shared benchmark utilities.

Wall-clock numbers on this CPU container are *indicative* (the TPU is the
target, not the runtime); every bench therefore also derives the analytic
quantity the paper's table is actually about (loss, comm steps, traffic,
memory). Multi-device timing benches run in subprocesses with 8 virtual
host devices so the main process keeps its single default device.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
import time

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


def timeit(fn, *args, iters=5, warmup=2):
    for _ in range(warmup):
        out = fn(*args)
    _block(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    _block(out)
    return (time.perf_counter() - t0) / iters


def percentile(xs, p):
    """Nearest-rank percentile of a non-empty list — shared with the
    bench subprocess payloads (run_subprocess_bench puts the repo root on
    the subprocess path) so the median/p90 policy lives in one place."""
    xs = sorted(xs)
    idx = min(len(xs) - 1, max(0, int(round(p / 100 * (len(xs) - 1)))))
    return xs[idx]


def write_bench_json(name: str, payload) -> str:
    """Write BENCH_<name>.json at the repo root — the machine-readable
    artifact CI uploads so the perf trajectory is tracked across PRs.
    ``payload``: dict (preferred: {"rows": [...], ...stats}) or a list of
    (name, us_per_call, derived) CSV rows."""
    if not isinstance(payload, dict):
        payload = {"rows": [
            {"name": n, "us_per_call": us, "derived": derived}
            for n, us, derived in payload]}
    payload = dict(payload)
    payload.setdefault("bench", name)
    payload.setdefault("schema_version", 1)
    path = os.path.join(ROOT, f"BENCH_{name}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"# wrote {os.path.relpath(path, ROOT)}", file=sys.stderr)
    return path


def telemetry_block(*, phases=None, model_flops_per_call=None,
                    wall_s=None, n_devices=1,
                    expected_collective_bytes=None,
                    measured_collective_bytes=None, **extra) -> dict:
    """Assemble the optional ``telemetry`` block a bench attaches to its
    BENCH_*.json payload (docs/observability.md): phase wall breakdown,
    achieved MFU (``model_flops_per_call / wall_s`` against
    ``n_devices × PEAK_FLOPS``), and the expected (CommRecord tape) vs
    measured (compiled HLO) collective bytes.

    Informational for now: scripts/bench_gate.py ignores metrics absent
    from the stored baseline, so adding this block changes no gate
    verdict — once baselines are refreshed the byte fields start gating
    as traffic (any increase fails)."""
    t = dict(extra)
    if phases:
        t["phases"] = {k: float(v) for k, v in phases.items()}
    if wall_s is not None:
        t["wall_s"] = float(wall_s)
    if model_flops_per_call and wall_s:
        from repro.launch.hlo_analysis import PEAK_FLOPS
        achieved = model_flops_per_call / wall_s
        t["achieved_flops"] = achieved
        t["mfu"] = achieved / (PEAK_FLOPS * max(n_devices, 1))
    if expected_collective_bytes is not None:
        t["expected_collective_bytes"] = float(expected_collective_bytes)
    if measured_collective_bytes is not None:
        t["measured_collective_bytes"] = float(measured_collective_bytes)
        if expected_collective_bytes:
            t["measured_over_expected"] = \
                float(measured_collective_bytes) / expected_collective_bytes
    return t


def _block(out):
    import jax
    jax.tree.map(lambda x: x.block_until_ready()
                 if hasattr(x, "block_until_ready") else x, out)


def run_subprocess_bench(code: str, *, devices: int = 8,
                         timeout: int = 1200) -> dict:
    """Run `code` (which must print a JSON dict on its last line) in a
    subprocess with N virtual devices."""
    prelude = (
        "import os\n"
        f"os.environ['XLA_FLAGS'] = "
        f"'--xla_force_host_platform_device_count={devices}'\n"
        f"import sys; sys.path.insert(0, {SRC!r})\n"
        f"sys.path.insert(0, {ROOT!r})\n")   # benchmarks.common importable
    proc = subprocess.run([sys.executable, "-c", prelude + code],
                          capture_output=True, text=True, timeout=timeout)
    if proc.returncode != 0:
        raise RuntimeError(f"bench subprocess failed:\n{proc.stderr[-2000:]}")
    return json.loads(proc.stdout.strip().splitlines()[-1])


def emit(rows, header=None):
    """Print CSV rows: name,us_per_call,derived."""
    if header:
        print(header)
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
