# NOTE: no XLA_FLAGS here on purpose — smoke tests and benches must see the
# default single device. Multi-device tests run in subprocesses
# (tests/test_distributed.py) and the dry-run sets its own 512-device flag.
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import pytest


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)
