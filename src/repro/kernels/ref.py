"""Pure-jnp oracles for the Pallas kernels (the ``ref.py`` contract).

These are *independent* re-derivations (no shared code with the kernels'
internals beyond jnp), used by the per-kernel allclose sweeps in
``tests/test_kernels.py``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def linear_attention_ref(q, k, v, log_a=None):
    """Decayed causal linear attention, O(S²) direct form. fp32 math.

    q, k: (BH, S, dk); v: (BH, S, dv); log_a: (BH, S) or None.
    Returns (o (BH, S, dv), final_state (BH, dk, dv) fp32).
    """
    bh, s, dk = q.shape
    dv = v.shape[-1]
    qf, kf, vf = (x.astype(jnp.float32) for x in (q, k, v))
    if log_a is None:
        log_a = jnp.zeros((bh, s), jnp.float32)
    cb = jnp.cumsum(log_a.astype(jnp.float32), axis=-1)
    diff = cb[:, :, None] - cb[:, None, :]
    mask = jnp.tril(jnp.ones((s, s), bool))
    d = jnp.where(mask[None], jnp.exp(jnp.minimum(diff, 0.0)), 0.0)
    scores = jnp.einsum("bik,bjk->bij", qf, kf) * d
    o = jnp.einsum("bij,bjv->biv", scores, vf)
    w = jnp.exp(cb[:, -1:] - cb)                      # decay i -> end
    state = jnp.einsum("bsk,bsv->bkv", kf * w[..., None], vf)
    return o.astype(q.dtype), state


def flash_attention_ref(q, k, v, *, causal=True, sliding_window=None,
                        scale=None):
    """GQA softmax attention, direct form. q: (B,Hq,S,dh), k/v: (B,Hkv,S,dh)."""
    if scale is None:
        scale = q.shape[-1] ** -0.5
    b, hq, sq, dh = q.shape
    hkv = k.shape[1]
    rep = hq // hkv
    kf = jnp.repeat(k, rep, axis=1).astype(jnp.float32)
    vf = jnp.repeat(v, rep, axis=1).astype(jnp.float32)
    s = jnp.einsum("bhsd,bhtd->bhst", q.astype(jnp.float32), kf) * scale
    if causal or sliding_window is not None:
        # Query row i is at global position (sk - sq) + i — the shared
        # q_offset convention (kernels/ops.py) for sq != sk shapes.
        qpos = (k.shape[2] - sq) + jnp.arange(sq)[:, None]
        kpos = jnp.arange(k.shape[2])[None, :]
        m = jnp.ones_like(s, bool)
        if causal:
            m &= (qpos >= kpos)[None, None]
        if sliding_window is not None:
            m &= ((qpos - kpos) < sliding_window)[None, None]
        s = jnp.where(m, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhst,bhtd->bhsd", p, vf)
    return o.astype(q.dtype)
