"""Bench-regression gate: flattening, classification, pass/fail rules."""

import importlib.util
import json
import os
import subprocess
import sys

SCRIPT = os.path.join(os.path.dirname(__file__), "..", "scripts",
                      "bench_gate.py")

spec = importlib.util.spec_from_file_location("bench_gate", SCRIPT)
bench_gate = importlib.util.module_from_spec(spec)
spec.loader.exec_module(bench_gate)


BASE = {
    "bench": "comm",
    "cases": [
        {"name": "lasp2_allgather",
         "wall": {"median_us": 90000.0, "p90_us": 110000.0},
         "comm": {"all-gather": 917728, "all-gather_count": 1,
                  "total_bytes": 917728},
         "hlo_collectives": {"all-gather": 1}},
    ],
}


def _mutate(**kw):
    cur = json.loads(json.dumps(BASE))
    case = cur["cases"][0]
    for path, val in kw.items():
        d = case
        *heads, leaf = path.split(".")
        for h in heads:
            d = d[h]
        d[leaf] = val
    return cur


def _gate(cur, **kw):
    kw.setdefault("wall_tol", 0.25)
    kw.setdefault("wall_floor_us", 1000.0)
    kw.setdefault("allow_missing", False)
    return bench_gate.gate_one("comm", BASE, cur, **kw)


def test_flatten_matches_rows_by_name():
    flat = bench_gate._flatten(BASE)
    assert flat["cases/lasp2_allgather/wall/median_us"] == 90000.0
    assert flat["cases/lasp2_allgather/comm/total_bytes"] == 917728


def test_flatten_duplicate_names_do_not_collide():
    obj = {"cases": [{"name": "x", "v": 1}, {"name": "x", "v": 2},
                     {"name": "x", "v": 3}]}
    flat = bench_gate._flatten(obj)
    assert flat == {"cases/x/v": 1.0, "cases/x#1/v": 2.0,
                    "cases/x#2/v": 3.0}


def test_identical_passes():
    fails, checked = _gate(json.loads(json.dumps(BASE)))
    assert not fails
    assert checked >= 4   # median, bytes, count, hlo count


def test_small_wall_regression_passes_large_fails():
    fails, _ = _gate(_mutate(**{"wall.median_us": 90000.0 * 1.2}))
    assert not fails
    fails, _ = _gate(_mutate(**{"wall.median_us": 90000.0 * 1.3}))
    assert fails and "wall-time regression" in fails[0]


def test_wall_improvement_passes():
    fails, _ = _gate(_mutate(**{"wall.median_us": 100.0}))
    assert not fails


def test_any_byte_increase_fails():
    fails, _ = _gate(_mutate(**{"comm.total_bytes": 917729}))
    assert fails and "collective increase" in fails[0]


def test_collective_count_increase_fails():
    fails, _ = _gate(_mutate(**{"hlo_collectives.all-gather": 2}))
    assert fails


def test_missing_metric_fails_unless_allowed():
    cur = json.loads(json.dumps(BASE))
    del cur["cases"][0]["comm"]
    fails, _ = _gate(cur)
    assert any("missing" in f for f in fails)
    fails, _ = _gate(cur, allow_missing=True)
    assert not fails


def test_cli_end_to_end(tmp_path):
    basedir = tmp_path / "baselines"
    curdir = tmp_path / "cur"
    basedir.mkdir()
    curdir.mkdir()
    (basedir / "BENCH_comm.json").write_text(json.dumps(BASE))
    (curdir / "BENCH_comm.json").write_text(
        json.dumps(_mutate(**{"comm.total_bytes": 10 ** 9})))
    proc = subprocess.run(
        [sys.executable, SCRIPT, "--baseline-dir", str(basedir),
         "--current-dir", str(curdir)],
        capture_output=True, text=True)
    assert proc.returncode == 1
    assert "collective increase" in proc.stdout

    # required bench absent from the current run → fail
    (curdir / "BENCH_comm.json").unlink()
    proc = subprocess.run(
        [sys.executable, SCRIPT, "--baseline-dir", str(basedir),
         "--current-dir", str(curdir), "--require", "comm"],
        capture_output=True, text=True)
    assert proc.returncode == 1

    # --update then gate → clean pass
    (curdir / "BENCH_comm.json").write_text(json.dumps(BASE))
    subprocess.run(
        [sys.executable, SCRIPT, "--baseline-dir", str(basedir),
         "--current-dir", str(curdir), "--update"], check=True)
    proc = subprocess.run(
        [sys.executable, SCRIPT, "--baseline-dir", str(basedir),
         "--current-dir", str(curdir)],
        capture_output=True, text=True)
    assert proc.returncode == 0, proc.stdout
