"""Config registry sanity + HLO-analysis unit tests."""

import numpy as np
import pytest

from repro.configs import ALL_IDS, ARCH_IDS, get_config, get_smoke
from repro.configs.base import SHAPES
from repro.launch import hlo_analysis as H
from repro.launch.mesh import DATA_AXIS, SEQ_AXIS

EXPECT_B = {"codeqwen1.5-7b": 7.2, "qwen1.5-110b": 111, "granite-34b": 34,
            "starcoder2-15b": 15, "hymba-1.5b": 1.5, "mamba2-2.7b": 2.7,
            "llama-3.2-vision-90b": 88, "moonshot-v1-16b-a3b": 29,
            "phi3.5-moe-42b-a6.6b": 42, "whisper-base": 0.072,
            "linear-llama3-1b": 1.3}


def test_registry_complete():
    assert len(ARCH_IDS) == 10
    assert len(SHAPES) == 4          # 40 cells
    for a in ALL_IDS:
        cfg = get_config(a)
        assert cfg.padded_vocab % 128 == 0
        assert get_smoke(a).param_count() < 5e6


@pytest.mark.parametrize("arch", ALL_IDS)
def test_param_counts_in_band(arch):
    n = get_config(arch).param_count() / 1e9
    lo, hi = 0.55 * EXPECT_B[arch], 1.5 * EXPECT_B[arch]
    assert lo <= n <= hi, f"{arch}: {n:.2f}B outside [{lo:.1f},{hi:.1f}]"


def test_exact_assigned_dims():
    c = get_config("qwen1.5-110b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab_size) == (80, 8192, 64, 8, 49152, 152064)
    assert c.qkv_bias
    m = get_config("mamba2-2.7b")
    assert (m.n_layers, m.d_model, m.mamba.d_state) == (64, 2560, 128)
    assert m.d_ff == 0
    h = get_config("hymba-1.5b")
    assert (h.d_model, h.n_heads, h.n_kv_heads, h.vocab_size,
            h.mamba.d_state) == (1600, 25, 5, 32001, 16)
    mo = get_config("moonshot-v1-16b-a3b")
    assert (mo.moe.num_experts, mo.moe.top_k) == (64, 6)
    ph = get_config("phi3.5-moe-42b-a6.6b")
    assert (ph.moe.num_experts, ph.moe.top_k) == (16, 2)


# --- HLO analysis unit tests -------------------------------------------------

FAKE_HLO = """
ENTRY %main {
  %ag = f32[8,2,4,32,64]{4,3,2,1,0} all-gather(%x), channel_id=1, replica_groups=[16,16]<=[256], dimensions={0}
  %ar = bf16[1024]{0} all-reduce(%y), replica_groups={{0,1,2,3}}, to_apply=%sum
  %rs = f32[128]{0} reduce-scatter(%z), replica_groups=[32,8]<=[256], dimensions={0}
  %cp = bf16[64,64]{1,0} collective-permute(%w), source_target_pairs={{0,1}}
  %ags = (f32[4,8], f32[32,8]) all-gather-start(%v), replica_groups=[2,8]<=[16], dimensions={0}
  %agd = f32[32,8] all-gather-done(%ags)
}
"""


def test_parse_collectives():
    colls = H.parse_collectives(FAKE_HLO, 256)
    ops = sorted(c.op for c in colls)
    assert ops == ["all-gather", "all-gather", "all-reduce",
                   "collective-permute", "reduce-scatter"]
    ag = next(c for c in colls if c.op == "all-gather"
              and c.result_bytes == 8 * 2 * 4 * 32 * 64 * 4)
    assert ag.group_size == 16
    ar = next(c for c in colls if c.op == "all-reduce")
    assert ar.result_bytes == 1024 * 2 and ar.group_size == 4
    # start op: tuple type → only the result half counted
    ags = next(c for c in colls if c.op == "all-gather"
               and c.group_size == 8)
    assert ags.result_bytes == (4 * 8 + 32 * 8) * 4 // 2


def test_traffic_model():
    c = H.Collective("all-reduce", 1000, 4)
    assert abs(c.traffic_bytes - 2 * 3 / 4 * 1000) < 1e-9
    c = H.Collective("all-gather", 1600, 16)
    assert abs(c.traffic_bytes - 15 / 16 * 1600) < 1e-9
    c = H.Collective("reduce-scatter", 100, 8)
    assert abs(c.traffic_bytes - 700) < 1e-9


def test_cost_vector_algebra():
    a = H.CostVector(10, 20, 5, {"all-gather": 5})
    b = H.CostVector(1, 2, 1, {"all-gather": 1})
    c = (a - b).scale(3) + b
    assert c.flops == 28 and c.hbm_bytes == 56
    assert c.coll_by_op["all-gather"] == 13


def test_roofline_terms_dominance():
    t = H.roofline_terms(H.CostVector(
        flops=H.PEAK_FLOPS, hbm_bytes=H.HBM_BW * 2, coll_bytes=H.ICI_BW))
    assert t["dominant"] == "memory"
    np.testing.assert_allclose(t["compute_s"], 1.0)
    np.testing.assert_allclose(t["memory_s"], 2.0)
    np.testing.assert_allclose(t["collective_s"], 1.0)


def test_cost_extrapolation_recovers_linear_model():
    """The roofline's c0 + A(c1 + G·c2) solve is exact for linear costs."""
    c0, c1, c2 = (H.CostVector(5, 7, 1, {}), H.CostVector(11, 3, 2, {}),
                  H.CostVector(2, 9, 4, {}))
    f = lambda a, g: c0 + (c1 + c2.scale(g)).scale(a)
    f11, f12, f21 = f(1, 1), f(1, 2), f(2, 1)
    c2_ = f12 - f11
    c1_ = (f21 - f11) - c2_
    c0_ = f11 - c1_ - c2_
    got = c0_ + (c1_ + c2_.scale(88)).scale(16)
    want = f(16, 88)
    np.testing.assert_allclose(got.flops, want.flops)
    np.testing.assert_allclose(got.hbm_bytes, want.hbm_bytes)
    np.testing.assert_allclose(got.coll_bytes, want.coll_bytes)


# --- per-axis replica-group classification (2D DP×SP budgets) --------------

class _FakeDev:
    def __init__(self, i):
        self.id = i


class _FakeMesh:
    """Stands in for a (2, 4) (data, sequence) mesh: device (d, s) has
    global id d*4 + s (row-major, as make_training_mesh lays out)."""

    axis_names = (DATA_AXIS, SEQ_AXIS)

    @property
    def devices(self):
        return np.array([[_FakeDev(d * 4 + s) for s in range(4)]
                         for d in range(2)])


def test_parse_replica_groups_explicit_and_iota():
    assert H.parse_replica_groups(
        "x = f32[2] all-reduce(y), replica_groups={{0,1,2,3},{4,5,6,7}}"
    ) == [[0, 1, 2, 3], [4, 5, 6, 7]]
    assert H.parse_replica_groups(
        "x = f32[2] all-reduce(y), replica_groups=[2,4]<=[8]"
    ) == [[0, 1, 2, 3], [4, 5, 6, 7]]
    # transposed iota: [4,2]<=[2,4]T(1,0) -> columns of the (2,4) layout
    assert H.parse_replica_groups(
        "x = f32[2] all-reduce(y), replica_groups=[4,2]<=[2,4]T(1,0)"
    ) == [[0, 4], [1, 5], [2, 6], [3, 7]]
    assert H.parse_replica_groups("x = f32[2] add(y)") is None
    # XLA's all-devices spellings: absent attribute OR empty braces
    assert H.parse_replica_groups(
        "x = f32[2] all-reduce(y), replica_groups={}, to_apply=%add"
    ) is None
    # collective-permute: source_target_pairs, each pair a 2-device group
    assert H.parse_replica_groups(
        "x = f32[2] collective-permute(y), "
        "source_target_pairs={{0,1},{1,2},{2,3},{3,0}}"
    ) == [[0, 1], [1, 2], [2, 3], [3, 0]]


def test_permute_axis_classification():
    # a ring strictly inside the sequence axis of the (2,4) mesh must NOT
    # be attributed to the data axis
    mesh = _FakeMesh()
    ring = [[0, 1], [1, 2], [2, 3], [3, 0], [4, 5], [5, 6], [6, 7], [7, 4]]
    assert H.group_axes(ring, mesh) == (SEQ_AXIS,)
    hlo = ("%cp = f32[4] collective-permute(f32[4] %p), "
           "source_target_pairs={{0,1},{1,2},{2,3},{3,0}}")
    counts = H.collective_axis_counts(hlo, mesh)
    assert counts == {("collective-permute", (SEQ_AXIS,)): 1}


def test_group_axes_classification():
    mesh = _FakeMesh()
    assert H.group_axes([[0, 1, 2, 3], [4, 5, 6, 7]], mesh) == (SEQ_AXIS,)
    assert H.group_axes([[0, 4], [1, 5], [2, 6], [3, 7]], mesh) \
        == (DATA_AXIS,)
    assert H.group_axes([[0, 1, 2, 3, 4, 5, 6, 7]], mesh) \
        == (DATA_AXIS, SEQ_AXIS)
    # no replica_groups attribute == every non-trivial axis
    assert H.group_axes(None, mesh) == (DATA_AXIS, SEQ_AXIS)


def test_collective_axis_counts_end_to_end():
    hlo = """
HloModule m
  %ag = (f32[1], f32[8]) all-gather-start(f32[1] %p), replica_groups={{0,1,2,3},{4,5,6,7}}, dimensions={0}
  %ar = f32[4] all-reduce(f32[4] %q), replica_groups={{0,1,2,3,4,5,6,7}}, to_apply=%add
  %zg = f32[16] all-gather(f32[8] %r), replica_groups={{0,4},{1,5},{2,6},{3,7}}, dimensions={0}
"""
    counts = H.collective_axis_counts(hlo, _FakeMesh())
    assert counts[("all-gather", (SEQ_AXIS,))] == 1
    assert counts[("all-reduce", (DATA_AXIS, SEQ_AXIS))] == 1
    assert counts[("all-gather", (DATA_AXIS,))] == 1
