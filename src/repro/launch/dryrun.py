import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# The two lines above MUST run before any other import (jax locks the
# device count at first initialization). Everything else follows.

import argparse          # noqa: E402
import json              # noqa: E402
import subprocess        # noqa: E402
import sys               # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402

"""Multi-pod dry-run: prove every (architecture × shape × mesh) cell
lowers AND compiles on the production meshes, and record the per-device
memory/cost/collective evidence for EXPERIMENTS.md §Dry-run.

Usage:
  python -m repro.launch.dryrun --arch codeqwen1.5-7b --shape train_4k
  python -m repro.launch.dryrun --arch ... --shape ... --multi-pod
  python -m repro.launch.dryrun --all [--multi-pod] [--jobs-file f.json]

``--all`` drives each cell in a fresh subprocess (compile-state isolation;
one cell's failure cannot poison the next) and aggregates JSON results
under results/dryrun/.
"""


def run_one(arch: str, shape_name: str, multi_pod: bool, out_dir: str):
    from repro.launch import hlo_analysis as H
    from repro.launch.cells import build_cell
    from repro.launch.mesh import make_production_mesh

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.size
    rec = {"arch": arch, "shape": shape_name,
           "mesh": "2x16x16" if multi_pod else "16x16",
           "devices": n_dev, "status": "building"}
    t0 = time.time()
    try:
        cell = build_cell(arch, shape_name, mesh)
        rec["note"] = cell.note
        rec["config_name"] = cell.cfg.name
        rec["params_b"] = cell.cfg.param_count() / 1e9
        rec["num_microbatches"] = cell.run.num_microbatches
        lowered = cell.lower()
        rec["lower_s"] = round(time.time() - t0, 1)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 1)
        rec["status"] = "ok"
        rec["memory"] = H.memory_report(compiled)
        from repro.core.compat import cost_analysis
        ca = cost_analysis(compiled)
        rec["cost"] = {"flops": float(ca.get("flops", 0.0)),
                       "bytes_accessed": float(ca.get("bytes accessed",
                                                      0.0))}
        colls = H.parse_collectives(compiled.as_text(), n_dev)
        rec["collectives"] = H.collective_summary(colls)
        print(f"[dryrun] {arch} x {shape_name} x {rec['mesh']}: OK "
              f"(lower {rec['lower_s']}s, compile {rec['compile_s']}s)")
        print(f"  memory_analysis: {compiled.memory_analysis()}")
        print(f"  cost_analysis: flops={rec['cost']['flops']:.3e} "
              f"bytes={rec['cost']['bytes_accessed']:.3e}")
        print(f"  collectives: { {k: round(v/1e6, 2) for k, v in rec['collectives'].items() if not k.endswith('_count')} } MB")
    except Exception as e:  # noqa: BLE001 — record, don't crash the sweep
        rec["status"] = "fail"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
        print(f"[dryrun] {arch} x {shape_name} x {rec['mesh']}: FAIL {e}",
              file=sys.stderr)
    rec["total_s"] = round(time.time() - t0, 1)
    os.makedirs(out_dir, exist_ok=True)
    tag = f"{arch}__{shape_name}__{rec['mesh']}".replace("/", "_")
    with open(os.path.join(out_dir, tag + ".json"), "w") as f:
        json.dump(rec, f, indent=1, default=str)
    return rec["status"] == "ok"


def run_all(multi_pod: bool, out_dir: str, archs=None, shapes=None,
            timeout: int = 3600):
    """Spawn one subprocess per cell (isolation + bounded memory)."""
    from repro.configs import ARCH_IDS
    from repro.configs.base import SHAPES
    archs = archs or ARCH_IDS
    shapes = shapes or list(SHAPES)
    results = {}
    for arch in archs:
        for shape in shapes:
            tag = f"{arch}__{shape}__{'2x16x16' if multi_pod else '16x16'}"
            path = os.path.join(out_dir, tag.replace("/", "_") + ".json")
            if os.path.exists(path):
                with open(path) as f:
                    if json.load(f).get("status") == "ok":
                        results[tag] = "cached"
                        continue
            cmd = [sys.executable, "-m", "repro.launch.dryrun",
                   "--arch", arch, "--shape", shape, "--out", out_dir]
            if multi_pod:
                cmd.append("--multi-pod")
            try:
                proc = subprocess.run(cmd, timeout=timeout,
                                      capture_output=True, text=True)
                ok = proc.returncode == 0
            except subprocess.TimeoutExpired:
                ok = False
                with open(path, "w") as f:
                    json.dump({"arch": arch, "shape": shape,
                               "status": "timeout"}, f)
            results[tag] = "ok" if ok else "fail"
            print(f"{tag}: {results[tag]}", flush=True)
    return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--timeout", type=int, default=3600)
    args = ap.parse_args()
    if args.all:
        res = run_all(args.multi_pod, args.out, timeout=args.timeout)
        bad = [k for k, v in res.items() if v == "fail"]
        print(f"\n{len(res) - len(bad)}/{len(res)} cells OK")
        sys.exit(1 if bad else 0)
    ok = run_one(args.arch, args.shape, args.multi_pod, args.out)
    sys.exit(0 if ok else 1)


if __name__ == "__main__":
    main()
